"""Batched serving example (deliverable b): a small LM served with the
continuous-batching engine — prefill under the planner-resolved execution
mode (TILE_STREAM cross-forwarding where profitable) + cached decode over
batched requests.  The engine re-plans per admitted wave's prompt shape;
pass ``plan=`` to pin one ``ExecutionPlan`` instead (DESIGN.md §8).

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import registry
from repro.serve.engine import Engine, Request


def main():
    cfg = registry.get_config("starcoder2-7b", smoke=True)
    mod = registry.model_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=4, max_len=96)
    plan = eng.plan_for(24)
    print(f"planner: {cfg.name} prefill -> "
          f"{eng.mode_for(24).value} "
          f"({len(plan.layers)} attn layers, "
          f"{plan.total_hbm_bytes >> 20} MiB predicted)")

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(int(rng.integers(4, 24)),))
                    .astype(np.int32),
                    max_new_tokens=12)
            for i in range(8)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")


if __name__ == "__main__":
    main()
