"""Batched serving example (deliverable b): a small LM served with the
slot-level continuous-batching engine — per-admission prefill under the
planner-resolved ``ExecutionPlan`` (per-layer modes, TILE_STREAM
cross-forwarding where profitable), per-step ``DecodePlan``s, immediate
slot recycling (DESIGN.md §11).  Requests are admitted into free slots
while other slots are mid-decode; pass ``plan=`` to pin one
``ExecutionPlan`` instead of re-planning per prompt length.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import registry
from repro.serve.engine import Engine, Request


def main():
    cfg = registry.get_config("starcoder2-7b", smoke=True)
    mod = registry.model_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=4, max_len=96)
    plan = eng.plan_for(24)
    print(f"planner: {cfg.name} prefill -> "
          f"{eng.mode_for(24).value} "
          f"({len(plan.layers)} attn layers, "
          f"{plan.total_hbm_bytes >> 20} MiB predicted)")

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(int(rng.integers(4, 24)),))
                    .astype(np.int32),
                    max_new_tokens=int(rng.integers(4, 16)),
                    arrival_step=int(rng.integers(0, 6)))
            for i in range(8)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    st = eng.stats()
    print(f"served {len(done)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s on CPU); "
          f"{st['steps']} steps, {st['decode_calls']} decode calls, "
          f"peak concurrency {st['max_concurrency']}")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")


if __name__ == "__main__":
    main()
