"""Quickstart: the paper's technique in 60 lines.

Builds one ViLBERT-style cross-modal attention layer and runs it through
all three execution systems (the paper's comparison: Non-stream /
Layer-stream / Tile-stream), verifying numerical equivalence and printing
the analytic HBM-traffic comparison that produces Fig. 6.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.streaming import choose_mode, streamed_bytes_per_layer
from repro.core.types import ExecutionMode
from repro.kernels import ops, ref


def main():
    # ViLBERT-base co-attention geometry (paper §III: N_X = N_Y = 4096;
    # reduced here to run fast on CPU)
    B, heads, seq, d_model = 1, 8, 512, 256
    hd = d_model // heads
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, heads, seq, hd)) * 0.3       # modal X
    x_other = jax.random.normal(ks[1], (B, seq, d_model)) * 0.3   # modal Y
    wk = jax.random.normal(ks[2], (d_model, heads, hd)) * d_model ** -0.5
    wv = jax.random.normal(ks[3], (d_model, heads, hd)) * d_model ** -0.5

    print("cross-modal attention: Q from modal X, K/V generated from modal Y")
    outs = {}
    for mode in ExecutionMode:
        outs[mode] = ops.attention_by_mode(mode, q, x_other, wk, wv,
                                           causal=False)
        print(f"  {mode.value:13s} -> out {outs[mode].shape}")
    for mode in ExecutionMode:
        np.testing.assert_allclose(outs[mode],
                                   outs[ExecutionMode.NON_STREAM],
                                   atol=1e-4, rtol=1e-4)
    print("all three execution systems agree (allclose) ✓\n")

    print("analytic HBM traffic per co-attention layer "
          "(paper config: seq 4096, d 1024, MHA):")
    for mode in ExecutionMode:
        t = streamed_bytes_per_layer(seq_q=4096, seq_kv=4096, d_model=1024,
                                     num_heads=8, num_kv_heads=8,
                                     head_dim=128, mode=mode)
        print(f"  {mode.value:13s} {t / 2**20:10.1f} MiB")
    print("\ntile-streaming eliminates the K/V HBM round-trip "
          "('CIM rewriting') entirely — the paper's core claim.")

    print("\nmode auto-selection (TBR-CIM reconfiguration analogue):")
    from repro.core.types import Family, ModelConfig
    for name, d, hkv in (("vilbert (MHA)", 1024, 8),
                         ("qwen3-32b (GQA 8kv)", 5120, 8)):
        cfg = ModelConfig(name=name, family=Family.DENSE, num_layers=1,
                          d_model=d, num_heads=d // 128, num_kv_heads=hkv,
                          d_ff=1, vocab_size=8, head_dim=128)
        print(f"  {name:22s} -> {choose_mode(cfg).value}")


if __name__ == "__main__":
    main()
