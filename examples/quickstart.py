"""Quickstart: the paper's technique in ~80 lines.

Builds one ViLBERT-style cross-modal attention layer and runs it through
all three execution systems (the paper's comparison: Non-stream /
Layer-stream / Tile-stream) via the plan API, verifying numerical
equivalence, printing the per-mode HBM-traffic comparison that produces
Fig. 6, and showing the compile→plan→run/simulate path on a full model
(DESIGN.md §8).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.types import ExecutionMode
from repro.kernels import ops
from repro.plan import ExecutionPlan, plan_attention, plan_model


def main():
    # ViLBERT-base co-attention geometry (paper §III: N_X = N_Y = 4096;
    # reduced here to run fast on CPU)
    B, heads, seq, d_model = 1, 8, 512, 256
    hd = d_model // heads
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, heads, seq, hd)) * 0.3       # modal X
    x_other = jax.random.normal(ks[1], (B, seq, d_model)) * 0.3   # modal Y
    wk = jax.random.normal(ks[2], (d_model, heads, hd)) * d_model ** -0.5
    wv = jax.random.normal(ks[3], (d_model, heads, hd)) * d_model ** -0.5

    print("cross-modal attention: Q from modal X, K/V generated from modal Y")
    outs = {}
    for mode in ExecutionMode:
        lp = plan_attention(mode, seq_q=seq, seq_kv=seq, d_kv=d_model,
                            heads=heads, kv_heads=heads, head_dim=hd,
                            cross=True)
        outs[mode] = ops.attention_by_plan(lp, q, x_other, wk, wv,
                                           causal=False)
        print(f"  {mode.value:13s} -> out {outs[mode].shape}")
    for mode in ExecutionMode:
        np.testing.assert_allclose(outs[mode],
                                   outs[ExecutionMode.NON_STREAM],
                                   atol=1e-4, rtol=1e-4)
    print("all three execution systems agree (allclose) ✓\n")

    print("analytic HBM traffic per co-attention layer "
          "(paper config: seq 4096, d 1024, MHA; from LayerPlan.hbm_bytes):")
    for mode in ExecutionMode:
        lp = plan_attention(mode, seq_q=4096, seq_kv=4096, d_kv=1024,
                            heads=8, kv_heads=8, head_dim=128,
                            bytes_per_el=2)
        print(f"  {mode.value:13s} {lp.hbm_bytes / 2**20:10.1f} MiB")
    print("\ntile-streaming eliminates the K/V HBM round-trip "
          "('CIM rewriting') entirely — the paper's core claim.")

    print("\nmode auto-selection (TBR-CIM reconfiguration analogue), via "
          "plan_model:")
    from repro.configs import registry
    for arch in ("vilbert-base", "qwen2-vl-2b"):
        plan = plan_model(registry.get_config(arch))
        modes = plan.uniform_mode.value if plan.uniform_mode else "mixed"
        print(f"  {arch:22s} -> {modes}  "
              f"({len(plan.layers)} attn layers, "
              f"{plan.total_hbm_bytes / 2**20:.0f} MiB predicted)")

    print("\nplans are serializable artifacts (sweep tooling):")
    plan = plan_model(registry.get_config("vilbert-base"))
    restored = ExecutionPlan.from_json(plan.to_json())
    assert restored == plan
    print(f"  to_json -> from_json round-trips "
          f"({len(plan.to_json())} bytes); summary: {plan.summary()}")


if __name__ == "__main__":
    main()
