"""The paper's full pipeline on ViLBERT (deliverable b): two-stream
cross-modal encoding with DTPU dynamic token pruning, comparing execution
modes and showing the pruning schedule shrink token counts across co-TRM
blocks (paper Fig. 2-4 narrative).

    PYTHONPATH=src python examples/crossmodal_pruning.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import pruning as P
from repro.core.types import ExecutionMode, PruningConfig


def main():
    cfg = registry.get_config("vilbert-base", smoke=True)
    cfg = dataclasses.replace(
        cfg, num_layers=6, num_coattn_layers=4, seq_y=256,
        pruning=PruningConfig(enabled=True, min_tokens=16,
                              keep_schedule=((0.25, 1.0), (0.5, 0.75),
                                             (0.75, 0.5), (1.01, 0.3))))
    mod = registry.model_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)

    B, S = 2, 256
    batch = {
        "regions": jax.random.normal(jax.random.PRNGKey(1),
                                     (B, S, cfg.d_model)) * 0.1,
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     cfg.vocab_size),
    }

    plan = P.keep_plan(cfg.pruning, cfg.num_coattn_layers, S)
    print(f"DTPU keep plan over {cfg.num_coattn_layers} co-TRM blocks: "
          f"{S} -> {' -> '.join(map(str, plan))}")
    print(f"attention compute retained: "
          f"{P.pruning_compute_savings(plan, S):.1%} "
          f"(speedup {1 / P.pruning_compute_savings(plan, S):.2f}x)\n")

    for mode in ExecutionMode:
        f = jax.jit(lambda p, b, m=mode: mod.forward(
            p, cfg, b, mode=m, return_token_counts=True))
        (logits, counts) = f(params, batch)
        jax.block_until_ready(logits)
        t0 = time.time()
        jax.block_until_ready(f(params, batch)[0])
        dt = (time.time() - t0) * 1e3
        counts = tuple((int(a), int(b)) for a, b in counts)
        print(f"{mode.value:13s}  vqa logits {logits.shape}  "
              f"token counts per block {counts}  {dt:7.1f} ms")

    print("\nTILE_STREAM generates each co-attention K/V tile from the "
          "other modality's tokens in-stream; pruning shrinks the KV-tile "
          "grid between blocks (hybrid->normal mode reconfiguration).")


if __name__ == "__main__":
    main()
