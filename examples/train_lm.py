"""End-to-end driver (deliverable b): train a ~100M-param dense LM for a few
hundred steps with the full framework stack — sharded step function,
deterministic data pipeline, async checkpointing, resume-on-restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--resume]

On CPU this takes a few minutes; the identical code path drives the
production mesh (launch/train.py).
"""
import argparse
import dataclasses

import jax

from repro.core.types import Family, ModelConfig, ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train import loop as L
from repro.train import optimizer as OPT

# ~100M params: 12L x d512 x ff2048, vocab 32k
CFG = ModelConfig(
    name="demo-100m", family=Family.DENSE,
    num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
    d_ff=2048, vocab_size=32000, head_dim=64,
    act="silu", dtype="float32", param_dtype="float32",
)
# CPU-demo shape (~0.5k tokens/step so a few hundred steps finish in
# minutes on one core); production shapes go through launch/train.py.
SHAPE = ShapeConfig("demo", seq_len=128, global_batch=4, kind="train")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    print(f"model: {CFG.param_count()/1e6:.1f}M params; "
          f"{SHAPE.global_batch}x{SHAPE.seq_len} tokens/step")
    mesh = make_host_mesh()
    src = SyntheticLM(CFG, SHAPE, seed=0)
    tcfg = L.TrainConfig(
        steps=args.steps, log_every=20, checkpoint_every=100,
        checkpoint_dir=args.ckpt,
        opt=OPT.OptimizerConfig(learning_rate=1e-3, warmup_steps=30,
                                decay_steps=args.steps))

    def on_log(m):
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.2f}  {m['steps_per_s']:.2f} it/s",
              flush=True)

    out = L.train(CFG, SHAPE, src, mesh, tcfg, hooks={"on_log": on_log})
    first, last = out["metrics"][0], out["metrics"][-1]
    print(f"\nloss {first['loss']:.3f} -> {last['loss']:.3f} "
          f"over {args.steps} steps")
    assert last["loss"] < first["loss"], "training did not reduce loss!"
    print("checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
