"""scan-or-unroll helper.  XLA's cost analysis counts a while-loop body
*once* regardless of trip count, so the dry-run's cost probes lower with
``runtime.flags(unroll=True)`` to python-unroll every layer/block loop and
make each FLOP visible (launch/dryrun.py corrects full-depth cells by linear
extrapolation from shallow unrolled probes)."""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import runtime


def maybe_scan(body: Callable, init: Any, xs: Any) -> Tuple[Any, Any]:
    """Drop-in for ``jax.lax.scan(body, init, xs)`` honoring the trace-time
    ``unroll`` runtime flag.  Stacks per-step outputs like scan does."""
    if not runtime.get("unroll", False):
        return jax.lax.scan(body, init, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    outs = []
    for i in range(length):
        carry, out = body(carry, jax.tree.map(lambda a: a[i], xs))
        outs.append(out)
    if outs and outs[0] is None:
        return carry, None
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *outs)
    return carry, stacked
