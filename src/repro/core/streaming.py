"""StreamDCIM execution engine — mode selection + streaming encoder blocks.

The TBR-CIM macro's *mode_config* bit (hybrid vs normal reconfiguration,
paper §II-A) maps on TPU to an analytic dataflow decision per attention
layer (DESIGN.md §2): fusing KV-generation into attention (TILE_STREAM)
reduces HBM traffic iff streaming the raw activations ``x_kv`` (width D)
beats streaming materialized K/V (width 2·Hkv·hd):

    per-q-block streamed bytes:   TILE_STREAM  = S·D
                                  LAYER_STREAM = S·2·Hkv·hd   (+ one-time
                                                 2·S·Hkv·hd write for K/V)

For MHA models (the paper's ViLBERT targets: Hkv·hd = D) tile-streaming
strictly wins — it halves streamed bytes AND removes the K/V round-trip,
which is exactly the paper's claim.  For aggressively-GQA LMs
(2·Hkv·hd << D) generation-fusion is traffic-negative, so the engine falls
back to LAYER_STREAM — the normal-mode/weight-stationary path.  This
arch-adaptive reconfiguration is the paper's microarchitectural flexibility
reborn as a compiler-visible dataflow choice.
"""
from __future__ import annotations

from typing import Optional

from repro.core.types import AttnKind, ExecutionMode, ModelConfig


def tile_stream_profitable(d_model: int, num_kv_heads: int,
                           head_dim: int) -> bool:
    """True iff fused KV-generation reduces streamed HBM bytes."""
    return 2 * num_kv_heads * head_dim >= d_model


def choose_mode(cfg: ModelConfig, *, d_model: Optional[int] = None,
                num_kv_heads: Optional[int] = None,
                head_dim: Optional[int] = None) -> ExecutionMode:
    """Resolve the execution mode for one attention layer.

    Honors an explicit cfg.execution_mode of NON_STREAM / LAYER_STREAM
    (benchmark baselines); for TILE_STREAM, applies the profitability rule
    unless cfg.fuse_kv_generation forces fusion on.
    """
    mode = cfg.execution_mode
    if mode != ExecutionMode.TILE_STREAM:
        return mode
    if cfg.attn_kind == AttnKind.MLA:
        return ExecutionMode.TILE_STREAM   # latent decompress: always fuse
    d = d_model or cfg.d_model
    hkv = num_kv_heads or cfg.num_kv_heads
    hd = head_dim or cfg.head_dim
    if cfg.fuse_kv_generation and tile_stream_profitable(d, hkv, hd):
        return ExecutionMode.TILE_STREAM
    return ExecutionMode.LAYER_STREAM


def streamed_bytes_per_layer(seq_q: int, seq_kv: int, d_model: int,
                             num_heads: int, num_kv_heads: int, head_dim: int,
                             mode: ExecutionMode, *, block_q: int = 256,
                             bytes_per_el: int = 2) -> int:
    """Analytic HBM-traffic model for one attention layer (used by the
    benchmark harness to project TPU speedups from CPU-measured numerics —
    DESIGN.md §6).  Counts Q/K/V/O/x_kv movement; weight traffic is
    identical across modes and omitted."""
    nqb = max(seq_q // block_q, 1)
    q_bytes = seq_q * num_heads * head_dim * bytes_per_el
    o_bytes = q_bytes
    kv_width = 2 * num_kv_heads * head_dim
    if mode == ExecutionMode.NON_STREAM:
        # Q,K,V written+read; scores A (H·Sq·Skv) written+read; P written+
        # read; out written.  (The paper's off-chip round-trip baseline.)
        a_bytes = num_heads * seq_q * seq_kv * bytes_per_el
        kv_bytes = seq_kv * kv_width * bytes_per_el
        return (2 * q_bytes + 2 * kv_bytes + 4 * a_bytes + 2 * o_bytes
                + seq_kv * d_model * bytes_per_el)
    if mode == ExecutionMode.LAYER_STREAM:
        # x_kv read once + K/V written once, then re-read per q block.
        kv_bytes = seq_kv * kv_width * bytes_per_el
        return (q_bytes + o_bytes + seq_kv * d_model * bytes_per_el
                + kv_bytes + nqb * kv_bytes)
    # TILE_STREAM: x_kv re-read per q block; K/V never touch HBM.
    return (q_bytes + o_bytes + nqb * seq_kv * d_model * bytes_per_el)
