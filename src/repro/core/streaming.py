"""Legacy mode-selection entry points — deprecation shims over
``repro.plan`` (DESIGN.md §8).

Since PR 2 the reconfiguration decision (per-layer mode selection, tiling,
traffic prediction) lives in the planner: build an ``ExecutionPlan`` with
``repro.plan.plan_model`` and hand it to the kernels
(``kernels.ops.attention_by_plan``), the simulator
(``repro.sim.simulate_plan``) and the serving engine
(``repro.serve.Engine(plan=...)``).  The functions below keep the PR-0/1
call sites working and are guaranteed (by ``tests/test_plan.py``) to agree
with the planner; new code should call ``repro.plan`` directly.

The decision itself — the TBR-CIM *mode_config* bit (hybrid vs normal
reconfiguration, paper §II-A) reborn as an analytic dataflow choice per
attention layer — is documented in ``repro.plan.heuristics`` and
DESIGN.md §2: fusing KV-generation into attention (TILE_STREAM) wins for
MHA models (the paper's ViLBERT targets) and is traffic-negative for
aggressively-GQA LMs, which fall back to LAYER_STREAM.
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.core.types import ExecutionMode, ModelConfig
# Planner internals re-exported for back-compat (``repro.plan.heuristics``
# is the canonical home; this import is intentionally light — it does not
# pull in the planner or simulator).
from repro.plan.heuristics import attn_hbm_bytes, resolve_layer_mode
from repro.plan.heuristics import tile_stream_profitable  # noqa: F401

__all__ = ["tile_stream_profitable", "choose_mode",
           "streamed_bytes_per_layer"]


def _deprecated(old: str, new: str) -> None:
    # stacklevel=3: point past this helper and the shim at the caller.
    warnings.warn(
        f"repro.core.streaming.{old} is deprecated since PR 2; "
        f"migrate to {new}", DeprecationWarning, stacklevel=3)


def choose_mode(cfg: ModelConfig, *, d_model: Optional[int] = None,
                num_kv_heads: Optional[int] = None,
                head_dim: Optional[int] = None) -> ExecutionMode:
    """Resolve the execution mode for one attention layer.

    .. deprecated:: PR 2 — use ``repro.plan.plan_model`` (whole-model
       resolution) or ``repro.plan.resolve_layer_mode`` (one layer).
       Emits ``DeprecationWarning`` (test-pinned in ``tests/test_plan.py``).
    """
    _deprecated("choose_mode",
                "repro.plan.plan_model (whole model) or "
                "repro.plan.heuristics.resolve_layer_mode (one layer)")
    return resolve_layer_mode(
        cfg.execution_mode,
        d_kv=d_model or cfg.d_model,
        num_kv_heads=num_kv_heads or cfg.num_kv_heads,
        head_dim=head_dim or cfg.head_dim,
        attn_kind=cfg.attn_kind,
        fuse_kv_generation=cfg.fuse_kv_generation)


def streamed_bytes_per_layer(seq_q: int, seq_kv: int, d_model: int,
                             num_heads: int, num_kv_heads: int, head_dim: int,
                             mode: ExecutionMode, *, block_q: int = 256,
                             bytes_per_el: int = 2) -> int:
    """Analytic HBM-traffic model for one attention layer (DESIGN.md §6).

    .. deprecated:: PR 2 — the planner records this prediction per layer
       in ``LayerPlan.hbm_bytes``; use ``repro.plan.attn_hbm_bytes`` for
       raw-geometry queries.  Emits ``DeprecationWarning`` (test-pinned
       in ``tests/test_plan.py``).
    """
    _deprecated("streamed_bytes_per_layer",
                "repro.plan.heuristics.attn_hbm_bytes (raw geometry) or "
                "LayerPlan.hbm_bytes (planned layers)")
    return attn_hbm_bytes(seq_q, seq_kv, d_model, num_heads, num_kv_heads,
                          head_dim, mode, block_q=block_q,
                          bytes_per_el=bytes_per_el)
