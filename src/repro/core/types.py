"""Core configuration types for the repro framework.

A single ``ModelConfig`` covers every supported architecture family; the
per-arch files in ``repro.configs`` instantiate it with exact published
hyperparameters.  ``ShapeConfig`` describes the assigned input-shape cells
(train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Optional, Sequence, Tuple


class Family(str, enum.Enum):
    DENSE = "dense"          # decoder-only dense transformer
    MOE = "moe"              # mixture-of-experts transformer
    SSM = "ssm"              # attention-free state-space (mamba2)
    HYBRID = "hybrid"        # parallel attention + SSM heads (hymba)
    ENCDEC = "encdec"        # encoder-decoder (whisper)
    VLM = "vlm"              # vision-language backbone (qwen2-vl)
    CROSSMODAL = "crossmodal"  # two-stream co-attention (ViLBERT — the paper's own)


class AttnKind(str, enum.Enum):
    FULL = "full"
    SLIDING = "sliding"      # sliding-window attention
    MLA = "mla"              # multi-head latent attention (deepseek-v3)
    NONE = "none"            # attention-free


class ExecutionMode(str, enum.Enum):
    """The paper's three comparison systems (DESIGN.md §1)."""

    NON_STREAM = "non_stream"      # unfused; every intermediate round-trips HBM
    LAYER_STREAM = "layer_stream"  # fused projections + separate flash attention
    TILE_STREAM = "tile_stream"    # StreamDCIM: fused KV-gen + attention kernel


@dataclasses.dataclass(frozen=True)
class PruningConfig:
    """DTPU dynamic token pruning (DESIGN.md §2, paper §II-A).

    ``keep_schedule`` maps layer-index fractions to keep-ratios; the actual
    kept token count is static per layer (JAX shapes), the token *choice* is
    dynamic (runtime attention-probability scores).
    """

    enabled: bool = False
    # (layer_fraction_threshold, keep_ratio) — Evo-ViT-style progressive pruning.
    keep_schedule: Tuple[Tuple[float, float], ...] = (
        (0.25, 1.0), (0.5, 0.7), (0.75, 0.5), (1.01, 0.35),
    )
    min_tokens: int = 16

    def keep_ratio(self, layer_idx: int, num_layers: int) -> float:
        frac = (layer_idx + 1) / max(num_layers, 1)
        for threshold, ratio in self.keep_schedule:
            if frac <= threshold:
                return ratio
        return self.keep_schedule[-1][1]

    def kept_tokens(self, layer_idx: int, num_layers: int, seq_len: int) -> int:
        n = int(seq_len * self.keep_ratio(layer_idx, num_layers))
        # Round to a multiple of 128 for MXU-aligned tiles, floor at min_tokens.
        n = max(self.min_tokens, (n // 128) * 128 if n >= 128 else n)
        return min(n, seq_len)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int            # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 → d_model // num_heads
    attn_kind: AttnKind = AttnKind.FULL
    sliding_window: int = 4096
    use_qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) splits
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0          # expert hidden size (deepseek: d_ff field *is* this)
    first_dense_layers: int = 0  # deepseek-v3: first k layers are dense
    # --- MLA (deepseek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # --- MTP (deepseek) ---
    mtp_depth: int = 0
    # --- SSM (mamba2 / hymba) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    conv_kernel: int = 4
    # --- enc-dec (whisper) ---
    num_encoder_layers: int = 0
    encoder_seq: int = 1500    # whisper frame positions after conv stub
    # --- crossmodal (vilbert) ---
    num_coattn_layers: int = 0
    d_model_y: int = 0         # second-stream width (vilbert text stream)
    num_heads_y: int = 0
    d_ff_y: int = 0
    seq_y: int = 0
    # --- norm/act ---
    norm_eps: float = 1e-6
    act: str = "silu"          # silu | gelu
    use_bias: bool = False
    # --- paper technique knobs ---
    execution_mode: ExecutionMode = ExecutionMode.TILE_STREAM
    pruning: PruningConfig = dataclasses.field(default_factory=PruningConfig)
    fuse_kv_generation: bool = True   # mixed-stationary cross-forwarding on/off

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---------- derived quantities ----------

    @property
    def group_size(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1) if self.num_kv_heads else 1

    def param_count(self) -> int:
        """Approximate parameter count N (used for MODEL_FLOPS = 6·N·D)."""
        d, f, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == Family.SSM:
            d_inner = self.ssm_expand * d
            per = (d * (2 * d_inner + 2 * self.ssm_heads)   # in_proj (x,z) + dt/heads
                   + d_inner * (2 * self.ssm_state)          # B,C projections
                   + d_inner * d                             # out_proj
                   + self.conv_kernel * d_inner + 2 * d)
            return emb + L * per
        if self.attn_kind == AttnKind.MLA:
            attn = (d * self.q_lora_rank
                    + self.q_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    + self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
                    + self.num_heads * self.v_head_dim * d)
        else:
            hq, hkv, hd = self.num_heads, self.num_kv_heads, self.head_dim
            attn = d * hd * (hq + 2 * hkv) + hq * hd * d
        if self.family == Family.MOE:
            e_ff = self.moe_d_ff or f
            moe = (self.num_experts + self.num_shared_experts) * 3 * d * e_ff + d * self.num_experts
            dense_ff = 3 * d * f
            per = attn + 2 * d
            total = emb
            for i in range(L):
                total += per + (dense_ff if i < self.first_dense_layers else moe)
            return total
        mlp = 3 * d * f if self.act == "silu" else 2 * d * f
        per = attn + mlp + 2 * d
        if self.family == Family.HYBRID:
            d_inner = self.ssm_expand * d
            per += (d * 2 * d_inner + d_inner * 2 * self.ssm_state + d_inner * d)
        total = emb + L * per
        if self.family == Family.ENCDEC:
            total += self.num_encoder_layers * per + self.num_encoder_layers * 0
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-in experts count)."""
        if self.family != Family.MOE:
            return self.param_count()
        e_ff = self.moe_d_ff or self.d_ff
        full = self.param_count()
        inactive_experts = self.num_experts - self.experts_per_token
        moe_layers = self.num_layers - self.first_dense_layers
        return full - moe_layers * inactive_experts * 3 * self.d_model * e_ff


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def pad_to(x: int, multiple: int) -> int:
    return int(math.ceil(x / multiple) * multiple)
