"""DTPU — dynamic token pruning unit (paper §II-A, Evo-ViT/SpAtten style).

Token importance = column mean of the attention probability matrix: how much
total attention mass flows *into* each token.  The DTPU is its own block in
the paper's Fig. 3(a) (separate from the CIM cores); here it is a standalone
module that scores, ranks, and compacts token sets with JAX-static shapes
(keep *counts* are static per layer; token *choice* is a runtime gather).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig, PruningConfig
from repro.kernels import ref


def attention_column_scores(q: jax.Array, k: jax.Array, *,
                            causal: bool = False,
                            sample_stride: int = 1) -> jax.Array:
    """Column-mean of softmax(QK^T) over heads and (optionally strided)
    queries.  q: (B,Hq,Sq,hd), k: (B,Hkv,Sk,hd) -> scores (B, Sk).

    ``sample_stride > 1`` subsamples query rows — the DTPU's scoring pass is
    O(Sq·Sk/stride) instead of O(Sq·Sk) with negligible rank distortion
    (tests check rank stability).
    """
    if sample_stride > 1:
        q = q[:, :, ::sample_stride]
    B, Hq, Sq, hd = q.shape
    Hkv = k.shape[1]
    G = Hq // max(Hkv, 1)
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32))
    s *= hd ** -0.5
    if causal:
        qi = jnp.arange(Sq)[:, None] * sample_stride
        ki = jnp.arange(k.shape[2])[None, :]
        s = jnp.where((ki <= qi)[None, None, None], s, ref.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return p.mean(axis=(1, 2, 3))                       # (B, Sk)


def select_tokens(scores: jax.Array, keep: int,
                  *, keep_order: bool = True) -> jax.Array:
    """Top-``keep`` token indices per batch row, ascending (order-preserving
    compaction so RoPE/causality stay consistent).  scores: (B, S)."""
    _, idx = jax.lax.top_k(scores, keep)                # (B, keep)
    if keep_order:
        idx = jnp.sort(idx, axis=-1)
    return idx


def gather_tokens(x: jax.Array, idx: jax.Array) -> jax.Array:
    """x: (B, S, D), idx: (B, keep) -> (B, keep, D)."""
    return jnp.take_along_axis(x, idx[..., None], axis=1)


def prune_stream(x: jax.Array, scores: jax.Array, keep: int,
                 positions: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Compact one modality stream to its ``keep`` most-attended tokens.

    Returns (x_kept, kept_idx, positions_kept).  ``positions`` (B, S) rides
    along so position-aware archs keep original coordinates.
    """
    idx = select_tokens(scores, keep)
    x_kept = gather_tokens(x, idx)
    pos_kept = None
    if positions is not None:
        pos_kept = jnp.take_along_axis(positions, idx, axis=1)
    return x_kept, idx, pos_kept


def keep_plan(pruning: PruningConfig, num_layers: int,
              seq_len: int) -> Tuple[int, ...]:
    """Static per-layer kept-token counts (monotone non-increasing)."""
    plan = []
    prev = seq_len
    for layer in range(num_layers):
        n = pruning.kept_tokens(layer, num_layers, seq_len)
        n = min(n, prev)
        plan.append(n)
        prev = n
    return tuple(plan)


def pruning_compute_savings(plan: Tuple[int, ...], seq_len: int) -> float:
    """Fraction of attention FLOPs retained vs no pruning (quadratic term)."""
    full = len(plan) * seq_len * seq_len
    kept = sum(n * n for n in plan)
    return kept / full
