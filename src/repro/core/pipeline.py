"""Compute/communication overlap primitives (distributed-optimization
tricks, DESIGN.md §5).

``ring_collective_matmul`` — the classic all-gather↔matmul overlap: instead
of all-gathering the sharded operand and then multiplying (serializing DCN/
ICI behind the MXU), each step multiplies the *resident* shard while
``ppermute`` streams the next shard around the ring.  XLA's latency-hiding
scheduler overlaps the permute with the dot, hiding (g-1)/g of the
collective time.  This is the paper's ping-pong compute-rewriting pipeline
at the *inter-chip* level: 'rewriting' = the neighbor shard DMA, 'compute'
= the local partial matmul.

Used with shard_map over the axis that shards the contracting/gathered dim.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import jax_compat


def ring_collective_matmul(x_shard: jax.Array, w: jax.Array, *,
                           axis: str) -> jax.Array:
    """Inside shard_map: x_shard (M/g, K) is this device's row-shard of x;
    w (K, N) is resident.  Computes the full (M, N) = all_gather(x) @ w with
    the gather pipelined behind the per-shard matmuls.

    Equivalent to ``all_gather(x_shard, axis) @ w`` (tests assert it).
    """
    g = jax_compat.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m = x_shard.shape[0]
    out = jnp.zeros((g * m, w.shape[1]), w.dtype)
    perm = [(i, (i + 1) % g) for i in range(g)]

    def step(i, carry):
        out, shard = carry
        # position of `shard` in the logical (gathered) order
        src = jax.lax.rem(idx - i + g, g)
        part = jnp.dot(shard, w, preferred_element_type=jnp.float32)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, part.astype(out.dtype), src * m, 0)
        # stream the next shard while (scheduler permitting) the next
        # iteration's dot runs — the inter-chip ping-pong
        shard = jax.lax.ppermute(shard, axis, perm)
        return out, shard

    out, _ = jax.lax.fori_loop(0, g, step, (out, x_shard))
    return out


def gather_matmul_overlapped(x: jax.Array, w: jax.Array, mesh, *,
                             axis: str = "model") -> jax.Array:
    """jit-level wrapper: x (M, K) sharded on dim0 over ``axis``; w
    replicated.  Returns the full product with ring overlap."""
    fn = functools.partial(ring_collective_matmul, axis=axis)
    return jax_compat.shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(None, None))(x, w)


def microbatch_overlap_note() -> str:
    """The gradient-accumulation scan in train/steps.py provides the
    batch-level overlap: microbatch i+1's forward issues while microbatch
    i's gradient all-reduce is in flight (XLA schedules the collectives of
    the scanned body asynchronously).  This function exists for
    documentation discoverability."""
    return "see train/steps.py make_train_step(microbatches=...)"
