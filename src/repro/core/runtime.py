"""Trace-time runtime flags (contextvar) — lets the dry-run/benchmarks flip
lowering strategies (block-loop unrolling for cost-analysis probes, KV block
sizes, activation-sharding hints) without threading args through every
model signature.  Flags are read at *trace* time, so wrap ``.lower()`` /
calls in ``with flags(...)``."""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Dict

_FLAGS: contextvars.ContextVar[Dict[str, Any]] = contextvars.ContextVar(
    "repro_runtime_flags", default={})


def get(name: str, default: Any = None) -> Any:
    return _FLAGS.get().get(name, default)


@contextlib.contextmanager
def flags(**kwargs: Any):
    cur = dict(_FLAGS.get())
    cur.update(kwargs)
    token = _FLAGS.set(cur)
    try:
        yield
    finally:
        _FLAGS.reset(token)
