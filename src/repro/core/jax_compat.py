"""Version-compat shims for JAX APIs that moved between releases.

The repo targets the modern API surface (``jax.shard_map`` with
``check_vma``), but the pinned environment may carry jax 0.4.x where
shard_map still lives in ``jax.experimental`` and the flag is named
``check_rep``.  Route every shard_map call through here.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(fn, *, mesh, in_specs, out_specs, check: bool = False):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
else:                                   # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(fn, *, mesh, in_specs, out_specs, check: bool = False):
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` appeared after 0.4.x; psum of 1 is the
    portable spelling inside shard_map/pmap bodies."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
