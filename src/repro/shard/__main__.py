"""CLI for ``repro.shard``: ``PYTHONPATH=src python -m repro.shard``.

Prints the scale-out table per (model, mode, topology) cell — chips,
resolved axis, latency, speedup, scale-out efficiency, collective bytes,
bottleneck — and optionally writes the machine-readable sweep (rows +
speedup-vs-chips curves, serialized sharded plans with ``--keep-plans``)
with ``--json``.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.types import ExecutionMode
from repro.shard.sweep import (DEFAULT_CHIPS, DEFAULT_MODELS,
                               run_shard_sweep)


def format_table(result) -> str:
    cells = {}
    for r in result.rows:
        cells.setdefault(result.label(r), []).append(r)
    lines = []
    for label, rows in cells.items():
        lines.append(f"== {label} ({len(rows)} points) ==")
        lines.append(f"  {'chips':>5s} {'axis':<9s} {'cycles':>12s} "
                     f"{'speedup':>8s} {'eff':>6s} {'noc_bytes':>12s} "
                     f"{'bottleneck':<12s}")
        for r in sorted(rows, key=lambda r: r.chips):
            lines.append(
                f"  {r.chips:>5d} {r.axis:<9s} {r.latency_cycles:>12d} "
                f"{r.speedup:>8.2f} {r.efficiency:>6.2f} "
                f"{r.collective_bytes:>12d} {r.bottleneck:<12s}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.shard",
        description="StreamDCIM chiplet-mesh scale-out sweep")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS),
                    help="comma-separated registry model names")
    ap.add_argument("--chips", default=",".join(map(str, DEFAULT_CHIPS)),
                    help="comma-separated chip counts")
    ap.add_argument("--topologies", default="ring",
                    help="comma-separated: ring,line")
    ap.add_argument("--modes", default="",
                    help="comma-separated execution modes (default: all)")
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--smoke", action="store_true",
                    help="use the tiny smoke configs")
    ap.add_argument("--link-bytes", type=int, default=None,
                    help="NoC link bytes/cycle (MeshSpec default 128)")
    ap.add_argument("--hop-cycles", type=int, default=None,
                    help="NoC per-hop latency (MeshSpec default 32)")
    ap.add_argument("--keep-plans", action="store_true",
                    help="embed serialized ShardedPlans in --json rows")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)

    mesh_kwargs = {}
    if args.link_bytes is not None:
        mesh_kwargs["link_bytes_per_cycle"] = args.link_bytes
    if args.hop_cycles is not None:
        mesh_kwargs["hop_cycles"] = args.hop_cycles
    modes = ([ExecutionMode(m) for m in args.modes.split(",") if m]
             or None)

    done = [0]

    def progress(row):
        done[0] += 1
        print(f"\r  {done[0]} points simulated", end="", file=sys.stderr)

    result = run_shard_sweep(
        [m for m in args.models.split(",") if m],
        chips=[int(c) for c in args.chips.split(",") if c],
        topologies=[t for t in args.topologies.split(",") if t],
        modes=modes, seq_len=args.seq, smoke=args.smoke,
        mesh_kwargs=mesh_kwargs, keep_plans=args.keep_plans,
        progress=progress)
    if done[0]:
        print(file=sys.stderr)
    print(format_table(result))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result.to_dict(), f, indent=1)
        print(f"wrote {args.json} ({len(result.rows)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
