"""Mesh-driven serving (DESIGN.md §13).

``serve.Engine(mesh=...)`` routes prefill and decode through
``shard_map`` over a real jax mesh (``launch.mesh`` builders — the
(data, model) production grid or ``make_host_mesh()`` for tests).  All
specs are replicated (``PartitionSpec()``): the mesh carries the
execution, the *plan*-level sharding lives in ``repro.shard.partition``
— so on the 1x1 host mesh the numerics are bit-identical to the
single-chip path, which the tier-1 suite asserts.  Parameter-level
sharding specs for real multi-device meshes come from
``distributed.sharding.param_shardings`` and compose with these wrappers
unchanged (jax re-shards inputs to match the entry specs).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import PartitionSpec as P

from repro.core.jax_compat import shard_map


def mesh_prefill(mod, params, cfg, batch: Dict[str, Any], *, mesh,
                 max_len: int, **kwargs):
    """Run ``mod.prefill`` under ``shard_map`` on ``mesh`` (replicated
    specs).  ``kwargs`` (``plan=`` / ``mode=``) pass through as static
    closure state, exactly as the single-chip engine passes them."""
    kw = {k: v for k, v in kwargs.items() if v is not None}

    def fn(p, toks):
        return mod.prefill(p, cfg, {"tokens": toks}, max_len=max_len, **kw)

    f = shard_map(fn, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                  check=False)
    return f(params, batch["tokens"])


def mesh_decode_fn(mod, cfg, mesh):
    """A jitted ``shard_map`` decode step: drop-in for the engine's
    ``jax.jit(decode_step)`` closure."""

    def fn(p, cache, tok):
        return mod.decode_step(p, cfg, cache, tok)

    return jax.jit(shard_map(fn, mesh=mesh, in_specs=(P(), P(), P()),
                             out_specs=P(), check=False))
