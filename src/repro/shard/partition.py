"""Sharded execution plans: one ``ExecutionPlan`` -> per-chip sub-plans +
explicit collectives (DESIGN.md §13).

``shard_plan(plan, mesh)`` extends the compile->plan pipeline with a
sharding axis, resolved per the same rule table ``distributed.sharding``
applies to real jax parameter trees:

* ``tensor``   — Megatron head/d_ff split when every attention op's heads
  AND kv-heads divide the chip count (``heads_shardable`` /
  ``kv_heads_shardable`` evaluated on a simulated ``model=chips`` mesh)
  and the FFN widths divide too.  Weights shard, activations replicate;
  each oproj / ffn_down output all-reduces.
* ``sequence`` — context-parallel fallback for non-divisible-head models
  (the starcoder2 / qwen2-vl case in the rule table): queries and FFN
  rows shard over chips, weights replicate, and each attention op
  all-gathers its KV source — choosing the cheaper of raw activations
  (``d_kv``) vs materialized K/V (``kv_width``), the same width race
  ``tile_stream_profitable`` runs for on-chip streaming.
* ``group``    — Hemlet-style group parallelism: whole layers assign to
  chips in contiguous blocks, activations forward chip-to-chip (p2p).

Every sub-plan is a real ``ExecutionPlan`` whose per-op ``hbm_bytes`` /
``rewrite_cycles`` are re-predicted from the *scaled* geometry through the
planner's own formulas, so the sharded prediction is exactly what
``sim.simulate_sharded_plan`` must reproduce per chip — the multi-chip
version of the plan/sim byte-exactness discipline.  Collective byte
predictions come from ``noc.collective_streams`` (the same wire plans the
simulator lowers).  ``ShardedPlan`` serializes like everything else.

Recorded kernel traces (DESIGN.md §10) describe full-size ops and are
dropped from sub-plans — sharded ops are analytic until re-recorded.
"""
from __future__ import annotations

import dataclasses
import json
from types import SimpleNamespace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.distributed.sharding import (_SimulatedMesh, heads_shardable,
                                        kv_heads_shardable)
from repro.plan.planner import (ExecutionPlan, GemmPlan, LayerPlan,
                                _predict_bytes, _predict_rewrites)
from repro.shard import noc
from repro.shard.noc import MeshSpec

SHARD_VERSION = 1

#: Gemm-name suffixes with a column-sharded (n/C) weight under tensor
#: parallelism; their outputs stay sharded and feed a row-parallel gemm.
_COL_SHARDED = ("_ffn_up", "_ffn_gate")
#: Row-sharded (k/C) gemms; their outputs are partial sums -> all-reduce.
_ROW_SHARDED = ("_ffn_down", "_oproj")


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One inter-chip collective, anchored into the plan's op stream.

    ``after`` names the op (unprefixed) whose completion produces the
    payload ("" = the plan input: the collective may start immediately).
    The simulator gates each receiving chip's *next* op on its arrival.
    ``payload_bytes`` is the logical tensor size; ``link_bytes`` the
    predicted total crossing NoC links (from the noc wire plan — ring
    all-reduce pays ``2*(C-1)*payload``, multicast ``(C-1)*payload``...).
    """

    name: str
    kind: str              # noc.COLLECTIVE_KINDS
    after: str
    payload_bytes: int
    link_bytes: int
    root: int = 0          # multicast / p2p source chip
    dst: int = -1          # p2p destination chip

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "CollectiveOp":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class ShardedPlan:
    """An ``ExecutionPlan`` split across a chiplet mesh."""

    base: ExecutionPlan
    mesh: MeshSpec
    axis: str                                # resolved (never "auto")
    chip_plans: Tuple[ExecutionPlan, ...]
    collectives: Tuple[CollectiveOp, ...]

    @property
    def chips(self) -> int:
        return self.mesh.chips

    @property
    def total_hbm_bytes(self) -> int:
        """Summed per-chip attention-traffic prediction (the quantity
        ``simulate_sharded_plan`` cross-asserts)."""
        return sum(p.total_hbm_bytes for p in self.chip_plans)

    @property
    def total_collective_link_bytes(self) -> int:
        return sum(c.link_bytes for c in self.collectives)

    @property
    def total_rewrite_cycles(self) -> int:
        return sum(p.total_rewrite_cycles for p in self.chip_plans)

    def to_dict(self) -> Dict[str, object]:
        return {
            "version": SHARD_VERSION,
            "mesh": self.mesh.to_dict(),
            "axis": self.axis,
            "base": self.base.to_dict(),
            "chip_plans": [p.to_dict() for p in self.chip_plans],
            "collectives": [c.to_dict() for c in self.collectives],
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "ShardedPlan":
        if d.get("version") != SHARD_VERSION:
            raise ValueError(
                f"sharded-plan version {d.get('version')!r} != "
                f"{SHARD_VERSION}; re-shard the plan")
        return cls(
            base=ExecutionPlan.from_dict(d["base"]),
            mesh=MeshSpec.from_dict(d["mesh"]),
            axis=str(d["axis"]),
            chip_plans=tuple(ExecutionPlan.from_dict(p)
                             for p in d["chip_plans"]),
            collectives=tuple(CollectiveOp.from_dict(c)
                              for c in d["collectives"]),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s: str) -> "ShardedPlan":
        return cls.from_dict(json.loads(s))


# --------------------------------------------------------------------------
# axis resolution


def _tensor_shardable(plan: ExecutionPlan, chips: int) -> bool:
    """Megatron split legality, via the ``distributed.sharding`` rule
    helpers on a simulated ``model=chips`` mesh (per-op: crossmodal
    streams carry different head counts)."""
    mesh = _SimulatedMesh({"model": chips, "data": 1})
    for lp in plan.layers:
        shim = SimpleNamespace(num_heads=lp.heads, num_kv_heads=lp.kv_heads)
        if not (heads_shardable(shim, mesh) and
                kv_heads_shardable(shim, mesh)):
            return False
    for g in plan.gemms:
        if g.name.endswith(_COL_SHARDED) and g.n % chips:
            return False
        if g.name.endswith(_ROW_SHARDED) and g.k % chips:
            return False
    return True


def _sequence_shardable(plan: ExecutionPlan, chips: int) -> bool:
    return (all(lp.seq_q % chips == 0 for lp in plan.layers) and
            all(g.m % chips == 0 for g in plan.gemms))


def _layer_indices(plan: ExecutionPlan) -> List[int]:
    return sorted({p.layer_index
                   for p in tuple(plan.layers) + tuple(plan.gemms)})


def resolve_axis(plan: ExecutionPlan, mesh: MeshSpec) -> str:
    """Resolve ``mesh.axis`` ("auto": tensor -> sequence -> group by
    divisibility); validate an explicit request."""
    C = mesh.chips
    if mesh.axis == "auto":
        if _tensor_shardable(plan, C):
            return "tensor"
        if _sequence_shardable(plan, C):
            return "sequence"
        if len(_layer_indices(plan)) >= C:
            return "group"
        raise ValueError(
            f"no sharding axis fits {plan.model} on {C} chips: heads/FFN "
            f"not divisible, sequence not divisible, fewer layers than "
            f"chips")
    if mesh.axis == "tensor" and not _tensor_shardable(plan, C):
        raise ValueError(f"tensor parallelism needs heads/kv-heads/d_ff "
                         f"divisible by {C} (model {plan.model})")
    if mesh.axis == "sequence" and not _sequence_shardable(plan, C):
        raise ValueError(f"sequence parallelism needs seq divisible by "
                         f"{C} (model {plan.model})")
    if mesh.axis == "group" and len(_layer_indices(plan)) < C:
        raise ValueError(f"group parallelism needs >= {C} layers "
                         f"(model {plan.model} has "
                         f"{len(_layer_indices(plan))})")
    return mesh.axis


# --------------------------------------------------------------------------
# per-chip sub-plans


def _repredict(lp: LayerPlan, hw) -> LayerPlan:
    """Re-run the planner's own byte/rewrite prediction on scaled
    geometry — sub-plan predictions stay formula-identical to what the
    schedulers will simulate."""
    return dataclasses.replace(
        lp, hbm_bytes=_predict_bytes(lp, lp.mode, hw),
        rewrite_cycles=_predict_rewrites(lp, lp.mode, hw))


def _shard_tensor(plan: ExecutionPlan, C: int) -> ExecutionPlan:
    """One chip's share under the Megatron split: heads/kv-heads divide,
    activations (d_q/d_kv/seq) replicate, column/row gemm dims divide."""
    hw = plan.hw_config()
    layers = tuple(
        _repredict(dataclasses.replace(
            lp, heads=lp.heads // C, kv_heads=lp.kv_heads // C,
            trace=None), hw)
        for lp in plan.layers)
    gemms = []
    for g in plan.gemms:
        if g.name.endswith(_COL_SHARDED):
            g = dataclasses.replace(g, n=g.n // C, trace=None)
        elif g.name.endswith(_ROW_SHARDED):
            g = dataclasses.replace(g, k=g.k // C, trace=None)
        else:
            g = dataclasses.replace(g, trace=None)
        gemms.append(g)
    return dataclasses.replace(plan, layers=layers, gemms=tuple(gemms))


def _shard_sequence(plan: ExecutionPlan, C: int) -> ExecutionPlan:
    """One chip's share under context parallelism: q tokens and gemm rows
    shard; KV stays full (gathered); weights replicate."""
    hw = plan.hw_config()
    layers = tuple(
        _repredict(dataclasses.replace(
            lp, seq_q=lp.seq_q // C,
            keep_tokens=max(1, lp.keep_tokens // C), trace=None), hw)
        for lp in plan.layers)
    gemms = tuple(dataclasses.replace(g, m=g.m // C, trace=None)
                  for g in plan.gemms)
    return dataclasses.replace(plan, layers=layers, gemms=gemms)


def _group_chunks(indices: Sequence[int], C: int) -> List[List[int]]:
    """Contiguous, balanced layer blocks (remainder to the front)."""
    n = len(indices)
    base, rem = divmod(n, C)
    out, at = [], 0
    for i in range(C):
        size = base + (1 if i < rem else 0)
        out.append(list(indices[at:at + size]))
        at += size
    return out


def _shard_group(plan: ExecutionPlan, C: int) -> List[ExecutionPlan]:
    """Hemlet-style: chip *i* owns a contiguous block of layers verbatim
    (weights stay resident per chip — no rewrite-pressure change per op,
    C-fold fewer layers' worth of rewrites per chip)."""
    chunks = _group_chunks(_layer_indices(plan), C)
    plans = []
    for chunk in chunks:
        own = set(chunk)
        layers = tuple(dataclasses.replace(lp, trace=None)
                       for lp in plan.layers if lp.layer_index in own)
        gemms = tuple(dataclasses.replace(g, trace=None)
                      for g in plan.gemms if g.layer_index in own)
        plans.append(dataclasses.replace(plan, layers=layers, gemms=gemms))
    return plans


# --------------------------------------------------------------------------
# collectives


def _ops_in_order(plan: ExecutionPlan):
    return sorted(tuple(plan.layers) + tuple(plan.gemms),
                  key=lambda p: p.op_index)


def _op_out_bytes(p, ab: int) -> int:
    if isinstance(p, LayerPlan):
        return p.seq_q * p.d_q * ab
    return p.m * p.n * ab


def _input_multicast(plan: ExecutionPlan, mesh: MeshSpec,
                     ab: int) -> Optional[CollectiveOp]:
    """Broadcast the model inputs from the host-attached chip: one
    ``seq x d`` payload per distinct stream width (crossmodal models feed
    two streams)."""
    payload, seen = 0, set()
    for lp in sorted(plan.layers, key=lambda p: p.op_index):
        if lp.d_q not in seen:
            seen.add(lp.d_q)
            payload += lp.seq_q * lp.d_q * ab
    if payload <= 0:
        return None
    return CollectiveOp(
        name="input:multicast", kind="multicast", after="",
        payload_bytes=payload,
        link_bytes=noc.collective_link_bytes(mesh, "multicast", payload),
        root=0)


def _tensor_collectives(sub: ExecutionPlan, mesh: MeshSpec,
                        ab: int) -> List[CollectiveOp]:
    colls = []
    mc = _input_multicast(sub, mesh, ab)
    if mc:
        colls.append(mc)
    for g in sub.gemms:
        if not g.name.endswith(_ROW_SHARDED):
            continue
        payload = g.m * g.n * ab          # n replicate-width on row gemms
        colls.append(CollectiveOp(
            name=f"{g.name}:allreduce", kind="all_reduce", after=g.name,
            payload_bytes=payload,
            link_bytes=noc.collective_link_bytes(
                mesh, "all_reduce", payload)))
    return colls


def _sequence_collectives(base: ExecutionPlan, sub: ExecutionPlan,
                          mesh: MeshSpec, ab: int) -> List[CollectiveOp]:
    colls = []
    mc = _input_multicast(base, mesh, ab)
    if mc:
        colls.append(mc)
    order = _ops_in_order(base)
    prev_name = {order[i].name: (order[i - 1].name if i else "")
                 for i in range(len(order))}
    for lp in base.layers:
        # Gather the cheaper KV representation: raw activations vs
        # materialized K/V — the sequence-parallel analog of the
        # tile_stream_profitable width race.
        width = min(lp.d_kv, lp.kv_width)
        payload = lp.seq_kv * width * ab
        colls.append(CollectiveOp(
            name=f"{lp.name}:kvgather", kind="all_gather",
            after=prev_name[lp.name], payload_bytes=payload,
            link_bytes=noc.collective_link_bytes(
                mesh, "all_gather", payload)))
    last = order[-1]
    payload = _op_out_bytes(last, ab)
    colls.append(CollectiveOp(
        name="output:gather", kind="all_gather", after=last.name,
        payload_bytes=payload,
        link_bytes=noc.collective_link_bytes(mesh, "all_gather", payload)))
    return colls


def shard_plan(plan: ExecutionPlan, mesh: MeshSpec, *,
               axis: Optional[str] = None) -> ShardedPlan:
    """Split ``plan`` across ``mesh``.  ``axis`` overrides ``mesh.axis``.

    1 chip is the identity: sub-plan predictions equal the base plan's
    (same formulas, same geometry) and the collective list is empty —
    the anchor for the 1-chip byte/cycle-identity tests.
    """
    if axis is not None:
        mesh = dataclasses.replace(mesh, axis=axis)
    resolved = resolve_axis(plan, mesh)
    C = mesh.chips
    ab = plan.hw_config().act_bytes

    if resolved == "group":
        chip_plans = _shard_group(plan, C)
    elif resolved == "tensor":
        chip_plans = [_shard_tensor(plan, C)] * C
    else:
        chip_plans = [_shard_sequence(plan, C)] * C

    colls: List[CollectiveOp] = []
    if C > 1:
        if resolved == "tensor":
            colls = _tensor_collectives(chip_plans[0], mesh, ab)
        elif resolved == "sequence":
            colls = _sequence_collectives(plan, chip_plans[0], mesh, ab)
        else:
            for i in range(C - 1):
                nxt = _ops_in_order(chip_plans[i + 1])
                cur = _ops_in_order(chip_plans[i])
                payload = _op_in_bytes(nxt[0], ab)
                colls.append(CollectiveOp(
                    name=f"stage{i}:fwd", kind="p2p", after=cur[-1].name,
                    payload_bytes=payload,
                    link_bytes=noc.collective_link_bytes(
                        mesh, "p2p", payload, root=i, dst=i + 1),
                    root=i, dst=i + 1))

    return ShardedPlan(base=plan, mesh=mesh, axis=resolved,
                       chip_plans=tuple(chip_plans),
                       collectives=tuple(colls))


def _op_in_bytes(p, ab: int) -> int:
    """Activation bytes entering an op (the p2p payload at a group
    boundary)."""
    if isinstance(p, LayerPlan):
        return p.seq_q * p.d_q * ab
    return p.m * p.k * ab
