"""Scale-out design-space exploration (DESIGN.md §13): the system-level
sweep CIMFlow argues for — chips x topology x per-chip ``HardwareConfig``
x model x mode, through plan -> shard -> simulate.

Every row records the sharded latency, the resolved axis, speedup vs the
1-chip cell and scale-out efficiency (speedup / chips), the bottleneck
resource (``obs.attribution.bottleneck_of`` — ``INTERCONNECT`` when the
NoC links dominate), and the serialized ``ShardedPlan`` so any row
replays standalone, same as ``repro.dse`` rows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs import registry
from repro.configs.hardware import HardwareConfig, STREAMDCIM_BASE
from repro.core.types import ExecutionMode
from repro.plan.planner import plan_model
from repro.shard.noc import MeshSpec
from repro.shard.partition import shard_plan
from repro.shard.sim import simulate_sharded_plan

SHARD_SWEEP_VERSION = 1

DEFAULT_MODELS = ("vilbert-base", "qwen2-vl-2b")
DEFAULT_CHIPS = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class ShardSweepRow:
    model: str
    seq_len: int
    mode: str
    hw: str
    topology: str
    chips: int
    axis: str
    latency_cycles: int
    hbm_bytes: int
    collective_bytes: int
    speedup: float              # vs the 1-chip cell (same model/mode/hw)
    efficiency: float           # speedup / chips
    bottleneck: str
    plan_json: Optional[Dict[str, object]] = None

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ShardSweepResult:
    rows: Tuple[ShardSweepRow, ...]

    def label(self, r: ShardSweepRow) -> str:
        return f"{r.model}/s{r.seq_len}/{r.mode}/{r.hw}/{r.topology}"

    def speedup_vs_chips(self) -> Dict[str, List[Tuple[int, float]]]:
        """The replayable scale-out curve: cell label -> sorted
        (chips, speedup) points."""
        out: Dict[str, List[Tuple[int, float]]] = {}
        for r in self.rows:
            out.setdefault(self.label(r), []).append((r.chips, r.speedup))
        for pts in out.values():
            pts.sort()
        return out

    def to_dict(self) -> Dict[str, object]:
        return {
            "schema_version": SHARD_SWEEP_VERSION,
            "rows": [r.to_dict() for r in self.rows],
            "speedup_vs_chips": {
                k: [[c, s] for c, s in v]
                for k, v in self.speedup_vs_chips().items()},
        }


def run_shard_sweep(models: Sequence[str] = DEFAULT_MODELS, *,
                    chips: Sequence[int] = DEFAULT_CHIPS,
                    topologies: Sequence[str] = ("ring",),
                    hw_points: Sequence[HardwareConfig] = (STREAMDCIM_BASE,),
                    modes: Optional[Sequence[ExecutionMode]] = None,
                    seq_len: int = 512,
                    smoke: bool = False,
                    mesh_kwargs: Optional[Dict[str, object]] = None,
                    keep_plans: bool = False,
                    progress=None) -> ShardSweepResult:
    """Sweep the scale-out grid.  ``mesh_kwargs`` overrides ``MeshSpec``
    link parameters (bandwidth, hop latency, multicast chunking);
    ``keep_plans`` embeds each row's serialized ``ShardedPlan``.
    Speedups are computed against the 1-chip run of the same cell (one
    is simulated for the baseline even when 1 is not in ``chips``)."""
    modes = tuple(modes or ExecutionMode)
    mesh_kwargs = dict(mesh_kwargs or {})
    rows: List[ShardSweepRow] = []
    from repro.obs.attribution import bottleneck_of
    for name in models:
        cfg = registry.get_config(name, smoke=smoke)
        for hw in hw_points:
            for mode in modes:
                plan = plan_model(cfg, hw=hw, seq_len=seq_len, mode=mode,
                                  force_mode=True)
                for topo in topologies:
                    base_cycles: Optional[int] = None
                    for c in sorted(set(chips) | {1}):
                        mesh = MeshSpec(chips=c, topology=topo,
                                        **mesh_kwargs)
                        splan = shard_plan(plan, mesh)
                        res = simulate_sharded_plan(splan, hw=hw)
                        if base_cycles is None:
                            base_cycles = res.cycles
                        if c not in chips:
                            continue
                        row = ShardSweepRow(
                            model=cfg.name, seq_len=plan.seq_len,
                            mode=mode.value, hw=hw.name, topology=topo,
                            chips=c, axis=splan.axis,
                            latency_cycles=res.cycles,
                            hbm_bytes=res.hbm_bytes,
                            collective_bytes=res.collective_bytes,
                            speedup=base_cycles / max(res.cycles, 1),
                            efficiency=(base_cycles
                                        / max(res.cycles, 1)) / c,
                            bottleneck=bottleneck_of(res.trace),
                            plan_json=(splan.to_dict()
                                       if keep_plans else None))
                        rows.append(row)
                        if progress is not None:
                            progress(row)
    return ShardSweepResult(rows=tuple(rows))
