"""Simulate a ``ShardedPlan`` on the DES engine (DESIGN.md §13).

Every chip is a full StreamDCIM accelerator: its resources are prefixed
(``c0.GEN``, ``c0.ATTN``, ``c0.BUS``, ``c0.NOC``, ``c0.HBM``, ``c0.VEC``)
so the existing mode schedulers lower each chip's sub-plan unchanged
through a resource-prefixing engine view.  Inter-chip collectives lower
through ``noc.lower_collective`` onto shared ``NOC_*`` link resources;
each chip's next op gates on *its own* arrival, so a pipelined multicast
tail overlaps downstream chips' compute the way ping-pong hides rewrites.

Byte-exactness (the multi-chip version of the ``simulate_serve``
discipline): after the run, this module RAISES unless

* every chip's per-op simulated HBM bytes equal that sub-plan op's
  ``hbm_bytes`` prediction, and
* summed ``NOC_*`` link bytes equal the sharded plan's predicted
  collective bytes.

The partitioner and the simulator computing the same number through
different code paths is the whole point of the assert.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.types import ExecutionMode
from repro.sim.dataflow import Engine
from repro.sim.pipeline import _SCHEDULERS
from repro.sim.trace import Trace
from repro.sim.workload import AttnOp, workload_from_plan
from repro.shard import noc
from repro.shard.partition import ShardedPlan


@dataclasses.dataclass(frozen=True)
class ShardSimResult:
    """One sharded run.  ``cycles`` is the mesh makespan; per-chip
    figures come from the trace's resource prefixes.  (A deliberate
    sibling of ``sim.pipeline.SimResult`` — that class reads the literal
    ``HBM`` resource, which no longer exists on a mesh.)"""

    plan: ShardedPlan
    hw: str
    cycles: int
    trace: Trace
    per_chip_cycles: Tuple[int, ...]
    per_chip_hbm_bytes: Tuple[int, ...]
    link_bytes: Dict[str, int]          # per NOC_* link
    hw_cfg: object = None

    @property
    def chips(self) -> int:
        return self.plan.chips

    @property
    def hbm_bytes(self) -> int:
        return sum(self.per_chip_hbm_bytes)

    @property
    def collective_bytes(self) -> int:
        return sum(self.link_bytes.values())


class _ShardEngine(Engine):
    """Engine applying per-resource calibration scales by the *base*
    resource name (``c3.ATTN`` scales by the fitted ``ATTN`` factor), so
    single-chip calibration fits (DESIGN.md §10) carry over to meshes."""

    def __init__(self, scale=None) -> None:
        super().__init__()
        self.scale = dict(scale or {})

    def task(self, kind, resource, cycles, deps=(), nbytes=0, tag=""):
        if cycles and self.scale:
            base = resource.split(".", 1)[-1]
            s = self.scale.get(base, 1.0)
            if s != 1.0:
                cycles = max(1, int(math.ceil(cycles * s)))
        return super().task(kind, resource, cycles, deps, nbytes, tag)


class _ChipView:
    """Engine proxy prefixing resources with ``c{i}.`` — the schedulers
    lower through it unchanged.  Barriers stay on the shared zero-cost
    SYNC pseudo-resource."""

    def __init__(self, eng: Engine, prefix: str) -> None:
        self._eng = eng
        self._prefix = prefix

    def task(self, kind, resource, cycles, deps=(), nbytes=0, tag=""):
        return self._eng.task(kind, self._prefix + resource, cycles, deps,
                              nbytes, tag)

    def barrier(self, deps, tag="sync"):
        return self._eng.barrier(deps, tag)


def chip_prefix(i: int) -> str:
    return f"c{i}."


def _lower_op(sched, view: _ChipView, op, start: int) -> int:
    if isinstance(op, AttnOp):
        return sched.build_attn(view, op, start)
    return sched.build_gemm(view, op, start)


def simulate_sharded_plan(splan: ShardedPlan, hw=None, *,
                          calibration=None) -> ShardSimResult:
    """Lower every chip's sub-plan + the collective wire plans onto one
    engine and run.  Raises ``RuntimeError`` on any byte disagreement
    between the partitioner's predictions and the simulated trace."""
    from repro.sim.replay import resolve_calibration
    hw = hw or splan.base.hw_config()
    eng = _ShardEngine(resolve_calibration(calibration))
    scheds = {m: _SCHEDULERS[m](hw) for m in ExecutionMode}
    C = splan.chips
    mesh = splan.mesh

    views = [_ChipView(eng, chip_prefix(i)) for i in range(C)]
    chip_ops: List[List[object]] = []
    mode_of: Dict[str, ExecutionMode] = {}
    for i, cp in enumerate(splan.chip_plans):
        wl = workload_from_plan(cp, prefix=chip_prefix(i))
        chip_ops.append([op for layer in wl.layers for op in layer.ops])
        for p in tuple(cp.layers) + tuple(cp.gemms):
            mode_of[chip_prefix(i) + p.name] = p.mode

    # Collectives keyed by their producing op ("" = plan input); an op
    # owned by several chips (tensor/sequence) fires its collectives once
    # every owner has produced its share.
    colls_after: Dict[str, List[object]] = {}
    for coll in splan.collectives:
        colls_after.setdefault(coll.after, []).append(coll)
    owners: Dict[str, set] = {}
    for i, cp in enumerate(splan.chip_plans):
        for p in tuple(cp.layers) + tuple(cp.gemms):
            owners.setdefault(p.name, set()).add(i)

    start = eng.barrier([], tag="start")
    prev: Dict[int, int] = {i: start for i in range(C)}
    gates: Dict[int, List[int]] = {i: [] for i in range(C)}

    def fire(colls) -> None:
        for coll in colls:
            arrivals = noc.lower_collective(
                eng, mesh, coll, dep_of=lambda c: [prev[c]],
                tag=coll.name)
            for chip, t in arrivals.items():
                gates[chip].append(t)

    fire(colls_after.get("", ()))

    # Round order: tensor/sequence meshes run symmetric op streams in
    # lockstep; group meshes run their disjoint stages chip-by-chip (the
    # p2p arrivals chain them).
    if splan.axis == "group":
        rounds = [[(i, op)] for i in range(C) for op in chip_ops[i]]
    else:
        rounds = [list(enumerate(ops)) for ops in zip(*chip_ops)]

    produced: Dict[str, set] = {}
    for rnd in rounds:
        fired: List[str] = []
        for chip, op in rnd:
            dep = prev[chip]
            if gates[chip]:
                dep = eng.barrier([dep] + gates[chip],
                                  tag=f"c{chip}.gate")
                gates[chip] = []
            prev[chip] = _lower_op(scheds[mode_of[op.name]], views[chip],
                                   op, dep)
            base_name = op.name.split(".", 1)[-1]
            done = produced.setdefault(base_name, set())
            done.add(chip)
            if done == owners[base_name]:
                fired.append(base_name)
        for name in fired:
            fire(colls_after.get(name, ()))

    eng.barrier([prev[i] for i in range(C)], tag="mesh_done")
    trace = eng.run()
    return _check_and_pack(splan, hw, trace)


def _check_and_pack(splan: ShardedPlan, hw, trace: Trace) -> ShardSimResult:
    C = splan.chips
    # One pass: bucket HBM bytes by (chip, op), link bytes by link, and
    # per-chip busy horizons.
    hbm_by_op: Dict[str, int] = {}
    chip_hbm = [0] * C
    chip_end = [0] * C
    link_bytes: Dict[str, int] = {}
    for e in trace.events:
        r = e.resource
        if noc.is_link_resource(r):
            link_bytes[r] = link_bytes.get(r, 0) + e.bytes
            continue
        if not r.startswith("c") or "." not in r:
            continue
        chip_s, base = r.split(".", 1)
        chip = int(chip_s[1:])
        chip_end[chip] = max(chip_end[chip], e.end)
        if base == "HBM":
            chip_hbm[chip] += e.bytes
            op = e.tag.split(":", 1)[0]
            hbm_by_op[op] = hbm_by_op.get(op, 0) + e.bytes

    for i, cp in enumerate(splan.chip_plans):
        for lp in cp.layers:
            got = hbm_by_op.get(chip_prefix(i) + lp.name, 0)
            if got != lp.hbm_bytes:
                raise RuntimeError(
                    f"chip {i} op {lp.name}: simulated HBM bytes {got} != "
                    f"sharded-plan prediction {lp.hbm_bytes} (mode "
                    f"{lp.mode.value}, axis {splan.axis}, "
                    f"{splan.mesh.name}) — the partitioner and the "
                    f"simulator disagree on the sharded traffic model")

    got_link = sum(link_bytes.values())
    want_link = splan.total_collective_link_bytes
    if got_link != want_link:
        raise RuntimeError(
            f"simulated NoC link bytes {got_link} != sharded-plan "
            f"collective prediction {want_link} (axis {splan.axis}, "
            f"{splan.mesh.name}) — the partitioner and the NoC model "
            f"disagree on the collective wire plan")

    return ShardSimResult(
        plan=splan, hw=hw.name, cycles=trace.makespan, trace=trace,
        per_chip_cycles=tuple(chip_end),
        per_chip_hbm_bytes=tuple(chip_hbm),
        link_bytes=link_bytes, hw_cfg=hw)
