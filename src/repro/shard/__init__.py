"""repro.shard — chiplet-mesh scale-out (DESIGN.md §13).

plan -> shard -> simulate -> serve across a StreamDCIM chiplet mesh:

* ``noc``       — ``MeshSpec`` topologies, NoC link resources, collective
  wire plans, the pipelined-multicast overlap calculus.
* ``partition`` — ``shard_plan``: tensor / sequence / group parallel
  sub-plans + explicit collectives with predicted bytes.
* ``sim``       — ``simulate_sharded_plan``: per-chip lowering through
  the existing mode schedulers + NoC collectives, byte-exactness
  asserted against the sharded plan.
* ``serve``     — ``shard_map`` prefill/decode wrappers behind
  ``serve.Engine(mesh=...)``.
* ``sweep``     — the chips x topology x per-chip-hardware system sweep
  (``python -m repro.shard``).
"""
from repro.shard.noc import (MeshSpec, collective_link_bytes,
                             collective_streams, link_name,
                             lower_collective, multicast_span,
                             pipelined_multicast_wins)
from repro.shard.partition import (CollectiveOp, ShardedPlan, resolve_axis,
                                   shard_plan)
from repro.shard.serve import mesh_decode_fn, mesh_prefill
from repro.shard.sim import ShardSimResult, simulate_sharded_plan
from repro.shard.sweep import (ShardSweepResult, ShardSweepRow,
                               run_shard_sweep)

__all__ = [
    "MeshSpec", "CollectiveOp", "ShardedPlan", "ShardSimResult",
    "ShardSweepResult", "ShardSweepRow",
    "collective_link_bytes", "collective_streams", "link_name",
    "lower_collective", "mesh_decode_fn", "mesh_prefill",
    "multicast_span", "pipelined_multicast_wins", "resolve_axis",
    "run_shard_sweep", "shard_plan", "simulate_sharded_plan",
]
