"""Inter-chip NoC model for the DES simulator (DESIGN.md §13).

A ``MeshSpec`` describes a StreamDCIM chiplet mesh: chip count, link
topology, per-link bandwidth and per-hop latency.  Each unidirectional
link is its own engine resource (``NOC_0``, ``NOC_1``, ...), so link
contention falls out of the in-order list scheduler exactly like HBM and
macro-array contention do on one chip.

Collectives are modeled as *wire plans*: a tuple of ``Stream``s, each a
chunk of payload traversing a sequence of ``Hop``s (one link each).
``collective_streams`` is the single source of truth — ``partition.py``
sums it to *predict* collective bytes, ``sim.py`` lowers the same streams
onto the engine, and the byte-exactness assert between the two holds by
construction (and is still checked, not hoped for).

Overlap calculus (cf. the csl-experiments SUMMA streaming study,
``gemm/analyze_pipeline_benefit.py``): a store-and-forward multicast
serializes ``(C-1) x (hop + payload/bw)``; splitting the payload into n
chunks pipelines the hops, reaching the furthest chip in
``(n + C - 2) x (hop + chunk/bw)``.  Pipelining wins exactly when the
serialized broadcast term dominates the per-chunk hop overhead —
``pipelined_multicast_wins`` evaluates both closed forms.  Because link
tasks occupy ``NOC_*`` resources rather than any chip's macro arrays,
whatever multicast tail remains after a chip's own arrival overlaps that
chip's compute — the same way the ping-pong shadow sub-array hides
rewrites under attention (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Mapping, Sequence, Tuple

TOPOLOGIES = ("ring", "line")

COLLECTIVE_KINDS = ("multicast", "all_gather", "reduce_scatter",
                    "all_reduce", "p2p")

#: Engine resource name for unidirectional inter-chip link ``i``.
LINK_PREFIX = "NOC_"


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """A chiplet mesh: every chip is a full StreamDCIM accelerator
    (its own macro arrays, HBM port, on-chip NoC); chips connect by
    unidirectional links.

    * ``ring`` — ``chips`` links, link *i* carries chip *i* -> *i+1 mod C*.
    * ``line`` — ``2*(chips-1)`` links: forward link *i* carries
      *i* -> *i+1*; backward link ``(chips-1)+i`` carries *i+1* -> *i*.
      Ring collective schedules still run, but the wrap step routes back
      through every link — the emergent penalty is the topology axis.

    ``axis`` picks the sharding axis (``partition.shard_plan``):
    ``auto`` resolves tensor -> sequence -> group by divisibility.
    """

    chips: int = 1
    topology: str = "ring"
    link_bytes_per_cycle: int = 128
    hop_cycles: int = 32
    pipelined_multicast: bool = True
    multicast_chunks: int = 8
    axis: str = "auto"

    def __post_init__(self):
        if self.chips < 1:
            raise ValueError(f"chips must be >= 1, got {self.chips}")
        if self.topology not in TOPOLOGIES:
            raise ValueError(
                f"unknown topology {self.topology!r}; one of {TOPOLOGIES}")
        if self.link_bytes_per_cycle < 1:
            raise ValueError("link_bytes_per_cycle must be >= 1, got "
                             f"{self.link_bytes_per_cycle}")
        if self.hop_cycles < 0:
            raise ValueError(f"hop_cycles must be >= 0, got {self.hop_cycles}")
        if self.multicast_chunks < 1:
            raise ValueError("multicast_chunks must be >= 1, got "
                             f"{self.multicast_chunks}")
        if self.axis not in ("auto", "tensor", "sequence", "group"):
            raise ValueError(f"unknown sharding axis {self.axis!r}")

    @property
    def name(self) -> str:
        return f"{self.topology}{self.chips}"

    @property
    def num_links(self) -> int:
        if self.chips == 1:
            return 0
        return self.chips if self.topology == "ring" else 2 * (self.chips - 1)

    def link_names(self) -> Tuple[str, ...]:
        return tuple(link_name(i) for i in range(self.num_links))

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "MeshSpec":
        return cls(**dict(d))


def link_name(i: int) -> str:
    return f"{LINK_PREFIX}{i}"


def is_link_resource(resource: str) -> bool:
    return resource.startswith(LINK_PREFIX)


# --------------------------------------------------------------------------
# wire plans


@dataclasses.dataclass(frozen=True)
class Hop:
    """One link traversal: ``nbytes`` cross link ``link`` and land on
    chip ``dst`` (which may forward them on the stream's next hop)."""

    link: int
    dst: int
    nbytes: int


@dataclasses.dataclass(frozen=True)
class Stream:
    """One chunk of a collective's payload flowing ``src`` -> hops."""

    src: int
    hops: Tuple[Hop, ...]


def _split(total: int, parts: int) -> List[int]:
    """Split ``total`` bytes into ``parts`` integer chunks (exact sum)."""
    parts = max(1, min(parts, total)) if total > 0 else 1
    base, rem = divmod(total, parts)
    return [base + (1 if i < rem else 0) for i in range(parts)]


def _step_hops(mesh: MeshSpec, src: int, dst: int,
               nbytes: int) -> List[Hop]:
    """Physical hops moving one chip at a time from ``src`` to ``dst``.
    Ring: always the forward direction.  Line: no wrap link, so backward
    moves use the dedicated reverse links."""
    C = mesh.chips
    hops: List[Hop] = []
    at = src
    if mesh.topology == "ring":
        while at != dst:
            nxt = (at + 1) % C
            hops.append(Hop(at, nxt, nbytes))
            at = nxt
    else:  # line
        while at != dst:
            if dst > at:
                hops.append(Hop(at, at + 1, nbytes))
                at += 1
            else:
                hops.append(Hop((C - 1) + (at - 1), at - 1, nbytes))
                at -= 1
    return hops


def _ring_walk(mesh: MeshSpec, start: int, steps: int,
               nbytes: int) -> List[Hop]:
    """``steps`` consecutive logical ring steps from ``start`` (each one
    chip forward); on a line the wrap step expands to physical hops."""
    C = mesh.chips
    hops: List[Hop] = []
    at = start
    for _ in range(steps):
        nxt = (at + 1) % C
        hops.extend(_step_hops(mesh, at, nxt, nbytes))
        at = nxt
    return hops


def _multicast_branches(mesh: MeshSpec, root: int) -> List[List[int]]:
    """Chip paths a broadcast from ``root`` follows (chain per branch)."""
    C = mesh.chips
    if mesh.topology == "ring":
        return [[(root + k) % C for k in range(C)]]
    fwd = list(range(root, C))
    bwd = list(range(root, -1, -1))
    out = []
    if len(fwd) > 1:
        out.append(fwd)
    if len(bwd) > 1:
        out.append(bwd)
    return out


def collective_streams(mesh: MeshSpec, kind: str, payload: int, *,
                       root: int = 0, dst: int = -1) -> Tuple[Stream, ...]:
    """The wire plan for one collective — the SINGLE source of truth for
    collective bytes (prediction in ``partition``, lowering in ``sim``).

    * ``multicast`` — pipelined chunk chains from ``root`` (chunk count 1
      when ``pipelined_multicast`` is off: store-and-forward).
    * ``all_gather`` — ring schedule: shard *j* (payload/C) starts at chip
      *j* and circulates C-1 ring steps.
    * ``reduce_scatter`` — the mirror image: shard *j*'s partial sums
      circulate C-1 steps and land reduced on chip *j*.
    * ``all_reduce`` — reduce-scatter then all-gather fused per shard:
      2*(C-1) ring steps, the textbook ``2*(C-1)/C * payload`` per chip.
    * ``p2p`` — ``root`` -> ``dst`` along the physical path, chunked like
      multicast so multi-hop forwards pipeline too.
    """
    C = mesh.chips
    if kind not in COLLECTIVE_KINDS:
        raise ValueError(f"unknown collective kind {kind!r}")
    if C == 1 or payload <= 0:
        return ()
    streams: List[Stream] = []
    if kind in ("multicast", "p2p"):
        n = mesh.multicast_chunks if mesh.pipelined_multicast else 1
        if kind == "multicast":
            branches = [
                [h for a, b in zip(path, path[1:])
                 for h in _step_hops(mesh, a, b, 0)]
                for path in _multicast_branches(mesh, root)]
        else:
            if not 0 <= dst < C:
                raise ValueError(f"p2p needs a dst chip, got {dst}")
            branches = [_step_hops(mesh, root, dst, 0)]
        for chunk in _split(payload, n):
            for branch in branches:
                streams.append(Stream(root, tuple(
                    dataclasses.replace(h, nbytes=chunk) for h in branch)))
        return tuple(streams)
    shards = _split(payload, C)
    for j, shard in enumerate(shards):
        if shard <= 0:
            continue
        if kind == "all_gather":
            start, steps = j, C - 1
        elif kind == "reduce_scatter":
            start, steps = (j + 1) % C, C - 1
        else:  # all_reduce
            start, steps = (j + 1) % C, 2 * (C - 1)
        streams.append(Stream(start, tuple(
            _ring_walk(mesh, start, steps, shard))))
    return tuple(streams)


def collective_link_bytes(mesh: MeshSpec, kind: str, payload: int, *,
                          root: int = 0, dst: int = -1) -> int:
    """Total bytes crossing inter-chip links for one collective."""
    return sum(h.nbytes for s in
               collective_streams(mesh, kind, payload, root=root, dst=dst)
               for h in s.hops)


def _hop_cycles(mesh: MeshSpec, nbytes: int) -> int:
    return mesh.hop_cycles + math.ceil(nbytes / mesh.link_bytes_per_cycle)


def lower_collective(eng, mesh: MeshSpec, coll, *,
                     dep_of: Callable[[int], Sequence[int]],
                     tag: str) -> Dict[int, int]:
    """Lower one collective's wire plan onto ``eng`` and return
    ``{chip: arrival task}`` — the task after which that chip holds its
    share of the result.  Per-chip arrivals are what make pipelined
    multicast overlap compute: chip *j* is gated only on its own last
    chunk, while the tail of the broadcast keeps streaming to chips
    *j+1..* on link resources no macro array ever waits for.

    ``coll`` is duck-typed (``kind`` / ``payload_bytes`` / ``root`` /
    ``dst`` attributes); ``dep_of(chip)`` supplies the producer tasks of
    data originating at that chip.  Reductions conservatively gate every
    stream on all chips' producers (ring steps touch every operand).
    """
    kind = coll.kind
    streams = collective_streams(mesh, kind, coll.payload_bytes,
                                 root=coll.root, dst=coll.dst)
    if not streams:
        return {}
    shared: List[int] = []
    if kind in ("reduce_scatter", "all_reduce"):
        deps = sorted({d for c in range(mesh.chips) for d in dep_of(c)})
        shared = [eng.barrier(deps, tag=f"{tag}:operands")] if deps else []
    recv: Dict[int, List[int]] = {}
    for si, st in enumerate(streams):
        prev = list(shared) if shared else list(dep_of(st.src))
        for hi, hop in enumerate(st.hops):
            t = eng.task("noc", link_name(hop.link),
                         _hop_cycles(mesh, hop.nbytes), prev,
                         nbytes=hop.nbytes, tag=f"{tag}:s{si}h{hi}")
            prev = [t]
            recv.setdefault(hop.dst, []).append(t)
    return {chip: (ts[0] if len(ts) == 1 else
                   eng.barrier(ts, tag=f"{tag}:c{chip}"))
            for chip, ts in recv.items()}


# --------------------------------------------------------------------------
# analytic overlap calculus


def multicast_span(mesh: MeshSpec, payload: int, *,
                   pipelined: bool = None) -> int:
    """Closed-form arrival cycle at the furthest chip on an idle mesh."""
    C = mesh.chips
    if C == 1 or payload <= 0:
        return 0
    depth = max(len(_step_hops(mesh, p[0], p[-1], 0))
                for p in _multicast_branches(mesh, 0))
    if pipelined is None:
        pipelined = mesh.pipelined_multicast
    n = mesh.multicast_chunks if pipelined else 1
    n = max(1, min(n, payload))
    chunk = math.ceil(payload / n)
    return (n + depth - 1) * _hop_cycles(mesh, chunk)


def pipelined_multicast_wins(mesh: MeshSpec, payload: int) -> bool:
    """True when chunked pipelining beats store-and-forward — i.e. when
    the serialized broadcast term ``(C-1) * payload/bw`` outweighs the
    extra per-chunk hop overhead (the (P-1)*broadcast > overhead rule
    from the csl-experiments pipeline-benefit analysis)."""
    return (multicast_span(mesh, payload, pipelined=True)
            < multicast_span(mesh, payload, pipelined=False))
