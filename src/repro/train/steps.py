"""Step-function factories: train_step / prefill_step / serve_step for any
registered architecture.  These are what launch/dryrun.py lowers and what
launch/train.py runs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.types import ExecutionMode, ModelConfig
from repro.train import optimizer as opt


def make_train_step(cfg: ModelConfig, ocfg: Optional[opt.OptimizerConfig] = None,
                    *, mode: Optional[ExecutionMode] = None,
                    use_pallas: bool = False, remat: bool = True,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``microbatches > 1`` scans gradient accumulation over the
    leading batch dim (compute/comm overlap lever: the per-microbatch grads
    reduce while the next microbatch computes under XLA's scheduler)."""
    ocfg = ocfg or opt.OptimizerConfig()
    mod = registry.model_module(cfg)
    loss_fn = functools.partial(mod.loss_fn, cfg=cfg, mode=mode,
                                use_pallas=use_pallas, remat=remat)

    def single_grads(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch=batch))(params)
        return loss, grads

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                if x.ndim >= 2 and x.shape[0] == 3:    # vlm positions (3,B,S)
                    return jnp.moveaxis(
                        x.reshape(3, microbatches, -1, *x.shape[2:]), 1, 0)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_step(carry, mb_batch):
                loss_sum, gacc = carry
                loss, grads = single_grads(params, mb_batch)
                gacc = jax.tree.map(jnp.add, gacc, grads)
                return (loss_sum + loss, gacc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(acc_step,
                                                (jnp.zeros(()), zeros), mb)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = single_grads(params, batch)
        params, opt_state, metrics = opt.apply(ocfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int, *,
                      mode: Optional[ExecutionMode] = None,
                      use_pallas: bool = False):
    mod = registry.model_module(cfg)

    def prefill_step(params, batch):
        return mod.prefill(params, cfg, batch, max_len, mode=mode,
                           use_pallas=use_pallas)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, tokens (B,1)) -> (logits, cache)."""
    mod = registry.model_module(cfg)

    def serve_step(params, cache, tokens):
        return mod.decode_step(params, cfg, cache, tokens)

    return serve_step


def make_forward_step(cfg: ModelConfig, *,
                      mode: Optional[ExecutionMode] = None,
                      use_pallas: bool = False):
    mod = registry.model_module(cfg)

    def forward_step(params, batch):
        return mod.forward(params, cfg, batch, mode=mode,
                           use_pallas=use_pallas)

    return forward_step
