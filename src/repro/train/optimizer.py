"""AdamW with f32 moments over (possibly bf16) params, cosine LR schedule,
global-norm clipping.  Hand-rolled (no optax offline) but API-compatible in
spirit: ``init -> state``, ``apply -> (params, state)``.

Optimizer state shards exactly like its param (ZeRO-1 via inheritance —
distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * scale


def init(params) -> OptState:
    mk = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(mk, params),
                    nu=jax.tree.map(mk, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply(cfg: OptimizerConfig, params, grads, state: OptState
          ) -> Tuple[Any, OptState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_); new_m.append(nm); new_v.append(nv)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (jax.tree.unflatten(tdef, new_p),
            OptState(step=step, mu=jax.tree.unflatten(tdef, new_m),
                     nu=jax.tree.unflatten(tdef, new_v)),
            metrics)
