"""Training loop: sharded step, deterministic resumable data, async
checkpointing, crash recovery, metrics.

Fault-tolerance contract (DESIGN.md §5):
* restart resumes from the latest *complete* checkpoint (atomic rename)
* the data stream is a pure function of (seed, step) — exact resume
* checkpoint writes are async (off the critical path)
* restore accepts a different device count (elastic)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.configs import registry
from repro.core.types import ExecutionMode, ModelConfig, ShapeConfig
from repro.distributed import sharding as SH
from repro.train import optimizer as OPT
from repro.train import steps as ST
from repro.train.checkpoint import Checkpointer


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    seed: int = 0
    microbatches: int = 1
    mode: Optional[ExecutionMode] = None
    use_pallas: bool = False
    opt: OPT.OptimizerConfig = dataclasses.field(
        default_factory=OPT.OptimizerConfig)


def train(cfg: ModelConfig, shape: ShapeConfig, source, mesh,
          tcfg: TrainConfig, *, hooks: Optional[Dict[str, Callable]] = None
          ) -> Dict[str, Any]:
    """Run the loop; returns final metrics + state handles."""
    hooks = hooks or {}
    mod = registry.model_module(cfg)

    pspecs = registry.param_specs(cfg)
    pshard = SH.param_shardings(pspecs, cfg, mesh)
    bshard = SH.batch_shardings(registry.input_specs(cfg, shape), mesh)

    ckpt = Checkpointer(tcfg.checkpoint_dir) if tcfg.checkpoint_dir else None
    start_step = 0
    from jax.sharding import NamedSharding, PartitionSpec
    replicated = NamedSharding(mesh, PartitionSpec())
    oshard = OPT.OptState(step=replicated, mu=pshard, nu=pshard)

    init_fn = jax.jit(lambda k: mod.init(k, cfg), out_shardings=pshard)
    params = init_fn(jax.random.PRNGKey(tcfg.seed))
    opt_state = jax.jit(OPT.init, out_shardings=oshard)(params)

    if ckpt is not None:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, {"params": params, "opt": opt_state},
                                 {"params": pshard, "opt": oshard})
            params, opt_state = state["params"], state["opt"]
            start_step = latest

    step_fn = jax.jit(
        ST.make_train_step(cfg, tcfg.opt, mode=tcfg.mode,
                           use_pallas=tcfg.use_pallas,
                           microbatches=tcfg.microbatches),
        in_shardings=(pshard, oshard, bshard),
        donate_argnums=(0, 1))

    metrics_hist = []
    t_last = time.time()
    for step in range(start_step, tcfg.steps):
        batch = jax.tree.map(jax.numpy.asarray, source.batch(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % tcfg.log_every == 0 or step == tcfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t_last
            m["steps_per_s"] = tcfg.log_every / max(dt, 1e-9)
            t_last = time.time()
            m["step"] = step + 1
            metrics_hist.append(m)
            if "on_log" in hooks:
                hooks["on_log"](m)
        if ckpt is not None and (step + 1) % tcfg.checkpoint_every == 0:
            ckpt.save_async(step + 1, {"params": params,
                                       "opt": opt_state})
    if ckpt is not None:
        ckpt.wait()
    return {"params": params, "opt_state": opt_state,
            "metrics": metrics_hist}


class _nullctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
