"""Fault-tolerant checkpointing (deliverable: checkpoint/restart, elastic).

Design (multihost-aware, no external deps):

* **Shard-wise**: each host writes only the param/optimizer shards it owns
  (``addressable_shards``) into ``step_<N>/shard_<host>.npz``; a JSON
  manifest records the global tree structure, shapes, and step metadata.
* **Atomic**: writes go to ``step_<N>.tmp/`` and are renamed only after the
  manifest fsyncs — a failure mid-write never corrupts the latest complete
  checkpoint (restart scans for the highest complete step).
* **Async**: ``save_async`` snapshots device arrays to host memory on the
  training thread (cheap device->host copy), then serializes on a
  background thread — the step loop never blocks on disk (straggler
  mitigation: slow disks don't stall the synchronous SPMD step).
* **Elastic restore**: ``restore`` reads the manifest + all shard files and
  ``jax.device_put``s to the *current* mesh's shardings — a checkpoint
  taken on 512 chips restores onto 256 (or 8) without conversion, enabling
  elastic up/down-scaling and CPU-host debugging of TPU checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "|"          # path separator inside npz keys ('/' is not npz-safe)


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            elif hasattr(k, "name"):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        out.append((_SEP.join(parts), leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 host_id: Optional[int] = None):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id if host_id is not None else jax.process_index()
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------ save ------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = True) -> None:
        """Snapshot to host, then write (async unless blocking)."""
        self.wait()                      # one in-flight save at a time
        host_leaves = []
        for path, leaf in _flatten(tree):
            if hasattr(leaf, "addressable_shards"):
                shards = [(list(s.index.__reduce__()[1][0])
                           if False else _index_desc(s.index), np.asarray(s.data))
                          for s in leaf.addressable_shards
                          if s.replica_id == 0]
                host_leaves.append((path, tuple(leaf.shape), str(leaf.dtype),
                                    shards))
            else:
                arr = np.asarray(leaf)
                host_leaves.append((path, tuple(arr.shape), str(arr.dtype),
                                    [(_index_desc(None), arr)]))

        if blocking:
            self._write(step, host_leaves)
        else:
            self._thread = threading.Thread(
                target=self._write_guard, args=(step, host_leaves),
                daemon=True)
            self._thread.start()

    def save_async(self, step: int, tree: Any) -> None:
        self.save(step, tree, blocking=False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_guard(self, step: int, host_leaves) -> None:
        try:
            self._write(step, host_leaves)
        except BaseException as e:  # noqa: BLE001
            self._error = e

    def _write(self, step: int, host_leaves) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        arrays = {}
        manifest = {"step": step, "time": time.time(), "leaves": []}
        for path, shape, dtype, shards in host_leaves:
            for i, (idx_desc, arr) in enumerate(shards):
                arrays[f"{path}{_SEP}#{i}"] = arr
            manifest["leaves"].append({
                "path": path, "shape": list(shape), "dtype": dtype,
                "shards": [{"key": f"{path}{_SEP}#{i}", "index": idx}
                           for i, (idx, _) in enumerate(shards)],
            })
        np.savez(os.path.join(tmp, f"shard_{self.host_id:05d}.npz"),
                 **arrays)
        with open(os.path.join(tmp, f"manifest_{self.host_id:05d}.json"),
                  "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ----------------------------- restore ----------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any,
                shardings: Optional[Any] = None) -> Any:
        """Rebuild the tree; device_put to ``shardings`` (the *current*
        mesh's) if given — elastic resharding happens here."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        manifests = sorted(f for f in os.listdir(d)
                           if f.startswith("manifest_"))
        leaves_meta: Dict[str, dict] = {}
        for mf in manifests:
            with open(os.path.join(d, mf)) as f:
                m = json.load(f)
            for leaf in m["leaves"]:
                leaves_meta.setdefault(leaf["path"], leaf)
        arrays: Dict[str, np.ndarray] = {}
        for fn in sorted(os.listdir(d)):
            if fn.startswith("shard_") and fn.endswith(".npz"):
                with np.load(os.path.join(d, fn)) as z:
                    for k in z.files:
                        arrays[k] = z[k]

        flat_target = _flatten(target_tree)
        shard_flat = _flatten(shardings) if shardings is not None else None
        rebuilt = []
        for i, (path, ref) in enumerate(flat_target):
            meta = leaves_meta.get(path)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {path!r}")
            full = np.zeros(meta["shape"], dtype=np.dtype(meta["dtype"]))
            for sh in meta["shards"]:
                arr = arrays[sh["key"]]
                idx = _desc_to_index(sh["index"], meta["shape"])
                full[idx] = arr
            if shard_flat is not None:
                rebuilt.append(jax.device_put(full, shard_flat[i][1]))
            else:
                rebuilt.append(jax.numpy.asarray(full))
        treedef = jax.tree_util.tree_structure(target_tree)
        return jax.tree_util.tree_unflatten(treedef, rebuilt)


def _index_desc(index) -> Any:
    """Serialize a tuple-of-slices shard index to JSON-able form."""
    if index is None:
        return None
    out = []
    for s in index:
        out.append([s.start, s.stop, s.step])
    return out


def _desc_to_index(desc, shape) -> Any:
    if desc is None:
        return tuple(slice(None) for _ in shape)
    return tuple(slice(a, b, c) for a, b, c in desc)
