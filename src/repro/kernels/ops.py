"""Public jit'd wrappers around the Pallas kernels.

Handles shape padding (MXU alignment), backend selection, and the paper's
three execution modes:

* ``NON_STREAM``   — unfused jnp with ``optimization_barrier`` between every
  matmul: Q, K, V, A, P all materialize (off-chip round-trips in the paper's
  baseline CIM systems).
* ``LAYER_STREAM`` — K/V materialized once (TranCIM pipeline mode), then
  flash attention streams them.
* ``TILE_STREAM``  — StreamDCIM: fused KV-generation + attention; K/V never
  exist in HBM.

Backend selection: Pallas TPU kernels lower natively on TPU; on CPU they run
in ``interpret=True`` mode (Python-emulated, used by tests/benchmarks at
reduced size).  Model code that must ``lower().compile()`` for the CPU-hosted
dry-run uses the jnp paths (``use_pallas=False``) — same math, same FLOPs;
the dataflow deltas are modeled analytically in ``benchmarks/`` (DESIGN.md §6).
"""
from __future__ import annotations

import functools
import sys
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import runtime
from repro.core.types import ExecutionMode
from repro.kernels import jnp_blocked as JB
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.stream_attention import stream_attention
from repro.kernels.tile_gemm import tile_gemm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _replay_recorder(*arrays):
    """The active ``repro.sim.replay`` recorder for this kernel call, or
    None — including when the replay module was never imported (checked
    via ``sys.modules`` so the common path costs one dict lookup)."""
    replay = sys.modules.get("repro.sim.replay")
    if replay is None:
        return None
    return replay.recorder_for(*arrays)


def _pad_axis(x: jax.Array, axis: int, multiple: int) -> Tuple[jax.Array, int]:
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad), size


def _pick_block(seq: int, preferred: int) -> int:
    """Largest power-of-two block <= preferred that keeps seq padding sane."""
    b = preferred
    while b > 128 and seq % b and seq < b:
        b //= 2
    return max(min(b, preferred), 8 if seq < 128 else 128)


def multi_head_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = False, window: int = 0,
                         q_offset: int = 0,
                         use_pallas: bool = False,
                         block_q: int = 256, block_k: int = 256) -> jax.Array:
    """GQA attention: q (B,Hq,Sq,hd), k/v (B,Hkv,Sk,hd) -> (B,Hq,Sq,hd)."""
    if not use_pallas:
        return JB.flash_attention_jnp(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_k=runtime.get("block_k", block_k),
            unroll=runtime.get("unroll", False))
    B, Hq, Sq, hd = q.shape
    scale = hd ** -0.5
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(k.shape[2], block_k)
    kv_len = k.shape[2]
    q, sq0 = _pad_axis(q, 2, bq)
    k, _ = _pad_axis(k, 2, bk)
    v, _ = _pad_axis(v, 2, bk)
    q, _ = _pad_axis(q, 3, 128)
    k, hd0 = _pad_axis(k, 3, 128)
    v, _ = _pad_axis(v, 3, 128)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=q_offset, scale=scale, kv_len=kv_len,
                          block_q=bq, block_k=bk,
                          interpret=not _on_tpu())
    return out[:, :, :sq0, :hd0]


def streaming_attention(q: jax.Array, x_kv: jax.Array, wk: jax.Array,
                        wv: jax.Array, *,
                        sin: Optional[jax.Array] = None,
                        cos: Optional[jax.Array] = None,
                        k_gamma: Optional[jax.Array] = None,
                        causal: bool = False, window: int = 0,
                        q_offset: int = 0, norm_eps: float = 1e-6,
                        use_pallas: bool = False,
                        block_q: int = 256, block_k: int = 256) -> jax.Array:
    """TILE_STREAM fused KV-gen+attention (see stream_attention.py)."""
    if not use_pallas:
        return JB.stream_attention_jnp(
            q, x_kv, wk, wv, sin=sin, cos=cos, k_gamma=k_gamma,
            causal=causal, window=window, q_offset=q_offset,
            norm_eps=norm_eps, block_k=runtime.get("block_k", block_k),
            unroll=runtime.get("unroll", False))
    B, Hq, Sq, hd = q.shape
    Sk = x_kv.shape[1]
    scale = hd ** -0.5
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    q, sq0 = _pad_axis(q, 2, bq)
    x_kv, _ = _pad_axis(x_kv, 1, bk)
    if sin is not None:
        sin, _ = _pad_axis(sin, 0, bk)
        cos, _ = _pad_axis(cos, 0, bk)
    out = stream_attention(q, x_kv, wk, wv, sin=sin, cos=cos,
                           k_gamma=k_gamma, causal=causal, window=window,
                           q_offset=q_offset, scale=scale, norm_eps=norm_eps,
                           kv_len=Sk, block_q=bq, block_k=bk,
                           interpret=not _on_tpu())
    return out[:, :, :sq0, :]


def mla_latent_attention(q_cat: jax.Array, k_cat: jax.Array, c: jax.Array,
                         *, causal: bool = True,
                         use_pallas: bool = False,
                         block_k: int = 512) -> jax.Array:
    """MLA absorbed-form attention == MQA over the shared latent.

    q_cat: (B, H, Sq, kvr+dr) scaled queries; k_cat: (B, 1, Sk, kvr+dr);
    c: (B, 1, Sk, kvr) latent 'values'.  Returns latent context
    (B, H, Sq, kvr).  The Pallas path pads the qk width to a lane multiple
    (zero dims don't change scores) and runs the flash kernel with an
    independent V width — the kernel-level realization of the paper's
    strongest tile-streaming case (K/V never exist; the latent IS the
    cache).
    """
    if not use_pallas:
        return JB.flash_attention_jnp(
            q_cat, k_cat, c, causal=causal,
            block_k=runtime.get("block_k", block_k),
            unroll=runtime.get("unroll", False))
    B, H, Sq, dqk = q_cat.shape
    Sk = k_cat.shape[2]
    bq = _pick_block(Sq, 256)
    bk = _pick_block(Sk, block_k)
    q_cat, sq0 = _pad_axis(q_cat, 2, bq)
    k_cat, _ = _pad_axis(k_cat, 2, bk)
    c_pad, _ = _pad_axis(c, 2, bk)
    q_cat, _ = _pad_axis(q_cat, 3, 128)
    k_cat, _ = _pad_axis(k_cat, 3, 128)
    c_pad, hv0 = _pad_axis(c_pad, 3, 128)
    # q_cat arrives pre-scaled for a hd^-0.5 attention at the *unpadded*
    # qk width — apply exactly that (padding must not change the scale).
    out = flash_attention(q_cat, k_cat, c_pad, causal=causal,
                          scale=dqk ** -0.5,
                          kv_len=Sk, block_q=bq, block_k=bk,
                          interpret=not _on_tpu())
    return out[:, :, :sq0, :hv0]


def projection(x: jax.Array, w: jax.Array, *,
               use_pallas: bool = False) -> jax.Array:
    """(..., K) @ (K, N) with f32 accumulation; weight-stationary on Pallas.
    ``runtime.flags(quantize_proj=True)`` routes through the int8 path
    (the paper's INT16-CIM precision knob -> v5e int8 MXU)."""
    if runtime.get("quantize_proj", False):
        from repro.kernels.quant import int8_matmul
        return int8_matmul(x, w)
    if not use_pallas:
        return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    x2, m0 = _pad_axis(x2, 0, 128)
    out = tile_gemm(x2, w, interpret=not _on_tpu())
    return out[:m0].reshape(*lead, w.shape[1])


def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
        c: jax.Array, *, chunk: int = 128,
        use_pallas: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD scan -> (y, final_state)."""
    if not use_pallas:
        return JB.ssd_chunked_jnp(x, dt, a, b, c, chunk=chunk,
                                  unroll=runtime.get("unroll", False))
    S = x.shape[1]
    ch = min(chunk, S)
    x, s0 = _pad_axis(x, 1, ch)
    dt, _ = _pad_axis(dt, 1, ch)
    b, _ = _pad_axis(b, 1, ch)
    c, _ = _pad_axis(c, 1, ch)
    y, state = ssd_scan(x, dt, a, b, c, chunk=ch, seq_len=s0,
                        interpret=not _on_tpu())
    return y[:, :s0], state


# ---------------------------------------------------------------------------
# Execution-mode dispatch: the paper's three comparison systems for one
# attention layer given pre-computed Q and the raw KV-side activations.
# The planner (``repro.plan``) decides the mode + tiling; the kernels only
# execute the decision (DESIGN.md §8).
# ---------------------------------------------------------------------------

def attention_by_plan(layer_plan, q: jax.Array, x_kv: jax.Array,
                      wk: jax.Array, wv: jax.Array, *,
                      sin: Optional[jax.Array] = None,
                      cos: Optional[jax.Array] = None,
                      k_gamma: Optional[jax.Array] = None,
                      causal: bool = False, window: int = 0,
                      q_offset: int = 0, norm_eps: float = 1e-6,
                      kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                      use_pallas: bool = False) -> jax.Array:
    """Execute one attention layer according to a planner-resolved
    ``repro.plan.LayerPlan``: its ``mode`` picks the dispatch (NON_STREAM /
    LAYER_STREAM / TILE_STREAM — numerically equivalent, tests assert it),
    its ``block_q``/``block_kv`` set the kernel tiling.  Array shapes may
    be reduced vs the plan's full geometry (CPU-hosted numerics at small
    dims); the dataflow decision is shape-independent.

    ``kv`` — the already-materialized (K, V) pair, when the caller holds
    one (prefill fills the cache with it anyway): the NON/LAYER branches
    consume it instead of re-projecting from ``x_kv``; the TILE_STREAM
    branch ignores it (re-generating K/V inside the fused kernel IS the
    cross-forwarding dataflow).

    Inside a ``repro.sim.replay.recording()`` block (and outside ``jit``)
    the call additionally emits one op-level ``KernelTrace`` — grid,
    block tiling actually used, wall-time cycles, bytes moved — ready to
    ``ExecutionPlan.attach_traces`` (DESIGN.md §10)."""
    call = functools.partial(
        _attention_dispatch,
        layer_plan.mode, q, x_kv, wk, wv, sin=sin, cos=cos, k_gamma=k_gamma,
        causal=causal, window=window, q_offset=q_offset, norm_eps=norm_eps,
        kv=kv, use_pallas=use_pallas, block_q=layer_plan.block_q,
        block_k=layer_plan.block_kv)
    rec = _replay_recorder(q, x_kv, wk, wv)
    if rec is None:
        return call()
    from repro.plan.heuristics import attn_hbm_bytes
    B, Hq, Sq, hd = q.shape
    Skv, d_kv = x_kv.shape[1], x_kv.shape[2]
    Hkv = wk.shape[1]
    bq = _pick_block(Sq, layer_plan.block_q)
    bk = _pick_block(Skv, layer_plan.block_kv)
    nbytes = B * attn_hbm_bytes(Sq, Skv, d_kv, Hq, Hkv, hd, layer_plan.mode,
                                block_q=bq,
                                bytes_per_el=q.dtype.itemsize)
    # Work the measured call performs: QK^T + PV plus the K/V generation
    # einsums (fused or materialized).  Q arrives pre-projected (this
    # function's contract), so no Q-projection term.
    flops = B * (4 * Hq * Sq * Skv * hd
                 + 4 * Skv * d_kv * Hkv * hd)
    return rec.measure(
        call, op=layer_plan.name, kind="attention",
        mode=layer_plan.mode.value,
        grid=(B, -(-Sq // bq), -(-Skv // bk)),
        block_q=bq, block_kv=bk, hbm_bytes=nbytes, flops=flops)


def decode_attention_by_plan(decode_layer_plan, q: jax.Array, k: jax.Array,
                             v: jax.Array, *,
                             window: int = 0, q_offset: int = 0,
                             use_pallas: bool = False) -> jax.Array:
    """Execute one decode-step attention according to a planner-resolved
    ``repro.plan.DecodeLayerPlan``: single-query GQA attention over the
    cached K/V — q (B, Hq, 1, hd), k/v (B, Hkv, S, hd) where S is the
    slot's attended KV length (the plan's post-pruning ``seq_kv``).  The
    plan's ``block_kv`` sets the kv tiling; the mode decision is already
    baked into the plan (all three modes are numerically identical for a
    1-row query — the dataflow difference is a traffic/latency decision
    the simulator models).

    Inside a ``repro.sim.replay.recording()`` block (and outside ``jit``)
    the call emits one op-level ``KernelTrace`` of kind ``"decode"`` —
    ready to ``DecodePlan.attach_traces``, exactly as ``attention_by_plan``
    records prefill ops (DESIGN.md §11)."""
    call = functools.partial(
        multi_head_attention, q, k, v, causal=False, window=window,
        q_offset=q_offset, use_pallas=use_pallas,
        block_q=8, block_k=decode_layer_plan.block_kv)
    rec = _replay_recorder(q, k, v)
    if rec is None:
        return call()
    from repro.plan.heuristics import decode_attn_hbm_bytes
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    bk = _pick_block(Skv, decode_layer_plan.block_kv)
    nbytes = B * decode_attn_hbm_bytes(
        Skv, Hq, Hkv, hd, decode_layer_plan.mode,
        append=not decode_layer_plan.cross,
        bytes_per_el=q.dtype.itemsize)
    flops = B * 4 * Hq * Sq * Skv * hd          # QK^T + PV, cached K/V
    return rec.measure(
        call, op=decode_layer_plan.name, kind="decode",
        mode=decode_layer_plan.mode.value,
        grid=(B, 1, -(-Skv // bk)),
        block_q=Sq, block_kv=bk, hbm_bytes=nbytes, flops=flops)


def batched_decode_attention_by_plan(decode_layer_plan, q: jax.Array,
                                     k: jax.Array, v: jax.Array,
                                     cache_len, *,
                                     window: int = 0,
                                     use_pallas: bool = False) -> jax.Array:
    """Execute one decode-step attention layer for a *bucket* of slots at
    once (DESIGN.md §15): q (B, Hq, 1, hd) carries one query row per
    slot, k/v (B, Hkv, W, hd) are the slots' gathered cache buffers, and
    ``cache_len`` (() or (B,)) the per-row valid entry count — the
    batched counterpart of ``decode_attention_by_plan``, row-for-row
    identical numerics (each row's online softmax never sees its
    neighbours).

    Dispatches to ``kernels.decode_attention`` (the batched Pallas
    kernel) under ``use_pallas``, else the lowerable
    ``jnp_blocked.decode_attention_jnp`` reference.  Record/replay: same
    ``KernelTrace`` contract as the per-slot entry — kind ``"decode"``,
    predicted bytes summed over the plan's per-slot ``seq_kv`` (NOT
    B x the buffer width: the traffic model charges what each slot
    *attends*, which the plan already clamped/pruned per slot)."""
    def call():
        if use_pallas:
            from repro.kernels.decode_attention import decode_attention
            return decode_attention(
                q, k, v, cache_len, window=window,
                block_k=decode_layer_plan.block_kv,
                interpret=not _on_tpu())
        return JB.decode_attention_jnp(
            q, k, v, cache_len, window=window,
            block_k=runtime.get("block_k", decode_layer_plan.block_kv),
            unroll=runtime.get("unroll", False))
    rec = _replay_recorder(q, k, v)
    if rec is None:
        return call()
    from repro.plan.heuristics import decode_attn_hbm_bytes
    B, Hq, Sq, hd = q.shape
    Hkv, W = k.shape[1], k.shape[2]
    bk = _pick_block(W, decode_layer_plan.block_kv)
    seq_kv = decode_layer_plan.seq_kv
    if len(seq_kv) != B:
        raise ValueError(
            f"bucket batch {B} != plan slots {len(seq_kv)} for "
            f"{decode_layer_plan.name}")
    nbytes = sum(decode_attn_hbm_bytes(
        kv, Hq, Hkv, hd, decode_layer_plan.mode,
        append=not decode_layer_plan.cross,
        bytes_per_el=q.dtype.itemsize) for kv in seq_kv)
    flops = sum(4 * Hq * Sq * kv * hd for kv in seq_kv)
    return rec.measure(
        call, op=decode_layer_plan.name, kind="decode",
        mode=decode_layer_plan.mode.value,
        grid=(B, 1, -(-W // bk)),
        block_q=Sq, block_kv=bk, hbm_bytes=nbytes, flops=flops)


def attention_by_mode(mode: ExecutionMode, q: jax.Array, x_kv: jax.Array,
                      wk: jax.Array, wv: jax.Array, *,
                      sin: Optional[jax.Array] = None,
                      cos: Optional[jax.Array] = None,
                      k_gamma: Optional[jax.Array] = None,
                      causal: bool = False, window: int = 0,
                      q_offset: int = 0, norm_eps: float = 1e-6,
                      use_pallas: bool = False) -> jax.Array:
    """Dispatch one attention layer by bare mode.

    .. deprecated:: PR 2 — deprecation shim kept for PR-0/1 call sites;
       build a plan (``repro.plan.plan_model`` / ``plan_attention``) and
       call ``attention_by_plan`` instead.  Dispatches the given mode
       verbatim (the planner's ``force_mode=True`` semantics) with the
       default block tiling.
    """
    return _attention_dispatch(
        mode, q, x_kv, wk, wv, sin=sin, cos=cos, k_gamma=k_gamma,
        causal=causal, window=window, q_offset=q_offset, norm_eps=norm_eps,
        use_pallas=use_pallas)


def _attention_dispatch(mode: ExecutionMode, q: jax.Array, x_kv: jax.Array,
                        wk: jax.Array, wv: jax.Array, *,
                        sin: Optional[jax.Array], cos: Optional[jax.Array],
                        k_gamma: Optional[jax.Array], causal: bool,
                        window: int, q_offset: int, norm_eps: float,
                        use_pallas: bool, block_q: int = 256,
                        block_k: int = 256,
                        kv: Optional[Tuple[jax.Array, jax.Array]] = None
                        ) -> jax.Array:
    if mode == ExecutionMode.TILE_STREAM:
        return streaming_attention(
            q, x_kv, wk, wv, sin=sin, cos=cos, k_gamma=k_gamma,
            causal=causal, window=window, q_offset=q_offset,
            norm_eps=norm_eps, use_pallas=use_pallas,
            block_q=block_q, block_k=block_k)

    if kv is not None:
        k, v = kv           # caller already materialized (normed + roped)
    else:
        # Materialize K, V (the "CIM rewriting" both baselines pay).
        k = jnp.einsum("bsd,dhe->bhse", x_kv, wk.astype(x_kv.dtype))
        v = jnp.einsum("bsd,dhe->bhse", x_kv, wv.astype(x_kv.dtype))
        if k_gamma is not None:
            k = ref.rms_norm(k, k_gamma, eps=norm_eps)
        if sin is not None:
            k = ref.apply_rope(k, sin, cos)

    if mode == ExecutionMode.NON_STREAM:
        # Force every intermediate to materialize: no cross-op fusion.
        q = jax.lax.optimization_barrier(q)
        k = jax.lax.optimization_barrier(k)
        v = jax.lax.optimization_barrier(v)
        out = ref.ref_attention(q, k, v, causal=causal, window=window,
                                q_offset=q_offset)
        return jax.lax.optimization_barrier(out)

    # LAYER_STREAM: flash attention over materialized K/V.
    return multi_head_attention(q, k, v, causal=causal, window=window,
                                q_offset=q_offset, use_pallas=use_pallas,
                                block_q=block_q, block_k=block_k)
