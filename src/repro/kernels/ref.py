"""Pure-jnp oracles for every Pallas kernel in this package.

These define the numerics the kernels must match (``assert_allclose`` in
tests, interpret-mode validation on CPU).  They are also the NON_STREAM
execution path of the paper reproduction (every intermediate materialized).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: avoids NaN rows when a
                 # query attends to zero keys (fully-masked sliding windows).


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate-half RoPE.  x: (..., seq, head_dim); sin/cos: (seq, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    shape = (1,) * (x.ndim - 2) + sin.shape
    sin = sin.reshape(shape).astype(x.dtype)
    cos = cos.reshape(shape).astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope_tables(seq_len: int, head_dim: int, theta: float = 10_000.0,
                offset: int = 0) -> Tuple[jax.Array, jax.Array]:
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]
    return jnp.sin(ang), jnp.cos(ang)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale).astype(x.dtype) * gamma.astype(x.dtype)


def _attn_mask(sq: int, sk: int, causal: bool, window: int,
               q_offset: int) -> Optional[jax.Array]:
    """(sq, sk) boolean mask — True = attend.  q_offset aligns decode steps."""
    if not causal and window <= 0:
        return None
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    return mask


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = False, window: int = 0, q_offset: int = 0,
                  scale: Optional[float] = None,
                  return_scores: bool = False):
    """Reference multi-head attention with GQA.

    q: (B, Hq, Sq, hd);  k/v: (B, Hkv, Sk, hd).  Returns (B, Hq, Sq, hd)
    and, optionally, token-importance scores (B, Sk) = column-mean of the
    attention probabilities over all heads & queries (the paper's DTPU
    ranking signal, SpAtten/Evo-ViT style).
    """
    B, Hq, Sq, hd = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = hd ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    mask = _attn_mask(Sq, k.shape[2], causal, window, q_offset)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    o = o.reshape(B, Hq, Sq, hd).astype(q.dtype)
    if return_scores:
        scores = p.sum(axis=(1, 2, 3)) / (Hq * Sq)   # (B, Sk) column mean
        return o, scores
    return o


def ref_stream_attention(q: jax.Array, x_kv: jax.Array,
                         wk: jax.Array, wv: jax.Array, *,
                         sin: Optional[jax.Array] = None,
                         cos: Optional[jax.Array] = None,
                         k_gamma: Optional[jax.Array] = None,
                         causal: bool = False, window: int = 0,
                         q_offset: int = 0,
                         return_scores: bool = False):
    """Oracle for the fused mixed-stationary cross-forwarding kernel.

    The kernel computes K = rope(qknorm(x_kv @ wk)) and V = x_kv @ wv on the
    fly, tile by tile, and feeds them straight into flash attention —
    K and V never exist in HBM.  This oracle materializes them.

    q:    (B, Hq, Sq, hd)   — already projected + roped (Q-CIM analogue)
    x_kv: (B, Sk, D)        — KV-side token activations (other modality for
                               cross-attention; same sequence for self)
    wk/wv: (D, Hkv, hd)
    """
    k = jnp.einsum("bsd,dhe->bhse", x_kv.astype(jnp.float32),
                   wk.astype(jnp.float32))
    v = jnp.einsum("bsd,dhe->bhse", x_kv.astype(jnp.float32),
                   wv.astype(jnp.float32))
    if k_gamma is not None:
        k = rms_norm(k, k_gamma.astype(jnp.float32))
    if sin is not None:
        k = apply_rope(k, sin, cos)
    return ref_attention(q, k.astype(q.dtype), v.astype(q.dtype),
                         causal=causal, window=window, q_offset=q_offset,
                         return_scores=return_scores)


def ref_tile_gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (M, K) @ w: (K, N) with f32 accumulation."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def ref_ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array, *, chunk: int = 64,
            initial_state: Optional[jax.Array] = None,
            return_final_state: bool = False):
    """Mamba-2 SSD (state-space duality) reference — naive sequential scan.

    x:  (B, S, H, P)   — per-head inputs (P = head dim)
    dt: (B, S, H)      — softplus-activated step sizes (already positive)
    a:  (H,)           — negative decay rates (A = -exp(a_log))
    b:  (B, S, N)      — input projection (shared across heads, G=1)
    c:  (B, S, N)      — output projection
    Returns y: (B, S, H, P) [and final state (B, H, P, N)].
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    decay = jnp.exp(dtf * a.astype(jnp.float32)[None, None, :])  # (B,S,H)

    def step(state, inputs):
        xt, dtt, dct, bt, ct = inputs
        # state: (B, H, P, N)
        state = state * dct[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    state0 = (jnp.zeros((B, H, P, N), jnp.float32)
              if initial_state is None else initial_state.astype(jnp.float32))
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(decay, 1, 0), jnp.moveaxis(bf, 1, 0),
          jnp.moveaxis(cf, 1, 0))
    final, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    if return_final_state:
        return y, final
    return y


def ref_decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         cache_len, *, window: int = 0) -> jax.Array:
    """Single-token decode attention oracle.

    q: (B, Hq, 1, hd); caches: (B, Hkv, Smax, hd); cache_len: () or (B,) int —
    number of valid cache entries (new token's K/V already written).
    """
    B, Hq, _, hd = q.shape
    Hkv, Smax = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k_cache.astype(jnp.float32))
    s *= hd ** -0.5
    pos = jnp.arange(Smax)[None, :]
    clen = jnp.asarray(cache_len).reshape(-1, 1)           # (B,1) or (1,1)
    valid = pos < clen
    if window > 0:
        valid &= pos > clen - 1 - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, hd).astype(q.dtype)
