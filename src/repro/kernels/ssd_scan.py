"""Mamba-2 SSD chunked-scan Pallas kernel — tile-streaming applied to SSMs.

The SSD algorithm (state-space duality, arXiv:2405.21060) splits the
sequence into chunks: within a chunk the recurrence is a *masked quadratic
matmul* (exactly the shape of an attention tile), across chunks a small
state (P×N per head) carries forward.  This mirrors StreamDCIM's dataflow:
the chunk tiles stream through VMEM, the carried state is the stationary
operand, and chunk tile DMA double-buffers against MXU compute.  The paper's
attention-specific technique is inapplicable to attention-free archs
(DESIGN.md §4 — mamba2-780m); this kernel is the *adapted* insight.

Grid: (batch, heads, chunks) — chunks innermost; the inter-chunk state lives
in VMEM scratch that persists across chunk grid steps.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_scr, *, chunk: int, num_chunks: int, seq_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (chunk, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (chunk,)
    a = a_ref[0, 0]                                    # scalar decay rate (<0)
    b = b_ref[0].astype(jnp.float32)                   # (chunk, N)
    c = c_ref[0].astype(jnp.float32)                   # (chunk, N)

    # Sequence-pad masking: zero the contribution of padded steps.
    pos = ci * chunk + jax.lax.iota(jnp.int32, chunk)
    valid = (pos < seq_len).astype(jnp.float32)
    dt = dt * valid                                    # decay 1, no input

    dta = dt * a                                       # log-decay per step
    ld = jnp.cumsum(dta)                               # (chunk,) inclusive
    # Gamma[t, s] = exp(LD_t - LD_s) for t >= s (prod of decays in (s, t]).
    gamma = ld[:, None] - ld[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m = jnp.where(tri, jnp.exp(gamma) * cb, 0.0)       # (chunk, chunk)
    u = x * dt[:, None]                                # dt-weighted input
    y_intra = jax.lax.dot_general(m, u, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    state = state_scr[...]                             # (P, N)
    # Inter-chunk: y_t += exp(LD_t) * C_t · state_in
    c_state = jax.lax.dot_general(c, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y_intra + jnp.exp(ld)[:, None] * c_state       # (chunk, P)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # State update: s_out = exp(LD_last)*s_in + sum_s exp(LD_last-LD_s) u_s b_s^T
    ld_last = ld[chunk - 1]
    w = jnp.exp(ld_last - ld)[:, None] * u             # (chunk, P)
    state_scr[...] = (jnp.exp(ld_last) * state
                      + jax.lax.dot_general(w, b, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_scr[...]


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 128,
             interpret: bool = False,
             seq_len: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Shapes as in ``ref.ref_ssd``:

    x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N) -> y (B,S,H,P),
    final_state (B,H,P,N).  S must be pre-padded to a chunk multiple;
    ``seq_len`` is the true length for pad masking.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    ch = min(chunk, S)
    nc = pl.cdiv(S, ch)
    seq_len = S if seq_len is None else seq_len

    kernel = functools.partial(_ssd_kernel, chunk=ch, num_chunks=nc,
                               seq_len=seq_len)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, ch, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, ch, 1), lambda bi, h, ci: (bi, ci, h)),
            pl.BlockSpec((1, 1), lambda bi, h, ci: (h, 0)),
            pl.BlockSpec((1, ch, N), lambda bi, h, ci: (bi, ci, 0)),
            pl.BlockSpec((1, ch, N), lambda bi, h, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ch, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a.reshape(H, 1).astype(jnp.float32), b, c)
    return y, state
