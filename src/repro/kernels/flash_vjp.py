"""Memory-efficient flash-attention backward (custom VJP).

``lax.scan``-based online-softmax saves per-block residuals (the (Sq, bk)
probability tiles) for backward — O(Sq·Sk) memory, defeating the point of
flash attention under ``jax.grad``.  This module implements the standard
two-pass flash backward: forward saves only (out, L = m + log l); backward
re-generates each K/V tile, recomputes the probability tile from L, and
accumulates dQ / dK / dV — O(Sq·bk) live memory.

For the TILE_STREAM path the backward *also* re-generates K/V from x_kv via
``jax.vjp`` of the tile generator, producing dx_kv / dW_K / dW_V / dγ in the
same block loop — the cross-forwarding dataflow applies to the backward pass
too (a beyond-paper extension; the paper only treats inference/forward).

The custom_vjp functions are module-level with static config passed through
``nondiff_argnums`` (per-call closures leak tracers under checkpoint+scan).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class _Cfg(NamedTuple):
    causal: bool
    window: int
    q_offset: int
    block_k: int
    unroll: bool
    kv_len: int          # true (pre-pad) K length for masking
    use_rope: bool = False
    use_norm: bool = False
    norm_eps: float = 1e-6


def _mask_for(qpos, kpos, kv_len, causal, window):
    mask = kpos[None, :] < kv_len
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window > 0:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    return mask


def _scan_or_unroll(body, init, xs, nkb, unroll, stack_out=False):
    if not unroll:
        return jax.lax.scan(body, init, xs)
    carry, outs = init, []
    for i in range(nkb):
        carry, o = body(carry, jax.tree.map(lambda a: a[i], xs))
        if stack_out:
            outs.append(o)
    return carry, outs


# ---------------------------------------------------------------------------
# Plain flash attention (LAYER_STREAM)
# ---------------------------------------------------------------------------

def _flash_fwd_pass(q, k, v, cfg: _Cfg):
    B, Hq, Sq, hd = q.shape
    Hkv, Skp = k.shape[1], k.shape[2]          # already padded
    hdv = v.shape[3]                           # V width may differ (MLA)
    G = Hq // Hkv
    bk = cfg.block_k
    nkb = Skp // bk
    scale = hd ** -0.5
    qpos = jnp.arange(Sq) + cfg.q_offset
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd) * scale
    kb = jnp.moveaxis(k.reshape(B, Hkv, nkb, bk, hd), 2, 0).astype(jnp.float32)
    vb = jnp.moveaxis(v.reshape(B, Hkv, nkb, bk, hdv), 2, 0).astype(jnp.float32)

    def blk(carry, inp):
        m_prev, l_prev, acc = carry
        j, k_j, v_j = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k_j)
        kpos = j * bk + jnp.arange(bk)
        s = jnp.where(_mask_for(qpos, kpos, cfg.kv_len, cfg.causal,
                                cfg.window)[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, v_j)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hdv), jnp.float32)
    (m, l, acc), _ = _scan_or_unroll(blk, (m0, l0, a0),
                                     (jnp.arange(nkb), kb, vb), nkb,
                                     cfg.unroll)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).reshape(B, Hq, Sq, hdv).astype(q.dtype)
    return out, m + jnp.log(l_safe)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, cfg: _Cfg):
    out, _ = _flash_fwd_pass(q, k, v, cfg)
    return out


def _flash_fwd(q, k, v, cfg: _Cfg):
    out, lse = _flash_fwd_pass(q, k, v, cfg)
    return out, (q, k, v, out, lse)


def _flash_bwd(cfg: _Cfg, res, dout):
    q, k, v, out, lse = res
    B, Hq, Sq, hd = q.shape
    Hkv, Skp = k.shape[1], k.shape[2]
    hdv = v.shape[3]
    G = Hq // Hkv
    bk = cfg.block_k
    nkb = Skp // bk
    scale = hd ** -0.5
    qpos = jnp.arange(Sq) + cfg.q_offset
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd)
    dof = dout.astype(jnp.float32).reshape(B, Hkv, G, Sq, hdv)
    of = out.astype(jnp.float32).reshape(B, Hkv, G, Sq, hdv)
    delta = jnp.sum(dof * of, axis=-1)
    kb = jnp.moveaxis(k.reshape(B, Hkv, nkb, bk, hd), 2, 0).astype(jnp.float32)
    vb = jnp.moveaxis(v.reshape(B, Hkv, nkb, bk, hdv), 2, 0).astype(jnp.float32)

    def blk(dq_acc, inp):
        j, k_j, v_j = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf * scale, k_j)
        kpos = j * bk + jnp.arange(bk)
        s = jnp.where(_mask_for(qpos, kpos, cfg.kv_len, cfg.causal,
                                cfg.window)[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])
        dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, dof)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", dof, v_j)
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhgqk,bhkd->bhgqd", ds, k_j)
        dk_j = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qf)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    dq, kvs = _scan_or_unroll(blk, dq0, (jnp.arange(nkb), kb, vb), nkb,
                              cfg.unroll, stack_out=True)
    if cfg.unroll:
        dk = jnp.stack([a for a, _ in kvs], 0)
        dv = jnp.stack([b for _, b in kvs], 0)
    else:
        dk, dv = kvs
    dk = jnp.moveaxis(dk, 0, 2).reshape(B, Hkv, Skp, hd)
    dv = jnp.moveaxis(dv, 0, 2).reshape(B, Hkv, Skp, hdv)
    return (dq.reshape(B, Hq, Sq, hd).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_mem_efficient(q, k, v, *, causal=False, window=0, q_offset=0,
                        block_k=512, unroll=False, q_chunk=8192):
    """GQA flash attention with O(Sq + bk) backward residuals.

    Long query sides are processed in static-offset chunks so the per-block
    probability tile stays O(q_chunk · block_k) — required for MLA prefill
    where 128 query heads share one latent KV (B·H·Sq·bk would otherwise
    reach tens of GiB at 32k).
    """
    Sk = k.shape[2]
    bk = min(block_k, Sk)
    nkb = -(-Sk // bk)
    pad = nkb * bk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Sq = q.shape[2]
    if Sq > q_chunk and Sq % q_chunk == 0:
        outs = []
        for i in range(Sq // q_chunk):
            cfg = _Cfg(causal=causal, window=window,
                       q_offset=q_offset + i * q_chunk, block_k=bk,
                       unroll=unroll, kv_len=Sk)
            outs.append(_flash(q[:, :, i * q_chunk:(i + 1) * q_chunk],
                               k, v, cfg))
        return jnp.concatenate(outs, axis=2)
    cfg = _Cfg(causal=causal, window=window, q_offset=q_offset, block_k=bk,
               unroll=unroll, kv_len=Sk)
    return _flash(q, k, v, cfg)


# ---------------------------------------------------------------------------
# Fused KV-generation + attention (TILE_STREAM)
# ---------------------------------------------------------------------------

def _gen_tile(x_j, wk_, wv_, gamma, sin_j, cos_j, cfg: _Cfg, hd: int):
    """x_j (B,bk,D) -> k_j, v_j (B,Hkv,bk,hd), f32."""
    k_j = jnp.einsum("btd,dhe->bthe", x_j.astype(jnp.float32),
                     wk_.astype(jnp.float32))
    v_j = jnp.einsum("btd,dhe->bthe", x_j.astype(jnp.float32),
                     wv_.astype(jnp.float32))
    if cfg.use_norm:
        var = jnp.mean(k_j * k_j, axis=-1, keepdims=True)
        k_j = k_j * jax.lax.rsqrt(var + cfg.norm_eps) \
            * gamma.astype(jnp.float32)[None, None, None]
    if cfg.use_rope:
        half = hd // 2
        k1, k2 = k_j[..., :half], k_j[..., half:]
        s_ = sin_j[None, :, None].astype(jnp.float32)
        c_ = cos_j[None, :, None].astype(jnp.float32)
        k_j = jnp.concatenate([k1 * c_ - k2 * s_, k2 * c_ + k1 * s_], -1)
    return (jnp.moveaxis(k_j, 2, 1), jnp.moveaxis(v_j, 2, 1))


def _stream_fwd_pass(q, xkv, wk_, wv_, gamma, sin, cos, cfg: _Cfg):
    B, Hq, Sq, hd = q.shape
    Skp, D = xkv.shape[1], xkv.shape[2]
    Hkv = wk_.shape[1]
    G = Hq // Hkv
    bk = cfg.block_k
    nkb = Skp // bk
    scale = hd ** -0.5
    qpos = jnp.arange(Sq) + cfg.q_offset
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd) * scale
    xb = jnp.moveaxis(xkv.reshape(B, nkb, bk, D), 1, 0)
    sinb = sin.reshape(nkb, bk, hd // 2)
    cosb = cos.reshape(nkb, bk, hd // 2)

    def blk(carry, inp):
        m_prev, l_prev, acc = carry
        j, x_j, sin_j, cos_j = inp
        k_j, v_j = _gen_tile(x_j, wk_, wv_, gamma, sin_j, cos_j, cfg, hd)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k_j)
        kpos = j * bk + jnp.arange(bk)
        s = jnp.where(_mask_for(qpos, kpos, cfg.kv_len, cfg.causal,
                                cfg.window)[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, v_j)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = _scan_or_unroll(
        blk, (m0, l0, a0), (jnp.arange(nkb), xb, sinb, cosb), nkb,
        cfg.unroll)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).reshape(B, Hq, Sq, hd).astype(q.dtype)
    return out, m + jnp.log(l_safe)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def _stream(q, xkv, wk, wv, gamma, sin, cos, cfg: _Cfg):
    out, _ = _stream_fwd_pass(q, xkv, wk, wv, gamma, sin, cos, cfg)
    return out


def _stream_fwd(q, xkv, wk, wv, gamma, sin, cos, cfg: _Cfg):
    out, lse = _stream_fwd_pass(q, xkv, wk, wv, gamma, sin, cos, cfg)
    return out, (q, xkv, wk, wv, gamma, sin, cos, out, lse)


def _stream_bwd(cfg: _Cfg, res, dout):
    q, xkv, wk_, wv_, gamma, sin, cos, out, lse = res
    B, Hq, Sq, hd = q.shape
    Skp, D = xkv.shape[1], xkv.shape[2]
    Hkv = wk_.shape[1]
    G = Hq // Hkv
    bk = cfg.block_k
    nkb = Skp // bk
    scale = hd ** -0.5
    qpos = jnp.arange(Sq) + cfg.q_offset
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd)
    dof = dout.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd)
    of = out.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd)
    delta = jnp.sum(dof * of, axis=-1)
    xb = jnp.moveaxis(xkv.reshape(B, nkb, bk, D), 1, 0)
    sinb = sin.reshape(nkb, bk, hd // 2)
    cosb = cos.reshape(nkb, bk, hd // 2)

    def blk(carry, inp):
        dq_acc, dwk_acc, dwv_acc, dg_acc = carry
        j, x_j, sin_j, cos_j = inp
        (k_j, v_j), vjp_fn = jax.vjp(
            lambda xx, wkk, wvv, gg: _gen_tile(xx, wkk, wvv, gg, sin_j,
                                               cos_j, cfg, hd),
            x_j, wk_, wv_, gamma)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf * scale, k_j)
        kpos = j * bk + jnp.arange(bk)
        s = jnp.where(_mask_for(qpos, kpos, cfg.kv_len, cfg.causal,
                                cfg.window)[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])
        dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, dof)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", dof, v_j)
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhgqk,bhkd->bhgqd", ds, k_j)
        dk_j = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qf)
        dx_j, dwk_j, dwv_j, dg_j = vjp_fn((dk_j, dv_j))
        return ((dq_acc, dwk_acc + dwk_j, dwv_acc + dwv_j, dg_acc + dg_j),
                dx_j)

    init = (jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32),
            jnp.zeros(wk_.shape, jnp.float32),
            jnp.zeros(wv_.shape, jnp.float32),
            jnp.zeros((hd,), jnp.float32))
    (dq, dwk, dwv, dg), dxs = _scan_or_unroll(
        blk, init, (jnp.arange(nkb), xb, sinb, cosb), nkb, cfg.unroll,
        stack_out=True)
    if cfg.unroll:
        dx = jnp.concatenate([jnp.asarray(d) for d in dxs], axis=1)
    else:
        dx = jnp.moveaxis(dxs, 0, 1).reshape(B, Skp, D)
    return (dq.reshape(B, Hq, Sq, hd).astype(q.dtype),
            dx.astype(xkv.dtype), dwk.astype(wk_.dtype),
            dwv.astype(wv_.dtype), dg.astype(gamma.dtype),
            jnp.zeros_like(sin), jnp.zeros_like(cos))


_stream.defvjp(_stream_fwd, _stream_bwd)


def stream_mem_efficient(q, x_kv, wk, wv, *, sin=None, cos=None,
                         k_gamma=None, causal=False, window=0, q_offset=0,
                         norm_eps=1e-6, block_k=512, unroll=False):
    """TILE_STREAM with memory-efficient backward: K/V tiles re-generated
    from x_kv in the backward block loop; dW_K/dW_V/dx_kv/dγ accumulate via
    per-tile ``jax.vjp`` of the generator."""
    B, Hq, Sq, hd = q.shape
    Sk = x_kv.shape[1]
    bk = min(block_k, Sk)
    nkb = -(-Sk // bk)
    pad = nkb * bk - Sk
    use_rope = sin is not None
    use_norm = k_gamma is not None
    if pad:
        x_kv = jnp.pad(x_kv, ((0, 0), (0, pad), (0, 0)))
        if use_rope:
            sin = jnp.pad(sin, ((0, pad), (0, 0)))
            cos = jnp.pad(cos, ((0, pad), (0, 0)))
    if sin is None:
        sin = jnp.zeros((nkb * bk, hd // 2), jnp.float32)
        cos = jnp.zeros((nkb * bk, hd // 2), jnp.float32)
    if k_gamma is None:
        k_gamma = jnp.zeros((hd,), jnp.float32)
    cfg = _Cfg(causal=causal, window=window, q_offset=q_offset, block_k=bk,
               unroll=unroll, kv_len=Sk, use_rope=use_rope,
               use_norm=use_norm, norm_eps=norm_eps)
    return _stream(q, x_kv, wk, wv, k_gamma, sin, cos, cfg)
