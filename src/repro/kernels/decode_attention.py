"""Batched single-query decode attention Pallas-TPU kernel (DESIGN.md §15).

One serving step advances a *bucket* of equal-shape slots at once: q is
(B, Hq, 1, hd) — one query row per slot — and K/V are the slots' cache
buffers (B, Hkv, W, hd) gathered from the paged pool
(``repro.serve.kv_cache``).  ``cache_len`` carries each row's valid
entry count (the new token's K/V already written), so ragged buckets
mask per row exactly like the oracle ``kernels.ref.ref_decode_attention``.

Grid: (batch, q_heads, kv_blocks) — kv innermost; the online-softmax
state lives in VMEM scratch persisting across kv grid steps, the same
discipline as ``flash_attention``.  GQA is handled in the K/V BlockSpec
index map.  The single query row is lane-padded to ``block_q`` rows
(TPU min tile); only row 0 is read back.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128   # TPU vector lane width; running stats are lane-replicated
BLOCK_Q = 8   # f32 min sublane tile: the 1-row query pads to 8 rows


def _decode_kernel(clen_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, window: int, bq: int, bk: int,
                   num_kv_blocks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                    # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    clen = clen_ref[0, 0]                                  # this row's length
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < clen                                     # ragged + seq pad
    if window > 0:
        mask &= kpos > clen - 1 - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                    # (bq, LANES)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)             # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    p = jnp.exp(s - m_new[:, :1])                          # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                        # (bq, LANES)
    l_new = l_prev * alpha + jnp.broadcast_to(
        jnp.sum(p, axis=-1, keepdims=True), l_prev.shape)
    acc = acc_scr[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(j == num_kv_blocks - 1)
    def _finish():
        l_final = l_scr[:, :1]
        l_safe = jnp.where(l_final == 0.0, 1.0, l_final)   # fully-masked rows
        o_ref[0, 0, :, :] = (acc_scr[...] / l_safe).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     cache_len: jax.Array, *,
                     window: int = 0,
                     scale: Optional[float] = None,
                     block_k: int = 256,
                     interpret: bool = False) -> jax.Array:
    """q: (B, Hq, 1, hd); k/v: (B, Hkv, W, hd); cache_len: () or (B,)
    int32 valid entries per row -> (B, Hq, 1, hd).

    Shapes are padded here (query rows to ``BLOCK_Q``, head dim to 128,
    KV length to the block size); padded keys sit beyond every row's
    ``cache_len`` and mask out, so no caller-side padding contract.
    """
    B, Hq, Sq, hd = q.shape
    if Sq != 1:
        raise ValueError(f"decode_attention is single-query (Sq == 1), "
                         f"got q shape {q.shape}")
    Hkv, W = k.shape[1], k.shape[2]
    G = Hq // Hkv
    if scale is None:
        scale = hd ** -0.5
    bk = max(min(block_k, W), 1)
    clen = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))
    # Lane-replicate per-row lengths so the kernel reads a (1, LANES)
    # int32 block (scalar operands must still tile on TPU).
    clen2 = jnp.broadcast_to(clen[:, None], (B, LANES))

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, BLOCK_Q - 1), (0, 0)))
    hd_pad = -(-hd // 128) * 128 - hd
    if hd_pad:
        qp = jnp.pad(qp, ((0, 0), (0, 0), (0, 0), (0, hd_pad)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, hd_pad)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, hd_pad)))
    w_pad = -(-W // bk) * bk - W
    if w_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, w_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, w_pad), (0, 0)))
    hdp = hd + hd_pad
    nkb = pl.cdiv(W + w_pad, bk)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, bq=BLOCK_Q, bk=bk,
        num_kv_blocks=nkb)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nkb),
        in_specs=[
            pl.BlockSpec((1, LANES), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, BLOCK_Q, hdp), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hdp), lambda b, h, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hdp), lambda b, h, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BLOCK_Q, hdp),
                               lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, BLOCK_Q, hdp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, LANES), jnp.float32),
            pltpu.VMEM((BLOCK_Q, LANES), jnp.float32),
            pltpu.VMEM((BLOCK_Q, hdp), jnp.float32),
        ],
        interpret=interpret,
    )(clen2, qp, k, v)
    return out[:, :, :1, :hd]
