"""Blocked (flash-style) pure-jnp compute paths.

These are the *lowerable* equivalents of the Pallas kernels: same tiling
structure, expressed as ``lax.scan`` over KV blocks / SSD chunks so that the
CPU-hosted dry-run compiles with bounded memory (no S×S score
materialization).  ``unroll=True`` python-unrolls the block loop — used by
the dry-run's depth probes so XLA cost analysis (which counts while-loop
bodies once) sees every FLOP.

Numerics match kernels/ref.py oracles exactly (tests assert it).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref

NEG_INF = ref.NEG_INF


def _block_count(s: int, b: int) -> int:
    return -(-s // b)


def flash_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = False, window: int = 0,
                        q_offset: int = 0, block_k: int = 512,
                        unroll: bool = False,
                        mem_efficient: bool = True) -> jax.Array:
    """GQA flash attention: q (B,Hq,Sq,hd), k/v (B,Hkv,Sk,hd).

    Online-softmax over KV blocks; peak memory O(Sq·block_k) per head.
    ``mem_efficient`` routes through the custom-VJP two-pass backward
    (kernels/flash_vjp.py) so jax.grad stays O(Sq) too.
    """
    if mem_efficient:
        from repro.kernels.flash_vjp import flash_mem_efficient
        return flash_mem_efficient(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, block_k=block_k,
                                   unroll=unroll)
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = hd ** -0.5
    bk = min(block_k, Sk)
    nkb = _block_count(Sk, bk)
    pad = nkb * bk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd) * scale
    kb = k.reshape(B, Hkv, nkb, bk, hd).astype(jnp.float32)
    vb = v.reshape(B, Hkv, nkb, bk, hd).astype(jnp.float32)
    qpos = jnp.arange(Sq) + q_offset

    def block(carry, inp):
        m_prev, l_prev, acc = carry
        j, k_j, v_j = inp                      # k_j: (B,Hkv,bk,hd)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k_j)
        kpos = j * bk + jnp.arange(bk)
        mask = kpos[None, :] < Sk
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, v_j)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    js = jnp.arange(nkb)
    kbs = jnp.moveaxis(kb, 2, 0)
    vbs = jnp.moveaxis(vb, 2, 0)
    if unroll:
        carry = (m0, l0, a0)
        for j in range(nkb):
            carry, _ = block(carry, (jnp.asarray(j), kbs[j], vbs[j]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(block, (m0, l0, a0), (js, kbs, vbs))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).reshape(B, Hq, Sq, hd)
    return out.astype(q.dtype)


def decode_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array,
                         cache_len, *, window: int = 0,
                         block_k: int = 512,
                         unroll: bool = False) -> jax.Array:
    """Batched single-query decode attention over cached K/V — the
    lowerable mirror of ``kernels.decode_attention`` (and a blocked
    restatement of ``ref.ref_decode_attention``).

    q: (B, Hq, 1, hd); k/v: (B, Hkv, W, hd); ``cache_len``: () or (B,)
    int32 valid cache entries per row (the new token's K/V already
    written).  Online-softmax over KV blocks, ragged rows masked by
    their own length — peak memory O(B·block_k) per head.
    """
    B, Hq, Sq, hd = q.shape
    Hkv, W = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = hd ** -0.5
    bk = min(block_k, W)
    nkb = _block_count(W, bk)
    pad = nkb * bk - W
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    clen = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd) * scale
    kbs = jnp.moveaxis(k.reshape(B, Hkv, nkb, bk, hd).astype(jnp.float32),
                       2, 0)
    vbs = jnp.moveaxis(v.reshape(B, Hkv, nkb, bk, hd).astype(jnp.float32),
                       2, 0)

    def block(carry, inp):
        m_prev, l_prev, acc = carry
        j, k_j, v_j = inp                      # k_j: (B,Hkv,bk,hd)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k_j)
        kpos = j * bk + jnp.arange(bk)
        mask = kpos[None, :] < clen[:, None]   # (B, bk): ragged + seq pad
        if window > 0:
            mask = mask & (kpos[None, :] > clen[:, None] - 1 - window)
        s = jnp.where(mask[:, None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, v_j)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    js = jnp.arange(nkb)
    if unroll:
        carry = (m0, l0, a0)
        for j in range(nkb):
            carry, _ = block(carry, (jnp.asarray(j), kbs[j], vbs[j]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(block, (m0, l0, a0), (js, kbs, vbs))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).reshape(B, Hq, Sq, hd)
    return out.astype(q.dtype)


def stream_attention_jnp(q: jax.Array, x_kv: jax.Array, wk: jax.Array,
                         wv: jax.Array, *, sin=None, cos=None,
                         k_gamma=None, causal: bool = False,
                         window: int = 0, q_offset: int = 0,
                         norm_eps: float = 1e-6, block_k: int = 512,
                         unroll: bool = False,
                         mem_efficient: bool = True) -> jax.Array:
    """Lowerable TILE_STREAM: K/V tiles generated from x_kv inside the
    block loop (never materialized at full length), cross-forwarded straight
    into the online-softmax update — the jnp mirror of
    kernels/stream_attention.py."""
    if mem_efficient:
        from repro.kernels.flash_vjp import stream_mem_efficient
        return stream_mem_efficient(
            q, x_kv, wk, wv, sin=sin, cos=cos, k_gamma=k_gamma,
            causal=causal, window=window, q_offset=q_offset,
            norm_eps=norm_eps, block_k=block_k, unroll=unroll)
    B, Hq, Sq, hd = q.shape
    Sk, D = x_kv.shape[1], x_kv.shape[2]
    Hkv = wk.shape[1]
    G = Hq // Hkv
    scale = hd ** -0.5
    bk = min(block_k, Sk)
    nkb = _block_count(Sk, bk)
    pad = nkb * bk - Sk
    if pad:
        x_kv = jnp.pad(x_kv, ((0, 0), (0, pad), (0, 0)))
        if sin is not None:
            sin = jnp.pad(sin, ((0, pad), (0, 0)))
            cos = jnp.pad(cos, ((0, pad), (0, 0)))

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, hd) * scale
    xb = jnp.moveaxis(x_kv.reshape(B, nkb, bk, D), 1, 0)
    sinb = jnp.moveaxis(sin.reshape(nkb, bk, hd // 2), 0, 0) if sin is not None else None
    qpos = jnp.arange(Sq) + q_offset
    wk2 = wk.reshape(D, Hkv * hd)
    wv2 = wv.reshape(D, Hkv * hd)

    def block(carry, inp):
        m_prev, l_prev, acc = carry
        if sin is not None:
            j, x_j, sin_j, cos_j = inp
        else:
            j, x_j = inp
        # --- generate this KV tile on the fly (cross-forwarding) ---
        k_j = jnp.dot(x_j.astype(jnp.float32), wk2.astype(jnp.float32))
        v_j = jnp.dot(x_j.astype(jnp.float32), wv2.astype(jnp.float32))
        k_j = k_j.reshape(B, bk, Hkv, hd)
        v_j = v_j.reshape(B, bk, Hkv, hd).transpose(0, 2, 1, 3)
        if k_gamma is not None:
            var = jnp.mean(k_j * k_j, axis=-1, keepdims=True)
            k_j = k_j * jax.lax.rsqrt(var + norm_eps) \
                * k_gamma.astype(jnp.float32)[None, None, None, :]
        if sin is not None:
            half = hd // 2
            k1, k2 = k_j[..., :half], k_j[..., half:]
            s_ = sin_j[None, :, None, :]
            c_ = cos_j[None, :, None, :]
            k_j = jnp.concatenate([k1 * c_ - k2 * s_, k2 * c_ + k1 * s_],
                                  axis=-1)
        k_j = k_j.transpose(0, 2, 1, 3)                    # (B,Hkv,bk,hd)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k_j)
        kpos = j * bk + jnp.arange(bk)
        mask = kpos[None, :] < Sk
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, v_j)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    js = jnp.arange(nkb)
    if sin is not None:
        sins = sin.reshape(nkb, bk, hd // 2)
        coss = cos.reshape(nkb, bk, hd // 2)
        xs = (js, xb, sins, coss)
    else:
        xs = (js, xb)
    if unroll:
        carry = (m0, l0, a0)
        for j in range(nkb):
            carry, _ = block(carry, jax.tree.map(lambda a: a[j], xs))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(block, (m0, l0, a0), xs)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).reshape(B, Hq, Sq, hd)
    return out.astype(q.dtype)


def ssd_chunked_jnp(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                    c: jax.Array, *, chunk: int = 128,
                    initial_state: Optional[jax.Array] = None,
                    unroll: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (dense matmuls per chunk, scan over chunks) — the jnp
    mirror of kernels/ssd_scan.py.  Shapes as ref_ssd."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    ch = min(chunk, S)
    nc = _block_count(S, ch)
    pad = nc * ch - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    xf = x.astype(jnp.float32).reshape(B, nc, ch, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, ch, H)
    bf = b.astype(jnp.float32).reshape(B, nc, ch, N)
    cf = c.astype(jnp.float32).reshape(B, nc, ch, N)
    af = a.astype(jnp.float32)
    # mask padded steps: dt=0 -> decay 1, no input
    if pad:
        valid = (jnp.arange(nc * ch) < S).reshape(nc, ch)
        dtf = dtf * valid[None, :, :, None]

    tri = (jnp.arange(ch)[:, None] >= jnp.arange(ch)[None, :])

    def chunk_step(state, inp):
        x_c, dt_c, b_c, c_c = inp              # (B,ch,H,P),(B,ch,H),(B,ch,N)
        dta = dt_c * af[None, None, :]         # (B,ch,H)
        ld = jnp.cumsum(dta, axis=1)           # inclusive log-decay
        gamma = ld[:, :, None, :] - ld[:, None, :, :]      # (B,ch,ch,H)
        cb = jnp.einsum("bin,bjn->bij", c_c, b_c)          # (B,ch,ch)
        m = jnp.where(tri[None, :, :, None], jnp.exp(gamma)
                      * cb[..., None], 0.0)                # (B,ch,ch,H)
        u = x_c * dt_c[..., None]                          # (B,ch,H,P)
        y_intra = jnp.einsum("bijh,bjhp->bihp", m, u)
        c_state = jnp.einsum("bin,bhpn->bihp", c_c, state)
        y = y_intra + jnp.exp(ld)[..., None] * c_state
        ld_last = ld[:, -1]                                # (B,H)
        w = jnp.exp(ld_last[:, None] - ld)[..., None] * u  # (B,ch,H,P)
        state = (jnp.exp(ld_last)[..., None, None] * state
                 + jnp.einsum("bjhp,bjn->bhpn", w, b_c))
        return state, y

    state0 = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
              else initial_state.astype(jnp.float32))
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    if unroll:
        state, ys = state0, []
        for j in range(nc):
            state, y = chunk_step(state, jax.tree.map(lambda a_: a_[j], xs))
            ys.append(y)
        y = jnp.stack(ys, axis=1)
    else:
        state, ys = jax.lax.scan(chunk_step, state0, xs)
        y = jnp.moveaxis(ys, 0, 1)
    y = y.reshape(B, nc * ch, H, P)[:, :S]
    return y.astype(x.dtype), state
