"""Flash attention Pallas-TPU kernel — the LAYER_STREAM baseline.

This models TranCIM-style layer-based streaming: K and V have already been
materialized to HBM by the projection layer ("CIM rewriting" completed for
the whole layer), and attention streams KV tiles through VMEM.

Grid: (batch, q_heads, q_blocks, kv_blocks) — kv innermost; the online
softmax state lives in VMEM scratch that persists across kv grid steps.
GQA is handled in the K/V BlockSpec index map (q head -> kv head).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128  # TPU vector lane width; running-max/denominator are lane-replicated


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, q_offset: int,
                  bq: int, bk: int, kv_len: int, num_kv_blocks: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    i = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                    # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = (i * bq + q_offset
            + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < kv_len                                   # seq-pad mask
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                    # (bq, LANES)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)             # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    p = jnp.exp(s - m_new[:, :1])                          # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                        # (bq, LANES)
    l_new = l_prev * alpha + jnp.broadcast_to(
        jnp.sum(p, axis=-1, keepdims=True), l_prev.shape)
    acc = acc_scr[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(j == num_kv_blocks - 1)
    def _finish():
        l_final = l_scr[:, :1]
        l_safe = jnp.where(l_final == 0.0, 1.0, l_final)   # fully-masked rows
        o_ref[0, 0, :, :] = (acc_scr[...] / l_safe).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, window: int = 0, q_offset: int = 0,
                    scale: Optional[float] = None,
                    kv_len: Optional[int] = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd) -> (B, Hq, Sq, hd).

    Shapes must be pre-padded: Sq % block_q == 0, hd % 128 == 0 (see
    ``ops.multi_head_attention`` for the padding wrapper).  ``kv_len`` is
    the true (unpadded) key count — padded keys are masked out.
    """
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    hdv = v.shape[3]            # may differ from hd (MLA: MQA over the
                                # latent — qk width kvr+rope, v width kvr)
    kv_len = Sk if kv_len is None else kv_len
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nqb = pl.cdiv(Sq, bq)
    nkb = pl.cdiv(Sk, bk)
    if scale is None:
        scale = hd ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, kv_len=kv_len, num_kv_blocks=nkb)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nqb, nkb),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hdv), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hdv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hdv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, hdv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
