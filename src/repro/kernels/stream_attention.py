"""StreamDCIM tile-streaming attention — the paper's core contribution on TPU.

Mixed-stationary cross-forwarding dataflow (paper §II-B) as one fused Pallas
kernel: ``W_K``/``W_V`` are VMEM-*stationary* (the TBR-CIM "weight part"),
token tiles of ``x_kv`` *stream* through VMEM (the "input part" — hybrid
mode's co-residency), and each generated ``K_j``/``V_j`` tile is
*cross-forwarded* directly into the ``Q·K_j^T`` / ``P·V_j`` MXU ops without
ever being written to HBM.  The Pallas grid pipeline double-buffers the
``x_kv`` tile DMA against MXU compute — the ping-pong fine-grained
compute-rewriting overlap of paper §II-C ("rewriting" = operand DMA).

All KV heads are generated from a single ``x_kv`` tile read (one DMA feeds
every head's K and V) — the TPU analogue of one macro broadcasting its
stationary rows to all other macros over the TBSN.

Grid: (batch, q_blocks, kv_blocks), kv innermost.  Online-softmax state for
*all* heads of one q-block lives in VMEM scratch.
"""
from __future__ import annotations

import functools
import sys
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _rope_tile(x, sin, cos):
    """x: (bk, H, hd); sin/cos: (bk, hd//2) -> rotate-half RoPE."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[:, None, :]
    c = cos[:, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def _stream_kernel(q_ref, x_ref, wk_ref, wv_ref, sin_ref, cos_ref, kg_ref,
                   o_ref, m_scr, l_scr, acc_scr, *,
                   scale: float, causal: bool, window: int, q_offset: int,
                   bq: int, bk: int, kv_len: int, num_kv_blocks: int,
                   hkv: int, group: int, hd: int, use_rope: bool,
                   use_k_norm: bool, norm_eps: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    i = pl.program_id(1)

    # ---- cross-forwarding step 1: generate this KV tile on the fly ----
    x = x_ref[0].astype(jnp.float32)                        # (bk, D)
    wk = wk_ref[...].astype(jnp.float32)                    # (D, Hkv*hd)
    wv = wv_ref[...].astype(jnp.float32)
    k_all = jax.lax.dot_general(x, wk, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    v_all = jax.lax.dot_general(x, wv, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    k_all = k_all.reshape(bk, hkv, hd)
    v_all = v_all.reshape(bk, hkv, hd)
    if use_k_norm:
        var = jnp.mean(k_all * k_all, axis=-1, keepdims=True)
        k_all = k_all * jax.lax.rsqrt(var + norm_eps) * kg_ref[0][None, None, :]
    if use_rope:
        k_all = _rope_tile(k_all, sin_ref[...].astype(jnp.float32),
                           cos_ref[...].astype(jnp.float32))

    # ---- cross-forwarding step 2: K_j, V_j feed QK^T / PV immediately ----
    q = q_ref[0].astype(jnp.float32)                        # (Hq, bq, hd)
    q = q.reshape(hkv, group * bq, hd)
    kt = jnp.transpose(k_all, (1, 0, 2))                    # (Hkv, bk, hd)
    vt = jnp.transpose(v_all, (1, 0, 2))
    s = jax.lax.dot_general(q, kt, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    # s: (Hkv, G*bq, bk).  Query position for row r is i*bq + r % bq.
    row = jax.lax.broadcasted_iota(jnp.int32, (group * bq, bk), 0)
    qpos = i * bq + q_offset + jax.lax.rem(row, bq)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (group * bq, bk), 1)
    mask = kpos < kv_len
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)

    m_prev = m_scr[...]                                     # (Hkv, G*bq, LANES)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    p = jnp.exp(s - m_new[..., :1])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * alpha + jnp.broadcast_to(
        jnp.sum(p, axis=-1, keepdims=True), l_prev.shape)
    acc_scr[...] = acc_scr[...] * alpha[..., :1] + jax.lax.dot_general(
        p, vt, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == num_kv_blocks - 1)
    def _finish():
        l_final = l_scr[..., :1]
        l_safe = jnp.where(l_final == 0.0, 1.0, l_final)
        o = (acc_scr[...] / l_safe).reshape(hkv * group, bq, hd)
        o_ref[0] = o.astype(o_ref.dtype)


def stream_attention(q: jax.Array, x_kv: jax.Array,
                     wk: jax.Array, wv: jax.Array, *,
                     sin: Optional[jax.Array] = None,
                     cos: Optional[jax.Array] = None,
                     k_gamma: Optional[jax.Array] = None,
                     causal: bool = False, window: int = 0,
                     q_offset: int = 0, scale: Optional[float] = None,
                     norm_eps: float = 1e-6, kv_len: Optional[int] = None,
                     block_q: int = 256, block_k: int = 256,
                     interpret: bool = False) -> jax.Array:
    """Fused KV-generation + attention (TILE_STREAM execution mode).

    q:     (B, Hq, Sq, hd) — pre-projected & roped queries (Q-CIM output)
    x_kv:  (B, Sk, D)      — KV-side activations (other modality for
                              cross-attention)
    wk/wv: (D, Hkv, hd)
    sin/cos: (Sk, hd//2) RoPE tables for key positions (None = no rope —
              correct for cross-attention to non-positional memories)
    k_gamma: (hd,) qk-norm gamma for K (qwen3) or None

    Shapes must be pre-padded: Sq % block_q == 0, Sk % block_k == 0,
    hd % 128 == 0, D % 128 == 0 (see ops.py wrapper).
    """
    B, Hq, Sq, hd = q.shape
    Sk, D = x_kv.shape[1], x_kv.shape[2]
    kv_len = Sk if kv_len is None else kv_len
    Hkv = wk.shape[1]
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nqb = pl.cdiv(Sq, bq)
    nkb = pl.cdiv(Sk, bk)
    if scale is None:
        scale = hd ** -0.5

    use_rope = sin is not None
    use_k_norm = k_gamma is not None
    if sin is None:
        sin = jnp.zeros((Sk, hd // 2), jnp.float32)
        cos = jnp.zeros((Sk, hd // 2), jnp.float32)
    if k_gamma is None:
        k_gamma = jnp.zeros((hd,), jnp.float32)

    kernel = functools.partial(
        _stream_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, kv_len=kv_len, num_kv_blocks=nkb,
        hkv=Hkv, group=G, hd=hd, use_rope=use_rope, use_k_norm=use_k_norm,
        norm_eps=norm_eps)

    wk2 = wk.reshape(D, Hkv * hd)
    wv2 = wv.reshape(D, Hkv * hd)

    call = lambda: pl.pallas_call(  # noqa: E731
        kernel,
        grid=(B, nqb, nkb),
        in_specs=[
            pl.BlockSpec((1, Hq, bq, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            # Weights: constant index map -> fetched once, VMEM-stationary.
            pl.BlockSpec((D, Hkv * hd), lambda b, i, j: (0, 0)),
            pl.BlockSpec((D, Hkv * hd), lambda b, i, j: (0, 0)),
            pl.BlockSpec((bk, hd // 2), lambda b, i, j: (j, 0)),
            pl.BlockSpec((bk, hd // 2), lambda b, i, j: (j, 0)),
            pl.BlockSpec((1, hd), lambda b, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, bq, hd), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hkv, G * bq, LANES), jnp.float32),
            pltpu.VMEM((Hkv, G * bq, LANES), jnp.float32),
            pltpu.VMEM((Hkv, G * bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, x_kv, wk2, wv2, sin.astype(jnp.float32), cos.astype(jnp.float32),
      k_gamma.reshape(1, hd))

    # Plan/trace replay instrumentation (DESIGN.md §10): under an active
    # ``repro.sim.replay.recording()`` block (and outside jit) emit one
    # kernel-level KernelTrace carrying the pallas grid actually launched
    # and the TILE_STREAM traffic (x_kv streamed, K/V never in HBM).
    replay = sys.modules.get("repro.sim.replay")
    rec = replay.recorder_for(q, x_kv, wk, wv) if replay is not None else None
    if rec is not None:
        itemsize = jnp.dtype(q.dtype).itemsize
        # q in + out once, x_kv re-streamed per q-block, weights fetched
        # once (constant index map) — mirrors the §II-B dataflow.
        io_bytes = (2 * q.size + nqb * x_kv.size
                    + wk.size + wv.size) * itemsize
        return rec.measure(
            call, op=rec.current_label("stream_attention"),
            kind="attention", mode="tile_stream", grid=(B, nqb, nkb),
            block_q=bq, block_kv=bk, hbm_bytes=io_bytes,
            flops=B * (4 * Hq * Sq * Sk * hd + 4 * Sk * D * Hkv * hd))
    return call()
