"""Ping-pong weight-stationary tiled matmul — TBR-CIM "normal mode" on TPU.

The paper's normal-mode macros hold weights stationary while input rows
stream through (used for I·W_Q, I·W_K generation).  Here the weight tile for
the current (n, k) grid cell stays VMEM-resident across the m-sweep while
input tiles stream, and the Pallas grid pipeline double-buffers the next
input tile's DMA against the current MXU op — the compute-rewriting overlap
of paper §II-C applied to a plain projection.

Grid: (n_blocks, m_blocks, k_blocks).  m is *inner* relative to n so each
weight column-block is fetched once and reused across every input row-block
(weight-stationary); k innermost accumulates partial products in scratch.
"""
from __future__ import annotations

import functools
import sys
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, o_ref, acc_scr, *, num_k_blocks: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == num_k_blocks - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def tile_gemm(x: jax.Array, w: jax.Array, *,
              block_m: int = 256, block_n: int = 256, block_k: int = 512,
              out_dtype: Optional[jnp.dtype] = None,
              interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N), f32 accumulation.

    M/K/N must be pre-padded to block multiples (ops.py wrapper pads).
    """
    M, K = x.shape
    N = w.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    nm, nn, nk = pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk)
    out_dtype = out_dtype or x.dtype

    kernel = functools.partial(_gemm_kernel, num_k_blocks=nk)
    call = lambda: pl.pallas_call(  # noqa: E731
        kernel,
        grid=(nn, nm, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda n, m, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda n, m, k: (k, n)),  # stationary in m
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda n, m, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)

    # Plan/trace replay instrumentation (DESIGN.md §10): inside an active
    # ``repro.sim.replay.recording()`` block (and outside jit) emit one
    # kernel-level KernelTrace with the grid actually launched.
    replay = sys.modules.get("repro.sim.replay")
    rec = replay.recorder_for(x, w) if replay is not None else None
    if rec is not None:
        itemsize = jnp.dtype(x.dtype).itemsize
        return rec.measure(
            call, op=rec.current_label("tile_gemm"), kind="gemm",
            grid=(nn, nm, nk), block_q=bm, block_kv=bn,
            hbm_bytes=(M * K + K * N + M * N) * itemsize,
            flops=2 * M * K * N)
    return call()
