"""Quantized projection path — the paper's precision knob on TPU.

StreamDCIM runs attention at INT16 on its CIM arrays (§III-A).  The TPU
analogue is int8 MXU matmuls (v5e: 394 TOPS int8 = 2× bf16): weights are
quantized per-output-channel, activations per-row (dynamic), accumulation
in int32, dequantized on the way out.  Enabled via
``runtime.flags(quantize_proj=True)`` on the MLP/projection path —
benchmarks/bench_stream_modes.py uses the 2× int8 peak in its projections.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_rows(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8: x (..., K) -> (int8, scales (..., 1))."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_cols(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-output-channel int8: w (K, N) -> (int8, scales (1, N))."""
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """(..., K) @ (K, N) through int8 with int32 accumulation."""
    lead = x.shape[:-1]
    xq, sx = quantize_rows(x.reshape(-1, x.shape[-1]).astype(jnp.float32))
    wq, sw = quantize_cols(w.astype(jnp.float32))
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32) if jax.default_backend() == "cpu" else xq,
        wq.astype(jnp.int32) if jax.default_backend() == "cpu" else wq,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * sx * sw
    return out.reshape(*lead, w.shape[1]).astype(x.dtype)
