"""Deterministic, resumable data pipeline.

Every batch is a pure function of ``(seed, step)`` — restart-from-checkpoint
resumes the stream exactly (no data loss or duplication, the fault-tolerance
contract in DESIGN.md §5).  Sources:

* ``SyntheticLM`` — Zipf-distributed token stream (shape-faithful stand-in;
  offline container has no corpus downloads)
* ``TextCorpus``  — byte-level tokenization of local files, packed into
  fixed-length sequences (the end-to-end example trains on this)
* multimodal variants emit the stub frontend tensors (frames / regions)

``ShardedLoader`` wraps a source with host-sharding (each host materializes
only its slice of the global batch) and a double-buffered prefetch thread.
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.core.types import Family, ModelConfig, ShapeConfig


class SyntheticLM:
    """Zipf token stream: batch(step) is deterministic in (seed, step)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 zipf_a: float = 1.2):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.zipf_a = zipf_a

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.shape.global_batch, self.shape.seq_len
        V = self.cfg.vocab_size
        toks = rng.zipf(self.zipf_a, size=(B, S + 1)).astype(np.int64)
        toks = (toks - 1) % V
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if self.cfg.family == Family.VLM:
            pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None, None],
                                  (3, B, S))
            out["positions"] = np.ascontiguousarray(pos)
        if self.cfg.family == Family.ENCDEC:
            out["frames"] = rng.standard_normal(
                (B, self.cfg.encoder_seq, self.cfg.d_model)).astype(
                    np.float32) * 0.1
        if self.cfg.family == Family.CROSSMODAL:
            out = {"regions": rng.standard_normal(
                       (B, S, self.cfg.d_model)).astype(np.float32) * 0.1,
                   "tokens": out["tokens"],
                   "answers": rng.integers(0, 3129, size=(B,)).astype(
                       np.int32)}
        return out


class TextCorpus:
    """Byte-tokenized local files packed to fixed-length rows.

    The whole corpus is memory-mapped once; batch(step) slices
    deterministically with a per-step shuffle so restart is exact.
    """

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, path: str,
                 seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        blobs = []
        if os.path.isdir(path):
            for name in sorted(os.listdir(path)):
                p = os.path.join(path, name)
                if os.path.isfile(p):
                    with open(p, "rb") as f:
                        blobs.append(np.frombuffer(f.read(), np.uint8))
        else:
            with open(path, "rb") as f:
                blobs.append(np.frombuffer(f.read(), np.uint8))
        data = np.concatenate(blobs) if blobs else np.zeros((1,), np.uint8)
        S = shape.seq_len
        n_rows = max(len(data) // (S + 1), 1)
        reps = -(-n_rows * (S + 1) // len(data))
        data = np.tile(data, max(reps, 1))[:n_rows * (S + 1)]
        self.rows = data.reshape(n_rows, S + 1).astype(np.int32) % \
            cfg.vocab_size

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, len(self.rows), size=(self.shape.global_batch,))
        rows = self.rows[idx]
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


class ShardedLoader:
    """Host-sharded, prefetching iterator over a deterministic source."""

    def __init__(self, source, *, start_step: int = 0, prefetch: int = 2,
                 host_count: Optional[int] = None,
                 host_id: Optional[int] = None):
        self.source = source
        self.host_count = host_count or jax.process_count()
        self.host_id = host_id if host_id is not None else jax.process_index()
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _shard(self, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = {}
        for k, v in batch.items():
            if k == "positions":           # (3, B, S) — shard dim 1
                b = v.shape[1] // self.host_count
                out[k] = v[:, self.host_id * b:(self.host_id + 1) * b]
            else:
                b = v.shape[0] // self.host_count
                out[k] = v[self.host_id * b:(self.host_id + 1) * b]
        return out

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._shard(self.source.batch(step))
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
