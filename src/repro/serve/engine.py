"""Slot-level continuous-batching serving engine (DESIGN.md §11).

The engine owns ``slots`` independent decode slots, each holding its own
KV cache (batch dim 1) and position; every engine step it (1) admits
arrived requests into free slots — *while other slots are mid-decode*,
no wave draining — running each admission's prefill under its
planner-resolved ``ExecutionPlan`` (per-layer modes dispatched through
``kernels.ops.attention_by_plan``, heterogeneous plans included), then
(2) advances every already-active slot by one token, and (3) recycles a
slot the moment its request's token budget is spent — a short request
never pads out to a long neighbour's length.

Decode is *batched* (DESIGN.md §15): active slots' caches live in a
paged K/V pool (``repro.serve.kv_cache.PagedKVCache``), and each step
the engine groups slots into shape buckets (equal KV length ⇒ equal
cache shape and position counter), gathers each bucket into one packed
cache and advances it with a single ``decode_step`` call —
``decode_batches`` counts those calls while ``decode_calls`` keeps
counting per-slot token advances, so ``decode_calls /
decode_batches`` is the dispatch amplification the batching removes.
Cache trees the pool cannot page (SSM / hybrid / MLA / enc-dec state,
or mesh-sharded serving) transparently fall back to the per-slot B=1
path with identical semantics.

The step timeline is the *shared* deterministic schedule
(``repro.serve.schedule.build_schedule``), the same object
``repro.sim.simulate_serve`` lowers through the cycle-approximate
simulator — so the simulator reproduces this engine's per-request decode
step counts exactly, and each decode step's ``DecodePlan``
(``repro.plan.plan_decode_step``) carries the predicted HBM bytes the
simulator cross-asserts.

Plans are compiled on admission from a bounded LRU cache
(``plan_cache_size``); the queue is a ``collections.deque`` — long-running
servers neither re-scan the queue per admission nor grow the plan cache
without limit.  The legacy ``mode=`` kwarg remains as a deprecation shim
that bypasses the planner.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.types import ExecutionMode, ModelConfig
from repro.obs.metrics import (METRICS_SCHEMA_VERSION, MetricsRegistry,
                               RequestSpan, observe_spans, spans_from_steps,
                               spans_from_timeline, summarize_spans)
from repro.serve.kv_cache import PagedKVCache, shape_buckets
from repro.serve.schedule import Schedule, ServeRequest, build_schedule


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    arrival_step: int = 0         # engine step the request becomes visible
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """What one engine step actually executed (the engine-side half of
    the engine==simulator agreement tests)."""

    step: int
    admitted: Tuple[int, ...]            # rids prefilled
    decoded: Tuple[int, ...]             # rids advanced one token
    kv_lens: Tuple[int, ...]             # per decoded slot: attended KV len
    decode_plan: Optional[object] = None  # the step's DecodePlan (or None)
    # Shape buckets the step's decode actually dispatched: (kv_len, rids)
    # per batched decode_step call; None on the per-slot fallback path.
    buckets: Optional[Tuple[Tuple[int, Tuple[int, ...]], ...]] = None


class _LRU:
    """Tiny bounded LRU mapping (OrderedDict-backed)."""

    def __init__(self, maxsize: int) -> None:
        self.maxsize = max(int(maxsize), 1)
        self._d: "OrderedDict[Any, Any]" = OrderedDict()

    def get(self, key):
        if key not in self._d:
            return None
        self._d.move_to_end(key)
        return self._d[key]

    def put(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512,
                 plan=None,
                 plan_cache_size: int = 32,
                 plan_decode: bool = True,
                 mode: Optional[ExecutionMode] = None,
                 mesh=None,
                 batch_decode: bool = True,
                 page_size: int = 64,
                 clock=time.perf_counter):
        """``plan``: an ``repro.plan.ExecutionPlan`` to serve under (pins
        every admission); default: re-plan per admitted prompt length from
        a bounded LRU cache.  Prefill plans and per-step ``DecodePlan``s
        each get their own LRU of ``plan_cache_size`` entries (up to 2x
        ``plan_cache_size`` plans total).  ``plan_decode=False`` skips
        per-step
        ``DecodePlan`` compilation (pure-throughput serving; step records
        then carry no plan).  ``mode``: deprecated explicit override
        (pre-PR-2 API) — skips the planner entirely.  ``mesh``: a jax
        mesh (``launch.mesh`` builders); prefill/decode then run under
        ``shard_map`` with replicated specs (``repro.shard.serve``,
        DESIGN.md §13) — numerics identical to the mesh-less path.
        ``batch_decode``: group equal-KV-length slots into one
        ``decode_step`` call through a paged K/V pool of ``page_size``
        positions per page (DESIGN.md §15); auto-falls back per slot
        for cache trees the pool cannot page and under ``mesh``.
        ``clock``: wall-time source (``time.perf_counter``-compatible)
        for the ``"wall"`` SLO stats — injectable so tests can pin
        percentiles deterministically."""
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.plan = plan
        self.plan_decode = plan_decode
        self._forced_mode = mode
        self._plan_cache = _LRU(plan_cache_size)
        # Decode plans get their own bound: their keys (kv-length tuples)
        # change almost every step, and sharing one LRU would let that
        # churn evict the highly-reusable per-prompt-length prefill plans.
        self._decode_plan_cache = _LRU(plan_cache_size)
        self.mod = registry.model_module(cfg)
        self._prefill_takes_plan = (
            hasattr(self.mod, "prefill")
            and "plan" in inspect.signature(self.mod.prefill).parameters)
        self.mesh = mesh
        if mesh is not None:
            from repro.shard.serve import mesh_decode_fn
            self._decode = mesh_decode_fn(self.mod, cfg, mesh)
        else:
            self._decode = jax.jit(
                lambda p, c, t: self.mod.decode_step(p, cfg, c, t))
        # Batched decode: mesh serving keeps per-slot B=1 calls (the
        # shard_map decode fn is traced for that shape); otherwise the
        # first admission decides — if its cache tree pages, the run
        # serves through the pool, else it falls back per slot.
        self.batch_decode = batch_decode and mesh is None
        self.page_size = page_size
        self._pool: Optional[PagedKVCache] = None
        self._clock = clock
        self._queue: deque = deque()
        self.step_log: List[StepRecord] = []
        self.decode_calls = 0         # per-slot token advances
        self.decode_batches = 0       # actual decode_step invocations
        self.last_schedule: Optional[Schedule] = None
        # Observability (DESIGN.md §12): per-run lifecycle bookkeeping.
        self.registry = MetricsRegistry()
        self._arrivals: Dict[int, int] = {}
        self._step_walls: Dict[int, Tuple[float, float]] = {}
        self._prefill_wall_end: Dict[int, float] = {}

    def submit(self, req: Request) -> None:
        # The cache peaks at prompt + max_new - 1 entries (the last
        # emitted token is never written back).
        if len(req.prompt) + req.max_new_tokens - 1 > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) - 1 exceeds the "
                f"engine's max_len ({self.max_len})")
        req.out_tokens = []
        self._queue.append(req)

    # ------------------------------------------------------------------
    # Planning
    # ------------------------------------------------------------------

    def plan_for(self, seq_len: int):
        """The ``ExecutionPlan`` governing an admission of prompt length
        ``seq_len`` (bounded-LRU cached per length).  A construction-time
        ``plan=`` wins; attention-free families have nothing to plan
        (None)."""
        if self.plan is not None:
            return self.plan
        if self.cfg.num_heads == 0:
            return None
        plan = self._plan_cache.get(seq_len)
        if plan is None:
            from repro.plan import plan_model
            plan = plan_model(self.cfg, seq_len=seq_len)
            self._plan_cache.put(seq_len, plan)
        return plan

    def decode_plan_for(self, kv_lens: Tuple[int, ...]):
        """The ``DecodePlan`` for one step whose active slots attend
        ``kv_lens`` (bounded-LRU cached per length tuple)."""
        if not self.plan_decode or self.cfg.num_heads == 0:
            return None
        key = tuple(kv_lens)
        dp = self._decode_plan_cache.get(key)
        if dp is None:
            from repro.plan import plan_decode_step
            # The deprecated mode= override bypasses the planner for
            # prefill; decode plans must honor it too, or step records
            # would contradict the mode the engine claims to serve under.
            dp = plan_decode_step(self.cfg, key, mode=self._forced_mode,
                                  force_mode=self._forced_mode is not None)
            self._decode_plan_cache.put(key, dp)
        return dp

    def mode_for(self, seq_len: int) -> ExecutionMode:
        """Planner-resolved prefill mode summary for one admission.
        Heterogeneous plans no longer collapse to this — prefill
        dispatches per layer (``prefill(plan=...)``); this accessor
        reports the uniform mode (or the first layer's, for
        heterogeneous plans) for logging and legacy callers."""
        if self._forced_mode is not None:       # deprecated explicit override
            return self._forced_mode
        plan = self.plan_for(seq_len)
        if plan is None or not plan.layers:
            return self.cfg.execution_mode
        return plan.uniform_mode or plan.layers[0].mode

    # ------------------------------------------------------------------
    # Execution
    # ------------------------------------------------------------------

    def _prefill_one(self, req: Request):
        """Prefill one request into a fresh slot cache (B=1, unpadded —
        per-request numerics never depend on the neighbours)."""
        toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None, :])
        plan = self.plan_for(len(req.prompt))
        kwargs: Dict[str, Any] = {}
        if self._forced_mode is not None:
            kwargs["mode"] = self._forced_mode
        elif plan is not None and self._prefill_takes_plan:
            kwargs["plan"] = plan
        else:
            kwargs["mode"] = self.mode_for(len(req.prompt))
        if self.mesh is not None:
            from repro.shard.serve import mesh_prefill
            logits, cache = mesh_prefill(
                self.mod, self.params, self.cfg, {"tokens": toks},
                mesh=self.mesh, max_len=self.max_len, **kwargs)
        else:
            logits, cache = self.mod.prefill(
                self.params, self.cfg, {"tokens": toks},
                max_len=self.max_len, **kwargs)
        return logits[:, -1], cache

    def run(self, *, greedy: bool = True) -> List[Request]:
        """Drain the queue under the continuous-batching schedule;
        returns completed requests in completion order.

        Every step admits into any free slot (other slots keep decoding),
        decodes each active slot once, and recycles finished slots
        immediately — a request with ``n`` output tokens consumes exactly
        ``n - 1`` decode steps regardless of its neighbours.
        """
        del greedy                              # argmax sampling only
        reqs = list(self._queue)
        self._queue.clear()
        schedule = build_schedule(
            [ServeRequest(r.rid, len(r.prompt), r.max_new_tokens,
                          r.arrival_step) for r in reqs],
            self.slots)
        self.last_schedule = schedule
        by_rid = {r.rid: r for r in reqs}
        slot_state: Dict[int, Dict[str, Any]] = {}
        rid_slot: Dict[int, int] = {}
        done: List[Request] = []
        self.step_log = []
        self.decode_calls = 0
        self.decode_batches = 0
        self._pool = None
        batched = self.batch_decode
        self.registry = MetricsRegistry()
        self._arrivals = {r.rid: r.arrival_step for r in reqs}
        self._step_walls = {}
        self._prefill_wall_end = {}
        V = self.cfg.vocab_size
        for st in schedule.steps:
            wall0 = self._clock()
            for slot, rid in st.admitted:
                r = by_rid[rid]
                last_logits, cache = self._prefill_one(r)
                tok = jnp.argmax(last_logits[:, :V], axis=-1)[:, None]
                r.out_tokens.append(int(tok[0, 0]))
                # Token #1 just materialized: the wall-clock TTFT mark.
                self._prefill_wall_end[rid] = self._clock()
                if batched and self._pool is None:
                    # First admission decides for the run: page the pool
                    # or fall back per slot (SSM/MLA/hybrid/enc-dec
                    # trees — every later cache shares the config).
                    if PagedKVCache.supports(cache):
                        self._pool = PagedKVCache.from_cache(
                            cache, slots=self.slots,
                            page_size=self.page_size)
                    else:
                        batched = False
                if self._pool is not None:
                    self._pool.admit(slot, cache)
                    cache = None          # the pool owns the K/V now
                slot_state[slot] = {"req": r, "cache": cache, "tok": tok}
                rid_slot[rid] = slot
            dp = None
            step_buckets = None
            if st.decoding:
                kv_lens = tuple(kv for _, _, kv in st.decoding)
                dp = self.decode_plan_for(kv_lens)
                if self._pool is not None:
                    step_buckets = self._decode_buckets(st, kv_lens,
                                                        slot_state, V)
                else:
                    for slot, rid, _kv in st.decoding:
                        ss = slot_state[slot]
                        logits, ss["cache"] = self._decode(
                            self.params, ss["cache"], ss["tok"])
                        self.decode_calls += 1
                        self.decode_batches += 1
                        tok = jnp.argmax(logits[:, 0, :V], axis=-1)[:, None]
                        ss["tok"] = tok
                        ss["req"].out_tokens.append(int(tok[0, 0]))
            self.step_log.append(StepRecord(
                step=st.step,
                admitted=tuple(r for _, r in st.admitted),
                decoded=tuple(r for _, r, _ in st.decoding),
                kv_lens=tuple(kv for _, _, kv in st.decoding),
                decode_plan=dp,
                buckets=step_buckets))
            self._step_walls[st.step] = (wall0, self._clock())
            for rid in st.finished:
                done.append(by_rid[rid])
                slot = rid_slot.pop(rid)
                if self._pool is not None:
                    self._pool.free(slot)               # recycle the pages
                del slot_state[slot]                    # recycle the slot
        self.registry.counter("steps").inc(len(self.step_log))
        self.registry.counter("decode_calls").inc(self.decode_calls)
        observe_spans(self.registry, self.request_spans, "steps.")
        observe_spans(self.registry, self.wall_spans, "wall.")
        return done

    def decode_wall_s(self) -> float:
        """Wall seconds spent in pure-decode steps (steps that also
        prefilled are excluded, so prefill wall never pollutes the
        decode-phase number).  The denominator for decode throughput:
        batching cuts dispatch here, while prefill cost is identical on
        both paths and dominates short-generation end-to-end walls."""
        total = 0.0
        for rec in self.step_log:
            if rec.decoded and not rec.admitted:
                bounds = self._step_walls.get(rec.step)
                if bounds is not None:
                    total += bounds[1] - bounds[0]
        return total

    def _decode_buckets(self, st, kv_lens, slot_state, V):
        """Advance one step's active slots bucket-by-bucket through the
        paged pool; returns the (kv_len, rids) buckets dispatched."""
        out = []
        for kv, positions in shape_buckets(kv_lens):
            slots = [st.decoding[p][0] for p in positions]
            rids = tuple(st.decoding[p][1] for p in positions)
            # Bucket invariant: equal schedule KV length <=> equal cache
            # position counter (kv counts the token being decoded, the
            # cache holds everything before it).
            for s in slots:
                if self._pool.len_of(s) + 1 != kv:
                    raise RuntimeError(
                        f"slot {s}: cache len {self._pool.len_of(s)} "
                        f"inconsistent with scheduled kv {kv}")
            cache = self._pool.gather(slots)
            toks = jnp.concatenate(
                [slot_state[s]["tok"] for s in slots], axis=0)
            logits, cache = self._decode(self.params, cache, toks)
            self._pool.scatter(slots, cache)
            self.decode_batches += 1
            self.decode_calls += len(slots)
            tok = jnp.argmax(logits[:, 0, :V], axis=-1)[:, None]
            tok_np = np.asarray(tok)
            for i, s in enumerate(slots):
                slot_state[s]["tok"] = tok[i:i + 1]
                slot_state[s]["req"].out_tokens.append(int(tok_np[i, 0]))
            out.append((kv, rids))
        return tuple(out)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def plan_cache_len(self) -> int:
        return len(self._plan_cache)

    @property
    def request_spans(self) -> List[RequestSpan]:
        """Step-domain lifecycle spans derived from the *executed*
        ``step_log`` — the engine-side half of the serving-metrics parity
        check (``obs.metrics.assert_serve_parity``, DESIGN.md §12)."""
        return spans_from_steps(self.step_log, self._arrivals)

    @property
    def wall_spans(self) -> List[RequestSpan]:
        """Wall-clock lifecycle spans (seconds) from the per-step
        timestamps the last ``run`` recorded: first token at the instant
        each admission's prefill materialized token #1, finish at the end
        of the request's last step."""
        if not self._step_walls:
            return []
        admit: Dict[int, int] = {}
        last: Dict[int, int] = {}
        decodes: Dict[int, int] = {}
        for rec in self.step_log:
            for rid in rec.admitted:
                admit[rid] = rec.step
                last[rid] = rec.step
                decodes.setdefault(rid, 0)
            for rid in rec.decoded:
                decodes[rid] = decodes.get(rid, 0) + 1
                last[rid] = rec.step
        return spans_from_timeline(admit, last, decodes, self._arrivals,
                                   self._step_walls,
                                   self._prefill_wall_end, unit="seconds")

    def stats(self) -> Dict[str, object]:
        """Summary of the last ``run``: step count, per-request decode
        steps, admission/finish steps, plus the serving SLO summaries —
        step-domain TTFT/TPOT/queue-delay/e2e p50/p95/p99 at the top
        level (directly comparable with ``ServeSimResult.metrics`` via
        ``obs.metrics.assert_serve_parity``), wall-clock summaries under
        ``"wall"``, and the raw registry under ``"metrics"``.

        Step and decode counts are derived from ``step_log`` — what the
        engine *executed* — not from the schedule it planned to execute,
        so an execution bug cannot hide behind a correct schedule (the
        simulator lowers the same schedule; comparing executed-vs-sim is
        the meaningful check).  Before any ``run`` — or after a
        zero-request run — every field is a well-defined zero/empty,
        never a division error."""
        s = self.last_schedule
        decode_steps: Dict[int, int] = {
            rid: 0 for rid in (s.decode_steps if s is not None else {})}
        for rec in self.step_log:
            for rid in rec.decoded:
                decode_steps[rid] = decode_steps.get(rid, 0) + 1
        out: Dict[str, object] = {
            "schema_version": METRICS_SCHEMA_VERSION,
            "steps": len(self.step_log),
            "decode_steps": decode_steps,
            "admit_step": dict(s.admit_step) if s is not None else {},
            "finish_step": dict(s.finish_step) if s is not None else {},
            "decode_calls": self.decode_calls,
            "decode_batches": self.decode_batches,
            "max_concurrency": max(
                (len(r.admitted) + len(r.decoded) for r in self.step_log),
                default=0),
            "plan_cache_len": self.plan_cache_len,
        }
        out.update(summarize_spans(self.request_spans, unit="steps"))
        out["wall"] = summarize_spans(self.wall_spans, unit="seconds")
        out["metrics"] = self.registry.to_dict()
        return out
