"""Batched serving engine: request queue -> slot-based continuous batching.

The engine owns a fixed decode batch of ``slots``; requests are admitted
into free slots (prompt prefilled into that slot's cache region), every
``decode_step`` advances all active slots by one token, finished slots are
recycled.  Prefill runs the planner-resolved execution mode (TILE_STREAM
cross-forwarding where profitable); decode is the cached path.

Mode resolution (PR 2): the engine consumes an ``repro.plan.ExecutionPlan``
— pass ``plan=`` to pin one, or let the engine call ``plan_model`` per
admitted wave's padded prompt length, so the StreamDCIM reconfiguration
decision tracks each batch's actual shape instead of being frozen at
construction (DESIGN.md §8).  The legacy ``mode=`` kwarg remains as a
deprecation shim that bypasses the planner.

Single-host reference implementation (examples/serve_batch.py); the sharded
variant jits prefill/decode with the same shardings as launch/dryrun.py
decode cells.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.types import ExecutionMode, ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512,
                 plan=None,
                 mode: Optional[ExecutionMode] = None):
        """``plan``: an ``repro.plan.ExecutionPlan`` to serve under (its
        resolved mode is used for every wave); default: re-plan per wave
        shape.  ``mode``: deprecated explicit override (pre-PR-2 API) —
        skips the planner entirely."""
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.plan = plan
        self._forced_mode = mode
        self._plan_cache: Dict[int, Any] = {}
        self.mod = registry.model_module(cfg)
        self._decode = jax.jit(
            lambda p, c, t: self.mod.decode_step(p, cfg, c, t))
        self._queue: List[Request] = []
        self._active: Dict[int, Request] = {}
        self._remaining: Dict[int, int] = {}

    def submit(self, req: Request) -> None:
        req.out_tokens = []
        self._queue.append(req)

    def plan_for(self, seq_len: int):
        """The ``ExecutionPlan`` governing a wave of padded prompt length
        ``seq_len`` (cached per length).  A construction-time ``plan=``
        wins; attention-free families have nothing to plan (None)."""
        if self.plan is not None:
            return self.plan
        if self.cfg.num_heads == 0:
            return None
        if seq_len not in self._plan_cache:
            from repro.plan import plan_model
            self._plan_cache[seq_len] = plan_model(self.cfg,
                                                   seq_len=seq_len)
        return self._plan_cache[seq_len]

    def mode_for(self, seq_len: int) -> ExecutionMode:
        """Planner-resolved prefill mode for one wave (decoder plans are
        uniform across layers; heterogeneous plans use the first layer's
        mode until per-layer prefill dispatch lands — ROADMAP)."""
        if self._forced_mode is not None:       # deprecated explicit override
            return self._forced_mode
        plan = self.plan_for(seq_len)
        if plan is None or not plan.layers:
            return self.cfg.execution_mode
        return plan.uniform_mode or plan.layers[0].mode

    def _prefill_batch(self, reqs: List[Request]):
        """Pad prompts to a common length, prefill, return caches+logits."""
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad
        logits, cache = self.mod.prefill(
            self.params, self.cfg, {"tokens": jnp.asarray(toks)},
            max_len=self.max_len, mode=self.mode_for(S))
        return logits[:, -1], cache

    def run(self, *, greedy: bool = True) -> List[Request]:
        """Drain the queue; returns completed requests.

        Simplification vs vLLM-grade engines: admission happens in waves of
        up to ``slots`` requests (cache slot re-packing between waves is a
        gather over the batch dim).
        """
        done: List[Request] = []
        while self._queue:
            wave = [self._queue.pop(0)
                    for _ in range(min(self.slots, len(self._queue)))]
            last_logits, cache = self._prefill_batch(wave)
            next_tok = jnp.argmax(
                last_logits[:, :self.cfg.vocab_size], axis=-1)[:, None]
            remaining = np.array([r.max_new_tokens for r in wave])
            for i, r in enumerate(wave):
                r.out_tokens.append(int(next_tok[i, 0]))
            steps = int(remaining.max()) - 1
            for _ in range(max(steps, 0)):
                logits, cache = self._decode(self.params, cache, next_tok)
                next_tok = jnp.argmax(
                    logits[:, 0, :self.cfg.vocab_size], axis=-1)[:, None]
                remaining -= 1
                for i, r in enumerate(wave):
                    if remaining[i] > 0:
                        r.out_tokens.append(int(next_tok[i, 0]))
            done.extend(wave)
        return done
