"""Paged/packed KV-cache pool for batched decode (DESIGN.md §15).

The engine's per-slot caches are exact but dispatch-wasteful: at 64+
concurrent slots, 64 B=1 ``decode_step`` calls per step dominate
tokens/s.  This module gives the engine a *paged* layout so equal-shape
slots share one call:

* one page pool per cache side (K and V), page-major:
  ``(num_pages, layers, kv_heads, page_size, head_dim)`` — a page holds
  ``page_size`` consecutive cache positions of one slot across every
  layer;
* a per-slot page table (position-ordered page ids) plus the slot's
  valid length; pages allocate on demand as the cache grows and return
  to the free list when the slot recycles;
* ``gather`` packs a *shape bucket* — slots with equal KV length, found
  by ``shape_buckets`` — into one batched cache
  ``{"layers": {"k": (L, B, Hkv, W, hd), ...}, "len": scalar}`` that
  ``decode_step`` advances in a single call; ``scatter`` writes the
  updated buffers back through the page tables (allocating the page a
  growth step crosses into).

Gather→compute→scatter round-trips are value-exact (pages are plain
slices), so the batched path's numerics reduce to ``decode_step``'s own
row independence — pinned by the batched-vs-B=1 parity tests.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def shape_buckets(kv_lens: Sequence[int]
                  ) -> List[Tuple[int, Tuple[int, ...]]]:
    """Group slot positions by KV length, order-preserving.

    Returns ``[(kv_len, positions), ...]`` where ``positions`` index into
    ``kv_lens``; buckets appear in order of their first member, members
    keep their relative order.  Slots in one bucket share cache shape
    *and* position counter, so one ``decode_step`` call advances them
    all.
    """
    order: List[int] = []
    members: Dict[int, List[int]] = {}
    for i, kv in enumerate(kv_lens):
        kv = int(kv)
        if kv < 1:
            raise ValueError(f"kv_lens must be >= 1, got {kv_lens!r}")
        if kv not in members:
            members[kv] = []
            order.append(kv)
        members[kv].append(i)
    return [(kv, tuple(members[kv])) for kv in order]


@dataclasses.dataclass
class _SlotEntry:
    pages: List[int]          # position-ordered page ids
    length: int               # valid cache entries (== cache["len"])


class PagedKVCache:
    """Demand-paged K/V pool for one engine's decode slots.

    Built lazily from the first admitted cache (``from_cache``): the pool
    only supports the plain per-layer ``{"k", "v"}`` cache tree the
    unified transformer uses — families with richer state (SSM / MLA /
    hybrid) stay on the engine's per-slot fallback.
    """

    def __init__(self, *, slots: int, num_layers: int, kv_heads: int,
                 width: int, head_dim: int, dtype,
                 page_size: int = 64) -> None:
        if slots < 1 or width < 1:
            raise ValueError(f"slots ({slots}) and width ({width}) must "
                             "be >= 1")
        self.slots = slots
        self.num_layers = num_layers
        self.kv_heads = kv_heads
        self.width = width                     # per-slot cache positions
        self.head_dim = head_dim
        self.page_size = min(int(page_size), width)
        self.pages_per_slot = -(-width // self.page_size)
        self.num_pages = slots * self.pages_per_slot
        shape = (self.num_pages, num_layers, kv_heads, self.page_size,
                 head_dim)
        self._k_pool = jnp.zeros(shape, dtype)
        self._v_pool = jnp.zeros(shape, dtype)
        self._free: deque = deque(range(self.num_pages))
        self._table: Dict[int, _SlotEntry] = {}

    # ------------------------------------------------------------------
    # Construction / introspection
    # ------------------------------------------------------------------

    @staticmethod
    def supports(cache) -> bool:
        """True iff ``cache`` is the plain stacked-KV tree this pool
        pages (``{"layers": {"k", "v"}, "len"}`` with B == 1 leaves)."""
        try:
            layers = cache["layers"]
        except (TypeError, KeyError):
            return False
        if not isinstance(layers, dict) or set(layers) != {"k", "v"}:
            return False
        k = layers["k"]
        return getattr(k, "ndim", 0) == 5 and k.shape[1] == 1

    @classmethod
    def from_cache(cls, cache, *, slots: int,
                   page_size: int = 64) -> "PagedKVCache":
        """Size a pool from one admitted B=1 cache's leaf shapes."""
        k = cache["layers"]["k"]               # (L, 1, Hkv, W, hd)
        L, _, Hkv, W, hd = k.shape
        return cls(slots=slots, num_layers=L, kv_heads=Hkv, width=W,
                   head_dim=hd, dtype=k.dtype, page_size=page_size)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def len_of(self, slot: int) -> int:
        return self._table[slot].length

    def page_table(self, slot: int) -> Tuple[int, ...]:
        return tuple(self._table[slot].pages)

    def _occupied(self, length: int) -> int:
        """Cache positions holding live entries at ``length`` — the ring
        buffer (sliding-window W < max context) caps at the full width
        once wrapped."""
        return min(length, self.width)

    def _pages_for(self, length: int) -> int:
        return -(-self._occupied(length) // self.page_size) if length else 0

    def _alloc(self, entry: _SlotEntry, length: int) -> None:
        need = self._pages_for(length)
        while len(entry.pages) < need:
            if not self._free:
                raise RuntimeError("paged KV pool exhausted (page leak?)")
            entry.pages.append(self._free.popleft())

    # ------------------------------------------------------------------
    # Slot lifecycle
    # ------------------------------------------------------------------

    def admit(self, slot: int, cache) -> None:
        """Page in one freshly prefilled B=1 cache for ``slot``."""
        if slot in self._table:
            raise ValueError(f"slot {slot} already admitted")
        if not self.supports(cache):
            raise ValueError("cache tree is not the plain {'k','v'} "
                             "layout this pool pages")
        entry = _SlotEntry(pages=[], length=int(cache["len"]))
        self._alloc(entry, entry.length)
        self._table[slot] = entry
        if entry.pages:
            self._write(entry, cache["layers"]["k"][:, 0],
                        cache["layers"]["v"][:, 0])

    def free(self, slot: int) -> None:
        """Recycle a finished slot's pages back to the pool."""
        entry = self._table.pop(slot)
        self._free.extend(entry.pages)

    # ------------------------------------------------------------------
    # Bucket gather / scatter
    # ------------------------------------------------------------------

    def gather(self, slot_ids: Sequence[int]):
        """Pack one shape bucket into a batched decode cache.

        All slots must hold equal lengths (equal length <=> equal
        position counter <=> one shared RoPE position — the bucket
        invariant).  Returns ``{"layers": {"k": (L, B, Hkv, W, hd),
        "v": ...}, "len": scalar}`` ready for one ``decode_step`` call.
        """
        entries = [self._table[s] for s in slot_ids]
        lens = {e.length for e in entries}
        if len(lens) != 1:
            raise ValueError(f"bucket slots {list(slot_ids)} hold unequal "
                             f"lengths {sorted(lens)}")
        length = entries[0].length
        B = len(entries)
        npg = self._pages_for(length)
        if npg == 0:
            k = jnp.zeros((self.num_layers, B, self.kv_heads, self.width,
                           self.head_dim), self._k_pool.dtype)
            return {"layers": {"k": k, "v": k},
                    "len": jnp.asarray(length, jnp.int32)}
        ids = np.asarray([e.pages[:npg] for e in entries], np.int32)

        def pack(pool):
            pages = jnp.take(pool, ids.reshape(-1), axis=0)
            pages = pages.reshape(B, npg, self.num_layers, self.kv_heads,
                                  self.page_size, self.head_dim)
            dense = jnp.moveaxis(pages, 1, 3)      # (B, L, Hkv, npg, pg, hd)
            dense = dense.reshape(B, self.num_layers, self.kv_heads,
                                  npg * self.page_size, self.head_dim)
            dense = jnp.moveaxis(dense, 0, 1)      # (L, B, Hkv, S, hd)
            S = npg * self.page_size
            if S < self.width:
                dense = jnp.pad(
                    dense, ((0, 0), (0, 0), (0, 0),
                            (0, self.width - S), (0, 0)))
            return dense[:, :, :, :self.width]

        return {"layers": {"k": pack(self._k_pool),
                           "v": pack(self._v_pool)},
                "len": jnp.asarray(length, jnp.int32)}

    def scatter(self, slot_ids: Sequence[int], cache) -> None:
        """Write one advanced bucket cache back through the page tables,
        allocating the page each slot's growth step crossed into.

        One ``.at[ids].set`` per pool for the whole bucket (equal lengths
        => equal page counts) — a per-slot write-back loop would cost as
        many eager dispatches as the batching saved.
        """
        new_len = int(cache["len"])
        entries = [self._table[s] for s in slot_ids]
        for e in entries:
            if new_len < e.length:
                raise ValueError("scatter would shrink a slot's cache")
            self._alloc(e, new_len)
            e.length = new_len
        npg = self._pages_for(new_len)
        if npg == 0:
            return
        B = len(entries)
        S = npg * self.page_size
        ids = np.asarray([e.pages[:npg] for e in entries],
                         np.int32).reshape(-1)

        def unpack(dense):
            dense = jnp.moveaxis(dense, 1, 0)  # (B, L, Hkv, W, hd)
            if S > self.width:
                dense = jnp.pad(
                    dense, ((0, 0), (0, 0), (0, 0),
                            (0, S - self.width), (0, 0)))
            pages = dense[:, :, :, :S].reshape(
                B, self.num_layers, self.kv_heads, npg, self.page_size,
                self.head_dim)
            pages = jnp.moveaxis(pages, 3, 1)  # (B, npg, L, Hkv, pg, hd)
            return pages.reshape(B * npg, self.num_layers, self.kv_heads,
                                 self.page_size, self.head_dim)

        self._k_pool = self._k_pool.at[ids].set(unpack(cache["layers"]["k"]))
        self._v_pool = self._v_pool.at[ids].set(unpack(cache["layers"]["v"]))

    def _write(self, entry: _SlotEntry, k, v) -> None:
        """Page out one slot's dense (L, Hkv, W, hd) buffers."""
        npg = len(entry.pages)
        if npg == 0:
            return
        S = npg * self.page_size
        if S > self.width:
            pad = ((0, 0), (0, 0), (0, S - self.width), (0, 0))
            k = jnp.pad(k, pad)
            v = jnp.pad(v, pad)
        ids = np.asarray(entry.pages, np.int32)

        def unpack(dense):
            pages = dense[:, :, :S].reshape(
                self.num_layers, self.kv_heads, npg, self.page_size,
                self.head_dim)
            return jnp.moveaxis(pages, 2, 0)   # (npg, L, Hkv, pg, hd)

        self._k_pool = self._k_pool.at[ids].set(unpack(k))
        self._v_pool = self._v_pool.at[ids].set(unpack(v))
