"""The slot-level continuous-batching schedule (DESIGN.md §11).

``build_schedule`` is the *single* deterministic scheduling core shared by
the live engine (``repro.serve.Engine.run``) and the serving-timeline
simulator (``repro.sim.simulate_serve``): given the request trace
(arrival step, prompt length, token budget) and a slot count, it produces
the exact per-step record of admissions, decodes, and completions.
Because both consumers execute the *same* schedule object, the simulator
reproduces the engine's per-request decode step counts by construction —
and tests still verify it empirically against the engine's executed
steps.

Semantics, per engine step ``t``:

1. slots whose request finished at the end of step ``t-1`` are free
   (immediate recycling — a short request never pads out to a wave max);
2. queued requests with ``arrival_step <= t`` are admitted FIFO into free
   slots; an admission runs that request's *prefill*, which emits its
   first token;
3. every slot that was already active (NOT admitted this step) runs one
   *decode*, emitting one token; its ``kv_len`` — the KV length the step
   attends over, including the token being decoded — is
   ``prompt_len + tokens_generated_before_this_step``;
4. a request with ``n`` output tokens therefore takes exactly ``n - 1``
   decode steps, finishing the step its last token is emitted.

This module is dependency-light on purpose (no jax, no simulator): the
simulator imports it without dragging the model stack in, and the engine
without dragging the simulator in.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """The schedule-relevant shadow of a live ``serve.Request``."""

    rid: int
    prompt_len: int
    max_new_tokens: int
    arrival_step: int = 0

    def __post_init__(self):
        if self.prompt_len < 1:
            raise ValueError(f"request {self.rid}: prompt_len must be >= 1")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must "
                             "be >= 1")
        if self.arrival_step < 0:
            raise ValueError(f"request {self.rid}: arrival_step must "
                             "be >= 0")


@dataclasses.dataclass(frozen=True)
class ScheduleStep:
    """One engine step: who prefills, who decodes, who finishes."""

    step: int
    admitted: Tuple[Tuple[int, int], ...]        # (slot, rid)
    decoding: Tuple[Tuple[int, int, int], ...]   # (slot, rid, kv_len)
    finished: Tuple[int, ...]                    # rids done after this step


@dataclasses.dataclass(frozen=True)
class Schedule:
    """The full deterministic timeline for one request trace."""

    slots: int
    steps: Tuple[ScheduleStep, ...]
    admit_step: Dict[int, int]       # rid -> step its prefill ran
    finish_step: Dict[int, int]      # rid -> step its last token came out
    decode_steps: Dict[int, int]     # rid -> decode steps it consumed

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    def max_concurrency(self) -> int:
        """Peak number of slots busy in any one step."""
        return max((len(s.admitted) + len(s.decoding) for s in self.steps),
                   default=0)


def build_schedule(requests: Sequence[ServeRequest],
                   slots: int) -> Schedule:
    """Compute the continuous-batching timeline for ``requests``.

    Admission is FIFO over arrival order (ties broken by submission
    order); a request whose ``arrival_step`` is in the future never
    blocks an already-arrived one behind it.
    """
    if slots < 1:
        raise ValueError(f"slots must be >= 1, got {slots}")
    rids = [r.rid for r in requests]
    if len(set(rids)) != len(rids):
        raise ValueError(f"duplicate request ids in trace: {rids}")
    queue = deque(sorted(requests,
                         key=lambda r: r.arrival_step))  # stable: FIFO ties
    # slot -> [request, generated_tokens]
    active: Dict[int, List[object]] = {}
    steps: List[ScheduleStep] = []
    admit_step: Dict[int, int] = {}
    finish_step: Dict[int, int] = {}
    decode_steps: Dict[int, int] = {}
    t = 0
    while queue or active:
        admitted: List[Tuple[int, int]] = []
        free = deque(s for s in range(slots) if s not in active)
        while free and queue and queue[0].arrival_step <= t:
            r = queue.popleft()
            s = free.popleft()
            active[s] = [r, 1]                   # prefill emits token #1
            admitted.append((s, r.rid))
            admit_step[r.rid] = t
            decode_steps[r.rid] = 0
        admitted_slots = {s for s, _ in admitted}
        decoding: List[Tuple[int, int, int]] = []
        for s in sorted(active):
            if s in admitted_slots:
                continue                         # admission step: no decode
            r, generated = active[s]
            decoding.append((s, r.rid, r.prompt_len + generated))
            active[s][1] = generated + 1
            decode_steps[r.rid] += 1
        finished: List[int] = []
        for s in sorted(active):
            r, generated = active[s]
            if generated >= r.max_new_tokens:
                finished.append(r.rid)
                finish_step[r.rid] = t
        for s in [s for s, (r, _) in active.items()
                  if r.rid in finished]:
            del active[s]                        # recycled for step t+1
        steps.append(ScheduleStep(step=t, admitted=tuple(admitted),
                                  decoding=tuple(decoding),
                                  finished=tuple(finished)))
        if not admitted and not decoding and queue:
            # Idle gap before the next arrival: jump the clock (the
            # engine has nothing to run; recording empty steps would
            # inflate step counts with no-ops).
            steps.pop()
            t = min(r.arrival_step for r in queue)
            continue
        t += 1
    return Schedule(slots=slots, steps=tuple(steps),
                    admit_step=admit_step, finish_step=finish_step,
                    decode_steps=decode_steps)
