"""Causal critical-path analysis over event-DAG traces (DESIGN.md §14).

``repro.obs.attribution`` answers "where did the busy cycles go" — but
busy-share is not causality: a resource can be 90% busy yet entirely off
the chain that bounds the makespan.  Since every ``Event`` now carries
its predecessor task ids (data deps + the in-order resource-occupancy
predecessor, stamped by ``Engine.run``), any ``Trace`` is a scheduling
DAG with the invariant

    event.start == 0  or  event.start == max(end of its deps)

so the *critical path* — a chain of events tiling ``[0, makespan]`` with
no gaps — always exists and is found by a backward walk over "binding"
predecessors (a dep whose ``end`` equals the event's ``start``).

The report splits on-path cycles by base resource (``c3.ATTN`` folds to
``ATTN``, NoC links to ``INTERCONNECT`` — sharded traces work
unchanged), by op class, and by event kind, and separates *exposed*
rewrite cycles (rewrites occupying a compute resource — the §I stall)
from *overlapped* ones (rewrites riding the ping-pong shadow ``BUS``
that still end up rate-limiting, i.e. a rewrite-bandwidth-bound
pipeline).  On the §I micro-workload the serial trace puts exposed
rewrites on the path for exactly 4/7 of the makespan — the paper's 57%
— while the ping-pong trace has zero exposed rewrite cycles on path.

``slack`` is the classic CPM latitude: how many cycles an event could
slip, holding the DAG fixed, before it grows the makespan.  Critical
events have slack 0.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

from repro.obs.attribution import (COMPUTE_RESOURCES, INTERCONNECT,
                                   OVERLAP_RESOURCE, base_resource, op_class)

#: Slack histogram bin edges, as fractions of the makespan.
SLACK_BINS = (0.0, 0.01, 0.05, 0.25, 1.0)


@dataclasses.dataclass(frozen=True)
class CritPathReport:
    """The longest chain ending at the makespan, plus its attribution."""

    path: Tuple  # chronological Events tiling [0, makespan]
    makespan: int
    critical_by_resource: Dict[str, int]   # base resource -> on-path cycles
    critical_by_class: Dict[str, int]      # op class -> on-path cycles
    critical_by_kind: Dict[str, int]       # event kind -> on-path cycles
    exposed_rewrite_cycles: int            # on-path rewrites on compute res
    overlapped_rewrite_cycles: int         # on-path rewrites on shadow BUS
    slack: Dict[int, int]                  # task_id -> slack cycles
    slack_histogram: Tuple[Tuple[str, int], ...]

    @property
    def path_cycles(self) -> int:
        return sum(e.cycles for e in self.path)

    @property
    def exposed_rewrite_share(self) -> float:
        """Fraction of the makespan causally bound by exposed rewrites —
        the §I claim, stated on the critical path instead of busy
        cycles.  4/7 on the serial micro-workload; 0.0 under
        ping-pong."""
        return (self.exposed_rewrite_cycles / self.makespan
                if self.makespan else 0.0)

    def critical_share(self, resource: str) -> float:
        """Fraction of the makespan on-path on ``resource`` (base name)."""
        return (self.critical_by_resource.get(resource, 0) / self.makespan
                if self.makespan else 0.0)

    @property
    def interconnect_share(self) -> float:
        """On-path share of the NoC links — nonzero only when a sharded
        trace is genuinely interconnect-bound, unlike busy-share."""
        return self.critical_share(INTERCONNECT)

    def to_dict(self) -> Dict[str, object]:
        return {
            "makespan": self.makespan,
            "path_events": len(self.path),
            "critical_by_resource": dict(self.critical_by_resource),
            "critical_by_class": dict(self.critical_by_class),
            "critical_by_kind": dict(self.critical_by_kind),
            "exposed_rewrite_cycles": self.exposed_rewrite_cycles,
            "overlapped_rewrite_cycles": self.overlapped_rewrite_cycles,
            "exposed_rewrite_share": self.exposed_rewrite_share,
            "interconnect_share": self.interconnect_share,
            "slack_histogram": [list(b) for b in self.slack_histogram],
        }


def _binding_pred(event, by_id):
    """The dep this event actually waited on: ``end == event.start``.
    Deterministic tie-break toward the longest (then earliest-submitted)
    binding dep, so heavyweight chains surface over zero-cost ones."""
    best = None
    for d in event.deps:
        p = by_id.get(d)
        if p is None or p.end != event.start:
            continue
        if best is None or (p.cycles, -p.task_id) > (best.cycles,
                                                     -best.task_id):
            best = p
    return best


def critical_path(trace) -> CritPathReport:
    """Extract the critical path and its causal attribution.

    Backward walk from the event that realizes the makespan, repeatedly
    following a binding predecessor until an event starting at cycle 0.
    The DAG invariant guarantees the walk never strands: every event
    with ``start > 0`` has a binding dep, so the path intervals tile
    ``[0, makespan]`` contiguously and ``path_cycles == makespan``
    exactly (a tier-1 property test pins this for all three modes).
    """
    events = list(trace.events)
    if not events:
        return CritPathReport(
            path=(), makespan=0, critical_by_resource={},
            critical_by_class={}, critical_by_kind={},
            exposed_rewrite_cycles=0, overlapped_rewrite_cycles=0,
            slack={}, slack_histogram=_histogram({}, 0))
    by_id = {e.task_id: e for e in events}
    makespan = trace.makespan
    # Walk back from the (deterministically chosen) last-finishing event.
    cur = max(events, key=lambda e: (e.end, -e.task_id))
    path: List = [cur]
    while cur.start > 0:
        pred = _binding_pred(cur, by_id)
        if pred is None:   # defensive: externally constructed trace
            break
        path.append(pred)
        cur = pred
    path.reverse()

    by_res: Dict[str, int] = defaultdict(int)
    by_cls: Dict[str, int] = defaultdict(int)
    by_kind: Dict[str, int] = defaultdict(int)
    exposed = overlapped = 0
    for e in path:
        res = base_resource(e.resource)
        by_res[res] += e.cycles
        by_cls[op_class(e.op)] += e.cycles
        by_kind[e.kind] += e.cycles
        if e.kind == "rewrite":
            if res == OVERLAP_RESOURCE:
                overlapped += e.cycles
            elif res in COMPUTE_RESOURCES:
                exposed += e.cycles
            else:
                exposed += e.cycles   # rewrite on any non-shadow resource
    slack = compute_slack(events, makespan)
    return CritPathReport(
        path=tuple(path),
        makespan=makespan,
        critical_by_resource=dict(sorted(by_res.items())),
        critical_by_class=dict(sorted(by_cls.items())),
        critical_by_kind=dict(sorted(by_kind.items())),
        exposed_rewrite_cycles=exposed,
        overlapped_rewrite_cycles=overlapped,
        slack=slack,
        slack_histogram=_histogram(slack, makespan),
    )


def compute_slack(events: Sequence, makespan: int) -> Dict[int, int]:
    """Per-event slack: latest finish (CPM backward pass over the stamped
    DAG) minus actual finish.  Zero for every event on some critical
    chain."""
    succs: Dict[int, List] = defaultdict(list)
    for e in events:
        for d in e.deps:
            succs[d].append(e)
    latest: Dict[int, int] = {}
    # Task ids are topologically ordered (deps precede), so a reverse
    # sweep is a valid backward pass.
    for e in sorted(events, key=lambda e: -e.task_id):
        ss = succs.get(e.task_id)
        if not ss:
            latest[e.task_id] = makespan
        else:
            latest[e.task_id] = min(latest[s.task_id] - s.cycles
                                    for s in ss)
    return {e.task_id: latest[e.task_id] - e.end for e in events}


def _histogram(slack: Dict[int, int],
               makespan: int) -> Tuple[Tuple[str, int], ...]:
    """Bin slack values by fraction of makespan: a mostly-zero histogram
    means a tight chain (little latitude to reorder); a long tail means
    ample overlap headroom."""
    labels = ["=0"]
    for lo, hi in zip(SLACK_BINS[:-1], SLACK_BINS[1:]):
        labels.append(f"({lo:.0%},{hi:.0%}]")
    labels.append(f">{SLACK_BINS[-1]:.0%}")
    counts = [0] * len(labels)
    for s in slack.values():
        frac = s / makespan if makespan else 0.0
        if s == 0:
            counts[0] += 1
            continue
        for k, hi in enumerate(SLACK_BINS[1:], start=1):
            if frac <= hi:
                counts[k] += 1
                break
        else:
            counts[-1] += 1
    return tuple(zip(labels, counts))


def format_critpath(report: CritPathReport, *, title: str = "",
                    limit: int = 12) -> str:
    """Text rendering behind ``python -m repro.obs --critpath``."""
    lines: List[str] = []
    if title:
        lines.append(title)
    lines.append(f"critical path: {len(report.path)} events tiling "
                 f"{report.makespan} cycles")
    lines.append(f"exposed rewrite on path: "
                 f"{report.exposed_rewrite_cycles} cycles "
                 f"({report.exposed_rewrite_share:.1%} of makespan), "
                 f"overlapped rewrite on path: "
                 f"{report.overlapped_rewrite_cycles}")
    lines.append("")
    lines.append(f"{'resource':<13} {'on-path':>12} {'share':>7}")
    for r, c in sorted(report.critical_by_resource.items(),
                       key=lambda kv: -kv[1]):
        lines.append(f"{r:<13} {c:>12} {report.critical_share(r):>6.1%}")
    lines.append("")
    lines.append(f"{'op class':<13} {'on-path':>12}")
    for k, c in sorted(report.critical_by_class.items(),
                       key=lambda kv: -kv[1]):
        lines.append(f"{k:<13} {c:>12}")
    lines.append("")
    lines.append("slack histogram (events by slack/makespan):")
    for label, count in report.slack_histogram:
        lines.append(f"  {label:<10} {count:>8}")
    lines.append("")
    lines.append(f"head of path (first {limit}):")
    lines.append(f"  {'cycle':>10}  {'res':<9} {'kind':<8} tag")
    for e in report.path[:limit]:
        lines.append(f"  {e.start:>10}  {e.resource:<9} {e.kind:<8} {e.tag}")
    if len(report.path) > limit:
        lines.append(f"  ... ({len(report.path) - limit} more on path)")
    return "\n".join(lines)
