"""What-if projection: rescale event durations, recompute the DAG
schedule (DESIGN.md §14).

Because ``Engine.run`` is an in-order-per-resource list scheduler and
every event's stamped ``deps`` include its resource-occupancy
predecessor, replaying the events in task-id order with

    start = max(projected end of deps, resource free)

reconstructs the original schedule *exactly* when durations are
unchanged (``project`` with ``k=1`` is identity to the cycle — a tier-1
test pins this).  Rescaling durations before the replay therefore
projects "resource R k× faster" / "link bandwidth k×" without
re-simulating the workload — validated against full re-simulation
(``simulate_plan(calibration=...)``) on registry models within a pinned
tolerance; the residual is per-task integer rounding only, since issue
order is fixed by construction in both.

``whatif_ping_pong`` toggles the §II-C shadow sub-array:

* **off** (a ping-pong trace): overlapped rewrites are remapped from the
  shadow ``BUS`` onto the compute array they shadow, re-serializing them
  — exact on the §I micro-workload (projects the ping-pong trace onto
  the serial makespan to the cycle).
* **on** (a serial trace): exposed rewrites are scaled to zero cost —
  the *perfect-overlap bound*.  It is a lower bound on the achievable
  makespan: a real shadow bus still serializes rewrites against its own
  bandwidth (the §I ping-pong trace is rewrite-bandwidth-bound at
  77824 > the 49152 bound).  DESIGN.md §14 states this envelope.

``headroom`` runs the k→∞ projection per base resource: the fractional
makespan reduction if that resource were free.  Stamped on every DSE
``SweepRow`` so frontiers explain *why* a design wins.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from repro.obs.attribution import (COMPUTE_RESOURCES, ATTN_RESOURCE,
                                   INTERCONNECT, OVERLAP_RESOURCE,
                                   base_resource)


@dataclasses.dataclass(frozen=True)
class WhatIfProjection:
    """One projected scenario next to its baseline."""

    label: str
    baseline_makespan: int
    projected_makespan: float
    scales: Dict[str, float]

    @property
    def speedup(self) -> float:
        return (self.baseline_makespan / self.projected_makespan
                if self.projected_makespan else float("inf"))

    def to_dict(self) -> Dict[str, object]:
        return {"label": self.label,
                "baseline_makespan": self.baseline_makespan,
                "projected_makespan": self.projected_makespan,
                "speedup": self.speedup,
                "scales": dict(self.scales)}


def _replay(events, duration_of: Callable,
            resource_of: Optional[Callable] = None) -> float:
    """List-schedule replay over stamped-DAG events in task-id order.
    ``duration_of(event) -> float`` and optional ``resource_of(event)``
    let callers rescale and remap; returns the projected makespan."""
    free: Dict[str, float] = {}
    end: Dict[int, float] = {}
    makespan = 0.0
    for e in sorted(events, key=lambda e: e.task_id):
        res = resource_of(e) if resource_of is not None else e.resource
        start = max([end[d] for d in e.deps if d in end], default=0.0)
        start = max(start, free.get(res, 0.0))
        fin = start + duration_of(e)
        end[e.task_id] = fin
        free[res] = fin
        if fin > makespan:
            makespan = fin
    return makespan


def project(trace, scales: Mapping[str, float],
            label: str = "") -> WhatIfProjection:
    """Project the makespan with per-base-resource speed factors.

    ``scales`` maps base resource names (``ATTN``, ``HBM``,
    ``INTERCONNECT`` for all NoC links, ...) to a speed factor ``k``:
    every event on that resource takes ``cycles / k``.  ``k = math.inf``
    makes the resource free (used by ``headroom``).  Unlisted resources
    keep their durations; ``k=1`` everywhere is exactly identity.
    """
    for r, k in scales.items():
        if k <= 0:
            raise ValueError(f"scale for {r} must be > 0, got {k}")

    def duration(e):
        k = scales.get(base_resource(e.resource), 1.0)
        return 0.0 if math.isinf(k) else e.cycles / k

    projected = _replay(trace.events, duration)
    return WhatIfProjection(
        label=label or "+".join(f"{r}x{k:g}"
                                for r, k in sorted(scales.items())),
        baseline_makespan=trace.makespan,
        projected_makespan=projected,
        scales=dict(scales))


def whatif_resource(trace, resource: str, k: float) -> WhatIfProjection:
    """Project "resource R k× faster"."""
    return project(trace, {base_resource(resource): k},
                   label=f"{base_resource(resource)} {k:g}x faster")


def whatif_link_bandwidth(trace, k: float) -> WhatIfProjection:
    """Project "NoC link bandwidth k×" on a sharded trace (all
    ``NOC_*`` link events fold to ``INTERCONNECT``)."""
    return project(trace, {INTERCONNECT: k},
                   label=f"link bandwidth {k:g}x")


def whatif_ping_pong(trace) -> WhatIfProjection:
    """Toggle the ping-pong shadow sub-array, auto-detecting direction.

    A trace with overlapped rewrites (on ``BUS``) projects ping-pong
    *off*: rewrites remap onto the attention array (chip prefix
    preserved) and re-serialize against compute — exact on the §I
    micro-workload.  A trace with exposed rewrites projects ping-pong
    *on*: exposed rewrite durations go to zero — the perfect-overlap
    lower bound (see module docstring for the validity envelope).
    """
    overlapped = any(e.kind == "rewrite"
                     and base_resource(e.resource) == OVERLAP_RESOURCE
                     for e in trace.events)
    if overlapped:
        def remap(e):
            head, dot, rest = e.resource.rpartition(".")
            if (e.kind == "rewrite"
                    and base_resource(e.resource) == OVERLAP_RESOURCE):
                return f"{head}{dot}{ATTN_RESOURCE}" if dot else ATTN_RESOURCE
            return e.resource

        projected = _replay(trace.events, lambda e: float(e.cycles), remap)
        return WhatIfProjection(
            label="ping-pong off (rewrites re-serialized)",
            baseline_makespan=trace.makespan,
            projected_makespan=projected,
            scales={})

    def duration(e):
        if (e.kind == "rewrite"
                and base_resource(e.resource) in COMPUTE_RESOURCES):
            return 0.0
        return float(e.cycles)

    projected = _replay(trace.events, duration)
    return WhatIfProjection(
        label="ping-pong on (perfect-overlap bound)",
        baseline_makespan=trace.makespan,
        projected_makespan=projected,
        scales={})


def headroom(trace,
             resources: Optional[Tuple[str, ...]] = None) -> Dict[str, float]:
    """Per-resource causal headroom: fractional makespan reduction with
    that base resource free (k→∞).  A busy-but-off-path resource scores
    ~0; the true bottleneck scores highest.  Keys are the trace's base
    resources (or ``resources`` if given)."""
    base = trace.makespan
    if not base:
        return {}
    # One k→∞ projection per base resource is the DSE stamp's hot path
    # (it runs per swept point).  The generic ``project``/``_replay``
    # pair would re-sort the events and re-derive ``base_resource`` for
    # every resource; precompute the replay tuples once and inline the
    # list-schedule loop — arithmetic identical to ``_replay`` with
    # ``duration = 0.0 if freed else cycles / 1.0``.
    prep = [(e.task_id, e.deps, e.cycles / 1.0, e.resource,
             base_resource(e.resource))
            for e in sorted(trace.events, key=lambda e: e.task_id)]
    names = resources or tuple(sorted({p[4] for p in prep}))
    out: Dict[str, float] = {}
    for r in names:
        free: Dict[str, float] = {}
        end: Dict[int, float] = {}
        end_get = end.get
        free_get = free.get
        makespan = 0.0
        for tid, deps, cyc, res, bres in prep:
            start = 0.0
            for d in deps:
                t = end_get(d)
                if t is not None and t > start:
                    start = t
            f = free_get(res, 0.0)
            if f > start:
                start = f
            fin = start if bres == r else start + cyc
            end[tid] = fin
            free[res] = fin
            if fin > makespan:
                makespan = fin
        out[r] = 1.0 - makespan / base
    return out


def parse_whatif(spec: str) -> Tuple[str, float]:
    """Parse a CLI ``RESOURCE:K`` spec (``ATTN:2``, ``HBM:4``,
    ``INTERCONNECT:2``, ``ping_pong`` with no factor)."""
    name, sep, factor = spec.partition(":")
    name = name.strip()
    if not name:
        raise ValueError(f"empty what-if spec {spec!r}")
    if not sep:
        return name, 1.0
    try:
        k = float(factor)
    except ValueError:
        raise ValueError(f"bad what-if factor in {spec!r}") from None
    return name, k


def run_whatif(trace, spec: str) -> WhatIfProjection:
    """Dispatch one CLI spec against a trace."""
    name, k = parse_whatif(spec)
    if name.lower() in ("ping_pong", "pingpong", "pp"):
        return whatif_ping_pong(trace)
    if name.upper() == INTERCONNECT:
        return whatif_link_bandwidth(trace, k)
    return whatif_resource(trace, name, k)


def format_whatif(projections: List[WhatIfProjection],
                  *, title: str = "") -> str:
    lines: List[str] = []
    if title:
        lines.append(title)
    lines.append(f"{'scenario':<36} {'baseline':>12} {'projected':>12} "
                 f"{'speedup':>8}")
    for p in projections:
        lines.append(f"{p.label:<36} {p.baseline_makespan:>12} "
                     f"{p.projected_makespan:>12.0f} {p.speedup:>7.2f}x")
    return "\n".join(lines)
