"""Lightweight counter/gauge/histogram registry + per-request lifecycle
metrics (DESIGN.md §12).

Serving claims need SLO numbers, not aggregate scalars: TTFT (admit →
first token), TPOT (mean inter-token gap), queue delay (arrival →
admission) at p50/p95/p99.  This module provides the two halves:

* a tiny **metrics registry** — ``Counter`` / ``Gauge`` / ``Histogram``
  with *exact* quantile summaries (values are kept, not sketched: serving
  smokes record hundreds of samples, not millions) — used by
  ``serve.Engine`` and ``sim.simulate_serve``;
* **request lifecycle spans** — ``RequestSpan`` records one request's
  queue→admit→first-token→finish timeline in an arbitrary time unit
  (engine steps, simulated cycles, wall seconds), and
  ``spans_from_steps`` derives them from *executed* step records (the
  engine's ``step_log`` or the simulator's ``ServeSimResult.steps``, both
  of which expose ``step``/``admitted``/``decoded``), never from the
  planned schedule — so an execution bug cannot hide behind a correct
  plan.

Step-domain convention: step ``t`` spans the half-open interval
``[t, t+1)`` and a token lands at the *end* of the step that produces it.
TTFT in steps is therefore exactly 1 (prefill emits token #1 in its
admission step) — the step-domain summaries exist for the engine==sim
parity assertion (``assert_serve_parity``); the *interesting* TTFT/TPOT
distributions are the simulator's cycle-domain ones and the engine's
wall-clock ones, which share the same ``RequestSpan`` shape.

This module is dependency-light on purpose (no jax, no simulator): both
the engine and the simulator import it without dragging the other in.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Mapping, Optional, Sequence

#: Version stamp for serialized metric summaries (artifact tooling).
METRICS_SCHEMA_VERSION = 1

#: The quantiles every summary reports (exact, linear interpolation).
SUMMARY_QUANTILES = (0.50, 0.95, 0.99)


def percentile(values: Sequence[float], q: float) -> float:
    """Exact q-quantile (0 <= q <= 1) with linear interpolation between
    order statistics (numpy's default method, without numpy).  Returns
    0.0 for an empty sample — summaries of zero-request runs must be
    well-defined zeros, not NaNs."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q!r}")
    if not values:
        return 0.0
    s = sorted(values)
    pos = q * (len(s) - 1)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    if lo == hi:
        return float(s[lo])
    frac = pos - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


def summarize(values: Sequence[float]) -> Dict[str, float]:
    """Exact-quantile summary: count/mean/p50/p95/p99/max (all-zero for
    an empty sample)."""
    out: Dict[str, float] = {"count": float(len(values))}
    out["mean"] = sum(values) / len(values) if values else 0.0
    for q in SUMMARY_QUANTILES:
        out[f"p{int(q * 100)}"] = percentile(values, q)
    out["max"] = float(max(values)) if values else 0.0
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class Counter:
    """Monotone event count."""

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only increase "
                             f"(inc {n})")
        self.value += n


class Gauge:
    """Last-write-wins scalar (queue depth, cache size, ...)."""

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Exact-quantile sample (values retained; see module docstring)."""

    def __init__(self, name: str) -> None:
        self.name = name
        self.values: List[float] = []

    def observe(self, value: float) -> None:
        self.values.append(float(value))

    def percentile(self, q: float) -> float:
        return percentile(self.values, q)

    def summary(self) -> Dict[str, float]:
        return summarize(self.values)


class MetricsRegistry:
    """Get-or-create registry; one per engine run / simulated timeline."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge(name))

    def histogram(self, name: str) -> Histogram:
        return self._histograms.setdefault(name, Histogram(name))

    def to_dict(self) -> Dict[str, object]:
        return {
            "schema_version": METRICS_SCHEMA_VERSION,
            "counters": {n: c.value
                         for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self._histograms.items())},
        }


# ---------------------------------------------------------------------------
# Request lifecycle spans
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RequestSpan:
    """One request's executed lifecycle in an arbitrary time unit.

    ``arrival``/``admit`` are the *starts* of the respective events;
    ``first_token``/``finish`` are token-emission instants (end of the
    step that produced the token).  ``tokens`` counts emitted tokens
    (prefill's token #1 included)."""

    rid: int
    arrival: float
    admit: float
    first_token: float
    finish: float
    tokens: int
    unit: str = "steps"

    def __post_init__(self):
        if not (self.arrival <= self.admit <= self.first_token
                <= self.finish):
            raise ValueError(
                f"request {self.rid}: lifecycle must be ordered "
                f"arrival <= admit <= first_token <= finish, got "
                f"({self.arrival}, {self.admit}, {self.first_token}, "
                f"{self.finish})")
        if self.tokens < 1:
            raise ValueError(f"request {self.rid}: tokens must be >= 1")

    @property
    def queue_delay(self) -> float:
        """Arrival → admission (time spent waiting for a slot)."""
        return self.admit - self.arrival

    @property
    def ttft(self) -> float:
        """Time to first token: admission → token #1 (DESIGN.md §12)."""
        return self.first_token - self.admit

    @property
    def tpot(self) -> float:
        """Time per output token: mean inter-token gap over the decode
        phase (0 for a single-token request — no gaps exist)."""
        if self.tokens < 2:
            return 0.0
        return (self.finish - self.first_token) / (self.tokens - 1)

    @property
    def e2e(self) -> float:
        """Arrival → last token."""
        return self.finish - self.arrival

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d.update(queue_delay=self.queue_delay, ttft=self.ttft,
                 tpot=self.tpot, e2e=self.e2e)
        return d


def spans_from_steps(steps: Iterable[object],
                     arrivals: Optional[Mapping[int, int]] = None,
                     ) -> List[RequestSpan]:
    """Derive step-domain ``RequestSpan``s from executed step records.

    ``steps`` is any iterable of records exposing ``step`` (int),
    ``admitted`` (rids prefilled) and ``decoded`` (rids advanced one
    token) — both the engine's ``StepRecord`` log and the simulator's
    ``ServeStepSim`` list qualify.  ``arrivals`` maps rid → arrival step
    (missing rids arrive at their admission step).  Finish is the last
    step a request appears in; tokens = 1 + its decode count."""
    arrivals = arrivals or {}
    admit: Dict[int, int] = {}
    last: Dict[int, int] = {}
    decodes: Dict[int, int] = {}
    for rec in steps:
        for rid in rec.admitted:
            admit[rid] = rec.step
            last[rid] = rec.step
            decodes.setdefault(rid, 0)
        for rid in rec.decoded:
            decodes[rid] = decodes.get(rid, 0) + 1
            last[rid] = rec.step
    return [RequestSpan(rid=rid,
                        arrival=float(arrivals.get(rid, admit[rid])),
                        admit=float(admit[rid]),
                        first_token=float(admit[rid] + 1),
                        finish=float(last[rid] + 1),
                        tokens=1 + decodes[rid],
                        unit="steps")
            for rid in sorted(admit)]


def spans_from_timeline(admit_step: Mapping[int, int],
                        finish_step: Mapping[int, int],
                        decode_steps: Mapping[int, int],
                        arrivals: Mapping[int, int],
                        bounds: Mapping[int, "Sequence[float]"],
                        first_token: Optional[Mapping[int, float]] = None,
                        unit: str = "cycles") -> List[RequestSpan]:
    """Map request lifecycles onto measured per-step time bounds.

    ``bounds`` maps each *executed* step to its ``(start, end)`` time in
    the target unit (simulated cycle bounds, wall-clock seconds, ...).
    A request's admission lands at its admit step's start; its arrival at
    the start of the first executed step at/after its arrival step (the
    scheduler jumps idle gaps, so the arrival step itself may never
    execute); its first token at ``first_token[rid]`` when the caller
    measured the prefill's actual completion, else at the admit step's
    end; its finish at its last step's end."""
    executed = sorted(bounds)
    first_token = first_token or {}
    spans: List[RequestSpan] = []
    for rid in sorted(admit_step):
        a = admit_step[rid]
        admit_t = float(bounds[a][0])
        arr_t = admit_t
        for s in executed:
            if s >= arrivals.get(rid, a):
                arr_t = min(float(bounds[s][0]), admit_t)
                break
        spans.append(RequestSpan(
            rid=rid, arrival=arr_t, admit=admit_t,
            first_token=float(first_token.get(rid, bounds[a][1])),
            finish=float(bounds[finish_step[rid]][1]),
            tokens=1 + decode_steps.get(rid, 0), unit=unit))
    return spans


#: The lifecycle metrics every serving summary reports.
SPAN_METRICS = ("queue_delay", "ttft", "tpot", "e2e")


def summarize_spans(spans: Sequence[RequestSpan],
                    unit: Optional[str] = None) -> Dict[str, object]:
    """Reduce spans to the serving SLO summary: requests + one
    exact-quantile summary per lifecycle metric.  Well-defined zeros for
    an empty span list (zero-request runs)."""
    out: Dict[str, object] = {
        "schema_version": METRICS_SCHEMA_VERSION,
        "requests": len(spans),
        "unit": unit or (spans[0].unit if spans else "steps"),
        "tokens": sum(s.tokens for s in spans),
    }
    for metric in SPAN_METRICS:
        out[metric] = summarize([getattr(s, metric) for s in spans])
    return out


def observe_spans(registry: "MetricsRegistry",
                  spans: Sequence[RequestSpan], prefix: str = "") -> None:
    """Fold lifecycle spans into a registry: ``requests``/``tokens``
    counters plus one histogram per lifecycle metric (the shared path by
    which ``serve.Engine`` and ``sim.simulate_serve`` record spans)."""
    registry.counter(prefix + "requests").inc(len(spans))
    registry.counter(prefix + "tokens").inc(sum(s.tokens for s in spans))
    for s in spans:
        for metric in SPAN_METRICS:
            registry.histogram(prefix + metric).observe(getattr(s, metric))


def assert_serve_parity(engine_stats: Mapping[str, object],
                        sim_metrics: Mapping[str, object]) -> None:
    """The engine==simulator SLO parity assertion (DESIGN.md §12): the
    step-domain lifecycle summaries both sides derived from their own
    *executed* records must agree exactly — requests, token counts, and
    every quantile of every metric.  Raises AssertionError naming the
    first divergence."""
    for key in ("requests", "tokens"):
        if engine_stats.get(key) != sim_metrics.get(key):
            raise AssertionError(
                f"engine/sim {key} diverge: engine "
                f"{engine_stats.get(key)!r} != sim {sim_metrics.get(key)!r}")
    for metric in SPAN_METRICS:
        e = engine_stats.get(metric)
        s = sim_metrics.get(metric)
        if e is None or s is None:
            raise AssertionError(
                f"missing step-domain summary {metric!r}: engine has "
                f"{sorted(engine_stats)} / sim has {sorted(sim_metrics)}")
        if dict(e) != dict(s):
            raise AssertionError(
                f"engine/sim {metric} percentiles diverge: "
                f"engine {dict(e)} != sim {dict(s)}")
