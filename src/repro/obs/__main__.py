"""``python -m repro.obs`` — text utilization / stall report for a saved
plan artifact or an on-the-fly simulation (DESIGN.md §12).

Usage::

    python -m repro.obs plan.json                  # saved ExecutionPlan
    python -m repro.obs --model vilbert-base --smoke --mode tile_stream
    python -m repro.obs --rewrite-stall            # paper §I micro-workload
    python -m repro.obs plan.json --perfetto out.json   # + Perfetto dump
    python -m repro.obs plan.json --json           # attribution as JSON
    python -m repro.obs plan.json --critpath       # causal critical path
    python -m repro.obs plan.json --whatif ATTN:2 --whatif ping_pong

Stale artifacts are rejected: ``ExecutionPlan.from_json`` checks the
plan's ``version`` stamp and raises on mismatch.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.attribution import attribute, format_report
from repro.obs.timeline import (timeline_from_sim, timeline_from_trace,
                                validate_timeline, write_timeline)


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Utilization / stall attribution report from a saved "
                    "ExecutionPlan artifact or an on-the-fly simulation.")
    p.add_argument("plan", nargs="?", default=None,
                   help="path to a saved ExecutionPlan JSON artifact")
    p.add_argument("--model", default=None,
                   help="simulate a registered model config instead of "
                        "loading a plan (e.g. vilbert-base)")
    p.add_argument("--smoke", action="store_true",
                   help="use the model's smoke-sized config")
    p.add_argument("--mode", default=None,
                   choices=["non_stream", "layer_stream", "tile_stream"],
                   help="force one execution mode (default: planner choice)")
    p.add_argument("--hw", default=None,
                   help="hardware preset name (default: plan's / base)")
    p.add_argument("--seq", type=int, default=0,
                   help="sequence length override for --model")
    p.add_argument("--rewrite-stall", action="store_true",
                   help="report the paper §I rewrite-stall micro-workload")
    p.add_argument("--ping-pong", action="store_true",
                   help="with --rewrite-stall: enable the shadow sub-array")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the attribution report as JSON")
    p.add_argument("--perfetto", metavar="OUT", default=None,
                   help="also write the Perfetto trace_event timeline here "
                        "(critical-path edges as flow events when "
                        "--critpath is also given)")
    p.add_argument("--critpath", action="store_true",
                   help="also report the causal critical path (on-path "
                        "resource/op-class shares, slack histogram)")
    p.add_argument("--whatif", metavar="RESOURCE:K", action="append",
                   default=[],
                   help="project a what-if scenario on the trace DAG "
                        "(repeatable): ATTN:2, HBM:4, INTERCONNECT:2, "
                        "or ping_pong to toggle the shadow sub-array")
    return p


def _simulate(args):
    """Resolve the CLI to one (SimResult-ish, title) pair."""
    from repro.configs.registry import get_config, get_hw_config
    from repro.core.types import ExecutionMode
    hw = get_hw_config(args.hw) if args.hw else None

    if args.rewrite_stall:
        from repro.configs.hardware import STREAMDCIM_BASE
        from repro.sim.pipeline import rewrite_stall_trace
        trace = rewrite_stall_trace(hw or STREAMDCIM_BASE,
                                    ping_pong=args.ping_pong)
        label = "ping-pong" if args.ping_pong else "serial"
        return None, trace, f"§I rewrite-stall micro-workload ({label})"

    from repro.sim.pipeline import simulate_plan
    if args.plan:
        from repro.plan.planner import ExecutionPlan
        with open(args.plan) as f:
            plan = ExecutionPlan.from_json(f.read())   # rejects stale version
        res = simulate_plan(plan, hw=hw)
        return res, res.trace, f"plan {args.plan} ({plan.model}@{plan.hw})"

    if args.model:
        from repro.plan.planner import plan_model
        mode = ExecutionMode(args.mode) if args.mode else None
        plan = plan_model(get_config(args.model, smoke=args.smoke), hw=hw,
                          seq_len=args.seq, mode=mode,
                          force_mode=mode is not None)
        res = simulate_plan(plan, hw=hw)
        return res, res.trace, f"{args.model} ({plan.hw})"

    raise SystemExit("nothing to report: pass a plan artifact, --model, "
                     "or --rewrite-stall (see --help)")


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    res, trace, title = _simulate(args)
    report = attribute(trace)
    crit = None
    if args.critpath:
        from repro.obs.critpath import critical_path, format_critpath
        crit = critical_path(trace)
    projections = []
    if args.whatif:
        from repro.obs.whatif import format_whatif, run_whatif
        projections = [run_whatif(trace, spec) for spec in args.whatif]
    if args.as_json:
        out = {"title": title, **report.to_dict()}
        if crit is not None:
            out["critical_path"] = crit.to_dict()
        if projections:
            out["whatif"] = [p.to_dict() for p in projections]
        print(json.dumps(out, indent=2))
    else:
        print(format_report(report, title=title))
        if crit is not None:
            print()
            print(format_critpath(crit, title=f"critical path — {title}"))
        if projections:
            print()
            print(format_whatif(projections, title=f"what-if — {title}"))
    if args.perfetto:
        tl = (timeline_from_sim(res, title=title,
                                critical_path=args.critpath)
              if res is not None
              else timeline_from_trace(trace, title=title,
                                       critical_path=args.critpath))
        validate_timeline(tl)
        write_timeline(tl, args.perfetto)
        print(f"\nperfetto timeline -> {args.perfetto} "
              f"(open at https://ui.perfetto.dev)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
