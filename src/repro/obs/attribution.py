"""Stall and busy-cycle attribution over simulator traces (DESIGN.md §12).

The paper's headline analysis (§I) is an attribution claim: under
layer-based streaming, 57% of the macro array's cycles go to CIM
rewriting instead of compute.  This module answers that question for
*any* trace, not just the hand-derived micro-workload:

* per-resource busy cycles / utilization and the **critical resource**
  (the busiest one — what a next design iteration should attack);
* per-**op-class** cycle breakdowns (attention / ffn / proj / decode),
  folding serve-step tag framing (``t3.pre.r1.<op>``) away so serving
  traces aggregate like plain prefill traces;
* **exposed vs overlapped rewrite cycles**: rewrites scheduled on a
  compute resource (NON/LAYER modes — no shadow sub-array) stall the
  array and are *exposed*; rewrites riding the ping-pong shadow bus
  (``BUS``, TILE mode) are *overlapped* and only their schedule residue
  can surface as idle time.

``bottleneck_of`` is the one-word reduction used to stamp every DSE
``SweepRow``; ``format_report`` renders the text report behind
``python -m repro.obs``.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from collections import defaultdict
from typing import Dict, List

#: Resources whose events are macro-array compute (not data movement).
COMPUTE_RESOURCES = ("GEN", "ATTN", "VEC")

#: Attention-macro resource — the array rewrites contend with (§I).
ATTN_RESOURCE = "ATTN"

#: Shadow sub-array rewrite port: rewrites here overlap compute (§II-C).
OVERLAP_RESOURCE = "BUS"

#: Aggregate label for the inter-chip NoC links of a sharded trace.
INTERCONNECT = "INTERCONNECT"

#: Link resource prefix — mirrors ``repro.shard.noc.LINK_PREFIX`` (obs
#: sits below shard in the layering, so the literal is pinned here and a
#: tier-1 test asserts the two stay equal).
NOC_LINK_PREFIX = "NOC_"

_FRAMING = re.compile(r"t\d+|r\d+|c\d+|pre|dec")

_CHIP = re.compile(r"c\d+")


@functools.lru_cache(maxsize=4096)
def base_resource(resource: str) -> str:
    """Fold a sharded-trace resource name to its single-chip base: the
    per-chip prefix strips (``c3.ATTN`` -> ``ATTN``) and NoC link
    instances aggregate (``NOC_L2`` -> ``INTERCONNECT``).  Identity on
    unprefixed single-chip names.  Memoized — the what-if replays call
    this once per event per projection, over a tiny name alphabet."""
    head, _, rest = resource.partition(".")
    if rest and _CHIP.fullmatch(head):
        resource = rest
    if resource.startswith(NOC_LINK_PREFIX):
        return INTERCONNECT
    return resource


def op_class(op: str) -> str:
    """Collapse an event's op name to its op class.

    Serve-step framing segments (``t{step}``, ``pre``/``dec``,
    ``r{rid}``) are stripped first, so ``t3.pre.r1.cox0_co`` classifies
    like ``cox0_co``.  Classes: ``decode`` (decode-plan ops carry a
    ``.decode`` suffix), ``ffn``, ``proj`` (output projections), ``sync``
    framing, and ``attention`` for everything else (including the §I
    ``it{n}`` micro-workload phases)."""
    parts = [p for p in op.split(".") if p]
    while parts and _FRAMING.fullmatch(parts[0]):
        parts.pop(0)
    base = ".".join(parts) or op
    if base == "sync" or base.endswith(":sync"):
        return "sync"
    if base == "decode" or base.endswith(".decode"):
        return "decode"
    if "ffn" in base:
        return "ffn"
    if base.endswith("_oproj") or "proj" in base:
        return "proj"
    return "attention"


@dataclasses.dataclass(frozen=True)
class OpClassBreakdown:
    """Cycle budget of one op class, split by event kind."""

    op_class: str
    compute: int = 0
    rewrite: int = 0
    dma: int = 0
    forward: int = 0
    attn_compute: int = 0        # compute cycles on the attention array
    rewrite_exposed: int = 0     # rewrites stalling a compute resource

    @property
    def total(self) -> int:
        return self.compute + self.rewrite + self.dma + self.forward

    @property
    def rewrite_stall_fraction(self) -> float:
        """§I metric per op class: exposed rewrite cycles over the
        attention array's (rewrite + compute) budget for this class."""
        denom = self.rewrite_exposed + self.attn_compute
        return self.rewrite_exposed / denom if denom else 0.0

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["total"] = self.total
        d["rewrite_stall_fraction"] = self.rewrite_stall_fraction
        return d


@dataclasses.dataclass(frozen=True)
class AttributionReport:
    """Where the cycles went, for one trace."""

    makespan: int
    busy: Dict[str, int]
    utilization: Dict[str, float]
    critical_resource: str
    critical_share: float
    rewrite_total: int
    rewrite_exposed: int
    rewrite_overlapped: int
    rewrite_stall_fraction: float
    by_op_class: Dict[str, OpClassBreakdown]

    @property
    def bottleneck(self) -> str:
        return self.critical_resource

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["by_op_class"] = {k: v.to_dict()
                            for k, v in self.by_op_class.items()}
        d["bottleneck"] = self.bottleneck
        return d


def attribute(trace) -> AttributionReport:
    """Reduce a ``sim.Trace`` to its attribution report.

    ``rewrite_stall_fraction`` follows ``Trace.rewrite_stall_fraction``
    (rewrite cycles over rewrite + ATTN compute — the §I number on a
    serial trace) but counts only *exposed* rewrites, so a ping-pong
    trace whose rewrites all ride the shadow bus attributes ~0 stall
    instead of reporting its overlap ratio as a stall."""
    busy: Dict[str, int] = defaultdict(int)
    per_class: Dict[str, Dict[str, int]] = defaultdict(
        lambda: defaultdict(int))
    rewrite_total = rewrite_exposed = 0
    for e in trace.events:
        cyc = e.cycles
        res = base_resource(e.resource)
        busy[res] += cyc
        c = per_class[op_class(e.op)]
        if e.kind in ("compute", "rewrite", "dma", "forward"):
            c[e.kind] += cyc
        if e.kind == "compute" and res == ATTN_RESOURCE:
            c["attn_compute"] += cyc
        if e.kind == "rewrite":
            rewrite_total += cyc
            if res != OVERLAP_RESOURCE:
                rewrite_exposed += cyc
                c["rewrite_exposed"] += cyc
    makespan = trace.makespan
    util = {r: (b / makespan if makespan else 0.0)
            for r, b in sorted(busy.items())}
    critical = bottleneck_of(trace)
    attn_comp = sum(c.get("attn_compute", 0) for c in per_class.values())
    denom = rewrite_exposed + attn_comp
    return AttributionReport(
        makespan=makespan,
        busy=dict(sorted(busy.items())),
        utilization=util,
        critical_resource=critical,
        critical_share=util.get(critical, 0.0),
        rewrite_total=rewrite_total,
        rewrite_exposed=rewrite_exposed,
        rewrite_overlapped=rewrite_total - rewrite_exposed,
        rewrite_stall_fraction=(rewrite_exposed / denom if denom else 0.0),
        by_op_class={k: OpClassBreakdown(op_class=k, **v)
                     for k, v in sorted(per_class.items())},
    )


def bottleneck_of(trace) -> str:
    """The critical resource: most busy cycles, ties broken toward the
    compute resources (a tied macro array beats a tied port — compute is
    what you'd rebalance first).  Sharded traces fold per-chip resources
    to their base names and the NoC links to ``INTERCONNECT``, so a
    mesh whose wire plan dominates reports interconnect-bound."""
    busy: Dict[str, int] = defaultdict(int)
    for r, b in trace.aggregates.busy.items():
        busy[base_resource(r)] += b
    if not busy:
        return ""
    order = {r: i for i, r in enumerate(
        COMPUTE_RESOURCES + (OVERLAP_RESOURCE, "NOC", "HBM",
                             INTERCONNECT))}
    return max(sorted(busy),
               key=lambda r: (busy[r], -order.get(r, len(order))))


def rewrite_stall_by_op(trace) -> Dict[str, float]:
    """Per-op-class §I stall fractions (0.0 for rewrite-free classes)."""
    return {k: v.rewrite_stall_fraction
            for k, v in attribute(trace).by_op_class.items()}


def format_report(report: AttributionReport, *, title: str = "") -> str:
    """Render the attribution as the ``python -m repro.obs`` text view."""
    lines: List[str] = []
    if title:
        lines.append(title)
    lines.append(f"makespan: {report.makespan} cycles   "
                 f"critical: {report.critical_resource} "
                 f"({report.critical_share:.1%} busy)")
    lines.append(f"rewrite: {report.rewrite_total} cycles "
                 f"({report.rewrite_exposed} exposed / "
                 f"{report.rewrite_overlapped} overlapped), "
                 f"stall fraction {report.rewrite_stall_fraction:.1%}")
    lines.append("")
    lines.append(f"{'resource':<9} {'busy':>12} {'util':>7}")
    for r, b in report.busy.items():
        lines.append(f"{r:<9} {b:>12} {report.utilization[r]:>6.1%}")
    lines.append("")
    lines.append(f"{'op class':<10} {'compute':>11} {'rewrite':>10} "
                 f"{'dma':>10} {'forward':>10} {'rw stall':>9}")
    for k, c in report.by_op_class.items():
        lines.append(f"{k:<10} {c.compute:>11} {c.rewrite:>10} "
                     f"{c.dma:>10} {c.forward:>10} "
                     f"{c.rewrite_stall_fraction:>8.1%}")
    return "\n".join(lines)
