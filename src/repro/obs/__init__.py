"""``repro.obs`` — observability for the plan → sim → serve stack
(DESIGN.md §12).

The simulator, serving engine, and DSE sweep all reduce to aggregate
scalars; this package is the instrumentation that shows *where the
cycles go* and what serving actually delivers:

``timeline.py``     Chrome/Perfetto ``trace_event`` export: resource
                    tracks for any ``sim.Trace``, serve-step and
                    per-request lifecycle tracks for ``ServeSimResult``,
                    a ``kernels`` track for ``KernelRecorder`` records,
                    and the ``validate_timeline`` CI gate.
``metrics.py``      Counter/gauge/histogram registry with exact-quantile
                    summaries + ``RequestSpan`` lifecycle records
                    (queue→admit→first-token→finish) behind the
                    TTFT/TPOT/queue-delay p50/p95/p99 in
                    ``Engine.stats()`` and ``ServeSimResult.metrics``,
                    and the engine==sim ``assert_serve_parity`` check.
``attribution.py``  Per-resource / per-op-class stall and busy
                    breakdowns: critical-resource share, exposed vs
                    overlapped rewrite cycles, the §I 57% rewrite-stall
                    fraction for any trace, and the ``bottleneck`` field
                    on DSE ``SweepRow``s.
``critpath.py``     Causal critical-path analysis over the stamped event
                    DAG: the chain that bounds the makespan, per-resource
                    / per-op-class *critical* shares, exposed-rewrite
                    on-path cycles (§I, causally), slack histograms.
``whatif.py``       What-if projection: rescale event durations and
                    replay the DAG schedule — "R k× faster", "link
                    bandwidth k×", "ping-pong toggled" — plus the
                    per-resource ``headroom`` stamped on ``SweepRow``s.

``python -m repro.obs`` renders a text utilization/stall report from a
saved plan artifact (or an on-the-fly model simulation) and can dump the
Perfetto timeline alongside; ``benchmarks/run.py --perfetto DIR`` dumps
timelines from every sim/serve/dse section it runs.
"""
from repro.obs.attribution import (INTERCONNECT, AttributionReport,
                                   OpClassBreakdown, attribute,
                                   base_resource, bottleneck_of,
                                   format_report, op_class,
                                   rewrite_stall_by_op)
from repro.obs.critpath import (CritPathReport, compute_slack,
                                critical_path, format_critpath)
from repro.obs.metrics import (METRICS_SCHEMA_VERSION, Counter, Gauge,
                               Histogram, MetricsRegistry, RequestSpan,
                               SPAN_METRICS, assert_serve_parity,
                               percentile, spans_from_steps, summarize,
                               summarize_spans)
from repro.obs.timeline import (KIND_COLORS, RESOURCE_ORDER,
                                TIMELINE_SCHEMA_VERSION, kernel_events,
                                load_timeline, timeline_from_records,
                                timeline_from_serve, timeline_from_sharded,
                                timeline_from_sim, timeline_from_trace,
                                trace_events, validate_timeline,
                                write_timeline)
from repro.obs.whatif import (WhatIfProjection, format_whatif, headroom,
                              project, run_whatif, whatif_link_bandwidth,
                              whatif_ping_pong, whatif_resource)

__all__ = [
    "INTERCONNECT", "AttributionReport", "OpClassBreakdown", "attribute",
    "base_resource", "bottleneck_of",
    "format_report", "op_class", "rewrite_stall_by_op",
    "CritPathReport", "compute_slack", "critical_path", "format_critpath",
    "WhatIfProjection", "format_whatif", "headroom", "project",
    "run_whatif", "whatif_link_bandwidth", "whatif_ping_pong",
    "whatif_resource",
    "METRICS_SCHEMA_VERSION", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "RequestSpan", "SPAN_METRICS", "assert_serve_parity",
    "percentile", "spans_from_steps", "summarize", "summarize_spans",
    "KIND_COLORS", "RESOURCE_ORDER", "TIMELINE_SCHEMA_VERSION",
    "kernel_events", "load_timeline", "timeline_from_records",
    "timeline_from_serve", "timeline_from_sharded", "timeline_from_sim",
    "timeline_from_trace", "trace_events", "validate_timeline",
    "write_timeline",
]
