"""Chrome/Perfetto ``trace_event`` export for simulator traces
(DESIGN.md §12).

A ``sim.Trace`` is a flat event list; nobody debugs a serving timeline
from 40 formatted rows.  This module renders any trace — prefill
simulations, DSE frontier replays, full ``simulate_serve`` timelines,
recorded ``KernelTrace`` streams — as Chrome ``trace_event`` JSON that
loads directly in https://ui.perfetto.dev (or ``chrome://tracing``):

* one track (thread) per simulator resource (GEN / ATTN / BUS / NOC /
  HBM / VEC), events colored by kind (compute / rewrite / dma / forward)
  with the full ``op:kind:tile`` tag preserved in ``args``;
* serving timelines additionally get a **steps** track (one slice per
  engine step) and a per-request **lifecycle** track group
  (queued → prefill → decode slices per request);
* ``KernelRecorder`` records lay out end-to-end on a **kernels** track.

Time convention: 1 simulated cycle = 1 microsecond of trace time (the
``ts``/``dur`` unit the viewers expect), so durations read directly as
cycle counts; wall-clock kernel records convert through their own
``clock_hz``.  ``validate_timeline`` is the CI gate: parses, non-empty
tracks, per-track monotone timestamps, non-negative durations.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

TIMELINE_SCHEMA_VERSION = 1

#: Stable track order: the floorplan resources first, stragglers after.
RESOURCE_ORDER = ("GEN", "ATTN", "BUS", "NOC", "HBM", "VEC")

#: Chrome trace-viewer reserved color names per event kind.
KIND_COLORS = {
    "compute": "thread_state_running",      # green
    "rewrite": "terrible",                  # red — the paper's villain
    "dma": "thread_state_iowait",           # orange
    "forward": "thread_state_runnable",     # blue
    "sync": "grey",
}

_PID_SIM = 1
_PID_STEPS = 2
_PID_REQUESTS = 3
_PID_KERNELS = 4
_PID_NOC = 9        # shared mesh links (timeline_from_sharded)
_PID_CHIPS = 10     # chip i renders as pid _PID_CHIPS + i


def _meta(pid: int, name: str, tid: Optional[int] = None,
          sort_index: Optional[int] = None) -> List[Dict[str, object]]:
    """process/thread naming metadata events."""
    key = "thread_name" if tid is not None else "process_name"
    ev: Dict[str, object] = {"ph": "M", "pid": pid, "name": key,
                             "args": {"name": name}}
    if tid is not None:
        ev["tid"] = tid
    out = [ev]
    if sort_index is not None and tid is not None:
        out.append({"ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_sort_index",
                    "args": {"sort_index": sort_index}})
    return out


def _resource_tids(resources: Iterable[str]) -> Dict[str, int]:
    seen = set(resources)
    ordered = [r for r in RESOURCE_ORDER if r in seen]
    ordered += sorted(seen - set(ordered))
    return {r: i + 1 for i, r in enumerate(ordered)}


def _slice(e, pid: int, tid: int) -> Dict[str, object]:
    """One complete ("X") event for a trace event on track (pid, tid)."""
    return {
        "name": e.tag or e.kind,
        "cat": e.kind,
        "ph": "X",
        "ts": float(e.start),
        "dur": float(e.cycles),
        "pid": pid,
        "tid": tid,
        "cname": KIND_COLORS.get(e.kind, "generic_work"),
        "args": {"tag": e.tag, "op": e.op, "kind_tag": e.kind_tag,
                 "tile": e.tile, "bytes": e.bytes,
                 "cycles": e.cycles},
    }


def trace_events(trace, *, pid: int = _PID_SIM,
                 process_name: str = "sim",
                 critical_path: bool = False) -> List[Dict[str, object]]:
    """Lower a ``sim.Trace`` to ``trace_event`` dicts: one complete
    ("X") event per trace event on its resource's track, sorted by start
    within each track (the in-order-per-resource scheduler makes starts
    monotone, so sorting is just defense against hand-built traces).

    With ``critical_path=True`` the edges of the causal critical path
    (``repro.obs.critpath``) are appended as Chrome flow events
    ("s"/"f" pairs), so Perfetto draws arrows along the chain that
    bounds the makespan."""
    tids = _resource_tids(e.resource for e in trace.events)
    out: List[Dict[str, object]] = _meta(pid, process_name)
    for res, tid in tids.items():
        out.extend(_meta(pid, res, tid, sort_index=tid))
    for e in sorted(trace.events, key=lambda e: (tids[e.resource], e.start)):
        out.append(_slice(e, pid, tids[e.resource]))
    if critical_path:
        from repro.obs.critpath import critical_path as _critpath
        out.extend(critical_path_flow_events(
            _critpath(trace).path, tids, pid))
    return out


def critical_path_flow_events(path: Sequence, tids: Mapping[str, int],
                              pid: int) -> List[Dict[str, object]]:
    """Chrome flow events along consecutive critical-path edges: an "s"
    (flow start) anchored at the tail of the source slice and an "f"
    (flow finish, binding point "e" = enclosing slice end) at the head
    of the destination slice.  Perfetto renders these as arrows."""
    out: List[Dict[str, object]] = []
    for k, (a, b) in enumerate(zip(path, path[1:])):
        fid = k + 1
        common = {"cat": "critpath", "name": "critical-path", "id": fid}
        out.append({**common, "ph": "s", "pid": pid,
                    "tid": tids[a.resource], "ts": float(a.end)})
        out.append({**common, "ph": "f", "bp": "e", "pid": pid,
                    "tid": tids[b.resource], "ts": float(b.start)})
    return out


def _wrap(events: List[Dict[str, object]], title: str) -> Dict[str, object]:
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "schema_version": TIMELINE_SCHEMA_VERSION,
            "title": title,
            "clock": "1 simulated cycle = 1us of trace time",
        },
    }


def timeline_from_trace(trace, *, title: str = "sim",
                        critical_path: bool = False) -> Dict[str, object]:
    """A complete timeline document for one simulated trace."""
    return _wrap(trace_events(trace, process_name=title,
                              critical_path=critical_path), title)


def timeline_from_sim(result, *, title: Optional[str] = None,
                      critical_path: bool = False) -> Dict[str, object]:
    """Timeline for a ``SimResult`` (prefill simulation / DSE replay)."""
    return timeline_from_trace(
        result.trace, title=title or f"{result.workload}@{result.hw}",
        critical_path=critical_path)


def _link_sort_key(name: str) -> Tuple[str, int]:
    digits = "".join(ch for ch in name if ch.isdigit())
    return (name.rstrip("0123456789"), int(digits) if digits else -1)


def timeline_from_sharded(result, *, title: Optional[str] = None
                          ) -> Dict[str, object]:
    """Timeline for a ``ShardSimResult`` (``repro.shard``): one process
    per chip carrying its own resource tracks (``c3.ATTN`` renders as
    the ``ATTN`` track of process ``chip3``) plus a ``noc`` process with
    one track per mesh link, so collective wire traffic reads directly
    against the per-chip compute it overlaps — or fails to."""
    from repro.obs.attribution import NOC_LINK_PREFIX
    mesh = result.plan.mesh
    title = title or f"shard:{mesh.name}@{result.hw}"
    chips: Dict[int, Dict[str, List[object]]] = {}
    links: Dict[str, List[object]] = {}
    stray: List[object] = []
    for e in result.trace.events:
        r = e.resource
        if r.startswith(NOC_LINK_PREFIX):
            links.setdefault(r, []).append(e)
            continue
        head, _, base = r.partition(".")
        if base and head[:1] == "c" and head[1:].isdigit():
            chips.setdefault(int(head[1:]), {}).setdefault(
                base, []).append(e)
        else:
            stray.append(e)
    events: List[Dict[str, object]] = []
    if links:
        events += _meta(_PID_NOC, "noc")
        for tid, link in enumerate(sorted(links, key=_link_sort_key), 1):
            events += _meta(_PID_NOC, link, tid, sort_index=tid)
            for e in sorted(links[link], key=lambda e: e.start):
                events.append(_slice(e, _PID_NOC, tid))
    for i in sorted(chips):
        pid = _PID_CHIPS + i
        events += _meta(pid, f"chip{i}")
        tids = _resource_tids(chips[i])
        for res, tid in tids.items():
            events += _meta(pid, res, tid, sort_index=tid)
            for e in sorted(chips[i][res], key=lambda e: e.start):
                events.append(_slice(e, pid, tid))
    if stray:
        holder = type("_Events", (), {"events": stray})()
        events += trace_events(holder, process_name="sim")
    return _wrap(events, title)


def step_bounds(steps) -> List[Tuple[int, int, int]]:
    """Cumulative (step, start_cycle, end_cycle) bounds from per-step
    ``cycles`` spans (``ServeStepSim`` records)."""
    out, t = [], 0
    for s in steps:
        out.append((s.step, t, t + s.cycles))
        t += s.cycles
    return out


def timeline_from_serve(result, *, records: Sequence[object] = (),
                        title: str = "serve") -> Dict[str, object]:
    """Timeline for a ``ServeSimResult``: resource tracks + a serve-step
    track + one lifecycle track per request (queued / prefill / decode
    slices from the cycle-domain ``RequestSpan``s) + optionally a
    kernels track from recorded ``KernelTrace``s."""
    events = trace_events(result.result.trace, process_name=title)
    events += _meta(_PID_STEPS, "serve steps")
    events += _meta(_PID_STEPS, "steps", 1, sort_index=1)
    for step, start, end in step_bounds(result.steps):
        rec = result.steps[0].__class__  # noqa: F841 (doc: ServeStepSim)
        s = next(x for x in result.steps if x.step == step)
        events.append({
            "name": f"step{step}",
            "cat": "serve-step", "ph": "X",
            "ts": float(start), "dur": float(end - start),
            "pid": _PID_STEPS, "tid": 1,
            "args": {"step": step, "admitted": list(s.admitted),
                     "decoded": list(s.decoded),
                     "kv_lens": list(s.kv_lens),
                     "hbm_bytes": s.hbm_bytes},
        })
    events += _meta(_PID_REQUESTS, "requests")
    for i, span in enumerate(result.cycle_spans):
        tid = i + 1
        events += _meta(_PID_REQUESTS, f"r{span.rid}", tid, sort_index=tid)
        phases = [("queued", span.arrival, span.admit, "grey"),
                  ("prefill", span.admit, span.first_token,
                   "thread_state_running"),
                  ("decode", span.first_token, span.finish,
                   "thread_state_runnable")]
        for name, t0, t1, color in phases:
            if t1 <= t0:
                continue
            events.append({
                "name": f"r{span.rid}:{name}",
                "cat": "request", "ph": "X",
                "ts": float(t0), "dur": float(t1 - t0),
                "pid": _PID_REQUESTS, "tid": tid, "cname": color,
                "args": {"rid": span.rid, "tokens": span.tokens,
                         "ttft_cycles": span.ttft,
                         "tpot_cycles": span.tpot},
            })
    if records:
        events += kernel_events(records)
    return _wrap(events, title)


def kernel_events(records: Sequence[object],
                  pid: int = _PID_KERNELS) -> List[Dict[str, object]]:
    """Lay recorded ``KernelTrace``s end-to-end on a ``kernels`` track
    (records carry durations, not timestamps — the recording ran them
    sequentially, so end-to-end placement reflects the measurement)."""
    events = _meta(pid, "kernels") + _meta(pid, "recorded", 1, sort_index=1)
    t = 0.0
    for r in records:
        events.append({
            "name": f"{r.op} [{r.kind}]",
            "cat": "kernel", "ph": "X",
            "ts": t, "dur": float(r.cycles),
            "pid": pid, "tid": 1,
            "cname": "thread_state_running",
            "args": {"op": r.op, "kind": r.kind, "mode": r.mode,
                     "grid": list(r.grid), "block_q": r.block_q,
                     "block_kv": r.block_kv, "hbm_bytes": r.hbm_bytes,
                     "flops": r.flops, "source": r.source,
                     "wall_time_s": r.wall_time_s},
        })
        t += float(r.cycles)
    return events


def timeline_from_records(records: Sequence[object],
                          *, title: str = "kernels") -> Dict[str, object]:
    """Timeline for a raw ``KernelRecorder.records`` list."""
    return _wrap(kernel_events(records), title)


def write_timeline(timeline: Mapping[str, object], path: str) -> str:
    with open(path, "w") as f:
        json.dump(timeline, f)
    return path


def validate_timeline(obj: Mapping[str, object]) -> Dict[str, int]:
    """The CI gate for emitted timelines: the document must carry a
    non-empty ``traceEvents`` list with at least one named track; every
    duration event needs numeric non-negative ts/dur and timestamps must
    be monotone non-decreasing within each (pid, tid) track.  Flow
    events ("s"/"t"/"f" — critical-path arrows) must carry a numeric
    non-negative ts and an id, and are exempt from the per-track
    monotonicity check (they anchor to slices, not to track order).
    Returns ``{"events": n, "tracks": m}``; raises ValueError on any
    violation."""
    events = obj.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("timeline has no traceEvents")
    tracks = set()
    last_ts: Dict[Tuple[object, object], float] = {}
    slices = 0
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph == "M":
            if e.get("name") in ("process_name", "thread_name"):
                tracks.add((e.get("pid"), e.get("tid")))
            continue
        if ph in ("s", "t", "f"):
            ts = e.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(f"flow event {i}: bad ts {ts!r}")
            if e.get("id") is None:
                raise ValueError(f"flow event {i}: missing flow id")
            continue
        if ph != "X":
            raise ValueError(f"event {i}: unsupported phase {ph!r}")
        ts, dur = e.get("ts"), e.get("dur")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i} ({e.get('name')!r}): bad ts {ts!r}")
        if not isinstance(dur, (int, float)) or dur < 0:
            raise ValueError(f"event {i} ({e.get('name')!r}): "
                             f"bad dur {dur!r}")
        key = (e.get("pid"), e.get("tid"))
        if ts < last_ts.get(key, 0.0):
            raise ValueError(
                f"event {i} ({e.get('name')!r}): timestamps not monotone "
                f"on track {key} ({ts} < {last_ts[key]})")
        last_ts[key] = float(ts)
        slices += 1
    if slices == 0:
        raise ValueError("timeline has metadata but no duration events")
    if not tracks:
        raise ValueError("timeline names no tracks")
    return {"events": slices, "tracks": len(last_ts)}


def load_timeline(path: str) -> Dict[str, object]:
    with open(path) as f:
        return json.load(f)
