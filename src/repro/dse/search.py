"""Successive-halving frontier search over ``HardwareConfig`` space
(DESIGN.md §16).

The grid sweep pays full-fidelity simulation for every design point; at
CIMFlow scale (ROADMAP item 4) that caps exploration at ~dozens of
points.  Successive halving spends the budget where it matters: early
rungs rank every candidate with a *cheap proxy* — the same canonical
``plan_model -> simulate_plan`` path, but at a reduced sequence length
and without the expensive ``bottleneck``/``headroom`` what-if stamps —
and only the survivors graduate to the next fidelity rung.  The final
rung re-evaluates survivors through the unmodified grid path
(``run_sweep(stamp=True)`` at the target shape), so every emitted
``SweepRow`` is exactly what the exhaustive grid would have produced for
that point: same replayable plan JSON, same frontier/knee extraction,
same attribution stamps.

Rung schedule: with ``N`` candidates, ``eta`` halving rate and ``R``
rungs, rung ``r`` evaluates ``ceil(N / eta**r)`` candidates at sequence
fidelity ``max(min_seq, target // eta**(R-1-r))`` (per model — the
target resolves each family's paper-typical default when ``seq_len=0``).
Survivor selection is frontier-safe by construction: every point on any
proxy rung's per-cell (model x calibration x energy-table) Pareto
frontier survives unconditionally; the remaining quota fills by
Pareto-peel rank (rank 0 = frontier, peel, rank 1, ...) minimized across
cells, ties broken by candidate order.  Determinism: no RNG anywhere
except ``sample_space``'s seeded candidate draw; identical inputs yield
identical rungs, survivors, and rows.

Proxy evaluations share the simulation cache under the ``"proxy"``
evaluator namespace (a stamp-less record must never satisfy a
full-fidelity lookup), so repeated searches — and the search's own
re-visits — warm-start from disk like the grid path does.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.configs.hardware import HardwareConfig
from repro.dse.sweep import (Axes, DEFAULT_AXES, SweepResult, SweepRow,
                             grid_points, pareto_frontier, run_sweep)
from repro.sim.energy import EnergyModel, STREAMDCIM_ENERGY_BASE


def sample_space(n: Optional[int] = None,
                 base: Optional[HardwareConfig] = None,
                 axes: Axes = DEFAULT_AXES,
                 include_presets: bool = True,
                 seed: int = 0,
                 ) -> Tuple[List[HardwareConfig], List[Dict[str, object]]]:
    """Materialize the candidate space: the validated grid (presets
    first, like ``grid_points``), deterministically subsampled to ``n``
    points with a seeded draw when the grid is larger.  Presets are
    always kept — a budget draw never drops the named designs."""
    from repro.configs import registry
    presets = (tuple(registry.HW_CONFIGS.values())
               if include_presets else ())
    points, skipped = grid_points(base, axes, presets)
    if n is None or n >= len(points):
        return points, skipped
    n = max(n, 0)
    head = points[:min(len(presets), n)]
    tail = points[len(head):]
    picked = sorted(random.Random(seed).sample(range(len(tail)),
                                               n - len(head)))
    return head + [tail[i] for i in picked], skipped


@dataclasses.dataclass
class RungRecord:
    """One rung's ledger: who was evaluated at what fidelity, who
    survived, and what the cache saved."""

    rung: int
    proxy: bool                       # False only for the final rung
    seq_lens: Dict[str, int]          # model -> evaluated seq fidelity
    candidates: List[str]             # hw names entering this rung
    survivors: List[str]              # hw names leaving this rung
    quota: int
    frontier_protected: List[str]     # rung-frontier union (always kept)
    cache_stats: Dict[str, int]

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SearchResult:
    """Final full-fidelity sweep over the surviving candidates plus the
    per-rung elimination ledger."""

    sweep: SweepResult
    rungs: List[RungRecord]
    space_size: int
    eta: int
    proxy_sims: int                   # simulated points on proxy rungs
    full_sims: int                    # simulated points at full fidelity

    def to_dict(self) -> Dict[str, object]:
        d = self.sweep.to_dict()
        d["search"] = {
            "space_size": self.space_size,
            "eta": self.eta,
            "num_rungs": len(self.rungs),
            "proxy_sims": self.proxy_sims,
            "full_sims": self.full_sims,
            "rungs": [r.to_dict() for r in self.rungs],
        }
        return d


def _resolved_target_seq(cfg, seq_len: int) -> int:
    """The numeric shape a ``seq_len=0`` sweep actually simulates (the
    workload builders' paper-typical defaults), so the proxy rung ladder
    divides a real number."""
    if seq_len:
        return seq_len
    from repro.core.types import Family
    if cfg.family == Family.ENCDEC:
        return 448
    return 4096


def _peel_ranks(rows: Sequence[SweepRow]) -> Dict[str, int]:
    """Pareto-peel rank per design-point name within one frontier cell:
    rank 0 = on the frontier, remove it, rank 1 = next skyline, ..."""
    remaining = list(rows)
    ranks: Dict[str, int] = {}
    rank = 0
    while remaining:
        front = pareto_frontier(remaining)
        names = {r.hw for r in front}
        for nm in names:
            ranks.setdefault(nm, rank)
        remaining = [r for r in remaining if r.hw not in names]
        rank += 1
    return ranks


def successive_halving(models: Optional[Sequence[str]] = None,
                       base: Optional[HardwareConfig] = None,
                       axes: Axes = DEFAULT_AXES,
                       candidates: Optional[Sequence[HardwareConfig]] = None,
                       num_candidates: Optional[int] = None,
                       eta: int = 2,
                       rungs: Optional[int] = None,
                       seq_len: int = 0,
                       min_seq: int = 128,
                       energy_model: Optional[EnergyModel] = None,
                       energy_models: Optional[Sequence[EnergyModel]] = None,
                       include_presets: bool = True,
                       knee_tolerance: float = 0.10,
                       calibrations: Sequence[object] = (None,),
                       cache=None,
                       workers: Optional[int] = None,
                       seed: int = 0,
                       progress=None) -> SearchResult:
    """Run the rung schedule described in the module docstring and
    return the survivors' full-fidelity ``SweepResult`` plus the ledger.

    ``candidates`` bypasses space sampling with an explicit point list
    (the small-grid equivalence tests); otherwise ``sample_space``
    draws ``num_candidates`` from the ``axes`` grid.  ``cache`` /
    ``workers`` thread straight through to ``run_sweep``."""
    from repro.configs import registry
    if eta < 2:
        raise ValueError(f"eta must be >= 2, got {eta}")
    ems = (list(energy_models) if energy_models
           else [energy_model or STREAMDCIM_ENERGY_BASE])
    model_names = list(models) if models else list(registry.SIM_ARCHS)
    if candidates is not None:
        pool, skipped = list(candidates), []
    else:
        pool, skipped = sample_space(num_candidates, base, axes,
                                     include_presets, seed)
    n = len(pool)
    if rungs is None:
        # Enough rungs that the final one simulates <= max(4, N/eta)
        # points, capped so the cheapest proxy stays a meaningful shape.
        rungs = 2 if n <= 16 else 3
    rungs = max(int(rungs), 1)
    by_name = {hw.name: hw for hw in pool}
    if len(by_name) != n:
        raise ValueError("candidate design-point names must be unique")
    cfgs = {m: registry.get_config(m) for m in model_names}
    targets = {m: _resolved_target_seq(cfgs[m], seq_len)
               for m in model_names}

    alive: List[str] = [hw.name for hw in pool]
    ledger: List[RungRecord] = []
    proxy_sims = 0
    for r in range(rungs - 1):
        quota = max(1, math.ceil(n / eta ** (r + 1)))
        if len(alive) <= quota:
            break
        div = eta ** (rungs - 1 - r)
        rung_seqs = {m: max(min_seq, targets[m] // div)
                     for m in model_names}
        hw_list = [by_name[nm] for nm in alive]
        # Per-model proxy sweep at that model's rung fidelity; stamp=False
        # skips the what-if headroom (ranking fodder, not artifacts).
        scores: Dict[str, int] = {}
        protected: List[str] = []
        rung_stats: Dict[str, int] = {}
        for m in model_names:
            res = run_sweep(models=[m], seq_lens=(rung_seqs[m],),
                            energy_models=ems, include_presets=False,
                            calibrations=calibrations, hw_points=hw_list,
                            cache=cache, workers=workers, stamp=False,
                            progress=progress)
            proxy_sims += len(hw_list) * len(calibrations)
            for k, v in res.cache_stats.items():
                rung_stats[k] = rung_stats.get(k, 0) + v
            for cell in res._cells():
                cell_rows = res.rows_for(cell[0], seq_len=cell[1],
                                         calibration=cell[2],
                                         energy_model=cell[3])
                ranks = _peel_ranks(cell_rows)
                for nm, rk in ranks.items():
                    scores[nm] = min(scores.get(nm, rk), rk)
                for row in pareto_frontier(cell_rows):
                    if row.hw not in protected:
                        protected.append(row.hw)
        # Frontier-safe survivor selection: rung-frontier union first,
        # then fill to quota by peel rank, ties by candidate order.
        survivors = [nm for nm in alive if nm in protected]
        if len(survivors) < quota:
            rest = sorted((nm for nm in alive if nm not in protected),
                          key=lambda nm: (scores.get(nm, n), alive.index(nm)))
            survivors += rest[:quota - len(survivors)]
        survivors = [nm for nm in alive if nm in survivors]  # stable order
        ledger.append(RungRecord(
            rung=r, proxy=True, seq_lens=dict(rung_seqs),
            candidates=list(alive), survivors=list(survivors),
            quota=quota, frontier_protected=list(protected),
            cache_stats=rung_stats))
        alive = survivors

    final_hw = [by_name[nm] for nm in alive]
    sweep = run_sweep(models=model_names, seq_lens=(seq_len,),
                      energy_models=ems, include_presets=False,
                      knee_tolerance=knee_tolerance,
                      calibrations=calibrations, hw_points=final_hw,
                      cache=cache, workers=workers, stamp=True,
                      progress=progress)
    sweep.skipped = list(skipped)
    ledger.append(RungRecord(
        rung=len(ledger), proxy=False,
        seq_lens={m: targets[m] if seq_len == 0 else seq_len
                  for m in model_names},
        candidates=list(alive), survivors=list(alive),
        quota=len(alive), frontier_protected=[],
        cache_stats=dict(sweep.cache_stats)))
    return SearchResult(sweep=sweep, rungs=ledger, space_size=n, eta=eta,
                        proxy_sims=proxy_sims,
                        full_sims=(len(final_hw) * len(model_names)
                                   * len(calibrations)))
