"""``repro.dse`` — energy-aware design-space exploration (DESIGN.md §9).

StreamDCIM's §IV evaluation is one hand-picked design point; the
architectural claim (tile-based reconfigurable macros + mixed-stationary
dataflow + ping-pong rewriting) is about *the space* of design points.
This package sweeps that space: a grid over ``HardwareConfig`` fields
(``num_groups``/``gen_groups`` splits, ``rewrite_bus_bits``,
``ping_pong``, any field via ``Axes.extra``) x registry models x shapes,
each point run through the canonical ``plan_model -> simulate_plan`` path
and scored with ``repro.sim.energy``.

Artifacts per sweep:

* ``SweepRow``      — latency, HBM bytes, total/per-resource energy, EDP,
                      per-resource utilization, and the serialized
                      ``ExecutionPlan`` (replayable: JSON -> ``from_json``
                      -> ``simulate_plan`` reproduces the row exactly);
* Pareto frontier   — non-dominated (latency, energy) rows per model;
* utilization knee  — the smallest design point within 10% of the best
                      latency per model (ROADMAP §Simulator);
* cost-table axis   — ``run_sweep(energy_models=...)`` folds every
                      ``EnergyModel`` over each simulated point (one
                      simulation per point; energy re-folds) and
                      ``SweepResult.frontier_sensitivity()`` reports how
                      much of the frontier survives swapping the table
                      (``python -m repro.dse --energy-axis``).

The scale-out axis (chips x topology x per-chip ``HardwareConfig``,
DESIGN.md §13) lives in ``repro.shard.sweep`` and is re-exported here:
``run_shard_sweep`` rows carry speedup-vs-chips and scale-out-efficiency
columns next to the single-chip sweep's latency/energy ones
(``python -m repro.shard`` / ``benchmarks/run.py shard``).

Entry points: ``python -m repro.dse`` and ``benchmarks/run.py dse``
(``--json`` artifact, ``--points N`` budget for CI smoke).
"""
from repro.dse.cache import (CachedPoint, SimCache, energy_fingerprint,
                             hw_fingerprint, sim_cache_key)
from repro.dse.sweep import (Axes, DEFAULT_AXES, SweepResult, SweepRow,
                             calibration_label, dominates, grid_points,
                             pareto_frontier, resolve_plan_json, run_sweep,
                             simulate_point, utilization_knee)
from repro.dse.search import (RungRecord, SearchResult, sample_space,
                              successive_halving)
from repro.shard.sweep import (ShardSweepResult, ShardSweepRow,
                               run_shard_sweep)

__all__ = [
    "Axes", "CachedPoint", "DEFAULT_AXES", "RungRecord", "SearchResult",
    "SimCache", "SweepResult", "SweepRow", "calibration_label",
    "dominates", "energy_fingerprint", "grid_points", "hw_fingerprint",
    "pareto_frontier", "resolve_plan_json", "run_sweep", "sample_space",
    "ShardSweepResult", "ShardSweepRow", "run_shard_sweep", "sim_cache_key",
    "simulate_point", "successive_halving", "utilization_knee",
]
