"""The DSE sweep engine: (HardwareConfig grid) x (models) x (shapes).

Every point runs the canonical compile->plan->simulate path
(``plan_model`` -> ``simulate_plan``) and is recorded as one ``SweepRow``
carrying latency, total/per-resource energy, EDP, per-resource
utilization, and the serialized ``ExecutionPlan`` — the plan JSON is the
replay artifact: feeding it back through ``ExecutionPlan.from_json`` ->
``simulate_plan`` reproduces the row's latency and energy exactly
(test-pinned), so a frontier point found in a sweep can always be
re-examined at full trace fidelity.

Grid semantics: design points are ``HardwareConfig.sweep`` products over
``Axes`` (paired ``groups`` splits so ``gen_groups < num_groups`` holds by
construction, plus independent axes); combinations the validator rejects
are recorded in ``SweepResult.skipped``, never silently dropped.  The
registry presets always lead the point list, so a ``--points N`` budget
(CI smoke) still covers the named designs.

Trace calibration (DESIGN.md §10): ``run_sweep(calibrations=...)`` adds a
third partition axis next to model and shape — each entry (None, or a
``repro.sim.replay.CalibrationReport`` fitted from recorded kernel
traces) sweeps the grid once with the fitted per-resource cycle scales
applied; rows are labeled and frontier/knee extraction never mixes
calibrated with uncalibrated timing.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.configs.hardware import HardwareConfig
from repro.sim.energy import EnergyModel, STREAMDCIM_ENERGY_BASE


# ---------------------------------------------------------------------------
# Grid definition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Axes:
    """One sweep grid over ``HardwareConfig`` fields.

    ``groups`` pairs ``(num_groups, gen_groups)`` because the two fields
    are constrained together (the mixed-stationary split); the remaining
    axes are independent.  ``extra`` admits any other config field
    (``macros_per_group``, ``noc_bytes_per_cycle``, ...) by name.
    """

    groups: Tuple[Tuple[int, int], ...] = ((2, 1), (4, 1), (4, 2),
                                           (8, 2), (8, 4))
    rewrite_bus_bits: Tuple[int, ...] = (512, 2048)
    ping_pong: Tuple[bool, ...] = (True, False)
    extra: Mapping[str, Tuple[object, ...]] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        clash = sorted(set(self.extra)
                       & {"num_groups", "gen_groups", "rewrite_bus_bits",
                          "ping_pong"})
        if clash:
            raise ValueError(
                f"extra axes {clash} collide with built-in Axes fields — "
                "set them on the Axes itself (groups pairs num_groups "
                "with gen_groups)")

    def overrides(self) -> Iterable[Dict[str, object]]:
        """Yield one override dict per grid combination."""
        extra_keys = sorted(self.extra)
        extra_vals = [self.extra[k] for k in extra_keys]
        for (ng, gg), bus, pp, *ev in itertools.product(
                self.groups, self.rewrite_bus_bits, self.ping_pong,
                *extra_vals):
            ov: Dict[str, object] = {"num_groups": ng, "gen_groups": gg,
                                     "rewrite_bus_bits": bus,
                                     "ping_pong": pp}
            ov.update(zip(extra_keys, ev))
            yield ov


DEFAULT_AXES = Axes()


def grid_points(base: Optional[HardwareConfig] = None,
                axes: Axes = DEFAULT_AXES,
                presets: Sequence[HardwareConfig] = (),
                ) -> Tuple[List[HardwareConfig], List[Dict[str, object]]]:
    """Materialize the design-point list: ``presets`` first (dedup'd by
    parameters), then the validated grid.  Returns (points, skipped) where
    each skipped record carries the overrides and the validator's reason."""
    points: List[HardwareConfig] = []
    seen = set()

    def key(hw: HardwareConfig):
        d = dataclasses.asdict(hw)
        d.pop("name")
        return tuple(sorted(d.items()))

    for hw in presets:
        if key(hw) not in seen:
            seen.add(key(hw))
            points.append(hw)
    skipped: List[Dict[str, object]] = []
    for ov in axes.overrides():
        try:
            hw = HardwareConfig.sweep(base, **ov)
        except ValueError as e:
            skipped.append({"overrides": ov, "reason": str(e)})
            continue
        if key(hw) not in seen:
            seen.add(key(hw))
            points.append(hw)
    return points, skipped


# ---------------------------------------------------------------------------
# Sweep rows / results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepRow:
    """One simulated (design point, model, shape) record."""

    model: str
    seq_len: int              # 0 = the model's paper-typical default
    hw: str
    hw_params: Mapping[str, object]
    energy_model: str
    latency_cycles: int
    hbm_bytes: int
    energy_pj: float
    edp: float                # energy_pj * latency_cycles
    utilization: Mapping[str, float]
    energy_by_resource: Mapping[str, float]
    plan_json: str            # ExecutionPlan.to_json() — the replay artifact
    calibration: str = "analytic"   # CalibrationReport the timing used
                                    # ("analytic" = uncalibrated model)
    # The applied per-resource scale factors (empty = analytic), so a
    # calibrated row is reproducible from the artifact alone:
    # simulate_plan(from_json(plan_json), calibration=calibration_scale)
    # replays the row's latency exactly, like plan_json does analytically.
    calibration_scale: Mapping[str, float] = dataclasses.field(
        default_factory=dict)
    # Critical resource of the simulated trace (``obs.bottleneck_of``) —
    # what a next design iteration at this point should attack.
    bottleneck: str = ""
    # Per-resource causal headroom (``obs.whatif.headroom``): fractional
    # makespan reduction with that resource free.  Unlike busy-share this
    # is a what-if over the trace DAG, so a busy-but-off-path resource
    # scores ~0 — the frontier explains *why* a design wins.
    headroom: Mapping[str, float] = dataclasses.field(default_factory=dict)

    @property
    def num_macros(self) -> int:
        return (int(self.hw_params["num_groups"])
                * int(self.hw_params["macros_per_group"]))

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["utilization"] = dict(self.utilization)
        d["energy_by_resource"] = dict(self.energy_by_resource)
        d["hw_params"] = dict(self.hw_params)
        d["calibration_scale"] = dict(self.calibration_scale)
        d["headroom"] = dict(self.headroom)
        d["num_macros"] = self.num_macros
        return d


def pareto_frontier(rows: Sequence[SweepRow]) -> List[SweepRow]:
    """Non-dominated rows under (latency_cycles, energy_pj) minimization:
    a row survives unless some other row is <= on both metrics and < on at
    least one.  Single pass over the latency-sorted list (skyline sweep);
    rows tied on *both* metrics are all non-dominated (``dominates``
    requires one strict inequality) and all kept — equal-cost points sort
    adjacent, so an exact tie with the last frontier member is the only
    tie case."""
    ordered = sorted(rows, key=lambda r: (r.latency_cycles, r.energy_pj))
    frontier: List[SweepRow] = []
    best: Optional[Tuple[int, float]] = None    # last frontier (lat, pj)
    for r in ordered:
        cost = (r.latency_cycles, r.energy_pj)
        if best is None or r.energy_pj < best[1] or cost == best:
            frontier.append(r)
            best = cost
    return frontier


def dominates(a: SweepRow, b: SweepRow) -> bool:
    """True if ``a`` Pareto-dominates ``b`` on (latency, energy)."""
    return (a.latency_cycles <= b.latency_cycles
            and a.energy_pj <= b.energy_pj
            and (a.latency_cycles < b.latency_cycles
                 or a.energy_pj < b.energy_pj))


def utilization_knee(rows: Sequence[SweepRow],
                     tolerance: float = 0.10) -> Optional[SweepRow]:
    """The ROADMAP's per-model utilization knee: the *smallest* design
    point (fewest total macros, ties broken by lower energy) whose latency
    is within ``tolerance`` of the best latency any point achieves —
    i.e. where adding macro groups stops buying speed and only dilutes
    utilization.  Returns None for an empty row set."""
    if not rows:
        return None
    best = min(r.latency_cycles for r in rows)
    eligible = [r for r in rows
                if r.latency_cycles <= (1.0 + tolerance) * best]
    return min(eligible, key=lambda r: (r.num_macros, r.energy_pj))


@dataclasses.dataclass
class SweepResult:
    """All rows of one sweep plus the derived artifacts."""

    rows: List[SweepRow]
    skipped: List[Dict[str, object]]
    energy_model: str
    knee_tolerance: float = 0.10

    def models(self) -> List[str]:
        seen: List[str] = []
        for r in self.rows:
            if r.model not in seen:
                seen.append(r.model)
        return seen

    def groups(self) -> List[Tuple[str, int]]:
        """The comparison units: (model, seq_len) pairs in row order.
        Frontier and knee extraction never mix shapes — the same design
        point at a shorter sequence would spuriously 'dominate' its
        longer-sequence twin, exactly like mixing models would."""
        seen: List[Tuple[str, int]] = []
        for r in self.rows:
            key = (r.model, r.seq_len)
            if key not in seen:
                seen.append(key)
        return seen

    def calibrations(self) -> List[str]:
        """Distinct calibration labels in row order (``["analytic"]``
        for an uncalibrated sweep).  A third partition key next to model
        and shape: calibrated latencies are scaled by fitted factors, so
        letting an analytic row 'dominate' a calibrated one would be as
        meaningless as mixing shapes."""
        seen: List[str] = []
        for r in self.rows:
            if r.calibration not in seen:
                seen.append(r.calibration)
        return seen

    def energy_models(self) -> List[str]:
        """Distinct energy-model labels in row order.  The fourth
        partition key (ROADMAP: ENERGY_CONFIGS x HW grid): energy_pj
        values under different pJ-cost tables are not comparable, so
        frontier/knee extraction never mixes them."""
        seen: List[str] = []
        for r in self.rows:
            if r.energy_model not in seen:
                seen.append(r.energy_model)
        return seen

    def _cells(self) -> List[Tuple[str, int, str, str]]:
        """(model, seq_len, calibration, energy_model) cells with rows."""
        cals = self.calibrations()
        ems = self.energy_models()
        return [(m, s, c, e) for m, s in self.groups() for c in cals
                for e in ems
                if any(r.model == m and r.seq_len == s
                       and r.calibration == c and r.energy_model == e
                       for r in self.rows)]

    def label(self, model: str, seq_len: int,
              calibration: Optional[str] = None,
              energy_model: Optional[str] = None) -> str:
        """Group label for reports: just the model name when one shape
        was swept, ``model@seqN`` when several disambiguate, a
        ``+calibration`` suffix when the sweep ran a calibration axis,
        and a ``/energy-model`` suffix when it ran the energy axis."""
        multi = len({s for m, s in self.groups() if m == model}) > 1
        lbl = f"{model}@seq{seq_len}" if multi else model
        if calibration is not None and len(self.calibrations()) > 1:
            lbl += f"+{calibration}"
        if energy_model is not None and len(self.energy_models()) > 1:
            lbl += f"/{energy_model}"
        return lbl

    def rows_for(self, model: str, seq_len: Optional[int] = None,
                 calibration: Optional[str] = None,
                 energy_model: Optional[str] = None) -> List[SweepRow]:
        return [r for r in self.rows if r.model == model
                and (seq_len is None or r.seq_len == seq_len)
                and (calibration is None or r.calibration == calibration)
                and (energy_model is None
                     or r.energy_model == energy_model)]

    def pareto(self, model: Optional[str] = None,
               seq_len: Optional[int] = None,
               calibration: Optional[str] = None,
               energy_model: Optional[str] = None) -> List[SweepRow]:
        """Latency/energy frontier, computed per (model, seq_len,
        calibration, energy_model) cell and concatenated in cell order
        over whatever arguments are left unfixed."""
        out: List[SweepRow] = []
        for m, s, c, e in self._cells():
            if (model is None or m == model) \
                    and (seq_len is None or s == seq_len) \
                    and (calibration is None or c == calibration) \
                    and (energy_model is None or e == energy_model):
                out.extend(pareto_frontier(self.rows_for(m, s, c, e)))
        return out

    def knees(self) -> Dict[str, SweepRow]:
        out: Dict[str, SweepRow] = {}
        for m, s, c, e in self._cells():
            knee = utilization_knee(self.rows_for(m, s, c, e),
                                    self.knee_tolerance)
            if knee is not None:
                out[self.label(m, s, c, e)] = knee
        return out

    def frontier_sensitivity(self) -> Dict[str, Dict[str, object]]:
        """How sensitive the Pareto frontier is to the energy cost table
        (the ROADMAP's ENERGY_CONFIGS x HW question): per (model, shape,
        calibration) group, the frontier's design-point names under each
        energy model, the Jaccard overlap of each against the base
        (first-swept) model's frontier, and the designs stable across
        *every* cost table.  Empty when only one energy model was swept
        (nothing to compare)."""
        ems = self.energy_models()
        if len(ems) < 2:
            return {}
        base = ems[0]
        out: Dict[str, Dict[str, object]] = {}
        for m, s in self.groups():
            for c in self.calibrations():
                fronts = {e: sorted({r.hw for r in pareto_frontier(
                    self.rows_for(m, s, c, e))}) for e in ems
                    if self.rows_for(m, s, c, e)}
                if len(fronts) < 2 or base not in fronts:
                    continue
                bset = set(fronts[base])
                jac = {}
                for e, hws in fronts.items():
                    u = bset | set(hws)
                    jac[e] = (len(bset & set(hws)) / len(u)) if u else 1.0
                stable = sorted(set.intersection(
                    *[set(h) for h in fronts.values()]))
                out[self.label(m, s, c)] = {
                    "base": base,
                    "frontier_hw": fronts,
                    "jaccard_vs_base": jac,
                    "stable_hw": stable,
                }
        return out

    def to_dict(self) -> Dict[str, object]:
        # Frontier members ARE entries of self.rows: index by identity
        # (value-equality .index() would deep-compare plan JSON, O(rows^2)).
        index_of = {id(r): i for i, r in enumerate(self.rows)}
        pareto_ids = {self.label(m, s, c, e):
                      [index_of[id(r)]
                       for r in pareto_frontier(self.rows_for(m, s, c, e))]
                      for m, s, c, e in self._cells()}
        return {
            "energy_model": self.energy_model,
            "energy_models": self.energy_models(),
            "num_rows": len(self.rows),
            "calibrations": self.calibrations(),
            "rows": [r.to_dict() for r in self.rows],
            "skipped": list(self.skipped),
            "pareto": pareto_ids,  # row indices, per (model, shape, cal, em)
            "knees": {m: r.to_dict() for m, r in self.knees().items()},
            "knee_tolerance": self.knee_tolerance,
            "frontier_sensitivity": self.frontier_sensitivity(),
        }


# ---------------------------------------------------------------------------
# The sweep driver
# ---------------------------------------------------------------------------

def calibration_label(calibration) -> str:
    """Row label for a ``simulate_point(calibration=...)`` argument:
    ``"analytic"`` for None (uncalibrated timing), the report's name for
    a ``CalibrationReport``, or a content-derived ``custom:ATTNx2-...``
    label for a raw scale mapping — two *different* ad-hoc scalings must
    never collapse into one frontier cell."""
    if calibration is None:
        return "analytic"
    name = getattr(calibration, "name", None)
    if name is not None:
        return name
    return "custom:" + "-".join(f"{r}x{s:g}"
                                for r, s in sorted(calibration.items()))


def _point_rows(cfg, hw: HardwareConfig, seq_len: int,
                energy_models: Sequence[EnergyModel],
                calibration=None) -> List[SweepRow]:
    """One (model config, design point, shape) evaluation through the
    canonical path — ``plan_model`` -> ``simulate_plan`` -> energy fold —
    returning one row per energy model.  The simulation runs *once*; the
    energy axis is a pure re-fold of the same trace under each pJ-cost
    table (latency/bytes are cost-table-invariant by construction)."""
    from repro.obs.attribution import bottleneck_of
    from repro.obs.whatif import headroom as causal_headroom
    from repro.plan.planner import plan_model
    from repro.sim.pipeline import simulate_plan
    from repro.sim.replay import resolve_calibration
    plan = plan_model(cfg, hw=hw, seq_len=seq_len)
    res = simulate_plan(plan, hw=hw, calibration=calibration)
    scale = resolve_calibration(calibration)
    plan_json = plan.to_json()
    bottleneck = bottleneck_of(res.trace)
    hroom = causal_headroom(res.trace)
    rows = []
    for em in energy_models:
        rep = res.energy(em)
        rows.append(SweepRow(
            model=cfg.name, seq_len=seq_len, hw=hw.name,
            hw_params=dataclasses.asdict(hw), energy_model=em.name,
            latency_cycles=res.cycles, hbm_bytes=res.hbm_bytes,
            energy_pj=rep.total_pj, edp=rep.edp,
            utilization=res.trace.utilizations(),
            energy_by_resource=dict(rep.by_resource),
            plan_json=plan_json,
            calibration=calibration_label(calibration),
            calibration_scale=dict(scale) if scale else {},
            bottleneck=bottleneck,
            headroom=hroom))
    return rows


def simulate_point(cfg, hw: HardwareConfig, seq_len: int = 0,
                   energy_model: Optional[EnergyModel] = None,
                   calibration=None) -> SweepRow:
    """One (model config, design point, shape) evaluation through the
    canonical path: ``plan_model`` -> ``simulate_plan`` -> energy fold.
    ``calibration`` (a ``repro.sim.replay.CalibrationReport`` or raw
    resource->factor mapping) scales the analytic timing by the fitted
    per-resource factors — the trace-calibrated sweep axis (DESIGN.md
    §10)."""
    em = energy_model or STREAMDCIM_ENERGY_BASE
    return _point_rows(cfg, hw, seq_len, [em], calibration)[0]


def run_sweep(models: Optional[Sequence[str]] = None,
              base: Optional[HardwareConfig] = None,
              axes: Axes = DEFAULT_AXES,
              points: Optional[int] = None,
              seq_lens: Sequence[int] = (0,),
              energy_model: Optional[EnergyModel] = None,
              energy_models: Optional[Sequence[EnergyModel]] = None,
              include_presets: bool = True,
              knee_tolerance: float = 0.10,
              calibrations: Sequence[object] = (None,),
              progress=None) -> SweepResult:
    """Run the grid.  ``models`` are registry arch names (default: the
    simulator-supported pool); ``points`` caps the number of *design
    points* (the per-model row count follows), presets first so a small
    budget still sweeps the named configs.

    ``calibrations`` is the trace-calibration axis (DESIGN.md §10): each
    entry — None for the uncalibrated analytic model, or a
    ``repro.sim.replay.CalibrationReport`` / raw resource->factor
    mapping — sweeps the whole grid once, labeled on the rows; frontier
    and knee extraction never mix calibrations.

    ``energy_models`` is the cost-table axis (ROADMAP: ENERGY_CONFIGS x
    HW grid): each ``EnergyModel`` re-folds every simulated point's trace
    (the simulation itself runs once per point — latency is
    cost-table-invariant), yielding per-table frontiers and the
    ``SweepResult.frontier_sensitivity()`` report.  The scalar
    ``energy_model`` remains the single-table entry point."""
    from repro.configs import registry
    ems = (list(energy_models) if energy_models
           else [energy_model or STREAMDCIM_ENERGY_BASE])
    model_names = list(models) if models else list(registry.SIM_ARCHS)
    presets = tuple(registry.HW_CONFIGS.values()) if include_presets else ()
    hw_points, skipped = grid_points(base, axes, presets)
    if points is not None:
        hw_points = hw_points[:max(points, 0)]
    rows: List[SweepRow] = []
    for name in model_names:
        cfg = registry.get_config(name)
        for seq in seq_lens:
            for cal in calibrations:
                for hw in hw_points:
                    pt_rows = _point_rows(cfg, hw, seq, ems,
                                          calibration=cal)
                    rows.extend(pt_rows)
                    if progress is not None:
                        # one call per *simulated point* — the energy
                        # axis re-folds the same trace, no extra work
                        progress(pt_rows[0])
    return SweepResult(rows=rows, skipped=skipped, energy_model=ems[0].name,
                       knee_tolerance=knee_tolerance)
