"""The DSE sweep engine: (HardwareConfig grid) x (models) x (shapes).

Every point runs the canonical compile->plan->simulate path
(``plan_model`` -> ``simulate_plan``) and is recorded as one ``SweepRow``
carrying latency, total/per-resource energy, EDP, per-resource
utilization, and the serialized ``ExecutionPlan`` — the plan JSON is the
replay artifact: feeding it back through ``ExecutionPlan.from_json`` ->
``simulate_plan`` reproduces the row's latency and energy exactly
(test-pinned), so a frontier point found in a sweep can always be
re-examined at full trace fidelity.

Grid semantics: design points are ``HardwareConfig.sweep`` products over
``Axes`` (paired ``groups`` splits so ``gen_groups < num_groups`` holds by
construction, plus independent axes); combinations the validator rejects
are recorded in ``SweepResult.skipped``, never silently dropped.  The
registry presets always lead the point list, so a ``--points N`` budget
(CI smoke) still covers the named designs.

Trace calibration (DESIGN.md §10): ``run_sweep(calibrations=...)`` adds a
third partition axis next to model and shape — each entry (None, or a
``repro.sim.replay.CalibrationReport`` fitted from recorded kernel
traces) sweeps the grid once with the fitted per-resource cycle scales
applied; rows are labeled and frontier/knee extraction never mixes
calibrated with uncalibrated timing.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.configs.hardware import HardwareConfig
from repro.dse.cache import (CachedPoint, SimCache, energy_fingerprint,
                             resolve_cache, sim_cache_key)
from repro.sim.energy import EnergyModel, STREAMDCIM_ENERGY_BASE


# ---------------------------------------------------------------------------
# Grid definition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Axes:
    """One sweep grid over ``HardwareConfig`` fields.

    ``groups`` pairs ``(num_groups, gen_groups)`` because the two fields
    are constrained together (the mixed-stationary split); the remaining
    axes are independent.  ``extra`` admits any other config field
    (``macros_per_group``, ``noc_bytes_per_cycle``, ...) by name.
    """

    groups: Tuple[Tuple[int, int], ...] = ((2, 1), (4, 1), (4, 2),
                                           (8, 2), (8, 4))
    rewrite_bus_bits: Tuple[int, ...] = (512, 2048)
    ping_pong: Tuple[bool, ...] = (True, False)
    extra: Mapping[str, Tuple[object, ...]] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        clash = sorted(set(self.extra)
                       & {"num_groups", "gen_groups", "rewrite_bus_bits",
                          "ping_pong"})
        if clash:
            raise ValueError(
                f"extra axes {clash} collide with built-in Axes fields — "
                "set them on the Axes itself (groups pairs num_groups "
                "with gen_groups)")

    def overrides(self) -> Iterable[Dict[str, object]]:
        """Yield one override dict per grid combination."""
        extra_keys = sorted(self.extra)
        extra_vals = [self.extra[k] for k in extra_keys]
        for (ng, gg), bus, pp, *ev in itertools.product(
                self.groups, self.rewrite_bus_bits, self.ping_pong,
                *extra_vals):
            ov: Dict[str, object] = {"num_groups": ng, "gen_groups": gg,
                                     "rewrite_bus_bits": bus,
                                     "ping_pong": pp}
            ov.update(zip(extra_keys, ev))
            yield ov


DEFAULT_AXES = Axes()


def grid_points(base: Optional[HardwareConfig] = None,
                axes: Axes = DEFAULT_AXES,
                presets: Sequence[HardwareConfig] = (),
                ) -> Tuple[List[HardwareConfig], List[Dict[str, object]]]:
    """Materialize the design-point list: ``presets`` first (dedup'd by
    parameters), then the validated grid.  Returns (points, skipped) where
    each skipped record carries the overrides and the validator's reason."""
    points: List[HardwareConfig] = []
    seen = set()

    def key(hw: HardwareConfig):
        d = dataclasses.asdict(hw)
        d.pop("name")
        return tuple(sorted(d.items()))

    for hw in presets:
        if key(hw) not in seen:
            seen.add(key(hw))
            points.append(hw)
    skipped: List[Dict[str, object]] = []
    for ov in axes.overrides():
        try:
            hw = HardwareConfig.sweep(base, **ov)
        except ValueError as e:
            skipped.append({"overrides": ov, "reason": str(e)})
            continue
        if key(hw) not in seen:
            seen.add(key(hw))
            points.append(hw)
    return points, skipped


# ---------------------------------------------------------------------------
# Sweep rows / results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepRow:
    """One simulated (design point, model, shape) record."""

    model: str
    seq_len: int              # 0 = the model's paper-typical default
    hw: str
    hw_params: Mapping[str, object]
    energy_model: str
    latency_cycles: int
    hbm_bytes: int
    energy_pj: float
    edp: float                # energy_pj * latency_cycles
    utilization: Mapping[str, float]
    energy_by_resource: Mapping[str, float]
    plan_json: str            # ExecutionPlan.to_json() — the replay artifact
    calibration: str = "analytic"   # CalibrationReport the timing used
                                    # ("analytic" = uncalibrated model)
    # The applied per-resource scale factors (empty = analytic), so a
    # calibrated row is reproducible from the artifact alone:
    # simulate_plan(from_json(plan_json), calibration=calibration_scale)
    # replays the row's latency exactly, like plan_json does analytically.
    calibration_scale: Mapping[str, float] = dataclasses.field(
        default_factory=dict)
    # Critical resource of the simulated trace (``obs.bottleneck_of``) —
    # what a next design iteration at this point should attack.
    bottleneck: str = ""
    # Per-resource causal headroom (``obs.whatif.headroom``): fractional
    # makespan reduction with that resource free.  Unlike busy-share this
    # is a what-if over the trace DAG, so a busy-but-off-path resource
    # scores ~0 — the frontier explains *why* a design wins.
    headroom: Mapping[str, float] = dataclasses.field(default_factory=dict)

    @property
    def num_macros(self) -> int:
        return (int(self.hw_params["num_groups"])
                * int(self.hw_params["macros_per_group"]))

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["utilization"] = dict(self.utilization)
        d["energy_by_resource"] = dict(self.energy_by_resource)
        d["hw_params"] = dict(self.hw_params)
        d["calibration_scale"] = dict(self.calibration_scale)
        d["headroom"] = dict(self.headroom)
        d["num_macros"] = self.num_macros
        return d


def pareto_frontier(rows: Sequence[SweepRow]) -> List[SweepRow]:
    """Non-dominated rows under (latency_cycles, energy_pj) minimization:
    a row survives unless some other row is <= on both metrics and < on at
    least one.  Single pass over the latency-sorted list (skyline sweep);
    rows tied on *both* metrics are all non-dominated (``dominates``
    requires one strict inequality) and all kept — equal-cost points sort
    adjacent, so an exact tie with the last frontier member is the only
    tie case."""
    ordered = sorted(rows, key=lambda r: (r.latency_cycles, r.energy_pj))
    frontier: List[SweepRow] = []
    best: Optional[Tuple[int, float]] = None    # last frontier (lat, pj)
    for r in ordered:
        cost = (r.latency_cycles, r.energy_pj)
        if best is None or r.energy_pj < best[1] or cost == best:
            frontier.append(r)
            best = cost
    return frontier


def dominates(a: SweepRow, b: SweepRow) -> bool:
    """True if ``a`` Pareto-dominates ``b`` on (latency, energy)."""
    return (a.latency_cycles <= b.latency_cycles
            and a.energy_pj <= b.energy_pj
            and (a.latency_cycles < b.latency_cycles
                 or a.energy_pj < b.energy_pj))


def utilization_knee(rows: Sequence[SweepRow],
                     tolerance: float = 0.10) -> Optional[SweepRow]:
    """The ROADMAP's per-model utilization knee: the *smallest* design
    point (fewest total macros, ties broken by lower energy) whose latency
    is within ``tolerance`` of the best latency any point achieves —
    i.e. where adding macro groups stops buying speed and only dilutes
    utilization.  Returns None for an empty row set."""
    if not rows:
        return None
    best = min(r.latency_cycles for r in rows)
    eligible = [r for r in rows
                if r.latency_cycles <= (1.0 + tolerance) * best]
    return min(eligible, key=lambda r: (r.num_macros, r.energy_pj))


@dataclasses.dataclass
class SweepResult:
    """All rows of one sweep plus the derived artifacts."""

    rows: List[SweepRow]
    skipped: List[Dict[str, object]]
    energy_model: str
    knee_tolerance: float = 0.10
    # Simulation-cache counters for this sweep (DESIGN.md §16): hits /
    # misses / disk_hits / stores, merged across parallel workers.
    # Empty when the sweep ran uncached.
    cache_stats: Dict[str, int] = dataclasses.field(default_factory=dict)

    def models(self) -> List[str]:
        seen: List[str] = []
        for r in self.rows:
            if r.model not in seen:
                seen.append(r.model)
        return seen

    def groups(self) -> List[Tuple[str, int]]:
        """The comparison units: (model, seq_len) pairs in row order.
        Frontier and knee extraction never mix shapes — the same design
        point at a shorter sequence would spuriously 'dominate' its
        longer-sequence twin, exactly like mixing models would."""
        seen: List[Tuple[str, int]] = []
        for r in self.rows:
            key = (r.model, r.seq_len)
            if key not in seen:
                seen.append(key)
        return seen

    def calibrations(self) -> List[str]:
        """Distinct calibration labels in row order (``["analytic"]``
        for an uncalibrated sweep).  A third partition key next to model
        and shape: calibrated latencies are scaled by fitted factors, so
        letting an analytic row 'dominate' a calibrated one would be as
        meaningless as mixing shapes."""
        seen: List[str] = []
        for r in self.rows:
            if r.calibration not in seen:
                seen.append(r.calibration)
        return seen

    def energy_models(self) -> List[str]:
        """Distinct energy-model labels in row order.  The fourth
        partition key (ROADMAP: ENERGY_CONFIGS x HW grid): energy_pj
        values under different pJ-cost tables are not comparable, so
        frontier/knee extraction never mixes them."""
        seen: List[str] = []
        for r in self.rows:
            if r.energy_model not in seen:
                seen.append(r.energy_model)
        return seen

    def _cells(self) -> List[Tuple[str, int, str, str]]:
        """(model, seq_len, calibration, energy_model) cells with rows."""
        cals = self.calibrations()
        ems = self.energy_models()
        return [(m, s, c, e) for m, s in self.groups() for c in cals
                for e in ems
                if any(r.model == m and r.seq_len == s
                       and r.calibration == c and r.energy_model == e
                       for r in self.rows)]

    def label(self, model: str, seq_len: int,
              calibration: Optional[str] = None,
              energy_model: Optional[str] = None) -> str:
        """Group label for reports: just the model name when one shape
        was swept, ``model@seqN`` when several disambiguate, a
        ``+calibration`` suffix when the sweep ran a calibration axis,
        and a ``/energy-model`` suffix when it ran the energy axis."""
        multi = len({s for m, s in self.groups() if m == model}) > 1
        lbl = f"{model}@seq{seq_len}" if multi else model
        if calibration is not None and len(self.calibrations()) > 1:
            lbl += f"+{calibration}"
        if energy_model is not None and len(self.energy_models()) > 1:
            lbl += f"/{energy_model}"
        return lbl

    def rows_for(self, model: str, seq_len: Optional[int] = None,
                 calibration: Optional[str] = None,
                 energy_model: Optional[str] = None) -> List[SweepRow]:
        return [r for r in self.rows if r.model == model
                and (seq_len is None or r.seq_len == seq_len)
                and (calibration is None or r.calibration == calibration)
                and (energy_model is None
                     or r.energy_model == energy_model)]

    def pareto(self, model: Optional[str] = None,
               seq_len: Optional[int] = None,
               calibration: Optional[str] = None,
               energy_model: Optional[str] = None) -> List[SweepRow]:
        """Latency/energy frontier, computed per (model, seq_len,
        calibration, energy_model) cell and concatenated in cell order
        over whatever arguments are left unfixed."""
        out: List[SweepRow] = []
        for m, s, c, e in self._cells():
            if (model is None or m == model) \
                    and (seq_len is None or s == seq_len) \
                    and (calibration is None or c == calibration) \
                    and (energy_model is None or e == energy_model):
                out.extend(pareto_frontier(self.rows_for(m, s, c, e)))
        return out

    def knees(self) -> Dict[str, SweepRow]:
        out: Dict[str, SweepRow] = {}
        for m, s, c, e in self._cells():
            knee = utilization_knee(self.rows_for(m, s, c, e),
                                    self.knee_tolerance)
            if knee is not None:
                out[self.label(m, s, c, e)] = knee
        return out

    def frontier_sensitivity(self) -> Dict[str, Dict[str, object]]:
        """How sensitive the Pareto frontier is to the energy cost table
        (the ROADMAP's ENERGY_CONFIGS x HW question): per (model, shape,
        calibration) group, the frontier's design-point names under each
        energy model, the Jaccard overlap of each against the base
        (first-swept) model's frontier, and the designs stable across
        *every* cost table.  Empty when only one energy model was swept
        (nothing to compare)."""
        ems = self.energy_models()
        if len(ems) < 2:
            return {}
        base = ems[0]
        out: Dict[str, Dict[str, object]] = {}
        for m, s in self.groups():
            for c in self.calibrations():
                fronts = {e: sorted({r.hw for r in pareto_frontier(
                    self.rows_for(m, s, c, e))}) for e in ems
                    if self.rows_for(m, s, c, e)}
                if len(fronts) < 2 or base not in fronts:
                    continue
                bset = set(fronts[base])
                jac = {}
                for e, hws in fronts.items():
                    u = bset | set(hws)
                    jac[e] = (len(bset & set(hws)) / len(u)) if u else 1.0
                stable = sorted(set.intersection(
                    *[set(h) for h in fronts.values()]))
                out[self.label(m, s, c)] = {
                    "base": base,
                    "frontier_hw": fronts,
                    "jaccard_vs_base": jac,
                    "stable_hw": stable,
                }
        return out

    def to_dict(self, intern_plans: bool = True) -> Dict[str, object]:
        # Frontier members ARE entries of self.rows: index by identity
        # (value-equality .index() would deep-compare plan JSON, O(rows^2)).
        index_of = {id(r): i for i, r in enumerate(self.rows)}
        pareto_ids = {self.label(m, s, c, e):
                      [index_of[id(r)]
                       for r in pareto_frontier(self.rows_for(m, s, c, e))]
                      for m, s, c, e in self._cells()}
        row_dicts = [r.to_dict() for r in self.rows]
        plan_table: Dict[str, str] = {}
        if intern_plans:
            # Store-by-hash: the energy axis emits one row per cost table
            # per simulated point, all sharing one plan — serializing the
            # plan JSON once per *distinct plan* (rows carry a
            # ``plan_ref`` into ``plan_table``) shrinks the artifact by
            # the axis multiplicity.  ``resolve_plan_json`` rehydrates.
            for rd in row_dicts:
                pj = rd.pop("plan_json")
                ref = hashlib.sha256(pj.encode()).hexdigest()[:16]
                plan_table.setdefault(ref, pj)
                rd["plan_ref"] = ref
        d = {
            "energy_model": self.energy_model,
            "energy_models": self.energy_models(),
            "num_rows": len(self.rows),
            "calibrations": self.calibrations(),
            "rows": row_dicts,
            "skipped": list(self.skipped),
            "pareto": pareto_ids,  # row indices, per (model, shape, cal, em)
            "knees": {m: r.to_dict() for m, r in self.knees().items()},
            "knee_tolerance": self.knee_tolerance,
            "frontier_sensitivity": self.frontier_sensitivity(),
            "cache_stats": dict(self.cache_stats),
        }
        if intern_plans:
            d["plan_table"] = plan_table
        return d


# ---------------------------------------------------------------------------
# The sweep driver
# ---------------------------------------------------------------------------

def calibration_label(calibration) -> str:
    """Row label for a ``simulate_point(calibration=...)`` argument:
    ``"analytic"`` for None (uncalibrated timing), the report's name for
    a ``CalibrationReport``, or a content-derived ``custom:ATTNx2-...``
    label for a raw scale mapping — two *different* ad-hoc scalings must
    never collapse into one frontier cell."""
    if calibration is None:
        return "analytic"
    name = getattr(calibration, "name", None)
    if name is not None:
        return name
    return "custom:" + "-".join(f"{r}x{s:g}"
                                for r, s in sorted(calibration.items()))


def resolve_plan_json(artifact: Mapping[str, object],
                      row: Mapping[str, object]) -> str:
    """Rehydrate a row's plan JSON from a ``SweepResult.to_dict()``
    artifact: interned artifacts carry ``plan_ref`` into the top-level
    ``plan_table`` side table; un-interned rows carry ``plan_json``
    inline.  Raises ``KeyError`` on a dangling reference."""
    if "plan_json" in row:
        return row["plan_json"]
    return artifact["plan_table"][row["plan_ref"]]


def _evaluate_point(cfg, hw: HardwareConfig, seq_len: int,
                    energy_models: Sequence[EnergyModel],
                    calibration=None,
                    cache: Optional[SimCache] = None,
                    stamp: bool = True,
                    ) -> Tuple[List[SweepRow], Optional[CachedPoint]]:
    """One (model config, design point, shape) evaluation through the
    canonical path — ``plan_model`` -> ``simulate_plan`` -> energy fold —
    returning one row per energy model plus the cacheable summary record
    (None when uncached).  The simulation runs *once*; the energy axis is
    a pure re-fold of the same trace under each pJ-cost table
    (latency/bytes are cost-table-invariant by construction).

    ``stamp=False`` skips the ``bottleneck``/``headroom`` attribution
    stamps — the what-if headroom replays the trace DAG once per
    resource, which is comparable in cost to the simulation itself, so
    the successive-halving search's cheap rungs opt out (their rows are
    ranking fodder, not frontier artifacts).  Cache entries are
    namespaced by that choice (``evaluator="proxy"``) so an unstamped
    record never satisfies a full-fidelity lookup."""
    from repro.plan.planner import plan_model
    from repro.sim.pipeline import simulate_plan
    from repro.sim.replay import resolve_calibration
    plan = plan_model(cfg, hw=hw, seq_len=seq_len)
    plan_json = plan.to_json()
    scale = resolve_calibration(calibration)
    label = calibration_label(calibration)
    scale_d = dict(scale) if scale else {}
    hw_params = dataclasses.asdict(hw)
    em_fps = [energy_fingerprint(em) for em in energy_models]

    def rows_of(cycles, hbm_bytes, util, folds, bottleneck, hroom):
        return [SweepRow(
            model=cfg.name, seq_len=seq_len, hw=hw.name,
            hw_params=hw_params, energy_model=em.name,
            latency_cycles=cycles, hbm_bytes=hbm_bytes,
            energy_pj=fold["total_pj"], edp=fold["edp"],
            utilization=dict(util),
            energy_by_resource=dict(fold["by_resource"]),
            plan_json=plan_json, calibration=label,
            calibration_scale=scale_d, bottleneck=bottleneck,
            headroom=dict(hroom))
            for em, fold in zip(energy_models, folds)]

    key = None
    if cache is not None:
        key = sim_cache_key(plan_json, hw, scale,
                            evaluator="point" if stamp else "proxy")
        hit = cache.lookup(key, em_fps)
        if hit is not None:
            return rows_of(hit.cycles, hit.hbm_bytes, hit.utilization,
                           [hit.energy[fp] for fp in em_fps],
                           hit.bottleneck, hit.headroom), hit

    res = simulate_plan(plan, hw=hw, calibration=calibration)
    bottleneck, hroom = "", {}
    if stamp:
        from repro.obs.attribution import bottleneck_of
        from repro.obs.whatif import headroom as causal_headroom
        bottleneck = bottleneck_of(res.trace)
        hroom = causal_headroom(res.trace)
    folds = []
    for em in energy_models:
        rep = res.energy(em)
        folds.append({"name": em.name, "total_pj": rep.total_pj,
                      "edp": rep.edp, "by_resource": dict(rep.by_resource)})
    record = None
    if cache is not None:
        record = CachedPoint(
            key=key, cycles=res.cycles, hbm_bytes=res.hbm_bytes,
            utilization=res.trace.utilizations(), bottleneck=bottleneck,
            headroom=hroom, energy=dict(zip(em_fps, folds)),
            info={"model": cfg.name, "seq_len": seq_len, "hw": hw.name,
                  "calibration": label})
        cache.store(record)
    return rows_of(res.cycles, res.hbm_bytes, res.trace.utilizations(),
                   folds, bottleneck, hroom), record


def _point_rows(cfg, hw: HardwareConfig, seq_len: int,
                energy_models: Sequence[EnergyModel],
                calibration=None, cache: Optional[SimCache] = None,
                stamp: bool = True) -> List[SweepRow]:
    """Back-compat row-only wrapper over ``_evaluate_point``."""
    return _evaluate_point(cfg, hw, seq_len, energy_models,
                           calibration=calibration, cache=cache,
                           stamp=stamp)[0]


def simulate_point(cfg, hw: HardwareConfig, seq_len: int = 0,
                   energy_model: Optional[EnergyModel] = None,
                   calibration=None) -> SweepRow:
    """One (model config, design point, shape) evaluation through the
    canonical path: ``plan_model`` -> ``simulate_plan`` -> energy fold.
    ``calibration`` (a ``repro.sim.replay.CalibrationReport`` or raw
    resource->factor mapping) scales the analytic timing by the fitted
    per-resource factors — the trace-calibrated sweep axis (DESIGN.md
    §10)."""
    em = energy_model or STREAMDCIM_ENERGY_BASE
    return _point_rows(cfg, hw, seq_len, [em], calibration)[0]


#: Worker-process cache instances, one per on-disk store path (or the
#: ``None`` key for a process-local memo) — reused across the tasks a
#: pool worker serves so intra-worker hits don't re-open the store.
_WORKER_CACHES: Dict[Optional[str], SimCache] = {}


def _sweep_worker(task):
    """Evaluate one sweep task in a pool worker.  Module-level (pickled
    by reference), resolves the model config from the registry by name,
    and binds a worker-local ``SimCache`` to the shared disk path so
    parallel workers warm the same store the serial path reads.  Returns
    ``(rows, CachedPoint|None, stats_delta)`` — the parent adopts the
    record into its own cache and merges the stat delta, keeping
    ``SweepResult.cache_stats`` identical in meaning to a serial run."""
    name, seq, cal, hw, ems, stamp, cache_path, want_record = task
    from repro.configs import registry
    cfg = registry.get_config(name)
    cache = None
    if want_record:
        cache = _WORKER_CACHES.get(cache_path)
        if cache is None:
            cache = SimCache(cache_path)
            _WORKER_CACHES[cache_path] = cache
    before = dict(cache.stats) if cache is not None else {}
    rows, record = _evaluate_point(cfg, hw, seq, list(ems),
                                   calibration=cal, cache=cache,
                                   stamp=stamp)
    delta = ({k: v - before.get(k, 0) for k, v in cache.stats.items()}
             if cache is not None else {})
    return rows, record, delta


def run_sweep(models: Optional[Sequence[str]] = None,
              base: Optional[HardwareConfig] = None,
              axes: Axes = DEFAULT_AXES,
              points: Optional[int] = None,
              seq_lens: Sequence[int] = (0,),
              energy_model: Optional[EnergyModel] = None,
              energy_models: Optional[Sequence[EnergyModel]] = None,
              include_presets: bool = True,
              knee_tolerance: float = 0.10,
              calibrations: Sequence[object] = (None,),
              progress=None,
              workers: Optional[int] = None,
              cache=None,
              stamp: bool = True,
              hw_points: Optional[Sequence[HardwareConfig]] = None,
              ) -> SweepResult:
    """Run the grid.  ``models`` are registry arch names (default: the
    simulator-supported pool); ``points`` caps the number of *design
    points* (the per-model row count follows), presets first so a small
    budget still sweeps the named configs.

    ``calibrations`` is the trace-calibration axis (DESIGN.md §10): each
    entry — None for the uncalibrated analytic model, or a
    ``repro.sim.replay.CalibrationReport`` / raw resource->factor
    mapping — sweeps the whole grid once, labeled on the rows; frontier
    and knee extraction never mix calibrations.

    ``energy_models`` is the cost-table axis (ROADMAP: ENERGY_CONFIGS x
    HW grid): each ``EnergyModel`` re-folds every simulated point's trace
    (the simulation itself runs once per point — latency is
    cost-table-invariant), yielding per-table frontiers and the
    ``SweepResult.frontier_sensitivity()`` report.  The scalar
    ``energy_model`` remains the single-table entry point.

    Fast-DSE knobs (DESIGN.md §16):

    * ``workers=N`` fans the evaluations out over a process pool.  The
      task list is built first in the exact serial nesting order (model
      -> shape -> calibration -> design point) and ``executor.map``
      preserves input order, so rows, skipped records, and ``progress``
      callbacks are byte-identical to a serial sweep — parallelism is a
      wall-clock optimization, never a semantic one.
    * ``cache`` memoizes the simulate->fold->stamp suffix: None (off), a
      ``SimCache``, or a directory path for the on-disk warm-start
      store.  ``SweepResult.cache_stats`` reports this sweep's
      hits/misses (deltas, even on a pre-warmed cache object).
    * ``stamp=False`` skips the bottleneck/headroom stamps (search
      proxy rungs); ``hw_points`` bypasses grid materialization with an
      explicit design-point list (the search's survivor sets)."""
    from repro.configs import registry
    ems = (list(energy_models) if energy_models
           else [energy_model or STREAMDCIM_ENERGY_BASE])
    model_names = list(models) if models else list(registry.SIM_ARCHS)
    if hw_points is not None:
        pts, skipped = list(hw_points), []
    else:
        presets = (tuple(registry.HW_CONFIGS.values())
                   if include_presets else ())
        pts, skipped = grid_points(base, axes, presets)
    if points is not None:
        pts = pts[:max(points, 0)]
    sim_cache = resolve_cache(cache)
    before = dict(sim_cache.stats) if sim_cache is not None else {}
    # Deterministic task order == the serial nesting order; every
    # execution strategy below walks this list in order.
    tasks = [(name, seq, cal, hw)
             for name in model_names
             for seq in seq_lens
             for cal in calibrations
             for hw in pts]
    rows: List[SweepRow] = []
    if workers and workers > 1 and len(tasks) > 1:
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor
        ctx = (mp.get_context("fork")
               if "fork" in mp.get_all_start_methods()
               else mp.get_context())
        payload = [(name, seq, cal, hw, tuple(ems), stamp,
                    sim_cache.path if sim_cache is not None else None,
                    sim_cache is not None)
                   for name, seq, cal, hw in tasks]
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=ctx) as ex:
            for pt_rows, record, delta in ex.map(_sweep_worker, payload,
                                                 chunksize=1):
                rows.extend(pt_rows)
                if sim_cache is not None:
                    if record is not None:
                        sim_cache.adopt(record)
                    sim_cache.merge_stats(delta)
                if progress is not None:
                    # one call per *simulated point* — the energy axis
                    # re-folds the same trace, no extra work
                    progress(pt_rows[0])
    else:
        for name, seq, cal, hw in tasks:
            cfg = registry.get_config(name)
            pt_rows, _ = _evaluate_point(cfg, hw, seq, ems,
                                         calibration=cal, cache=sim_cache,
                                         stamp=stamp)
            rows.extend(pt_rows)
            if progress is not None:
                progress(pt_rows[0])
    stats = ({k: v - before.get(k, 0)
              for k, v in sim_cache.stats.items()}
             if sim_cache is not None else {})
    return SweepResult(rows=rows, skipped=skipped, energy_model=ems[0].name,
                       knee_tolerance=knee_tolerance, cache_stats=stats)
