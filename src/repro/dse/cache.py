"""Simulation result cache for the DSE pipeline (DESIGN.md §16).

The sweep hot path is ``plan_model -> simulate_plan -> energy fold ->
bottleneck/headroom stamps``; everything after planning is a pure
function of (plan JSON, hardware timing parameters, calibration scale,
lowering).  ``SimCache`` memoizes that pure suffix under a content hash
of exactly those inputs, so:

* re-sweeping a grid in-process (the successive-halving search re-visits
  survivors; ``frontier_sensitivity`` style analyses re-run sweeps) pays
  planning only;
* ``run.py dse`` warm-starts across invocations through the on-disk
  store (one JSON file per key, written atomically so parallel workers
  can share a directory);
* the energy-table axis stays a re-fold: one cached entry carries the
  folds for every ``EnergyModel`` it has been evaluated under, keyed by
  the *content* of the cost table (never its name — two different ad-hoc
  tables must never collide).

What is cached is the ``SweepRow``-feeding summary — latency cycles, HBM
bytes, per-resource utilization, bottleneck, causal headroom, and
per-table energy folds — **not** the event trace: entries are a few KB,
and every number is bit-identical to a cold simulation because it *is*
the cold simulation's number serialized through JSON (floats round-trip
exactly).  A lookup only hits when every requested energy fold is
already present; otherwise the point re-simulates and the stored entry
is replaced with the union of folds (correctness first, reuse second).

Key hygiene: the hardware fingerprint drops the config ``name`` (timing
is a function of parameters, so ``streamdcim-base`` and an identically
parameterized ad-hoc point share an entry), and the ``evaluator`` field
namespaces full-fidelity sweep points (``"point"``) away from the
search's cheap rung evaluations (``"proxy"`` — those skip the
bottleneck/headroom stamps, so their records must never satisfy a
full-fidelity lookup).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Dict, Iterable, Mapping, Optional

from repro.configs.hardware import HardwareConfig
from repro.sim.energy import EnergyModel

#: Bump on any change to the cached-record shape or the key recipe;
#: mismatched on-disk entries are ignored (treated as misses), never
#: mis-replayed.
CACHE_SCHEMA_VERSION = 1


def _canonical(obj) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def hw_fingerprint(hw: HardwareConfig) -> str:
    """Content hash of the *timing-relevant* hardware parameters: the
    ``name`` is presentation, not physics, and is excluded."""
    d = dataclasses.asdict(hw)
    d.pop("name", None)
    return hashlib.sha256(_canonical(d).encode()).hexdigest()[:16]


def energy_fingerprint(em: EnergyModel) -> str:
    """Content hash of one pJ-cost table (including its leakage map).
    The name is *included*: ``SweepRow.energy_model`` labels partition
    frontier cells, so two same-cost tables under different names are
    still distinct rows and cache their folds separately."""
    d = dataclasses.asdict(em)
    d["leak_pj_per_cycle"] = dict(sorted(d["leak_pj_per_cycle"].items()))
    return hashlib.sha256(_canonical(d).encode()).hexdigest()[:16]


def sim_cache_key(plan_json: str, hw: HardwareConfig,
                  scale: Optional[Mapping[str, float]] = None,
                  lowering: str = "plan",
                  evaluator: str = "point") -> str:
    """The content key over everything that determines the simulated
    schedule: the serialized ``ExecutionPlan`` (geometry, modes, attached
    kernel traces), the hardware timing parameters, the resolved
    per-resource calibration scale, the lowering (``"plan"`` for
    ``simulate_plan``; serve sweeps would key ``"serve-fine"`` /
    ``"serve-coarse"`` — the decode-lowering axis changes event shape),
    and the evaluator namespace (see module docstring)."""
    payload = _canonical({
        "v": CACHE_SCHEMA_VERSION,
        "plan": hashlib.sha256(plan_json.encode()).hexdigest(),
        "hw": hw_fingerprint(hw),
        "scale": dict(sorted((scale or {}).items())),
        "lowering": lowering,
        "evaluator": evaluator,
    })
    return hashlib.sha256(payload.encode()).hexdigest()


@dataclasses.dataclass
class CachedPoint:
    """One memoized simulation summary (see module docstring)."""

    key: str
    cycles: int
    hbm_bytes: int
    utilization: Dict[str, float]
    bottleneck: str
    headroom: Dict[str, float]
    #: ``energy_fingerprint(em)`` -> {"name", "total_pj", "edp",
    #: "by_resource"} — the folds computed so far for this trace.
    energy: Dict[str, Dict[str, object]]
    #: Non-keying provenance (model, seq_len, hw name) for debuggability.
    info: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["schema_version"] = CACHE_SCHEMA_VERSION
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "CachedPoint":
        return cls(key=d["key"], cycles=int(d["cycles"]),
                   hbm_bytes=int(d["hbm_bytes"]),
                   utilization=dict(d["utilization"]),
                   bottleneck=str(d["bottleneck"]),
                   headroom=dict(d["headroom"]),
                   energy={k: dict(v) for k, v in d["energy"].items()},
                   info=dict(d.get("info", {})))


def _empty_stats() -> Dict[str, int]:
    return {"hits": 0, "misses": 0, "disk_hits": 0, "stores": 0}


class SimCache:
    """In-memory + optional on-disk simulation cache.

    ``path=None`` is a process-local memo; with a directory path every
    entry also persists as ``<key>.json`` (written atomically via
    tempfile + rename, so concurrent sweep workers sharing the directory
    race benignly — last identical write wins).
    """

    def __init__(self, path: Optional[str] = None) -> None:
        self.path = path
        self._mem: Dict[str, CachedPoint] = {}
        self.stats = _empty_stats()
        if path:
            os.makedirs(path, exist_ok=True)

    # ---------- lookup / store ----------

    def _entry_path(self, key: str) -> str:
        return os.path.join(self.path, f"{key}.json")

    def _load_disk(self, key: str) -> Optional[CachedPoint]:
        if not self.path:
            return None
        p = self._entry_path(key)
        try:
            with open(p) as f:
                d = json.load(f)
        except (OSError, ValueError):
            return None
        if d.get("schema_version") != CACHE_SCHEMA_VERSION:
            return None           # stale schema: miss, never mis-replay
        try:
            return CachedPoint.from_dict(d)
        except (KeyError, TypeError, ValueError):
            return None

    def lookup(self, key: str,
               energy_fps: Iterable[str] = ()) -> Optional[CachedPoint]:
        """Return the entry for ``key`` iff it exists AND already carries
        a fold for every fingerprint in ``energy_fps`` (a partial entry
        re-simulates — the trace is not stored, so missing folds cannot
        be recovered from the cache)."""
        pt = self._mem.get(key)
        from_disk = False
        if pt is None:
            pt = self._load_disk(key)
            from_disk = pt is not None
        if pt is not None and all(fp in pt.energy for fp in energy_fps):
            if from_disk:
                self._mem[key] = pt
                self.stats["disk_hits"] += 1
            self.stats["hits"] += 1
            return pt
        self.stats["misses"] += 1
        return None

    def store(self, pt: CachedPoint) -> None:
        """Insert/replace an entry (union of energy folds with any
        existing record for the same key)."""
        prev = self._mem.get(pt.key) or self._load_disk(pt.key)
        if prev is not None:
            merged = dict(prev.energy)
            merged.update(pt.energy)
            pt = dataclasses.replace(pt, energy=merged)
        self._mem[pt.key] = pt
        self.stats["stores"] += 1
        if self.path:
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(pt.to_dict(), f)
                os.replace(tmp, self._entry_path(pt.key))
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise

    def adopt(self, pt: CachedPoint) -> None:
        """Insert a record produced elsewhere (a sweep pool worker) into
        the in-memory map — fold-union like ``store`` but without stat
        bumps or a disk write (a disk-backed worker already persisted the
        entry; double-writing would only race)."""
        prev = self._mem.get(pt.key)
        if prev is not None:
            merged = dict(prev.energy)
            merged.update(pt.energy)
            pt = dataclasses.replace(pt, energy=merged)
        self._mem[pt.key] = pt

    # ---------- bookkeeping ----------

    def __len__(self) -> int:
        return len(self._mem)

    def merge_stats(self, other: Mapping[str, int]) -> None:
        """Fold a worker's stat delta into this cache's counters (the
        parallel sweep executor reports per-task stats back)."""
        for k, v in other.items():
            self.stats[k] = self.stats.get(k, 0) + int(v)


def resolve_cache(cache) -> Optional[SimCache]:
    """Normalize a ``run_sweep(cache=...)`` argument: None, a ``SimCache``
    instance, or a directory path string (opens/creates the disk store)."""
    if cache is None or isinstance(cache, SimCache):
        return cache
    if isinstance(cache, (str, os.PathLike)):
        return SimCache(str(cache))
    raise TypeError(f"cache must be None, a SimCache, or a directory "
                    f"path, got {cache!r}")
