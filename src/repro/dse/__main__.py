"""CLI for ``repro.dse``: ``PYTHONPATH=src python -m repro.dse``.

Prints a per-model sweep table (design point, latency, energy, EDP, macro
utilization; Pareto members starred, the utilization knee marked) and
optionally writes the full machine-readable sweep — rows with serialized
plans, frontier indices, knees — with ``--json``.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.dse.sweep import DEFAULT_AXES, run_sweep
from repro.sim.energy import ENERGY_PRESETS


def format_table(result, model: str, seq_len: int, knees=None,
                 calibration: str = None,
                 energy_model: str = None) -> str:
    knees = result.knees() if knees is None else knees
    rows = result.rows_for(model, seq_len, calibration, energy_model)
    frontier = set(id(r) for r in result.pareto(model, seq_len, calibration,
                                               energy_model))
    knee = knees.get(result.label(model, seq_len, calibration, energy_model))
    lines = [f"== {result.label(model, seq_len, calibration, energy_model)} "
             f"({len(rows)} points, "
             f"energy model {energy_model or result.energy_model}) ==",
             f"{'':2s}{'design point':<42s} {'cycles':>12s} {'energy(uJ)':>11s} "
             f"{'EDP':>10s} {'utilGEN':>8s} {'utilATTN':>9s}"]
    for r in sorted(rows, key=lambda r: r.latency_cycles):
        mark = "*" if id(r) in frontier else " "
        mark += "K" if knee is not None and r is knee else " "
        lines.append(
            f"{mark:2s}{r.hw:<42.42s} {r.latency_cycles:>12d} "
            f"{r.energy_pj / 1e6:>11.1f} {r.edp:>10.2e} "
            f"{r.utilization.get('GEN', 0.0):>8.2f} "
            f"{r.utilization.get('ATTN', 0.0):>9.2f}")
    if knee is not None:
        lines.append(f"   knee: {knee.hw} ({knee.num_macros} macros, "
                     f"within {result.knee_tolerance:.0%} of best latency)")
    return "\n".join(lines)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse",
        description="StreamDCIM design-space exploration sweep")
    ap.add_argument("--models", nargs="*", default=None,
                    help="registry arch names (default: simulator pool)")
    ap.add_argument("--points", type=int, default=None,
                    help="design-point budget (presets first; CI smoke)")
    ap.add_argument("--seq", type=int, nargs="*", default=[0],
                    help="sequence lengths (0 = model default)")
    ap.add_argument("--energy", default="streamdcim-energy-base",
                    choices=sorted(ENERGY_PRESETS),
                    help="energy model preset")
    ap.add_argument("--energy-axis", action="store_true",
                    help="sweep EVERY energy preset as a joint axis with "
                         "the hardware grid and report frontier "
                         "sensitivity to the cost table (ROADMAP)")
    ap.add_argument("--calibration", metavar="PATH", default=None,
                    help="CalibrationReport JSON (repro.sim.replay) — "
                         "sweeps the analytic AND the trace-calibrated "
                         "timing as a second axis (DESIGN.md §10)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the full sweep artifact (rows + plans + "
                         "pareto + knees)")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool width for the sweep (rows stay "
                         "byte-identical to serial; DESIGN.md §16)")
    ap.add_argument("--cache", metavar="DIR", default=None,
                    help="on-disk simulation cache directory — re-runs "
                         "warm-start from it (DESIGN.md §16)")
    ap.add_argument("--search", action="store_true",
                    help="successive-halving frontier search instead of "
                         "the exhaustive grid: cheap low-seq rungs rank "
                         "candidates, survivors graduate to full "
                         "fidelity (DESIGN.md §16)")
    ap.add_argument("--search-candidates", type=int, default=None,
                    help="candidate budget drawn from the grid for "
                         "--search (default: the whole grid)")
    ap.add_argument("--search-eta", type=int, default=2,
                    help="halving rate between rungs (default 2)")
    ap.add_argument("--search-rungs", type=int, default=None,
                    help="rung count (default: 2 for <=16 candidates, "
                         "else 3)")
    args = ap.parse_args(argv)

    calibrations = (None,)
    if args.calibration:
        from repro.sim.replay import CalibrationReport
        with open(args.calibration) as f:
            calibrations = (None, CalibrationReport.from_json(f.read()))

    done = [0]

    def progress(row):
        done[0] += 1
        print(f"\r  {done[0]} points simulated", end="", file=sys.stderr)

    energy_models = None
    if args.energy_axis:
        # --energy stays the *base* table (leads the axis: ordering and
        # frontier_sensitivity compare the other presets against it).
        base = ENERGY_PRESETS[args.energy]
        energy_models = [base] + [e for e in ENERGY_PRESETS.values()
                                  if e.name != base.name]
    search = None
    if args.search:
        from repro.dse.search import successive_halving
        search = successive_halving(
            models=args.models, axes=DEFAULT_AXES,
            num_candidates=args.search_candidates,
            eta=args.search_eta, rungs=args.search_rungs,
            seq_len=args.seq[0],
            energy_model=ENERGY_PRESETS[args.energy],
            energy_models=energy_models, calibrations=calibrations,
            cache=args.cache, workers=args.workers, progress=progress)
        result = search.sweep
    else:
        result = run_sweep(models=args.models, axes=DEFAULT_AXES,
                           points=args.points, seq_lens=args.seq,
                           energy_model=ENERGY_PRESETS[args.energy],
                           energy_models=energy_models,
                           calibrations=calibrations, progress=progress,
                           workers=args.workers, cache=args.cache)
    print(file=sys.stderr)
    knees = result.knees()
    for model, seq_len in result.groups():
        for cal in result.calibrations():
            for em in result.energy_models():
                print(format_table(result, model, seq_len, knees=knees,
                                   calibration=cal, energy_model=em))
                print()
    sens = result.frontier_sensitivity()
    for label, rec in sens.items():
        print(f"== {label}: frontier sensitivity to the cost table ==")
        for em, j in rec["jaccard_vs_base"].items():
            print(f"   {em:<28s} jaccard vs {rec['base']}: {j:.2f} "
                  f"({len(rec['frontier_hw'][em])} frontier designs)")
        print(f"   stable across all tables: {rec['stable_hw']}")
    if search is not None:
        print(f"== successive-halving search: {search.space_size} "
              f"candidates, eta={search.eta} ==")
        for rec in search.rungs:
            kind = "proxy" if rec.proxy else "full"
            print(f"   rung {rec.rung} ({kind}): "
                  f"{len(rec.candidates)} -> {len(rec.survivors)} "
                  f"(quota {rec.quota}, seq {sorted(set(rec.seq_lens.values()))})")
        print(f"   proxy sims {search.proxy_sims}, "
              f"full sims {search.full_sims}")
    if result.cache_stats:
        print(f"# cache: {result.cache_stats}")
    if result.skipped:
        print(f"# {len(result.skipped)} invalid grid combinations skipped")
    if args.json:
        art = search.to_dict() if search is not None else result.to_dict()
        with open(args.json, "w") as f:
            json.dump(art, f, indent=2)
        print(f"# sweep artifact -> {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
