"""Whisper-style encoder-decoder (arXiv:2212.04356) — [audio] backbone.

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, D) (post-conv, post-sinusoid).
Decoder cross-attention K/V come from the encoder output — the textbook
StreamDCIM cross-modal case (modal X = text queries, modal Y = audio
memory), routed through the execution-mode dispatch.
LayerNorm + GELU + learned decoder positions, per Whisper.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ExecutionMode, ModelConfig
from repro.core.scan_utils import maybe_scan
from repro.kernels import ops, ref
from repro.models import layers as L

Params = Dict[str, Any]


def _enc_layer_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {"ln1": L.layer_norm_init(cfg),
            "attn": L.attention_init(ks[0], cfg),
            "ln2": L.layer_norm_init(cfg),
            "mlp": L.mlp_init(ks[1], cfg)}


def _dec_layer_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {"ln1": L.layer_norm_init(cfg),
            "self_attn": L.attention_init(ks[0], cfg),
            "ln2": L.layer_norm_init(cfg),
            "cross_attn": L.attention_init(ks[1], cfg),
            "ln3": L.layer_norm_init(cfg),
            "mlp": L.mlp_init(ks[2], cfg)}


def init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    n_enc = cfg.num_encoder_layers or cfg.num_layers
    enc_keys = jax.random.split(ks[0], n_enc)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": L.embed_init(ks[2], cfg),
        # Learned decoder positions, enlarged beyond whisper's 448 to cover
        # the assigned 32k shapes (DESIGN.md §7).
        "dec_pos": L.dense_init(ks[3], (32768, cfg.d_model),
                                jnp.dtype(cfg.param_dtype), scale=0.01),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_ln": L.layer_norm_init(cfg),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "dec_ln": L.layer_norm_init(cfg),
    }


def encode(params: Params, cfg: ModelConfig, frames: jax.Array, *,
           mode: Optional[ExecutionMode] = None,
           use_pallas: bool = False) -> jax.Array:
    """frames: (B, S_enc, D) stub conv-frontend output -> encoder states."""
    mode = mode or cfg.execution_mode

    def step(x, lp):
        h = L.layer_norm(lp["ln1"], x, eps=cfg.norm_eps)
        x = x + L.attention_forward(lp["attn"], cfg, h, causal=False,
                                    mode=mode, use_pallas=use_pallas)
        h2 = L.layer_norm(lp["ln2"], x, eps=cfg.norm_eps)
        x = x + L.mlp_forward(lp["mlp"], cfg, h2, use_pallas=use_pallas)
        return x, None

    x, _ = maybe_scan(step, frames.astype(jnp.dtype(cfg.dtype)),
                        params["enc_layers"])
    return L.layer_norm(params["enc_ln"], x, eps=cfg.norm_eps)


def decode_train(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 enc_out: jax.Array, *, mode: Optional[ExecutionMode] = None,
                 use_pallas: bool = False) -> jax.Array:
    """Teacher-forced decoder -> logits (B, S_dec, V)."""
    mode = mode or cfg.execution_mode
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens)
    pos = params["dec_pos"][:S].astype(x.dtype)
    x = x + pos[None]

    def step(x, lp):
        h = L.layer_norm(lp["ln1"], x, eps=cfg.norm_eps)
        x = x + L.attention_forward(lp["self_attn"], cfg, h, causal=True,
                                    mode=mode, use_pallas=use_pallas)
        h2 = L.layer_norm(lp["ln2"], x, eps=cfg.norm_eps)
        # Cross-modal attention: KV generated from encoder memory in-stream.
        x = x + L.attention_forward(lp["cross_attn"], cfg, h2,
                                    x_kv=enc_out, causal=False, mode=mode,
                                    use_pallas=use_pallas)
        h3 = L.layer_norm(lp["ln3"], x, eps=cfg.norm_eps)
        x = x + L.mlp_forward(lp["mlp"], cfg, h3, use_pallas=use_pallas)
        return x, None

    x, _ = maybe_scan(step, x, params["dec_layers"])
    x = L.layer_norm(params["dec_ln"], x, eps=cfg.norm_eps)
    return L.unembed(params["embed"], x, cfg)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            mode: Optional[ExecutionMode] = None, use_pallas: bool = False,
            remat: bool = False) -> jax.Array:
    enc = encode(params, cfg, batch["frames"], mode=mode,
                 use_pallas=use_pallas)
    return decode_train(params, cfg, batch["tokens"], enc, mode=mode,
                        use_pallas=use_pallas)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            mode: Optional[ExecutionMode] = None, use_pallas: bool = False,
            remat: bool = False) -> jax.Array:
    logits = forward(params, cfg, batch, mode=mode, use_pallas=use_pallas)
    labels = batch["labels"]
    valid = labels >= 0
    labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)


# ----------------------------- serving ------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_out: jax.Array) -> Params:
    dt = jnp.dtype(cfg.dtype)
    n = cfg.num_layers
    one = {"k": jnp.zeros((batch, cfg.num_kv_heads, max_len, cfg.head_dim), dt),
           "v": jnp.zeros((batch, cfg.num_kv_heads, max_len, cfg.head_dim), dt)}
    stacked = jax.tree.map(lambda a: jnp.broadcast_to(
        a[None], (n,) + a.shape), one)
    return {"layers": stacked, "len": jnp.zeros((), jnp.int32)}


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            max_len: int, *, mode: Optional[ExecutionMode] = None,
            use_pallas: bool = False) -> Tuple[jax.Array, Params]:
    """Encoder pass + teacher-forced decoder prompt; returns (logits, cache).
    Cache holds decoder self-attn K/V; encoder states ride in the cache dict
    for decode-time cross-attention."""
    mode = mode or cfg.execution_mode
    enc = encode(params, cfg, batch["frames"], mode=mode,
                 use_pallas=use_pallas)
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len, enc)
    x = L.embed_lookup(params["embed"], tokens)
    x = x + params["dec_pos"][:S].astype(x.dtype)[None]

    def step(carry, inp):
        lp, lc = inp
        x = carry
        h = L.layer_norm(lp["ln1"], x, eps=cfg.norm_eps)
        q = jnp.einsum("bsd,dhe->bhse", h, lp["self_attn"]["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhe->bhse", h, lp["self_attn"]["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhe->bhse", h, lp["self_attn"]["wv"].astype(h.dtype))
        attn = ops.multi_head_attention(q, k, v, causal=True,
                                        use_pallas=use_pallas)
        x = x + jnp.einsum("bhse,hed->bsd", attn,
                           lp["self_attn"]["wo"].astype(h.dtype))
        nc = dict(lc)
        nc["k"] = jax.lax.dynamic_update_slice_in_dim(
            lc["k"], k.astype(lc["k"].dtype), 0, 2)
        nc["v"] = jax.lax.dynamic_update_slice_in_dim(
            lc["v"], v.astype(lc["v"].dtype), 0, 2)
        h2 = L.layer_norm(lp["ln2"], x, eps=cfg.norm_eps)
        x = x + L.attention_forward(lp["cross_attn"], cfg, h2, x_kv=enc,
                                    causal=False, mode=mode,
                                    use_pallas=use_pallas)
        h3 = L.layer_norm(lp["ln3"], x, eps=cfg.norm_eps)
        x = x + L.mlp_forward(lp["mlp"], cfg, h3, use_pallas=use_pallas)
        return x, nc

    x, new_layers = maybe_scan(step, x, (params["dec_layers"],
                                           cache["layers"]))
    x = L.layer_norm(params["dec_ln"], x, eps=cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"layers": new_layers, "enc": enc,
                    "len": jnp.full((), S, jnp.int32)}


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jax.Array) -> Tuple[jax.Array, Params]:
    """One decoder token with cached self-attn K/V + cross-attn to enc."""
    pos = cache["len"]
    enc = cache["enc"]
    x = L.embed_lookup(params["embed"], tokens)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], pos, 1, 0).astype(x.dtype)[None, 0]

    def step(carry, inp):
        lp, lc = inp
        x = carry
        h = L.layer_norm(lp["ln1"], x, eps=cfg.norm_eps)
        q = jnp.einsum("bsd,dhe->bhse", h, lp["self_attn"]["wq"].astype(h.dtype))
        k1 = jnp.einsum("bsd,dhe->bhse", h, lp["self_attn"]["wk"].astype(h.dtype))
        v1 = jnp.einsum("bsd,dhe->bhse", h, lp["self_attn"]["wv"].astype(h.dtype))
        kc = jax.lax.dynamic_update_slice_in_dim(
            lc["k"], k1.astype(lc["k"].dtype), pos, 2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            lc["v"], v1.astype(lc["v"].dtype), pos, 2)
        attn = ref.ref_decode_attention(q, kc, vc, pos + 1)
        x = x + jnp.einsum("bhse,hed->bsd", attn,
                           lp["self_attn"]["wo"].astype(h.dtype))
        h2 = L.layer_norm(lp["ln2"], x, eps=cfg.norm_eps)
        x = x + L.attention_forward(lp["cross_attn"], cfg, h2, x_kv=enc,
                                    causal=False,
                                    mode=ExecutionMode.TILE_STREAM)
        h3 = L.layer_norm(lp["ln3"], x, eps=cfg.norm_eps)
        x = x + L.mlp_forward(lp["mlp"], cfg, h3)
        return x, {"k": kc, "v": vc}

    x, new_layers = maybe_scan(step, x, (params["dec_layers"],
                                           cache["layers"]))
    x = L.layer_norm(params["dec_ln"], x, eps=cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"layers": new_layers, "enc": enc, "len": pos + 1}
