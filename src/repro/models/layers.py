"""Shared model primitives (functional style: explicit param dicts).

Attention mixers route through the execution-mode dispatch in
``kernels.ops`` (mode resolved per layer by the planner rules in
``repro.plan.heuristics``) so every architecture can run the paper's three
execution systems (NON_STREAM / LAYER_STREAM / TILE_STREAM) — the
StreamDCIM technique is a first-class framework feature, not a bolt-on.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import AttnKind, ExecutionMode, ModelConfig, pad_to
from repro.kernels import ops, ref

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm_init(cfg: ModelConfig, dim: Optional[int] = None) -> Params:
    return {"gamma": jnp.ones((dim or cfg.d_model,), _pdtype(cfg))}


def rms_norm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    return ref.rms_norm(x, params["gamma"], eps=eps)


def layer_norm_init(cfg: ModelConfig, dim: Optional[int] = None) -> Params:
    d = dim or cfg.d_model
    return {"gamma": jnp.ones((d,), _pdtype(cfg)),
            "beta": jnp.zeros((d,), _pdtype(cfg))}


def layer_norm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype) * params["gamma"].astype(x.dtype)
            + params["beta"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Embedding (vocab padded to a multiple of 128 for clean sharding/MXU tiles)
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig, vocab: Optional[int] = None,
               dim: Optional[int] = None) -> Params:
    v = pad_to(vocab or cfg.vocab_size, 128)
    d = dim or cfg.d_model
    p = {"embedding": dense_init(key, (v, d), _pdtype(cfg), scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(jax.random.fold_in(key, 1), (d, v),
                                  _pdtype(cfg))
    return p


def embed_lookup(params: Params, tokens: jax.Array) -> jax.Array:
    from repro.distributed.hints import constrain
    return constrain(params["embedding"][tokens], "embed_out")


def unembed(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embedding"].T
    else:
        w = params["unembed"]
    return jnp.dot(x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# RoPE (incl. qwen2-vl M-RoPE: per-section (t, h, w) frequency interleave)
# ---------------------------------------------------------------------------

def rope_tables_for(cfg: ModelConfig, seq_len: int, offset: int = 0,
                    head_dim: Optional[int] = None):
    return ref.rope_tables(seq_len, head_dim or cfg.head_dim,
                           theta=cfg.rope_theta, offset=offset)


def mrope_tables(cfg: ModelConfig, positions: jax.Array,
                 head_dim: Optional[int] = None):
    """positions: (3, B, S) — t/h/w position streams (text: all equal).

    Returns sin/cos shaped (B, S, hd//2): section s of the frequency bands
    uses position stream s (M-RoPE, arXiv:2409.12191).
    """
    hd = head_dim or cfg.head_dim
    half = hd // 2
    freqs = 1.0 / (cfg.rope_theta
                   ** (jnp.arange(half, dtype=jnp.float32) / half))
    sections = cfg.mrope_sections or (half,)
    idx = []
    for s, n in enumerate(sections):
        idx.extend([s] * n)
    idx = jnp.asarray(idx[:half], jnp.int32)
    pos_sel = positions[idx]                     # (half, B, S) band -> stream
    ang = jnp.moveaxis(pos_sel, 0, -1) * freqs   # (B, S, half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope_bsd(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, H, S, hd); sin/cos: (S, hd//2) or (B, S, hd//2)."""
    half = x.shape[-1] // 2
    if sin.ndim == 2:
        sin_b = sin[None, None]
        cos_b = cos[None, None]
    else:
        sin_b = sin[:, None]
        cos_b = cos[:, None]
    x1, x2 = x[..., :half], x[..., half:]
    sin_b = sin_b.astype(x.dtype)
    cos_b = cos_b.astype(x.dtype)
    return jnp.concatenate(
        [x1 * cos_b - x2 * sin_b, x2 * cos_b + x1 * sin_b], axis=-1)


# ---------------------------------------------------------------------------
# Attention mixer (dense GQA — the paper-technique carrier)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, d_model: Optional[int] = None,
                   num_heads: Optional[int] = None,
                   num_kv_heads: Optional[int] = None,
                   head_dim: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    hq = num_heads or cfg.num_heads
    hkv = num_kv_heads or cfg.num_kv_heads
    hd = head_dim or cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq, hd), _pdtype(cfg)),
        "wk": dense_init(ks[1], (d, hkv, hd), _pdtype(cfg)),
        "wv": dense_init(ks[2], (d, hkv, hd), _pdtype(cfg)),
        "wo": dense_init(ks[3], (hq, hd, d), _pdtype(cfg)),
    }
    if cfg.use_qk_norm:
        p["q_gamma"] = jnp.ones((hd,), _pdtype(cfg))
        p["k_gamma"] = jnp.ones((hd,), _pdtype(cfg))
    return p


def attention_forward(params: Params, cfg: ModelConfig, x: jax.Array, *,
                      x_kv: Optional[jax.Array] = None,
                      sin=None, cos=None, causal: bool = True,
                      mode: Optional[ExecutionMode] = None,
                      use_pallas: bool = False,
                      q_offset: int = 0) -> jax.Array:
    """Full attention sublayer on pre-normed x.  x_kv (pre-normed KV-side
    activations) defaults to x (self-attention); pass the other modality /
    encoder output for cross-attention — the kernel generates K/V from it on
    the fly in TILE_STREAM mode.

    Mode resolution goes through the planner's per-layer rule
    (repro.plan.heuristics — the TBR-CIM hybrid/normal reconfiguration
    analogue): a TILE_STREAM request may fall back to LAYER_STREAM for
    aggressively-GQA geometries where generation-fusion is
    HBM-traffic-negative (DESIGN.md §2).  Full-model paths resolve this
    once via ``repro.plan.plan_model``; this per-call resolution is
    guaranteed to agree with it (tests/test_plan.py)."""
    from repro.plan.heuristics import resolve_layer_mode
    x_kv = x if x_kv is None else x_kv
    mode = resolve_layer_mode(
        mode or cfg.execution_mode, d_kv=x_kv.shape[-1],
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        attn_kind=cfg.attn_kind,
        fuse_kv_generation=cfg.fuse_kv_generation)
    window = cfg.sliding_window if cfg.attn_kind == AttnKind.SLIDING else 0

    from repro.distributed.hints import constrain
    q = jnp.einsum("bsd,dhe->bhse", x, params["wq"].astype(x.dtype))
    if cfg.use_qk_norm:
        q = ref.rms_norm(q, params["q_gamma"], eps=cfg.norm_eps)
    if sin is not None:
        q_sin, q_cos = sin, cos
        if q_offset or q.shape[2] != x_kv.shape[1]:
            # Decode/offset: q uses the tail of the tables.
            q_sin = sin[q_offset:q_offset + q.shape[2]] if sin.ndim == 2 else sin
            q_cos = cos[q_offset:q_offset + q.shape[2]] if cos.ndim == 2 else cos
        q = apply_rope_bsd(q, q_sin, q_cos)
    q = constrain(q, "attn_q")   # context-parallel hint (hillclimb lever)

    out = ops.attention_by_mode(
        mode, q, x_kv, params["wk"], params["wv"],
        sin=sin if sin is not None and sin.ndim == 2 else None,
        cos=cos if cos is not None and cos.ndim == 2 else None,
        k_gamma=params.get("k_gamma"), causal=causal, window=window,
        q_offset=q_offset, norm_eps=cfg.norm_eps, use_pallas=use_pallas)
    out = constrain(out, "attn_out")
    # M-RoPE (batch-dependent tables) can't use the fused-rope path above;
    # handled by the caller passing pre-roped K via mode dispatch fallback.
    return jnp.einsum("bhse,hed->bsd", out, params["wo"].astype(x.dtype))


def attention_forward_mrope(params: Params, cfg: ModelConfig, x: jax.Array, *,
                            sin_b, cos_b, causal: bool = True,
                            mode: Optional[ExecutionMode] = None,
                            use_pallas: bool = False) -> jax.Array:
    """qwen2-vl: batch-dependent M-RoPE tables (B, S, hd//2).  K is roped
    outside the kernel (LAYER_STREAM semantics for K-gen; TILE_STREAM still
    applies to the V path conceptually but we keep it uniform here)."""
    mode = mode or cfg.execution_mode
    q = jnp.einsum("bsd,dhe->bhse", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bhse", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bhse", x, params["wv"].astype(x.dtype))
    q = apply_rope_bsd(q, sin_b, cos_b)
    k = apply_rope_bsd(k, sin_b, cos_b)
    out = ops.multi_head_attention(q, k, v, causal=causal,
                                   use_pallas=use_pallas)
    return jnp.einsum("bhse,hed->bsd", out, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Decode-path attention (KV cache)
# ---------------------------------------------------------------------------

def rope_at(pos, head_dim: int, theta: float):
    """sin/cos (1, hd//2) for a single dynamic position — O(hd), no table."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32) * freqs
    return jnp.sin(ang)[None], jnp.cos(ang)[None]


def attention_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                     cache: Params, *, use_rope: bool = True
                     ) -> Tuple[jax.Array, Params]:
    """x: (B, 1, D) pre-normed; cache: {k: (B,Hkv,W,hd), v: ..., len: ()}.

    Sliding-window archs allocate W = min(max_len, window) and the cache is
    a *ring buffer* (slot = pos % W) — a 0.5M-token SWA stream runs in a
    window-sized cache.  RoPE is applied at write time with the absolute
    position, so ring wrapping is transparent to attention.
    """
    pos = cache["len"]
    W = cache["k"].shape[2]
    q = jnp.einsum("bsd,dhe->bhse", x, params["wq"].astype(x.dtype))
    k_new = jnp.einsum("bsd,dhe->bhse", x, params["wk"].astype(x.dtype))
    v_new = jnp.einsum("bsd,dhe->bhse", x, params["wv"].astype(x.dtype))
    if cfg.use_qk_norm:
        q = ref.rms_norm(q, params["q_gamma"], eps=cfg.norm_eps)
        k_new = ref.rms_norm(k_new, params["k_gamma"], eps=cfg.norm_eps)
    if use_rope and cfg.head_dim:
        sin_t, cos_t = rope_at(pos, cfg.head_dim, cfg.rope_theta)
        q = apply_rope_bsd(q, sin_t, cos_t)
        k_new = apply_rope_bsd(k_new, sin_t, cos_t)
    slot = jax.lax.rem(pos, W)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, 2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, 2)
    is_ring = cfg.attn_kind == AttnKind.SLIDING
    valid = jnp.minimum(pos + 1, W) if is_ring else pos + 1
    out = ref.ref_decode_attention(q, k_cache, v_cache, valid, window=0)
    o = jnp.einsum("bhse,hed->bsd", out, params["wo"].astype(x.dtype))
    return o, {"k": k_cache, "v": v_cache, "len": pos + 1}


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_model: Optional[int] = None,
             d_ff: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {"w_gate": dense_init(ks[0], (d, f), _pdtype(cfg)),
                "w_up": dense_init(ks[1], (d, f), _pdtype(cfg)),
                "w_down": dense_init(ks[2], (f, d), _pdtype(cfg))}
    return {"w_up": dense_init(ks[0], (d, f), _pdtype(cfg)),
            "w_down": dense_init(ks[1], (f, d), _pdtype(cfg))}


def mlp_forward(params: Params, cfg: ModelConfig, x: jax.Array, *,
                use_pallas: bool = False) -> jax.Array:
    if "w_gate" in params:
        g = ops.projection(x, params["w_gate"].astype(x.dtype), use_pallas=use_pallas)
        u = ops.projection(x, params["w_up"].astype(x.dtype), use_pallas=use_pallas)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(ops.projection(x, params["w_up"].astype(x.dtype),
                                       use_pallas=use_pallas))
    return ops.projection(h, params["w_down"].astype(x.dtype),
                          use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# MoE FFN — gather-based static-capacity dispatch (EP over 'model' when the
# expert count divides the axis, TP-within-expert otherwise; DESIGN.md §5)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), _pdtype(cfg), scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, f), _pdtype(cfg)),
        "w_up": dense_init(ks[2], (e, d, f), _pdtype(cfg)),
        "w_down": dense_init(ks[3], (e, f, d), _pdtype(cfg)),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=f * cfg.num_shared_experts)
    return p


def moe_forward(params: Params, cfg: ModelConfig, x: jax.Array, *,
                capacity_factor: Optional[float] = None,
                use_pallas: bool = False) -> jax.Array:
    """x: (B, S, D).  Static-shape top-k routing with per-expert capacity.

    Dispatch = gather (expert_slots -> token ids), combine = scatter-add.
    No (T, E, C) one-hot tensors: memory stays O(T·E + E·C·D).
    """
    from repro.core import runtime
    from repro.distributed.hints import constrain
    if capacity_factor is None:
        capacity_factor = runtime.get("moe_capacity", 1.25)
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    # Grouped dispatch (GShard groups == data shards): routing/slotting is
    # computed independently per token group, so the expert gather never
    # crosses the data axis — the dominant MoE collective disappears
    # (perf lever; groups=1 is the plain formulation).
    groups = runtime.get("moe_groups", 1)
    T_all = B * S
    if T_all % groups != 0:
        groups = 1
    Tg = T_all // groups
    xt = x.reshape(groups, Tg, D)
    cap = max(int(Tg * K / E * capacity_factor), 4)
    cap = min(pad_to(cap, 4), Tg)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, K)               # (G, Tg, K)
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)

    def slot_group(topi_g, topw_g):
        """One group's slotting: (Tg,K) -> (E,C) token ids / weights."""
        flat_e = topi_g.reshape(-1)                    # (Tg*K,)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot).max(
            axis=-1, where=onehot > 0, initial=0)
        keep = pos_in_e < cap
        slot = jnp.where(keep, flat_e * cap + pos_in_e, E * cap)
        token_of_slot = jnp.zeros((E * cap + 1,), jnp.int32).at[slot].set(
            jnp.arange(Tg * K, dtype=jnp.int32) // K, mode="drop")
        slot_used = jnp.zeros((E * cap + 1,), jnp.bool_).at[slot].set(
            True, mode="drop")
        wslot = jnp.zeros((E * cap + 1,), jnp.float32).at[slot].set(
            topw_g.reshape(-1), mode="drop")
        return (token_of_slot[:E * cap].reshape(E, cap),
                slot_used[:E * cap].reshape(E, cap),
                wslot[:E * cap].reshape(E, cap))

    tok_ids, used, wslot = jax.vmap(slot_group)(topi, topw)  # (G,E,C...)

    xe = jnp.take_along_axis(
        xt[:, :, None, :].astype(x.dtype),
        tok_ids.reshape(groups, E * cap, 1, 1), axis=1
    )[:, :, 0].reshape(groups, E, cap, D)
    xe = xe * used[..., None].astype(xe.dtype)
    xe = jnp.swapaxes(xe, 0, 1)                        # (E, G, C, D)
    xe = constrain(xe, "moe_dispatch")                 # P(model, data, ...)
    g = jnp.einsum("egcd,edf->egcf", xe, params["w_gate"].astype(xe.dtype))
    u = jnp.einsum("egcd,edf->egcf", xe, params["w_up"].astype(xe.dtype))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("egcf,efd->egcd", h, params["w_down"].astype(xe.dtype))
    ye = jnp.swapaxes(ye, 0, 1)                        # (G, E, C, D)

    # combine: weight each slot by its gate and scatter-add back per group
    def combine_group(ye_g, tok_g, w_g):
        return jnp.zeros((Tg, D), jnp.float32).at[tok_g.reshape(-1)].add(
            (ye_g * w_g[..., None].astype(ye_g.dtype))
            .reshape(E * cap, D).astype(jnp.float32))

    y = jax.vmap(combine_group)(ye, tok_ids, wslot)    # (G, Tg, D)
    out = y.astype(x.dtype).reshape(B, S, D)
    if "shared" in params:
        out = out + mlp_forward(params["shared"], cfg, x,
                                use_pallas=use_pallas)
    return out
