"""Unified decoder-only transformer covering the dense / moe / ssm / hybrid /
vlm families.  Homogeneous layer stacks are ``lax.scan``-ed over stacked
params (small HLO even at 64 layers); heterogeneous prefixes (deepseek's
first dense layers) get their own stack.

Every attention layer runs through the paper's execution-mode dispatch, so
any arch can execute NON_STREAM / LAYER_STREAM / TILE_STREAM.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.scan_utils import maybe_scan
from repro.core.types import AttnKind, ExecutionMode, Family, ModelConfig
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import ssm as SSM

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Per-layer init/apply
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, moe: bool) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": L.rms_norm_init(cfg)}
    if cfg.family == Family.SSM:
        p["ssm"] = SSM.ssm_init(ks[0], cfg)
        return p
    if cfg.attn_kind == AttnKind.MLA:
        p["attn"] = MLA.mla_init(ks[0], cfg)
    elif cfg.num_heads:
        p["attn"] = L.attention_init(ks[0], cfg)
    if cfg.family == Family.HYBRID:
        p["ssm"] = SSM.ssm_init(ks[1], cfg)
        p["mix_beta"] = jnp.ones((2,), jnp.float32)
    p["norm2"] = L.rms_norm_init(cfg)
    if moe:
        p["moe"] = L.moe_init(ks[2], cfg)
    else:
        p["mlp"] = L.mlp_init(ks[3], cfg)
    return p


def _layer_apply(p: Params, cfg: ModelConfig, x: jax.Array, *,
                 sin, cos, moe: bool,
                 mode: Optional[ExecutionMode],
                 use_pallas: bool, mrope_tabs=None) -> jax.Array:
    h = L.rms_norm(p["norm1"], x, eps=cfg.norm_eps)
    if cfg.family == Family.SSM:
        return x + SSM.ssm_forward(p["ssm"], cfg, h, use_pallas=use_pallas)

    if cfg.attn_kind == AttnKind.MLA:
        attn_out = MLA.mla_forward(p["attn"], cfg, h, sin=sin, cos=cos,
                                   causal=True, mode=mode,
                                   use_pallas=use_pallas)
    elif mrope_tabs is not None:
        attn_out = L.attention_forward_mrope(p["attn"], cfg, h,
                                             sin_b=mrope_tabs[0],
                                             cos_b=mrope_tabs[1], causal=True,
                                             mode=mode, use_pallas=use_pallas)
    else:
        attn_out = L.attention_forward(p["attn"], cfg, h, sin=sin, cos=cos,
                                       causal=True, mode=mode,
                                       use_pallas=use_pallas)
    if cfg.family == Family.HYBRID:
        ssm_out = SSM.ssm_forward(p["ssm"], cfg, h, use_pallas=use_pallas)
        beta = jax.nn.softmax(p["mix_beta"]).astype(x.dtype)
        x = x + beta[0] * attn_out + beta[1] * ssm_out
    else:
        x = x + attn_out
    h2 = L.rms_norm(p["norm2"], x, eps=cfg.norm_eps)
    if moe:
        x = x + L.moe_forward(p["moe"], cfg, h2, use_pallas=use_pallas)
    else:
        x = x + L.mlp_forward(p["mlp"], cfg, h2, use_pallas=use_pallas)
    return x


# ---------------------------------------------------------------------------
# Model init / forward
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    n_dense = cfg.first_dense_layers if cfg.family == Family.MOE else (
        cfg.num_layers if cfg.family != Family.MOE else 0)
    params: Params = {"embed": L.embed_init(ks[0], cfg),
                      "final_norm": L.rms_norm_init(cfg)}
    if cfg.family == Family.MOE:
        if cfg.first_dense_layers:
            dkeys = jax.random.split(ks[1], cfg.first_dense_layers)
            params["dense_layers"] = jax.vmap(
                lambda k: _layer_init(k, cfg, moe=False))(dkeys)
        mkeys = jax.random.split(ks[2], cfg.num_layers - cfg.first_dense_layers)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe=True))(mkeys)
    else:
        lkeys = jax.random.split(ks[1], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe=False))(lkeys)
    if cfg.mtp_depth:
        params["mtp_proj"] = L.dense_init(ks[3], (2 * cfg.d_model, cfg.d_model),
                                          jnp.dtype(cfg.param_dtype))
    return params


def _scan_stack(stack: Params, cfg: ModelConfig, x: jax.Array, *,
                sin, cos, moe: bool, mode, use_pallas, mrope_tabs,
                remat: bool) -> jax.Array:
    from repro.core import runtime
    body = functools.partial(_layer_apply, cfg=cfg, sin=sin, cos=cos, moe=moe,
                             mode=mode, use_pallas=use_pallas,
                             mrope_tabs=mrope_tabs)
    # remat policy knob (perf lever): 'none' recomputes everything (min
    # memory); 'dots' saves matmul outputs (no matmul recompute in bwd).
    policy_name = runtime.get("remat_policy", "none")
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if policy_name == "dots" else None)

    def step(carry, lp):
        fn = jax.checkpoint(lambda c, p: body(p, x=c), policy=policy) \
            if remat else (lambda c, p: body(p, x=c))
        return fn(carry, lp), None

    x, _ = maybe_scan(step, x, stack)
    return x


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            mode: Optional[ExecutionMode] = None, use_pallas: bool = False,
            remat: bool = False) -> jax.Array:
    """batch: {"tokens": (B,S) int32 | "embeds": (B,S,D),
               "positions": (3,B,S) optional (vlm M-RoPE)}.
    Returns logits (B, S, vocab_padded) in f32."""
    x = forward_hidden(params, cfg, batch, mode=mode, use_pallas=use_pallas,
                       remat=remat)
    return L.unembed(params["embed"], x, cfg)


def forward_hidden(params: Params, cfg: ModelConfig,
                   batch: Dict[str, jax.Array], *,
                   mode: Optional[ExecutionMode] = None,
                   use_pallas: bool = False,
                   remat: bool = False) -> jax.Array:
    """forward() up to (but excluding) the unembed projection."""
    mode = mode or cfg.execution_mode
    if "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = L.embed_lookup(params["embed"], batch["tokens"])
    S = x.shape[1]
    sin = cos = None
    mrope_tabs = None
    if cfg.family == Family.VLM and cfg.mrope_sections and "positions" in batch:
        mrope_tabs = L.mrope_tables(cfg, batch["positions"])
    elif cfg.num_heads and cfg.attn_kind != AttnKind.NONE:
        hd = (cfg.qk_rope_head_dim if cfg.attn_kind == AttnKind.MLA
              else cfg.head_dim)
        sin, cos = L.rope_tables_for(cfg, S, head_dim=hd)
    if cfg.family == Family.MOE and cfg.first_dense_layers:
        x = _scan_stack(params["dense_layers"], cfg, x, sin=sin, cos=cos,
                        moe=False, mode=mode, use_pallas=use_pallas,
                        mrope_tabs=mrope_tabs, remat=remat)
    x = _scan_stack(params["layers"], cfg, x, sin=sin, cos=cos,
                    moe=(cfg.family == Family.MOE), mode=mode,
                    use_pallas=use_pallas, mrope_tabs=mrope_tabs, remat=remat)
    return L.rms_norm(params["final_norm"], x, eps=cfg.norm_eps)


def chunked_xent(params: Params, cfg: ModelConfig, hidden: jax.Array,
                 labels: jax.Array, *, chunk: int = 512
                 ) -> jax.Array:
    """Cross-entropy with the unembed projection computed per sequence
    chunk — the (B, S, vocab) logits tensor never materializes (vocabs here
    reach 256k; full logits would dominate training memory)."""
    B, S, D = hidden.shape
    c = min(chunk, S)
    nc = S // c if S % c == 0 else 1
    if S % c != 0:
        c = S
        nc = 1
    hc = jnp.moveaxis(hidden.reshape(B, nc, c, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)

    def chunk_loss(carry, inp):
        h, l = inp
        logits = L.unembed(params["embed"], h, cfg)
        valid = l >= 0
        l = jnp.maximum(l, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, l[..., None], axis=-1)[..., 0]
        s, n = carry
        return (s + jnp.sum(nll * valid), n + jnp.sum(valid)), None

    (loss_sum, count), _ = maybe_scan(
        chunk_loss, (jnp.zeros(()), jnp.zeros((), jnp.int32)), (hc, lc))
    return loss_sum / jnp.maximum(count, 1)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            mode: Optional[ExecutionMode] = None, use_pallas: bool = False,
            remat: bool = True) -> jax.Array:
    """Next-token cross-entropy; labels == -1 are masked."""
    hidden = forward_hidden(params, cfg, batch, mode=mode,
                            use_pallas=use_pallas, remat=remat)
    return chunked_xent(params, cfg, hidden, batch["labels"])


# ---------------------------------------------------------------------------
# Serving: prefill + decode (KV / latent / SSM-state caches per family)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dt = jnp.dtype(cfg.dtype)
    n_layers = cfg.num_layers
    if cfg.family == Family.SSM:
        one = SSM.ssm_init_cache(cfg, batch, dt)
    elif cfg.attn_kind == AttnKind.MLA:
        one = MLA.mla_init_cache(cfg, batch, max_len, dt)
    else:
        kv_len = max_len
        if cfg.attn_kind == AttnKind.SLIDING:
            kv_len = min(max_len, cfg.sliding_window)   # ring buffer
        one = {"k": jnp.zeros((batch, cfg.num_kv_heads, kv_len,
                               cfg.head_dim), dt),
               "v": jnp.zeros((batch, cfg.num_kv_heads, kv_len,
                               cfg.head_dim), dt)}
        if cfg.family == Family.HYBRID:
            ssm_c = {k: v for k, v in SSM.ssm_init_cache(cfg, batch, dt).items()
                     if k != "len"}
            one = {"attn": one, "ssm": ssm_c}
    # stack per layer; drop inner "len" counters — one global counter
    one = {k: v for k, v in one.items() if k != "len"}
    stacked = jax.tree.map(lambda a: jnp.broadcast_to(
        a[None], (n_layers,) + a.shape), one)
    return {"layers": stacked, "len": jnp.zeros((), jnp.int32)}


def _decode_layer(p: Params, cfg: ModelConfig, x: jax.Array, cache_l: Params,
                  pos) -> Tuple[jax.Array, Params]:
    h = L.rms_norm(p["norm1"], x, eps=cfg.norm_eps)
    if cfg.family == Family.SSM:
        out, new_c = SSM.ssm_decode(p["ssm"], cfg, h,
                                    {**cache_l, "len": pos})
        new_c.pop("len")
        return x + out, new_c
    if cfg.attn_kind == AttnKind.MLA:
        out, new_c = MLA.mla_decode(p["attn"], cfg, h,
                                    {**cache_l, "len": pos})
        new_c.pop("len")
    elif cfg.family == Family.HYBRID:
        a_out, new_a = L.attention_decode(p["attn"], cfg, h,
                                          {**cache_l["attn"], "len": pos})
        s_out, new_s = SSM.ssm_decode(p["ssm"], cfg, h,
                                      {**cache_l["ssm"], "len": pos})
        beta = jax.nn.softmax(p["mix_beta"]).astype(x.dtype)
        out = beta[0] * a_out + beta[1] * s_out
        new_a.pop("len"); new_s.pop("len")
        new_c = {"attn": new_a, "ssm": new_s}
    else:
        out, new_c = L.attention_decode(p["attn"], cfg, h,
                                        {**cache_l, "len": pos})
        new_c.pop("len")
    x = x + out
    h2 = L.rms_norm(p["norm2"], x, eps=cfg.norm_eps)
    if "moe" in p:
        x = x + L.moe_forward(p["moe"], cfg, h2)
    else:
        x = x + L.mlp_forward(p["mlp"], cfg, h2)
    return x, new_c


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jax.Array) -> Tuple[jax.Array, Params]:
    """One serving step: tokens (B, 1) -> (logits (B, 1, V), new cache).

    The (layer-stacked) cache is scanned together with the layer params.
    MoE prefix layers (deepseek) share the same cache tensor layout, so we
    scan dense-prefix and moe stacks separately over cache slices.
    """
    x = L.embed_lookup(params["embed"], tokens)
    pos = cache["len"]

    def step(carry, inp):
        lp, lc = inp
        y, new_c = _decode_layer(lp, cfg, carry, lc, pos)
        return y, new_c

    if cfg.family == Family.MOE and cfg.first_dense_layers:
        nd = cfg.first_dense_layers
        head = jax.tree.map(lambda a: a[:nd], cache["layers"])
        tail = jax.tree.map(lambda a: a[nd:], cache["layers"])
        x, new_head = maybe_scan(step, x, (params["dense_layers"], head))
        x, new_tail = maybe_scan(step, x, (params["layers"], tail))
        new_layers = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0), new_head, new_tail)
    else:
        x, new_layers = maybe_scan(step, x, (params["layers"],
                                               cache["layers"]))
    x = L.rms_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"layers": new_layers, "len": pos + 1}


def _ssm_prefill_state(p_ssm: Params, cfg: ModelConfig, h: jax.Array,
                       use_pallas: bool):
    """Run the SSM mixer over the prompt, returning (out, conv_state,
    final ssd state) for cache fill."""
    B, S, _ = h.shape
    d, d_inner, nheads, headdim = SSM.ssm_dims(cfg)
    proj = jnp.dot(h, p_ssm["in_proj"].astype(h.dtype))
    xs, z, b, c, dt = SSM._split_proj(cfg, proj, d_inner, nheads)
    xbc = jnp.concatenate([xs, b, c], axis=-1)
    conv_out, conv_state = SSM._causal_conv(xbc, p_ssm["conv_w"].astype(h.dtype))
    xbc_a = jax.nn.silu(conv_out)
    xs = xbc_a[..., :d_inner]
    b = xbc_a[..., d_inner:d_inner + cfg.ssm_state]
    c = xbc_a[..., d_inner + cfg.ssm_state:]
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p_ssm["dt_bias"][None, None])
    a = -jnp.exp(p_ssm["a_log"])
    xh = xs.reshape(B, S, nheads, headdim)
    from repro.kernels import ops as _ops
    y, final_state = _ops.ssd(xh, dtp, a, b, c, chunk=cfg.ssm_chunk,
                              use_pallas=use_pallas)
    y = y + xh * p_ssm["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, d_inner)
    from repro.kernels import ref as _ref
    y = _ref.rms_norm(y * jax.nn.silu(z), p_ssm["norm_gamma"],
                      eps=cfg.norm_eps)
    out = jnp.dot(y, p_ssm["out_proj"].astype(h.dtype))
    return out, conv_state, final_state


def _project_kv(p_attn: Params, cfg: ModelConfig, h: jax.Array, sin, cos):
    k = jnp.einsum("bsd,dhe->bhse", h, p_attn["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhe->bhse", h, p_attn["wv"].astype(h.dtype))
    if cfg.use_qk_norm:
        from repro.kernels import ref as _ref
        k = _ref.rms_norm(k, p_attn["k_gamma"], eps=cfg.norm_eps)
    if sin is not None:
        k = L.apply_rope_bsd(k, sin, cos)
    return k, v


def _prefill_layer(p: Params, cfg: ModelConfig, x: jax.Array, cache_l: Params,
                   *, sin, cos, use_pallas: bool,
                   lp=None) -> Tuple[jax.Array, Params]:
    """One layer of single-pass prefill: compute the layer output AND fill
    the cache.  K/V materialize into the cache by necessity (they ARE the
    cache); the attention *compute* dispatches through the planner's
    per-layer decision when ``lp`` (an ``repro.plan.LayerPlan``) is given —
    ``kernels.ops.attention_by_plan`` with the layer's resolved mode and
    block tiling — and falls back to the flash path (LAYER_STREAM
    semantics) otherwise.  MLA keeps the latent-only cache —
    tile-streaming decompression at decode."""
    from repro.kernels import ops as _ops
    h = L.rms_norm(p["norm1"], x, eps=cfg.norm_eps)
    new_c = dict(cache_l)
    window = cfg.sliding_window if cfg.attn_kind == AttnKind.SLIDING else 0

    if cfg.family == Family.SSM:
        out, conv_state, final_state = _ssm_prefill_state(
            p["ssm"], cfg, h, use_pallas)
        new_c["conv"] = conv_state.astype(cache_l["conv"].dtype)
        new_c["state"] = final_state
        x = x + out
        return x, new_c

    if cfg.attn_kind == AttnKind.MLA:
        c_lat, k_rope = MLA._latent(p["attn"], cfg, h, sin, cos)
        new_c["c"] = jax.lax.dynamic_update_slice_in_dim(
            cache_l["c"], c_lat.astype(cache_l["c"].dtype), 0, 1)
        new_c["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
            cache_l["k_rope"], k_rope[:, 0].astype(cache_l["k_rope"].dtype),
            0, 1)
        attn_out = MLA.mla_forward(p["attn"], cfg, h, sin=sin, cos=cos,
                                   causal=True, use_pallas=use_pallas)
        x = x + attn_out
    else:
        q = jnp.einsum("bsd,dhe->bhse", h, p["attn"]["wq"].astype(h.dtype))
        if cfg.use_qk_norm:
            from repro.kernels import ref as _ref
            q = _ref.rms_norm(q, p["attn"]["q_gamma"], eps=cfg.norm_eps)
        if sin is not None:
            q = L.apply_rope_bsd(q, sin, cos)
        k, v = _project_kv(p["attn"], cfg, h, sin, cos)
        if lp is not None:
            # Planner-resolved per-layer dispatch (DESIGN.md §11): the
            # plan's mode picks the execution system (numerically
            # equivalent across modes), its blocks set the kernel tiling.
            attn_out = _ops.attention_by_plan(
                lp, q, h, p["attn"]["wk"], p["attn"]["wv"],
                sin=sin, cos=cos, k_gamma=p["attn"].get("k_gamma"),
                causal=True, window=window, norm_eps=cfg.norm_eps,
                kv=(k, v),      # cache fill already materialized them
                use_pallas=use_pallas)
        else:
            attn_out = _ops.multi_head_attention(q, k, v, causal=True,
                                                 window=window,
                                                 use_pallas=use_pallas)
        attn_out = jnp.einsum("bhse,hed->bsd", attn_out,
                              p["attn"]["wo"].astype(h.dtype))
        kv_slot = cache_l["attn"] if cfg.family == Family.HYBRID else cache_l
        filled = dict(kv_slot)
        S_in = k.shape[2]
        W = kv_slot["k"].shape[2]
        if S_in > W:
            # Ring-buffer (SWA): keep the last W keys, rolled so that
            # absolute position p lands in slot p % W.
            k = jnp.roll(k[:, :, -W:], S_in % W, axis=2)
            v = jnp.roll(v[:, :, -W:], S_in % W, axis=2)
        filled["k"] = jax.lax.dynamic_update_slice_in_dim(
            kv_slot["k"], k.astype(kv_slot["k"].dtype), 0, 2)
        filled["v"] = jax.lax.dynamic_update_slice_in_dim(
            kv_slot["v"], v.astype(kv_slot["v"].dtype), 0, 2)
        if cfg.family == Family.HYBRID:
            s_out, conv_state, final_state = _ssm_prefill_state(
                p["ssm"], cfg, h, use_pallas)
            new_ssm = dict(cache_l["ssm"])
            new_ssm["conv"] = conv_state.astype(cache_l["ssm"]["conv"].dtype)
            new_ssm["state"] = final_state
            beta = jax.nn.softmax(p["mix_beta"]).astype(x.dtype)
            x = x + beta[0] * attn_out + beta[1] * s_out
            new_c = {"attn": filled, "ssm": new_ssm}
        else:
            x = x + attn_out
            new_c = filled

    h2 = L.rms_norm(p["norm2"], x, eps=cfg.norm_eps)
    if "moe" in p:
        x = x + L.moe_forward(p["moe"], cfg, h2, use_pallas=use_pallas)
    else:
        x = x + L.mlp_forward(p["mlp"], cfg, h2, use_pallas=use_pallas)
    return x, new_c


def _dispatch_segments(cfg: ModelConfig, plan, lo: int, hi: int,
                       per_layer: bool = False):
    """Maximal runs ``[a, b)`` of model layers in the stack range
    ``[lo, hi)`` sharing one planner dispatch decision (mode + block
    tiling), each paired with a representative ``LayerPlan``.  A uniform
    (or absent) plan yields one segment — the whole stack scans in one
    ``lax.scan`` exactly as before; a heterogeneous plan splits the scan
    at mode boundaries so no layer collapses to another layer's mode.
    Plan-less layers (SSM/hybrid mixers with no attention op) carry no
    dispatch decision and merge into the surrounding segment.

    ``per_layer=True`` forces one segment per layer — used while a
    ``repro.sim.replay`` recording is active, so each layer's
    ``KernelTrace`` is emitted under *its own* op name instead of the
    segment representative's."""
    if plan is None:
        return [(lo, hi, None)]
    reps = []
    for i in range(lo, hi):
        lps = [lp for lp in plan.layers if lp.layer_index == i]
        reps.append(lps[0] if lps else None)
    if per_layer:
        return [(lo + i, lo + i + 1, reps[i]) for i in range(hi - lo)]
    def key(lp):
        return (lp.mode, lp.block_q, lp.block_kv)
    segs = []
    start = 0
    seg_rep = None                  # first attention rep in the segment
    for i in range(hi - lo):
        r = reps[i]
        if r is None:
            continue                # no dispatch decision: stay mergeable
        if seg_rep is None:
            seg_rep = r
        elif key(r) != key(seg_rep):
            segs.append((lo + start, lo + i, seg_rep))
            start, seg_rep = i, r
    segs.append((lo + start, hi, seg_rep))
    return segs


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            max_len: int, *, mode: Optional[ExecutionMode] = None,
            plan=None,
            use_pallas: bool = False) -> Tuple[jax.Array, Params]:
    """Single-pass prompt processing: fills the cache and returns
    full-prompt logits (B, S, V).

    ``plan`` — an ``repro.plan.ExecutionPlan`` for this model: each
    layer's attention dispatches under *its own* resolved mode and block
    tiling (``kernels.ops.attention_by_plan``); heterogeneous plans split
    the layer scan into maximal same-mode segments instead of collapsing
    to the first layer's mode (DESIGN.md §11).  ``mode`` is the legacy
    knob (the cache-fill path is mode-invariant; kept for API
    compatibility)."""
    del mode                                # legacy knob, see docstring
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    x = L.embed_lookup(params["embed"], tokens)
    sin = cos = None
    if cfg.num_heads and cfg.attn_kind != AttnKind.NONE:
        hd = (cfg.qk_rope_head_dim if cfg.attn_kind == AttnKind.MLA
              else cfg.head_dim)
        sin, cos = L.rope_tables_for(cfg, S, head_dim=hd)

    def scan_fill(x, stack, cache_slice, lp=None):
        def stp(carry, inp):
            lpar, lc = inp
            return _prefill_layer(lpar, cfg, carry, lc, sin=sin, cos=cos,
                                  use_pallas=use_pallas, lp=lp)
        return maybe_scan(stp, x, (stack, cache_slice))

    if cfg.family == Family.MOE and cfg.first_dense_layers:
        nd = cfg.first_dense_layers
        stacks = [("dense_layers", 0, nd), ("layers", nd, cfg.num_layers)]
    else:
        stacks = [("layers", 0, cfg.num_layers)]
    # Under an active kernel recording, split per layer so each layer's
    # KernelTrace carries its own op name (recording implies the
    # unrolled path — inside lax.scan the recorder sees tracers and
    # stays silent anyway).
    import sys
    replay = sys.modules.get("repro.sim.replay")
    rec_active = (replay is not None
                  and replay.active_recorder() is not None)
    parts = []
    for pname, lo, hi in stacks:
        for a, b, lp in _dispatch_segments(cfg, plan, lo, hi,
                                           per_layer=rec_active):
            seg_p = jax.tree.map(lambda t: t[a - lo:b - lo], params[pname])
            seg_c = jax.tree.map(lambda t: t[a:b], cache["layers"])
            x, new_c = scan_fill(x, seg_p, seg_c, lp)
            parts.append(new_c)
    new_layers = parts[0] if len(parts) == 1 else jax.tree.map(
        lambda *ls: jnp.concatenate(ls, 0), *parts)

    x = L.rms_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"layers": new_layers,
                    "len": jnp.full((), S, jnp.int32)}
