"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

MLA compresses K/V into a small latent c_kv (plus a shared roped key) and
decompresses per head at attention time.  This is the *strongest* case for
the paper's tile-streaming insight: K and V literally do not exist as
tensors until attention runs — StreamDCIM's "generate KV tiles in flight"
is the only sane dataflow.  Prefill/train decompress tile-wise; decode uses
the absorbed form (latent-space scores) so the cache stays tiny
(kv_lora_rank + rope_dim per token).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ExecutionMode, ModelConfig
from repro.kernels import ops, ref
from repro.models.layers import _pdtype, dense_init

Params = Dict[str, Any]


def mla_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    H = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq_a": dense_init(ks[0], (d, qr), _pdtype(cfg)),
        "q_norm": jnp.ones((qr,), _pdtype(cfg)),
        "wq_b": dense_init(ks[1], (qr, H, dn + dr), _pdtype(cfg)),
        "wkv_a": dense_init(ks[2], (d, kvr + dr), _pdtype(cfg)),
        "kv_norm": jnp.ones((kvr,), _pdtype(cfg)),
        "wk_b": dense_init(ks[3], (kvr, H, dn), _pdtype(cfg)),
        "wv_b": dense_init(ks[4], (kvr, H, dv), _pdtype(cfg)),
        "wo": dense_init(ks[5], (H, dv, d), _pdtype(cfg)),
    }
    return p


def _project_q(params: Params, cfg: ModelConfig, x: jax.Array,
               sin, cos) -> Tuple[jax.Array, jax.Array]:
    """Returns (q_nope (B,H,S,dn), q_rope (B,H,S,dr))."""
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = jnp.dot(x, params["wq_a"].astype(x.dtype))
    cq = ref.rms_norm(cq, params["q_norm"], eps=cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bhse", cq, params["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    if sin is not None:
        q_rope = ref.apply_rope(q_rope, sin, cos)
    return q_nope, q_rope


def _latent(params: Params, cfg: ModelConfig, x: jax.Array, sin, cos):
    """Returns (c_kv (B,S,kvr) rms-normed, k_rope (B,1,S,dr) roped)."""
    kvr, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ckv = jnp.dot(x, params["wkv_a"].astype(x.dtype))
    c, k_rope = ckv[..., :kvr], ckv[..., kvr:]
    c = ref.rms_norm(c, params["kv_norm"], eps=cfg.norm_eps)
    k_rope = k_rope[:, None]                       # (B, 1, S, dr)
    if sin is not None:
        k_rope = ref.apply_rope(k_rope, sin, cos)
    return c, k_rope


def mla_forward(params: Params, cfg: ModelConfig, x: jax.Array, *,
                sin=None, cos=None, causal: bool = True,
                mode: Optional[ExecutionMode] = None,
                use_pallas: bool = False) -> jax.Array:
    """Prefill/train path: decompress K/V (tile-wise in TILE_STREAM via the
    stream kernel over the latent, since K = c_kv @ wk_b is exactly the
    'KV generated at runtime' pattern)."""
    mode = mode or cfg.execution_mode
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_rope_head_dim and cfg.qk_nope_head_dim, \
        cfg.qk_rope_head_dim, cfg.v_head_dim
    dn = cfg.qk_nope_head_dim
    kvr = cfg.kv_lora_rank
    q_nope, q_rope = _project_q(params, cfg, x, sin, cos)
    c, k_rope = _latent(params, cfg, x, sin, cos)

    # Scores decompose: q_nope·k_nope + q_rope·k_rope.  Absorb wk_b into the
    # query (q_lat = q_nope @ wk_b^T) so attention runs in latent space —
    # the TILE_STREAM analogue for MLA (K/V never materialize; the latent
    # IS the cache).  Structurally this is MQA with one shared 'key'
    # [c ; k_rope] of width kvr+dr and 'value' c of width kvr, so it
    # streams through the flash block loop (memory O(S·block) — a (B,H,S,S)
    # probability tensor would be 4 TiB/device at the 32k prefill shape).
    q_lat = jnp.einsum("bhse,rhe->bhsr", q_nope,
                       params["wk_b"].astype(x.dtype))   # (B,H,S,kvr)
    scale = (dn + dr) ** -0.5
    # flash applies hd_qk^-0.5; rescale q so the effective scale matches.
    fake_hd = kvr + dr
    rescale = scale * (fake_hd ** 0.5)
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1) * rescale
    k_cat = jnp.concatenate([c, k_rope[:, 0]], axis=-1)[:, None]  # (B,1,S,·)
    ctx_lat = ops.mla_latent_attention(
        q_cat, k_cat.astype(q_cat.dtype), c[:, None].astype(q_cat.dtype),
        causal=causal, use_pallas=use_pallas)             # (B,H,S,kvr)
    out = jnp.einsum("bhsr,rhe->bhse", ctx_lat,
                     params["wv_b"].astype(x.dtype))     # (B,H,S,dv)
    return jnp.einsum("bhse,hed->bsd", out, params["wo"].astype(x.dtype))


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    kvr, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    return {"c": jnp.zeros((batch, max_len, kvr), dtype),
            "k_rope": jnp.zeros((batch, max_len, dr), dtype),
            "len": jnp.zeros((), jnp.int32)}


def mla_decode(params: Params, cfg: ModelConfig, x: jax.Array, cache: Params
               ) -> Tuple[jax.Array, Params]:
    """Absorbed-form decode: scores/context computed in latent space; cache
    holds only (c_kv, k_rope) per position — (kvr + dr) floats/token."""
    from repro.models.layers import rope_at
    B = x.shape[0]
    pos = cache["len"]
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    sin_t, cos_t = rope_at(pos, dr, cfg.rope_theta)
    q_nope, q_rope = _project_q(params, cfg, x, sin_t, cos_t)
    c_new, kr_new = _latent(params, cfg, x, sin_t, cos_t)
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["c"], c_new.astype(cache["c"].dtype), pos, 1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new[:, 0].astype(cache["k_rope"].dtype), pos, 1)

    q_lat = jnp.einsum("bhse,rhe->bhsr", q_nope, params["wk_b"].astype(x.dtype))
    scale = (dn + dr) ** -0.5
    s = (jnp.einsum("bhsr,btr->bhst", q_lat.astype(jnp.float32),
                    c_cache.astype(jnp.float32))
         + jnp.einsum("bhse,bte->bhst", q_rope.astype(jnp.float32),
                      kr_cache.astype(jnp.float32))) * scale
    t = jnp.arange(c_cache.shape[1])[None, None, None, :]
    s = jnp.where(t <= pos, s, ref.NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhst,btr->bhsr", p_attn, c_cache.astype(jnp.float32))
    out = jnp.einsum("bhsr,rhe->bhse", ctx_lat.astype(x.dtype),
                     params["wv_b"].astype(x.dtype))
    o = jnp.einsum("bhse,hed->bsd", out, params["wo"].astype(x.dtype))
    return o, {"c": c_cache, "k_rope": kr_cache, "len": pos + 1}
