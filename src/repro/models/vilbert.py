"""ViLBERT-style two-stream multimodal encoder (arXiv:1908.02265) — the
paper's own evaluation workload (§III: ViLBERT-base/large, VQA v2.0,
N_X = N_Y = 4096).

Structure: language stream runs ``text_pre_layers`` plain encoder layers,
then both streams run ``num_coattn_layers`` co-TRM blocks.  A co-TRM block
per stream = co-attention (Q from own stream; K/V *generated from the other
modality's activations* — StreamDCIM's cross-forwarding case) +
self-attention + FFN.

DTPU token pruning (core/pruning.py) runs between co-TRM blocks: each
stream's tokens are ranked by the attention mass the *other* stream pays
them (cross-attention column scores), and both streams are compacted on a
static keep schedule.  The vision frontend is a stub: region/patch
embeddings arrive precomputed (B, S_x, D_x).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import pruning as P
from repro.core.types import ExecutionMode, ModelConfig
from repro.core.scan_utils import maybe_scan
from repro.kernels import ops, ref
from repro.models import layers as L

Params = Dict[str, Any]


def _xattn_init(key, cfg: ModelConfig, d_q: int, d_kv: int,
                num_heads: int, head_dim: int) -> Params:
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {"wq": L.dense_init(ks[0], (d_q, num_heads, head_dim), dt),
            "wk": L.dense_init(ks[1], (d_kv, num_heads, head_dim), dt),
            "wv": L.dense_init(ks[2], (d_kv, num_heads, head_dim), dt),
            "wo": L.dense_init(ks[3], (num_heads, head_dim, d_q), dt)}


def _stream_block_init(key, cfg: ModelConfig, d: int, d_other: int,
                       heads: int, d_ff: int) -> Params:
    hd = d // heads
    ks = jax.random.split(key, 3)
    return {
        "ln_co": L.layer_norm_init(cfg, d),
        "co_attn": _xattn_init(ks[0], cfg, d, d_other, heads, hd),
        "ln_self": L.layer_norm_init(cfg, d),
        "self_attn": _xattn_init(ks[1], cfg, d, d, heads, hd),
        "ln_ff": L.layer_norm_init(cfg, d),
        "mlp": L.mlp_init(ks[2], cfg, d_model=d, d_ff=d_ff),
    }


def _text_layer_init(key, cfg: ModelConfig) -> Params:
    d, h, f = cfg.d_model_y, cfg.num_heads_y, cfg.d_ff_y
    ks = jax.random.split(key, 2)
    return {"ln1": L.layer_norm_init(cfg, d),
            "attn": _xattn_init(ks[0], cfg, d, d, h, d // h),
            "ln2": L.layer_norm_init(cfg, d),
            "mlp": L.mlp_init(ks[1], cfg, d_model=d, d_ff=f)}


def init(key, cfg: ModelConfig) -> Params:
    """Vision stream X: width cfg.d_model; language stream Y: cfg.d_model_y."""
    ks = jax.random.split(key, 8)
    n_pre = cfg.num_layers - cfg.num_coattn_layers
    pre_keys = jax.random.split(ks[0], max(n_pre, 1))
    cox_keys = jax.random.split(ks[1], cfg.num_coattn_layers)
    coy_keys = jax.random.split(ks[2], cfg.num_coattn_layers)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "text_embed": L.embed_init(ks[3], cfg, dim=cfg.d_model_y),
        "text_pos": L.dense_init(ks[4], (cfg.seq_y or 4096, cfg.d_model_y),
                                 dt, scale=0.01),
        "vis_proj": L.dense_init(ks[5], (cfg.d_model, cfg.d_model), dt),
        "text_pre": jax.vmap(lambda k: _text_layer_init(k, cfg))(pre_keys),
        "co_x": jax.vmap(lambda k: _stream_block_init(
            k, cfg, cfg.d_model, cfg.d_model_y, cfg.num_heads,
            cfg.d_ff))(cox_keys),
        "co_y": jax.vmap(lambda k: _stream_block_init(
            k, cfg, cfg.d_model_y, cfg.d_model, cfg.num_heads_y,
            cfg.d_ff_y))(coy_keys),
        "pool_x": L.dense_init(ks[6], (cfg.d_model, cfg.d_model), dt),
        "pool_y": L.dense_init(ks[7], (cfg.d_model_y, cfg.d_model), dt),
        "vqa_head": L.dense_init(jax.random.fold_in(key, 99),
                                 (cfg.d_model, 3129), dt),  # VQA v2 answers
    }


def _resolve(cfg: ModelConfig, mode: ExecutionMode, d_kv: int,
             kv_heads: int, head_dim: int) -> ExecutionMode:
    """Planner rule per layer (repro.plan.heuristics) on the true KV-source
    width — cross-attention resolves against the *other* modality's d."""
    from repro.plan.heuristics import resolve_layer_mode
    return resolve_layer_mode(mode, d_kv=d_kv, num_kv_heads=kv_heads,
                              head_dim=head_dim,
                              fuse_kv_generation=cfg.fuse_kv_generation)


def _self_attn(p: Params, cfg: ModelConfig, x: jax.Array, heads: int,
               mode: ExecutionMode, use_pallas: bool) -> jax.Array:
    q = jnp.einsum("bsd,dhe->bhse", x, p["wq"].astype(x.dtype))
    mode = _resolve(cfg, mode, x.shape[-1], heads, q.shape[-1])
    out = ops.attention_by_mode(mode, q, x, p["wk"], p["wv"], causal=False,
                                use_pallas=use_pallas)
    return jnp.einsum("bhse,hed->bsd", out, p["wo"].astype(x.dtype))


def _co_attn(p: Params, cfg: ModelConfig, x_own: jax.Array,
             x_other: jax.Array, mode: ExecutionMode,
             use_pallas: bool) -> jax.Array:
    """Q from own stream; K/V generated from the *other* modality — the
    mixed-stationary cross-forwarding target (paper Fig. 4a)."""
    q = jnp.einsum("bsd,dhe->bhse", x_own, p["wq"].astype(x_own.dtype))
    mode = _resolve(cfg, mode, x_other.shape[-1], q.shape[1], q.shape[-1])
    out = ops.attention_by_mode(mode, q, x_other, p["wk"], p["wv"],
                                causal=False, use_pallas=use_pallas)
    return jnp.einsum("bhse,hed->bsd", out, p["wo"].astype(x_own.dtype))


def _stream_block(p: Params, cfg: ModelConfig, x_own: jax.Array,
                  x_other: jax.Array, heads: int, mode: ExecutionMode,
                  use_pallas: bool) -> jax.Array:
    h = L.layer_norm(p["ln_co"], x_own, eps=cfg.norm_eps)
    ho = L.layer_norm(p["ln_co"], x_other, eps=cfg.norm_eps) \
        if x_other.shape[-1] == x_own.shape[-1] else x_other
    x_own = x_own + _co_attn(p["co_attn"], cfg, h, ho, mode, use_pallas)
    h2 = L.layer_norm(p["ln_self"], x_own, eps=cfg.norm_eps)
    x_own = x_own + _self_attn(p["self_attn"], cfg, h2, heads, mode,
                               use_pallas)
    h3 = L.layer_norm(p["ln_ff"], x_own, eps=cfg.norm_eps)
    return x_own + L.mlp_forward(p["mlp"], cfg, h3, use_pallas=use_pallas)


def _dtpu_cross_scores(px: Params, x: jax.Array, y: jax.Array,
                       stride: int = 8) -> jax.Array:
    """Rank Y tokens by attention mass from X queries (DTPU scoring pass)."""
    q = jnp.einsum("bsd,dhe->bhse", x, px["co_attn"]["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bhse", y, px["co_attn"]["wk"].astype(y.dtype))
    return P.attention_column_scores(q, k, causal=False,
                                     sample_stride=stride)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            mode: Optional[ExecutionMode] = None, use_pallas: bool = False,
            remat: bool = False,
            return_token_counts: bool = False):
    """batch: {"regions": (B, S_x, D_x) stub vision embeds,
               "tokens": (B, S_y) text ids}.
    Returns VQA logits (B, 3129) (+ per-block kept-token counts)."""
    mode = mode or cfg.execution_mode
    x = jnp.dot(batch["regions"].astype(jnp.dtype(cfg.dtype)),
                params["vis_proj"].astype(jnp.dtype(cfg.dtype)))
    y = L.embed_lookup(params["text_embed"], batch["tokens"])
    y = y + params["text_pos"][:y.shape[1]].astype(y.dtype)[None]

    n_pre = cfg.num_layers - cfg.num_coattn_layers

    def pre_body(carry, lp):
        h = L.layer_norm(lp["ln1"], carry, eps=cfg.norm_eps)
        c = carry + _self_attn(lp["attn"], cfg, h, cfg.num_heads_y, mode,
                               use_pallas)
        h2 = L.layer_norm(lp["ln2"], c, eps=cfg.norm_eps)
        return c + L.mlp_forward(lp["mlp"], cfg, h2, use_pallas=use_pallas)

    def pre_step(carry, lp):
        fn = jax.checkpoint(pre_body) if remat else pre_body
        return fn(carry, lp), None

    if n_pre > 0:
        y, _ = maybe_scan(pre_step, y, params["text_pre"])

    # Co-TRM blocks with DTPU pruning between blocks (static keep plan).
    nx, ny = x.shape[1], y.shape[1]
    plan_x = P.keep_plan(cfg.pruning, cfg.num_coattn_layers, nx) \
        if cfg.pruning.enabled else (nx,) * cfg.num_coattn_layers
    plan_y = P.keep_plan(cfg.pruning, cfg.num_coattn_layers, ny) \
        if cfg.pruning.enabled else (ny,) * cfg.num_coattn_layers

    counts = []
    for i in range(cfg.num_coattn_layers):
        px = jax.tree.map(lambda a: a[i], params["co_x"])
        py = jax.tree.map(lambda a: a[i], params["co_y"])
        if cfg.pruning.enabled and plan_x[i] < x.shape[1]:
            sx = _dtpu_cross_scores(py, y, x)     # X tokens scored by Y
            x, _, _ = P.prune_stream(x, sx, plan_x[i])
        if cfg.pruning.enabled and plan_y[i] < y.shape[1]:
            sy = _dtpu_cross_scores(px, x, y)     # Y tokens scored by X
            y, _, _ = P.prune_stream(y, sy, plan_y[i])
        counts.append((x.shape[1], y.shape[1]))

        def co_body(x_, y_, px_=px, py_=py):
            x_new = _stream_block(px_, cfg, x_, y_, cfg.num_heads, mode,
                                  use_pallas)
            y_new = _stream_block(py_, cfg, y_, x_, cfg.num_heads_y, mode,
                                  use_pallas)
            return x_new, y_new

        fn = jax.checkpoint(co_body) if remat else co_body
        x, y = fn(x, y)

    hx = jnp.tanh(jnp.dot(x.mean(axis=1), params["pool_x"].astype(x.dtype)))
    hy = jnp.tanh(jnp.dot(y.mean(axis=1), params["pool_y"].astype(y.dtype)))
    logits = jnp.dot(hx * hy, params["vqa_head"].astype(hx.dtype))
    logits = logits.astype(jnp.float32)
    if return_token_counts:
        return logits, tuple(counts)
    return logits


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            mode: Optional[ExecutionMode] = None, use_pallas: bool = False,
            remat: bool = False) -> jax.Array:
    logits = forward(params, cfg, batch, mode=mode, use_pallas=use_pallas,
                     remat=remat)
    labels = batch["answers"]                    # (B,) int
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
