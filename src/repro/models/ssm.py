"""Mamba-2 (SSD) mixer — attention-free state-space layer (arXiv:2405.21060).

The StreamDCIM attention technique is inapplicable here (no Q·K^T); the
*insight* transfers to the SSD chunk dataflow via kernels/ssd_scan.py
(DESIGN.md §4).  Used standalone (mamba2-780m) and inside hymba's hybrid
heads.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig
from repro.kernels import ops, ref
from repro.models.layers import _pdtype, dense_init

Params = Dict[str, Any]


def ssm_dims(cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    d_inner = cfg.ssm_expand * d
    nheads = cfg.ssm_heads or max(d_inner // cfg.ssm_head_dim, 1)
    headdim = d_inner // nheads
    return d, d_inner, nheads, headdim


def ssm_init(key, cfg: ModelConfig, d_model: Optional[int] = None) -> Params:
    d, d_inner, nheads, headdim = ssm_dims(cfg, d_model)
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    # in_proj produces [x (d_inner), z (d_inner), B (N), C (N), dt (nheads)]
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_inner + 2 * N + nheads),
                              _pdtype(cfg)),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, d_inner + 2 * N),
                             _pdtype(cfg), scale=0.5),
        "a_log": jnp.zeros((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "norm_gamma": jnp.ones((d_inner,), _pdtype(cfg)),
        "out_proj": dense_init(ks[2], (d_inner, d), _pdtype(cfg)),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array, d_inner: int, nheads: int):
    N = cfg.ssm_state
    x = proj[..., :d_inner]
    z = proj[..., d_inner:2 * d_inner]
    b = proj[..., 2 * d_inner:2 * d_inner + N]
    c = proj[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = proj[..., 2 * d_inner + 2 * N:]
    return x, z, b, c, dt


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C).
    state (B, K-1, C) carries history for decode."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return out, new_state


def ssm_forward(params: Params, cfg: ModelConfig, xin: jax.Array, *,
                d_model: Optional[int] = None,
                use_pallas: bool = False) -> jax.Array:
    """xin: (B, S, D) pre-normed -> (B, S, D)."""
    d, d_inner, nheads, headdim = ssm_dims(cfg, d_model)
    B, S, _ = xin.shape
    proj = jnp.dot(xin, params["in_proj"].astype(xin.dtype))
    x, z, b, c, dt = _split_proj(cfg, proj, d_inner, nheads)
    xbc = jnp.concatenate([x, b, c], axis=-1)
    xbc, _ = _causal_conv(xbc, params["conv_w"].astype(xin.dtype))
    xbc = jax.nn.silu(xbc)
    x = xbc[..., :d_inner]
    b = xbc[..., d_inner:d_inner + cfg.ssm_state]
    c = xbc[..., d_inner + cfg.ssm_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None])
    a = -jnp.exp(params["a_log"])
    xh = x.reshape(B, S, nheads, headdim)
    y, _ = ops.ssd(xh, dt, a, b, c, chunk=cfg.ssm_chunk,
                   use_pallas=use_pallas)
    y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, d_inner)
    # gated RMSNorm (mamba2's norm-before-out-proj)
    y = ref.rms_norm(y * jax.nn.silu(z), params["norm_gamma"],
                     eps=cfg.norm_eps)
    return jnp.dot(y, params["out_proj"].astype(xin.dtype))


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype,
                   d_model: Optional[int] = None) -> Params:
    d, d_inner, nheads, headdim = ssm_dims(cfg, d_model)
    N = cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_inner + 2 * N), dtype),
        "state": jnp.zeros((batch, nheads, headdim, N), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }


def ssm_decode(params: Params, cfg: ModelConfig, xin: jax.Array,
               cache: Params, *, d_model: Optional[int] = None
               ) -> Tuple[jax.Array, Params]:
    """Single-token recurrent step.  xin: (B, 1, D)."""
    d, d_inner, nheads, headdim = ssm_dims(cfg, d_model)
    B = xin.shape[0]
    proj = jnp.dot(xin, params["in_proj"].astype(xin.dtype))
    x, z, b, c, dt = _split_proj(cfg, proj, d_inner, nheads)
    xbc = jnp.concatenate([x, b, c], axis=-1)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"].astype(xin.dtype),
                                   state=cache["conv"])
    xbc = jax.nn.silu(xbc)
    x = xbc[..., :d_inner]
    b = xbc[..., d_inner:d_inner + cfg.ssm_state]
    c = xbc[..., d_inner + cfg.ssm_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    xh = x.reshape(B, nheads, headdim).astype(jnp.float32)
    decay = jnp.exp(dt[:, 0, :, None, None] * a[None, :, None, None])
    state = (cache["state"] * decay
             + jnp.einsum("bhp,bn->bhpn", xh * dt[:, 0, :, None],
                          b[:, 0].astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", state, c[:, 0].astype(jnp.float32))
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(xin.dtype)
    y = ref.rms_norm(y * jax.nn.silu(z), params["norm_gamma"],
                     eps=cfg.norm_eps)
    out = jnp.dot(y, params["out_proj"].astype(xin.dtype))
    return out, {"conv": conv_state.astype(cache["conv"].dtype),
                 "state": state, "len": cache["len"] + 1}
