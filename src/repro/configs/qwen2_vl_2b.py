"""Qwen2-VL-2B [arXiv:2409.12191; hf] — VLM backbone with M-RoPE
(t/h/w sections 16/24/24 over head_dim/2 = 64).  Vision patch frontend is a
stub: ``input_specs()`` provides embeddings + 3-axis position ids."""
from repro.core.types import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family=Family.VLM,
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128,
    mrope_sections=(16, 24, 24), rope_theta=1_000_000.0, act="silu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke", family=Family.VLM,
    num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=512, head_dim=24,
    mrope_sections=(4, 4, 4), act="silu",
    tie_embeddings=True, dtype="float32", param_dtype="float32",
)
