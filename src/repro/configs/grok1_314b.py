"""Grok-1 314B [hf:xai-org/grok-1] — MoE 8 experts top-2, GQA."""
from repro.core.types import Family, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family=Family.MOE,
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072, head_dim=128,
    num_experts=8, experts_per_token=2, moe_d_ff=32768,
    rope_theta=10_000.0, act="gelu",
)

SMOKE = ModelConfig(
    name="grok1-smoke", family=Family.MOE,
    num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=512, head_dim=24,
    num_experts=4, experts_per_token=2, moe_d_ff=128,
    act="gelu", dtype="float32", param_dtype="float32",
)
