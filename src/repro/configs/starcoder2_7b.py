"""StarCoder2-7B [arXiv:2402.19173; hf] — dense GQA + RoPE."""
from repro.core.types import Family, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family=Family.DENSE,
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152, head_dim=128,
    rope_theta=1_000_000.0, act="gelu", use_bias=True,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke", family=Family.DENSE,
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32,
    rope_theta=1_000_000.0, act="gelu",
    dtype="float32", param_dtype="float32",
)
