"""Mamba2-780m [arXiv:2405.21060] — attention-free SSD.  The paper's
attention technique is inapplicable; the SSD chunk kernel carries the
adapted tile-streaming insight (DESIGN.md §4)."""
from repro.core.types import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family=Family.SSM,
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, attn_kind=AttnKind.NONE,
    ssm_state=128, ssm_heads=48, ssm_head_dim=64, ssm_expand=2,
    ssm_chunk=256, act="silu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family=Family.SSM,
    num_layers=2, d_model=96, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=512, attn_kind=AttnKind.NONE,
    ssm_state=16, ssm_heads=4, ssm_chunk=16,
    act="silu", tie_embeddings=True,
    dtype="float32", param_dtype="float32",
)
