"""Qwen3-32B [hf:Qwen/Qwen3-32B] — dense GQA + qk_norm, head_dim=128."""
from repro.core.types import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family=Family.DENSE,
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    d_ff=25600, vocab_size=151936, head_dim=128,
    use_qk_norm=True, rope_theta=1_000_000.0, act="silu",
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family=Family.DENSE,
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32,
    use_qk_norm=True, rope_theta=1_000_000.0, act="silu",
    dtype="float32", param_dtype="float32",
)
