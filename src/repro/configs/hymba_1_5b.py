"""Hymba-1.5B [arXiv:2411.13676; hf] — hybrid parallel attention+SSM heads,
SWA on attention heads, ssm_state=16."""
from repro.core.types import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family=Family.HYBRID,
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    attn_kind=AttnKind.SLIDING, sliding_window=1024,
    ssm_state=16, ssm_heads=25, ssm_head_dim=128, ssm_expand=2,
    rope_theta=10_000.0, act="silu",
)

SMOKE = ModelConfig(
    name="hymba-smoke", family=Family.HYBRID,
    num_layers=2, d_model=100, num_heads=5, num_kv_heads=5,
    d_ff=192, vocab_size=512, head_dim=20,
    attn_kind=AttnKind.SLIDING, sliding_window=16,
    ssm_state=8, ssm_heads=4, ssm_chunk=16,
    act="silu", dtype="float32", param_dtype="float32",
)
