"""Minitron-4B [arXiv:2407.14679; hf] — pruned Nemotron, huge vocab.

Nemotron's squared-ReLU MLP is approximated with GELU (2-matrix MLP, same
FLOP structure); noted in DESIGN.md §7.
"""
from repro.core.types import Family, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family=Family.DENSE,
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=9216, vocab_size=256000, head_dim=128,
    rope_theta=10_000.0, act="gelu",
)

SMOKE = ModelConfig(
    name="minitron-smoke", family=Family.DENSE,
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=192, vocab_size=1024, head_dim=16,
    act="gelu", dtype="float32", param_dtype="float32",
)
