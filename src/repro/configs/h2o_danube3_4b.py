"""H2O-Danube3-4B [arXiv:2401.16818] — llama+mistral mix with sliding-window
attention (window 4096).  head_dim = 120 (3840/32) — MXU padding exercised."""
from repro.core.types import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube3-4b", family=Family.DENSE,
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
    d_ff=10240, vocab_size=32000, head_dim=120,
    attn_kind=AttnKind.SLIDING, sliding_window=4096,
    rope_theta=10_000.0, act="silu",
)

SMOKE = ModelConfig(
    name="danube3-smoke", family=Family.DENSE,
    num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=512, head_dim=24,
    attn_kind=AttnKind.SLIDING, sliding_window=16,
    act="silu", dtype="float32", param_dtype="float32",
)
