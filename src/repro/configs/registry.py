"""Architecture registry: ``--arch <id>`` resolution, model-module dispatch,
and ``input_specs()`` (ShapeDtypeStruct stand-ins — no allocation) for every
(arch × assigned-shape) cell."""
from __future__ import annotations

import importlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.hardware import HW_PRESETS, HardwareConfig
from repro.core.types import (AttnKind, Family, ModelConfig, ShapeConfig,
                              SHAPES)

ARCHS: Dict[str, str] = {
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "minitron-4b": "repro.configs.minitron_4b",
    "h2o-danube3-4b": "repro.configs.h2o_danube3_4b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "whisper-base": "repro.configs.whisper_base",
    # the paper's own models (extra beyond the assigned pool)
    "vilbert-base": "repro.configs.vilbert_base",
    "vilbert-large": "repro.configs.vilbert_large",
}

ASSIGNED = [a for a in ARCHS if not a.startswith("vilbert")]

# Sub-quadratic archs that run the long_500k cell (DESIGN.md §4); pure
# full-attention archs skip it.
LONG_CONTEXT_OK = {"mamba2-780m", "hymba-1.5b", "h2o-danube3-4b"}


# CIM design points for the repro.sim simulator (same registry object as
# repro.configs.hardware.HW_PRESETS — adding a preset updates both names).
#
# Provenance, one line per entry (cross-referenced from DESIGN.md §7/§9;
# "napkin" = order-of-magnitude estimate, not a paper number):
#
#   streamdcim-base    — paper §II/Fig. 2 macro geometry (groups of
#                        128x128 INT8 TBR-CIM macros, dual-rail bit-serial
#                        input) with the §I TranCIM-derived 512-bit
#                        rewrite bus, calibrated so serial rewriting
#                        stalls ~57% of the §I QK^T micro-workload.
#   streamdcim-small   — napkin: half the macro groups/macros of base, a
#                        capacity-pressure corner (no paper counterpart).
#   streamdcim-widebus — paper §I sensitivity direction: 4x rewrite bus
#                        (2048-bit) showing the stall analysis when the
#                        write port stops being the bottleneck.
HW_CONFIGS: Dict[str, HardwareConfig] = HW_PRESETS

# Energy-cost design points (same object as repro.sim.energy.ENERGY_PRESETS)
# for SimResult.energy() / repro.dse sweeps.
#
# Provenance (DESIGN.md §7/§9 — ratios between modes/design points are
# meaningful, absolute joules are not):
#
#   streamdcim-energy-base      — napkin v5e-class constants (HBM ~45
#                                 pJ/byte ≈ 5.6 pJ/bit DRAM, on-chip ~2
#                                 pJ/byte, ~0.8 pJ/bf16-flop — the
#                                 benchmarks/common.py aliases), with the
#                                 CIM-side per-macro-cycle/rewrite-byte
#                                 costs chosen so the three-way energy
#                                 AND EDP ordering reproduces paper §IV
#                                 (TILE < LAYER < NON on MHA models).
#   streamdcim-energy-lowleak   — napkin 5x leakage reduction (aggressive
#                                 power gating); flattens the Pareto
#                                 frontier's idle-area penalty.
#   streamdcim-energy-dramheavy — napkin 2x pJ/HBM-byte (older HBM /
#                                 LPDDR-class); traffic deltas between
#                                 execution modes dominate even harder.
from repro.sim.energy import ENERGY_PRESETS, EnergyModel  # noqa: E402

ENERGY_CONFIGS: Dict[str, EnergyModel] = ENERGY_PRESETS

# Models the simulator's workload lowering supports (the paper's §III pool).
SIM_ARCHS = ["vilbert-base", "vilbert-large", "qwen2-vl-2b", "whisper-base"]


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def get_hw_config(name: str) -> HardwareConfig:
    return HW_CONFIGS[name]


def get_energy_model(name: str) -> EnergyModel:
    return ENERGY_CONFIGS[name]


def model_module(cfg: ModelConfig):
    if cfg.family == Family.ENCDEC:
        from repro.models import encdec
        return encdec
    if cfg.family == Family.CROSSMODAL:
        from repro.models import vilbert
        return vilbert
    from repro.models import transformer
    return transformer


def cell_supported(arch: str, shape_name: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason string."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return "full-attention arch: 0.5M dense KV out of scope (DESIGN §4)"
    if cfg.family == Family.CROSSMODAL and "decode" in shape_name:
        return "encoder-only: no decode step"
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                per_pod_batch: Optional[int] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the *global* batch of one step.

    For train/prefill: the token batch.  For decode: the new-token batch
    (the KV cache is a separate spec — see ``cache_specs``).
    """
    B = per_pod_batch or shape.global_batch
    S = 1 if shape.is_decode else shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.dtype)

    if cfg.family == Family.ENCDEC:
        specs = {"frames": sds((B, cfg.encoder_seq, cfg.d_model), dt),
                 "tokens": sds((B, S), i32)}
        if shape.kind == "train":
            specs["labels"] = sds((B, S), i32)
        return specs
    if cfg.family == Family.CROSSMODAL:
        specs = {"regions": sds((B, shape.seq_len, cfg.d_model), dt),
                 "tokens": sds((B, shape.seq_len), i32)}
        if shape.kind == "train":
            specs["answers"] = sds((B,), i32)
        return specs

    specs = {"tokens": sds((B, S), i32)}
    if shape.kind == "train":
        specs["labels"] = sds((B, S), i32)
    if cfg.family == Family.VLM and not shape.is_decode:
        specs["positions"] = sds((3, B, S), i32)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                per_pod_batch: Optional[int] = None) -> Any:
    """ShapeDtypeStructs for the decode-time cache (eval_shape — no alloc)."""
    B = per_pod_batch or shape.global_batch
    mod = model_module(cfg)
    if cfg.family == Family.ENCDEC:
        enc = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        def mk():
            cache = mod.init_cache(cfg, B, shape.seq_len,
                                   jnp.zeros(enc.shape, enc.dtype))
            cache["enc"] = jnp.zeros(enc.shape, enc.dtype)
            return cache
        return jax.eval_shape(mk)
    return jax.eval_shape(lambda: mod.init_cache(cfg, B, shape.seq_len))


def param_specs(cfg: ModelConfig) -> Any:
    """ShapeDtypeStructs for params via eval_shape (no allocation)."""
    mod = model_module(cfg)
    return jax.eval_shape(lambda: mod.init(jax.random.PRNGKey(0), cfg))
