"""Whisper-base [arXiv:2212.04356] — enc-dec audio backbone; conv frontend
stubbed (input_specs provides (B, 1500, 512) frame embeddings).  Decoder
position table enlarged to cover the assigned 32k shapes (true whisper caps
at 448 — DESIGN.md §7)."""
from repro.core.types import Family, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family=Family.ENCDEC,
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    num_encoder_layers=6, encoder_seq=1500,
    tie_embeddings=True, act="gelu",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family=Family.ENCDEC,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    num_encoder_layers=2, encoder_seq=48,
    tie_embeddings=True, act="gelu",
    dtype="float32", param_dtype="float32",
)
