"""ViLBERT-large — the paper's second model: BERT-large language stream
(1024, 16H, 24L) with a matched vision stream; 12 co-TRM blocks."""
from repro.core.types import Family, ModelConfig, PruningConfig

CONFIG = ModelConfig(
    name="vilbert-large", family=Family.CROSSMODAL,
    num_layers=24,
    d_model=1024, num_heads=16, d_ff=4096,     # vision stream
    num_kv_heads=16, vocab_size=30522,
    num_coattn_layers=12,
    d_model_y=1024, num_heads_y=16, d_ff_y=4096, seq_y=4096,
    act="gelu", pruning=PruningConfig(enabled=True),
)

SMOKE = ModelConfig(
    name="vilbert-large-smoke", family=Family.CROSSMODAL,
    num_layers=6, d_model=64, num_heads=4, d_ff=128,
    num_kv_heads=4, vocab_size=512,
    num_coattn_layers=3,
    d_model_y=64, num_heads_y=4, d_ff_y=128, seq_y=64,
    act="gelu", pruning=PruningConfig(enabled=True, min_tokens=8),
    dtype="float32", param_dtype="float32",
)
