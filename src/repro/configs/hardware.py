"""StreamDCIM hardware configurations — the simulator's architecture axis.

``HardwareConfig`` is the accelerator-side sibling of ``ModelConfig``: where
a ``ModelConfig`` pins one network, a ``HardwareConfig`` pins one CIM design
point for ``repro.sim`` to execute it on (paper §II / Fig. 2).  The default
``STREAMDCIM_BASE`` is calibrated so the §I TranCIM analysis reproduces:
with K = 2048x512 INT8 over a 512-bit rewrite bus, serial (layer-based
streaming) rewriting stalls ~57% of the QK^T phase.

Presets are registered in ``repro.configs.registry.HW_CONFIGS`` next to
``ARCHS``; ``benchmarks/bench_sim.py`` resolves its design points from
there (``registry.get_hw_config``).
"""
from __future__ import annotations

import dataclasses
import math

# Short axis labels for sweep-derived design-point names
# ("streamdcim-base/g8-gg4-bus1024-pp0"): every sweepable field has one.
_SWEEP_ABBREV = {
    "num_groups": "g",
    "gen_groups": "gg",
    "macros_per_group": "mpg",
    "macro_rows": "r",
    "macro_cols": "c",
    "input_bits": "ib",
    "bits_per_cycle": "bpc",
    "drain_cycles": "dc",
    "rewrite_bus_bits": "bus",
    "hbm_bytes_per_cycle": "hbm",
    "noc_bytes_per_cycle": "noc",
    "ping_pong": "pp",
    "act_bytes": "ab",
}


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """One tile-based streaming digital-CIM design point.

    The macro array is ``num_groups`` groups of ``macros_per_group`` TBR-CIM
    macros; each macro stores a ``macro_rows x macro_cols`` INT8 stationary
    tile and evaluates one input vector bit-serially.  ``rewrite_bus_bits``
    is the shared write port into the CIM sub-arrays (paper §I: 512-bit);
    ``ping_pong`` says whether each macro has the shadow sub-array that lets
    tile t+1 rewrite while tile t computes (paper §II-C).
    """

    name: str = "streamdcim-base"
    # --- macro array geometry ---
    num_groups: int = 4
    macros_per_group: int = 16
    macro_rows: int = 128          # stationary-operand rows (k dim)
    macro_cols: int = 128          # stationary-operand cols (n dim / lanes)
    # --- timing ---
    input_bits: int = 8            # INT8 activations, bit-serial input
    bits_per_cycle: int = 2        # dual-rail input DACless digital issue
    drain_cycles: int = 2          # adder-tree + accumulator drain per vector
    rewrite_bus_bits: int = 512    # CIM write-port width (paper §I)
    # --- memories / networks (bytes per cycle) ---
    hbm_bytes_per_cycle: int = 64  # off-chip DRAM port
    noc_bytes_per_cycle: int = 128  # tile-based streaming network (TBSN)
    # --- features ---
    ping_pong: bool = True         # shadow sub-array (compute-rewrite overlap)
    act_bytes: int = 1             # INT8 activations/scores in DMA accounting
    # --- dataflow split: groups running weight-stationary generation vs
    #     input-stationary attention (mixed-stationary, paper §II-B) ---
    gen_groups: int = 2

    def __post_init__(self):
        # ValueError (not assert): sweep-constructed design points must fail
        # loudly even under ``python -O``, and the message must carry the
        # offending values so a DSE grid error is self-diagnosing.
        def positive(field: str) -> None:
            v = getattr(self, field)
            if v <= 0:
                raise ValueError(
                    f"{self.name}: {field} must be > 0, got {v!r}")
        for field in ("num_groups", "macros_per_group", "macro_rows",
                      "macro_cols", "input_bits", "bits_per_cycle",
                      "rewrite_bus_bits", "hbm_bytes_per_cycle",
                      "noc_bytes_per_cycle", "act_bytes"):
            positive(field)
        if self.drain_cycles < 0:
            raise ValueError(f"{self.name}: drain_cycles must be >= 0, "
                             f"got {self.drain_cycles!r}")
        if not 0 < self.gen_groups < self.num_groups:
            raise ValueError(
                f"{self.name}: gen_groups must satisfy 0 < gen_groups < "
                f"num_groups, got gen_groups={self.gen_groups} "
                f"num_groups={self.num_groups}")
        if self.rewrite_bus_bits % 8:
            raise ValueError(
                f"{self.name}: rewrite_bus_bits must be a multiple of 8 "
                f"(whole bytes per write-port cycle), got "
                f"{self.rewrite_bus_bits}")

    # ---------- sweep construction ----------

    @classmethod
    def sweep(cls, base: "HardwareConfig | None" = None,
              name: "str | None" = None, **overrides) -> "HardwareConfig":
        """Build a validated sweep design point: ``base`` (default
        ``STREAMDCIM_BASE``) with field overrides and a deterministic
        derived name (``streamdcim-base/g8-gg4-bus1024``) so sweep
        artifacts and Pareto reports are self-describing.  Validation is
        the same ``__post_init__`` path every config takes; unknown
        fields raise ``ValueError`` (a typo'd axis must not silently
        sweep nothing)."""
        base = base if base is not None else STREAMDCIM_BASE
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(overrides) - known)
        if unknown:
            raise ValueError(f"unknown HardwareConfig sweep field(s) "
                             f"{unknown}; sweepable: {sorted(known)}")
        if name is None:
            order = list(_SWEEP_ABBREV)      # canonical axis order
            parts = [f"{_SWEEP_ABBREV.get(k, k)}{int(v) if isinstance(v, bool) else v}"
                     for k, v in sorted(overrides.items(),
                                        key=lambda kv: order.index(kv[0]))
                     if getattr(base, k) != v]
            name = base.name + ("/" + "-".join(parts) if parts else "")
        return dataclasses.replace(base, name=name, **overrides)

    # ---------- derived quantities ----------

    @property
    def vector_cycles(self) -> int:
        """Cycles for one input vector through a stationary tile set."""
        return math.ceil(self.input_bits / self.bits_per_cycle) + self.drain_cycles

    @property
    def rewrite_bytes_per_cycle(self) -> int:
        return self.rewrite_bus_bits // 8

    @property
    def num_macros(self) -> int:
        return self.num_groups * self.macros_per_group

    @property
    def gen_macros(self) -> int:
        return self.gen_groups * self.macros_per_group

    @property
    def attn_macros(self) -> int:
        return (self.num_groups - self.gen_groups) * self.macros_per_group

    @property
    def macro_tile_bytes(self) -> int:
        return self.macro_rows * self.macro_cols  # INT8 stationary cells


STREAMDCIM_BASE = HardwareConfig()

# Half the macro array — utilization/stall behavior under tighter capacity.
STREAMDCIM_SMALL = dataclasses.replace(
    STREAMDCIM_BASE, name="streamdcim-small", num_groups=2, gen_groups=1,
    macros_per_group=8)

# Wider rewrite bus: what §I's stall analysis looks like when the write
# port is no longer the bottleneck.
STREAMDCIM_WIDEBUS = dataclasses.replace(
    STREAMDCIM_BASE, name="streamdcim-widebus", rewrite_bus_bits=2048)

HW_PRESETS = {h.name: h for h in
              (STREAMDCIM_BASE, STREAMDCIM_SMALL, STREAMDCIM_WIDEBUS)}
