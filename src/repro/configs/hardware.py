"""StreamDCIM hardware configurations — the simulator's architecture axis.

``HardwareConfig`` is the accelerator-side sibling of ``ModelConfig``: where
a ``ModelConfig`` pins one network, a ``HardwareConfig`` pins one CIM design
point for ``repro.sim`` to execute it on (paper §II / Fig. 2).  The default
``STREAMDCIM_BASE`` is calibrated so the §I TranCIM analysis reproduces:
with K = 2048x512 INT8 over a 512-bit rewrite bus, serial (layer-based
streaming) rewriting stalls ~57% of the QK^T phase.

Presets are registered in ``repro.configs.registry.HW_CONFIGS`` next to
``ARCHS``; ``benchmarks/bench_sim.py`` resolves its design points from
there (``registry.get_hw_config``).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """One tile-based streaming digital-CIM design point.

    The macro array is ``num_groups`` groups of ``macros_per_group`` TBR-CIM
    macros; each macro stores a ``macro_rows x macro_cols`` INT8 stationary
    tile and evaluates one input vector bit-serially.  ``rewrite_bus_bits``
    is the shared write port into the CIM sub-arrays (paper §I: 512-bit);
    ``ping_pong`` says whether each macro has the shadow sub-array that lets
    tile t+1 rewrite while tile t computes (paper §II-C).
    """

    name: str = "streamdcim-base"
    # --- macro array geometry ---
    num_groups: int = 4
    macros_per_group: int = 16
    macro_rows: int = 128          # stationary-operand rows (k dim)
    macro_cols: int = 128          # stationary-operand cols (n dim / lanes)
    # --- timing ---
    input_bits: int = 8            # INT8 activations, bit-serial input
    bits_per_cycle: int = 2        # dual-rail input DACless digital issue
    drain_cycles: int = 2          # adder-tree + accumulator drain per vector
    rewrite_bus_bits: int = 512    # CIM write-port width (paper §I)
    # --- memories / networks (bytes per cycle) ---
    hbm_bytes_per_cycle: int = 64  # off-chip DRAM port
    noc_bytes_per_cycle: int = 128  # tile-based streaming network (TBSN)
    # --- features ---
    ping_pong: bool = True         # shadow sub-array (compute-rewrite overlap)
    act_bytes: int = 1             # INT8 activations/scores in DMA accounting
    # --- dataflow split: groups running weight-stationary generation vs
    #     input-stationary attention (mixed-stationary, paper §II-B) ---
    gen_groups: int = 2

    def __post_init__(self):
        assert 0 < self.gen_groups < self.num_groups

    # ---------- derived quantities ----------

    @property
    def vector_cycles(self) -> int:
        """Cycles for one input vector through a stationary tile set."""
        return math.ceil(self.input_bits / self.bits_per_cycle) + self.drain_cycles

    @property
    def rewrite_bytes_per_cycle(self) -> int:
        return self.rewrite_bus_bits // 8

    @property
    def num_macros(self) -> int:
        return self.num_groups * self.macros_per_group

    @property
    def gen_macros(self) -> int:
        return self.gen_groups * self.macros_per_group

    @property
    def attn_macros(self) -> int:
        return (self.num_groups - self.gen_groups) * self.macros_per_group

    @property
    def macro_tile_bytes(self) -> int:
        return self.macro_rows * self.macro_cols  # INT8 stationary cells


STREAMDCIM_BASE = HardwareConfig()

# Half the macro array — utilization/stall behavior under tighter capacity.
STREAMDCIM_SMALL = dataclasses.replace(
    STREAMDCIM_BASE, name="streamdcim-small", num_groups=2, gen_groups=1,
    macros_per_group=8)

# Wider rewrite bus: what §I's stall analysis looks like when the write
# port is no longer the bottleneck.
STREAMDCIM_WIDEBUS = dataclasses.replace(
    STREAMDCIM_BASE, name="streamdcim-widebus", rewrite_bus_bits=2048)

HW_PRESETS = {h.name: h for h in
              (STREAMDCIM_BASE, STREAMDCIM_SMALL, STREAMDCIM_WIDEBUS)}
