"""DeepSeek-V3 671B [arXiv:2412.19437; hf] — MLA + 256-expert top-8 MoE
(+1 shared), 3 dense prefix layers, MTP depth 1.

MLA is the strongest tile-streaming case: K/V only ever exist as latent
decompressions (DESIGN.md §4).
"""
from repro.core.types import AttnKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family=Family.MOE,
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432,                       # dense-prefix layer hidden
    vocab_size=129280, attn_kind=AttnKind.MLA,
    num_experts=256, num_shared_experts=1, experts_per_token=8,
    moe_d_ff=2048, first_dense_layers=3,
    q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    mtp_depth=1, rope_theta=10_000.0, act="silu",
)

SMOKE = ModelConfig(
    name="deepseekv3-smoke", family=Family.MOE,
    num_layers=3, d_model=96, num_heads=4, num_kv_heads=4,
    d_ff=192, vocab_size=512, attn_kind=AttnKind.MLA,
    num_experts=8, num_shared_experts=1, experts_per_token=2,
    moe_d_ff=64, first_dense_layers=1,
    q_lora_rank=48, kv_lora_rank=32,
    qk_rope_head_dim=16, qk_nope_head_dim=16, v_head_dim=16,
    act="silu", dtype="float32", param_dtype="float32",
)
