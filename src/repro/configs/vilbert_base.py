"""ViLBERT-base [arXiv:1908.02265] — the paper's own evaluation model
(§III-A: VQA v2.0, N_X = N_Y = 4096 tokens).  Language stream = BERT-base
(768, 12H); vision stream 1024/8H; 6 text-only layers then 6 co-TRM blocks.
DTPU pruning uses the Evo-ViT-style default schedule."""
from repro.core.types import Family, ModelConfig, PruningConfig

CONFIG = ModelConfig(
    name="vilbert-base", family=Family.CROSSMODAL,
    num_layers=12,            # language-stream depth (6 pre + 6 co-TRM)
    d_model=1024, num_heads=8, d_ff=1024,      # vision stream
    num_kv_heads=8, vocab_size=30522,
    num_coattn_layers=6,
    d_model_y=768, num_heads_y=12, d_ff_y=3072, seq_y=4096,
    act="gelu", pruning=PruningConfig(enabled=True),
)

SMOKE = ModelConfig(
    name="vilbert-smoke", family=Family.CROSSMODAL,
    num_layers=4, d_model=64, num_heads=4, d_ff=128,
    num_kv_heads=4, vocab_size=512,
    num_coattn_layers=2,
    d_model_y=48, num_heads_y=4, d_ff_y=96, seq_y=64,
    act="gelu", pruning=PruningConfig(enabled=True, min_tokens=8),
    dtype="float32", param_dtype="float32",
)
