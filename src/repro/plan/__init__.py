"""``repro.plan`` — the unified compile→plan→run/simulate API (DESIGN.md §8).

StreamDCIM's core contribution is a *reconfiguration decision*: per-layer
macro-mode selection (normal vs hybrid → NON_STREAM / LAYER_STREAM /
TILE_STREAM), tiling, and rewrite scheduling.  ``plan_model`` makes that
decision once per (model, shape, hardware) triple and records it in an
``ExecutionPlan`` — a sequence of per-layer ``LayerPlan``s with resolved
modes, block tiling, fuse/prune decisions, and predicted HBM bytes +
rewrite cycles — consumed by the kernel path
(``kernels.ops.attention_by_plan``), the simulator
(``sim.simulate_plan``), and the serving engine
(``serve.Engine(plan=...)``).  Plans serialize (``to_json``) for sweep
tooling and replay.

``repro.plan.heuristics`` holds the decision rules (formerly scattered
across ``core.streaming``, ``kernels.ops``, ``sim.workload`` and
``serve.engine``); the legacy entry points remain as deprecation shims.

This module keeps its heavy imports lazy (PEP 562) so that the
``core.streaming`` shims don't drag the simulator package into every
model import.
"""
from repro.plan.heuristics import (DEFAULT_BLOCK, attn_hbm_bytes,
                                   resolve_layer_mode,
                                   tile_stream_profitable)

from repro.plan.heuristics import (decode_attn_hbm_bytes,  # noqa: F401
                                   decode_rewrite_cycles)

__all__ = [
    "DEFAULT_BLOCK", "attn_hbm_bytes", "decode_attn_hbm_bytes",
    "decode_rewrite_cycles", "resolve_layer_mode",
    "tile_stream_profitable",
    "ExecutionPlan", "LayerPlan", "GemmPlan", "PLAN_VERSION",
    "plan_model", "plan_attention", "resolve_hw",
    "DecodePlan", "DecodeLayerPlan", "DECODE_PLAN_VERSION",
    "plan_decode_step",
    "plan_decode_buckets",
]

_PLANNER_NAMES = {"ExecutionPlan", "LayerPlan", "GemmPlan", "PLAN_VERSION",
                  "plan_model", "plan_attention", "resolve_hw"}
_DECODE_NAMES = {"DecodePlan", "DecodeLayerPlan", "DECODE_PLAN_VERSION",
                 "plan_decode_step", "plan_decode_buckets"}


def __getattr__(name):
    if name in _PLANNER_NAMES:
        from repro.plan import planner
        return getattr(planner, name)
    if name in _DECODE_NAMES:
        from repro.plan import decode
        return getattr(decode, name)
    raise AttributeError(f"module 'repro.plan' has no attribute {name!r}")
