"""``plan_model``: one compile→plan step shared by kernels, simulator and
serving (DESIGN.md §8).

An ``ExecutionPlan`` is the single, inspectable, serializable artifact that
records StreamDCIM's *reconfiguration decision* for one (model, shape,
hardware) triple: per-attention-layer execution mode (the TBR-CIM
hybrid/normal reconfiguration analogue), block tiling, fuse/prune
decisions, and the predicted per-layer HBM bytes + CIM rewrite cycles.
It is consumed by

* ``repro.kernels.ops.attention_by_plan``   — the jax-numeric path,
* ``repro.sim.simulate_plan``               — the cycle-approximate
  simulator (per-layer heterogeneous modes in one run), and
* ``repro.serve.Engine(plan=...)``          — the serving engine, which
  re-plans per admitted wave's prompt shape.

Layer enumeration reuses the simulator's lowering (``sim.workload``): the
planner sees exactly the op graph the simulator executes, so predicted and
simulated traffic are asserted against the *same object* in benchmarks and
tests.  Plans follow CIMFlow's compile-then-evaluate shape
(arXiv:2505.01107) and NeuroSim's one-config-object-through-both-paths
discipline (arXiv:2505.02314).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import (Dict, Iterable, Mapping, Optional, Tuple, TYPE_CHECKING,
                    Union)

from repro.configs.hardware import HW_PRESETS, HardwareConfig
from repro.core.types import (AttnKind, ExecutionMode, ModelConfig,
                              ShapeConfig, SHAPES)
from repro.plan.heuristics import (DEFAULT_BLOCK, attn_hbm_bytes,
                                   resolve_layer_mode)

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.sim.replay import KernelTrace

PLAN_VERSION = 1


# ---------------------------------------------------------------------------
# Plan dataclasses
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """The resolved decision record for one attention layer (paper-sense:
    one attention op, including its Q projection and KV generation)."""

    op_index: int          # position in the lowered op stream
    layer_index: int       # model layer this op belongs to
    name: str              # op tag (e.g. "cox0_co") — stable across paths
    mode: ExecutionMode    # resolved mode (NOT the requested one)
    seq_q: int
    seq_kv: int
    d_q: int               # width of the query-side activations
    d_kv: int              # width of the KV-source activations
    heads: int
    kv_heads: int
    head_dim: int
    cross: bool            # K/V generated from the *other* stream
    block_q: int           # q-tile edge handed to the kernels/simulator
    block_kv: int          # kv-tile edge
    fuse_kv: bool          # generation-fusion on (== mode is TILE_STREAM)
    keep_tokens: int       # DTPU prune decision: kept q tokens (== seq_q
                           # when pruning is off; informational for now)
    hbm_bytes: int         # predicted streamed HBM bytes for this layer
    rewrite_cycles: int    # predicted CIM write-port cycles for this layer
    # Recorded kernel execution for this op (repro.sim.replay.KernelTrace)
    # or None; when present, simulate_plan replays it in place of the
    # analytic lowering (DESIGN.md §10).
    trace: Optional["KernelTrace"] = None

    @property
    def kv_width(self) -> int:
        return 2 * self.kv_heads * self.head_dim

    def attach_trace(self, trace: Optional["KernelTrace"]) -> "LayerPlan":
        """A copy with ``trace`` attached (or detached for None).  The
        record must name this op — attaching another op's timing would
        silently mis-calibrate the replay."""
        if trace is not None and trace.op != self.name:
            raise ValueError(f"trace for op {trace.op!r} cannot attach to "
                             f"LayerPlan {self.name!r}")
        return dataclasses.replace(self, trace=trace)


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """A plain weight-stationary GEMM (FFN matmul, output projection).
    Carried so a plan is self-contained for simulation; ``mode`` is the
    enclosing layer's resolved mode (NON_STREAM round-trips activations)."""

    op_index: int
    layer_index: int
    name: str
    m: int
    k: int
    n: int
    mode: ExecutionMode
    trace: Optional["KernelTrace"] = None   # recorded timing (see LayerPlan)

    def attach_trace(self, trace: Optional["KernelTrace"]) -> "GemmPlan":
        if trace is not None and trace.op != self.name:
            raise ValueError(f"trace for op {trace.op!r} cannot attach to "
                             f"GemmPlan {self.name!r}")
        return dataclasses.replace(self, trace=trace)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The compile→plan artifact for one (model, shape, hw) triple."""

    model: str
    shape: str             # shape-cell name, or "seq<N>" / "default"
    hw: str                # HardwareConfig name (preset or ad-hoc)
    seq_len: int           # requested sequence length (0 = model default)
    layers: Tuple[LayerPlan, ...]
    gemms: Tuple[GemmPlan, ...] = ()
    # Full design-point parameters (dataclasses.asdict of the resolved
    # HardwareConfig), so ad-hoc/modified design points — the sweep use
    # case — survive serialization and re-planning, not just the name.
    hw_params: Mapping[str, object] = dataclasses.field(default_factory=dict)

    def hw_config(self) -> HardwareConfig:
        """The design point this plan was compiled for."""
        if self.hw_params:
            return HardwareConfig(**self.hw_params)
        return HW_PRESETS[self.hw]

    # ---------- inspection ----------

    @property
    def modes(self) -> Tuple[ExecutionMode, ...]:
        """Distinct resolved modes, in first-appearance order."""
        seen = []
        for lp in self.layers:
            if lp.mode not in seen:
                seen.append(lp.mode)
        return tuple(seen)

    @property
    def uniform_mode(self) -> Optional[ExecutionMode]:
        """The single resolved mode, or None for a heterogeneous plan."""
        ms = self.modes
        return ms[0] if len(ms) == 1 else None

    @property
    def heterogeneous(self) -> bool:
        return len(self.modes) > 1

    @property
    def total_hbm_bytes(self) -> int:
        """Predicted attention-layer HBM traffic (weight/FFN traffic is
        mode-invariant and omitted, matching the analytic model)."""
        return sum(lp.hbm_bytes for lp in self.layers)

    @property
    def total_rewrite_cycles(self) -> int:
        return sum(lp.rewrite_cycles for lp in self.layers)

    @property
    def traced_ops(self) -> Tuple[str, ...]:
        """Names of ops carrying an attached ``KernelTrace`` (these replay
        recorded timing in ``simulate_plan``; the rest lower analytically
        — DESIGN.md §10)."""
        return tuple(p.name for p in self.layers + self.gemms
                     if p.trace is not None)

    def layer(self, key: Union[int, str]) -> LayerPlan:
        """Look up a LayerPlan by op name, or by *position* in
        ``self.layers`` for an int (NOT the model layer index — multimodal
        layers hold several attention ops; use ``layers_of`` for those,
        and note ``with_layer_modes`` int keys ARE model layer indices)."""
        if isinstance(key, str):
            for lp in self.layers:
                if lp.name == key:
                    return lp
            raise KeyError(key)
        return self.layers[key]

    def layers_of(self, layer_index: int) -> Tuple[LayerPlan, ...]:
        """All attention ops of one *model* layer (the unit
        ``with_layer_modes`` int keys address)."""
        return tuple(lp for lp in self.layers
                     if lp.layer_index == layer_index)

    def summary(self) -> Dict[str, object]:
        """Compact dict for sweep tooling / ``benchmarks/run.py --json``."""
        counts: Dict[str, int] = {}
        for lp in self.layers:
            counts[lp.mode.value] = counts.get(lp.mode.value, 0) + 1
        return {
            "model": self.model, "shape": self.shape, "hw": self.hw,
            "seq_len": self.seq_len, "attention_layers": len(self.layers),
            "modes": counts,
            "heterogeneous": self.heterogeneous,
            "total_hbm_bytes": self.total_hbm_bytes,
            "total_rewrite_cycles": self.total_rewrite_cycles,
            "traced_ops": len(self.traced_ops),
        }

    # ---------- trace attachment (repro.sim.replay) ----------

    def attach_traces(self, traces: Union[Mapping[str, object],
                                          Iterable[object]]
                      ) -> "ExecutionPlan":
        """Return a new plan with recorded ``KernelTrace``s attached to
        the ops they name.  ``traces`` is an iterable of records (later
        records win) or an op->trace mapping; records whose ``op`` names
        no plan op — e.g. kernel-level ``parent/kernel`` sub-records —
        are ignored, so a raw ``KernelRecorder.records`` list attaches
        directly."""
        if isinstance(traces, Mapping):
            by_op = dict(traces)
        else:
            by_op = {t.op: t for t in traces}
        layers = tuple(lp.attach_trace(by_op[lp.name])
                       if lp.name in by_op else lp for lp in self.layers)
        gemms = tuple(g.attach_trace(by_op[g.name])
                      if g.name in by_op else g for g in self.gemms)
        return dataclasses.replace(self, layers=layers, gemms=gemms)

    def without_traces(self) -> "ExecutionPlan":
        """A copy with every attached trace dropped (pure analytic plan)."""
        return dataclasses.replace(
            self,
            layers=tuple(lp.attach_trace(None) for lp in self.layers),
            gemms=tuple(g.attach_trace(None) for g in self.gemms))

    # ---------- heterogeneous re-planning ----------

    def with_layer_modes(
            self, overrides: Mapping[Union[int, str], ExecutionMode]
    ) -> "ExecutionPlan":
        """Return a new plan with some layers forced to different modes.

        Keys are op names (``"cox0_co"``) or model layer indices (all
        attention ops of that layer).  Predicted bytes / rewrite cycles are
        recomputed for the affected layers; each gemm follows the nearest
        *preceding* attention op of its layer (``plan_model``'s rule), so
        an op-level override also moves that op's output projection.
        """
        hw = self.hw_config()
        new_layers = []
        for lp in self.layers:
            mode = lp.mode
            if lp.name in overrides:
                mode = ExecutionMode(overrides[lp.name])
            elif lp.layer_index in overrides:
                mode = ExecutionMode(overrides[lp.layer_index])
            if mode != lp.mode:
                # A recorded trace is only valid for the mode it ran
                # under — a mode override drops it back to analytic.
                lp = dataclasses.replace(
                    lp, mode=mode, trace=None,
                    fuse_kv=mode == ExecutionMode.TILE_STREAM,
                    hbm_bytes=_predict_bytes(lp, mode, hw),
                    rewrite_cycles=_predict_rewrites(lp, mode, hw))
            new_layers.append(lp)
        attn_by_layer: Dict[int, list] = {}
        for lp in new_layers:                    # op order is preserved
            attn_by_layer.setdefault(lp.layer_index, []).append(lp)
        def gemm_mode(g: GemmPlan) -> ExecutionMode:
            preceding = [lp.mode for lp in attn_by_layer.get(g.layer_index, [])
                         if lp.op_index < g.op_index]
            return preceding[-1] if preceding else g.mode
        def regem(g: GemmPlan) -> GemmPlan:
            m = gemm_mode(g)
            if m == g.mode:
                return g
            return dataclasses.replace(g, mode=m, trace=None)
        new_gemms = tuple(regem(g) for g in self.gemms)
        return dataclasses.replace(self, layers=tuple(new_layers),
                                   gemms=new_gemms)

    # ---------- serialization ----------

    def to_dict(self) -> Dict[str, object]:
        def enc(obj):
            d = dataclasses.asdict(obj)
            d["mode"] = obj.mode.value
            # KernelTrace serializes via its own versioned encoder so a
            # traced plan round-trips traces exactly (DESIGN.md §10).
            d["trace"] = obj.trace.to_dict() if obj.trace else None
            return d
        return {
            "version": PLAN_VERSION,
            "model": self.model, "shape": self.shape, "hw": self.hw,
            "hw_params": dict(self.hw_params),
            "seq_len": self.seq_len,
            "layers": [enc(lp) for lp in self.layers],
            "gemms": [enc(g) for g in self.gemms],
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "ExecutionPlan":
        if d.get("version") != PLAN_VERSION:
            raise ValueError(f"unsupported plan version {d.get('version')!r}")

        def dec(rec):
            rec = dict(rec)
            rec["mode"] = ExecutionMode(rec["mode"])
            tr = rec.get("trace")
            if tr is not None:
                from repro.sim.replay import KernelTrace
                rec["trace"] = KernelTrace.from_dict(tr)
            return rec

        layers = tuple(LayerPlan(**dec(lp)) for lp in d["layers"])
        gemms = tuple(GemmPlan(**dec(g)) for g in d.get("gemms", []))
        return cls(model=d["model"], shape=d["shape"], hw=d["hw"],
                   hw_params=dict(d.get("hw_params", {})),
                   seq_len=int(d["seq_len"]), layers=layers, gemms=gemms)

    @classmethod
    def from_json(cls, s: str) -> "ExecutionPlan":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Prediction helpers (mirror the simulator's scheduler arithmetic exactly)
# ---------------------------------------------------------------------------

def resolve_hw(hw: Union[str, HardwareConfig, None]) -> HardwareConfig:
    if hw is None:
        return HW_PRESETS["streamdcim-base"]
    if isinstance(hw, str):
        return HW_PRESETS[hw]
    return hw


def _predict_bytes(lp: LayerPlan, mode: ExecutionMode,
                   hw: HardwareConfig) -> int:
    return attn_hbm_bytes(lp.seq_q, lp.seq_kv, lp.d_kv, lp.heads,
                          lp.kv_heads, lp.head_dim, mode,
                          block_q=lp.block_q, bytes_per_el=hw.act_bytes)


def _predict_rewrites(lp: LayerPlan, mode: ExecutionMode,
                      hw: HardwareConfig,
                      act_bytes: Optional[int] = None) -> int:
    """CIM write-port cycles spent rewriting K/V for this layer — the same
    arithmetic the simulator's schedulers charge (``sim.pipeline``):
    streaming modes rewrite one KV tile per (q-block, kv-tile) pair
    (TILE_STREAM rides the shadow-array bus, LAYER_STREAM stalls the
    array — the §I 57% analysis); NON_STREAM rewrites K and V whole.
    ``act_bytes`` overrides the hardware's DMA element width so a plan's
    byte and cycle predictions always assume the same element size."""
    rbpc = hw.rewrite_bytes_per_cycle
    ab = hw.act_bytes if act_bytes is None else act_bytes
    if mode == ExecutionMode.NON_STREAM:
        k_bytes = lp.seq_kv * lp.kv_heads * lp.head_dim * ab
        return 2 * math.ceil(k_bytes / rbpc)
    nqb = math.ceil(lp.seq_q / lp.block_q)
    nkb = math.ceil(lp.seq_kv / lp.block_kv)
    kv_tile_bytes = 2 * lp.block_kv * lp.kv_heads * lp.head_dim * ab
    return nqb * nkb * math.ceil(kv_tile_bytes / rbpc)


# ---------------------------------------------------------------------------
# plan_model / plan_attention
# ---------------------------------------------------------------------------

def _resolve_shape(shape: Union[ShapeConfig, str, None],
                   seq_len: int) -> Tuple[str, int]:
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if shape is not None:
        return shape.name, (seq_len or shape.seq_len)
    return (f"seq{seq_len}" if seq_len else "default"), seq_len


def plan_model(cfg: ModelConfig,
               shape: Union[ShapeConfig, str, None] = None, *,
               hw: Union[str, HardwareConfig, None] = None,
               seq_len: int = 0,
               mode: Optional[ExecutionMode] = None,
               force_mode: bool = False,
               layer_modes: Optional[Mapping[Union[int, str],
                                             ExecutionMode]] = None,
               block_q: int = DEFAULT_BLOCK,
               block_kv: int = DEFAULT_BLOCK) -> ExecutionPlan:
    """Compile one (model, shape, hw) triple into an ``ExecutionPlan``.

    * ``shape`` — a ``ShapeConfig`` (or its registry name); its ``seq_len``
      is used unless an explicit ``seq_len`` is given.  ``seq_len=0`` with
      no shape picks the model's paper-typical sequence (``sim.workload``).
    * ``mode`` — the requested execution mode (default:
      ``cfg.execution_mode``).  A TILE_STREAM request is still subject to
      the per-layer profitability / MLA / fusion-knob rules
      (``plan.heuristics``) unless ``force_mode=True``, which pins every
      layer verbatim (benchmark baselines).
    * ``layer_modes`` — per-layer overrides ({op name | layer index:
      mode}) applied after resolution: the heterogeneous-plan entry point.

    Raises ``ValueError`` for attention-free families (no K/V streaming to
    schedule — same contract as ``sim.build_workload``).
    """
    from repro.sim.workload import AttnOp, build_workload
    hw_cfg = resolve_hw(hw)
    shape_name, seq = _resolve_shape(shape, seq_len)
    wl = build_workload(cfg, seq)
    requested = mode or cfg.execution_mode

    layers = []
    gemms = []
    op_index = 0
    for layer in wl.layers:
        cur_mode = requested
        for op in layer.ops:
            if isinstance(op, AttnOp):
                if force_mode:
                    resolved = requested
                else:
                    resolved = resolve_layer_mode(
                        requested, d_kv=op.d_kv, num_kv_heads=op.kv_heads,
                        head_dim=op.head_dim, attn_kind=cfg.attn_kind,
                        fuse_kv_generation=cfg.fuse_kv_generation)
                cur_mode = resolved
                keep = op.seq_q
                if cfg.pruning.enabled:
                    keep = cfg.pruning.kept_tokens(
                        layer.index, len(wl.layers), op.seq_q)
                lp = LayerPlan(
                    op_index=op_index, layer_index=layer.index, name=op.name,
                    mode=resolved, seq_q=op.seq_q, seq_kv=op.seq_kv,
                    d_q=op.d_q, d_kv=op.d_kv, heads=op.heads,
                    kv_heads=op.kv_heads, head_dim=op.head_dim,
                    cross=op.cross, block_q=block_q, block_kv=block_kv,
                    fuse_kv=resolved == ExecutionMode.TILE_STREAM,
                    keep_tokens=keep, hbm_bytes=0, rewrite_cycles=0)
                lp = dataclasses.replace(
                    lp, hbm_bytes=_predict_bytes(lp, resolved, hw_cfg),
                    rewrite_cycles=_predict_rewrites(lp, resolved, hw_cfg))
                layers.append(lp)
            else:
                gemms.append(GemmPlan(op_index=op_index,
                                      layer_index=layer.index, name=op.name,
                                      m=op.m, k=op.k, n=op.n, mode=cur_mode))
            op_index += 1

    plan = ExecutionPlan(model=cfg.name, shape=shape_name, hw=hw_cfg.name,
                         hw_params=dataclasses.asdict(hw_cfg),
                         seq_len=seq, layers=tuple(layers),
                         gemms=tuple(gemms))
    if layer_modes:
        plan = plan.with_layer_modes(layer_modes)
    return plan


def plan_attention(mode: ExecutionMode, *, seq_q: int, seq_kv: int,
                   d_kv: int, heads: int, kv_heads: int, head_dim: int,
                   d_q: Optional[int] = None,
                   hw: Union[str, HardwareConfig, None] = None,
                   block_q: int = DEFAULT_BLOCK,
                   block_kv: int = DEFAULT_BLOCK,
                   bytes_per_el: Optional[int] = None,
                   name: str = "attn", cross: bool = False,
                   force_mode: bool = True,
                   attn_kind: AttnKind = AttnKind.FULL,
                   fuse_kv_generation: bool = True) -> LayerPlan:
    """Build a single ad-hoc ``LayerPlan`` from raw geometry — the planner
    entry point for one attention layer outside a full model (benchmarks,
    the ``attention_by_mode`` deprecation shim, unit tests).

    ``force_mode=True`` (default) pins ``mode`` verbatim, matching the
    legacy dispatch semantics; ``force_mode=False`` applies the resolution
    rules.  ``bytes_per_el`` overrides the hardware's DMA element width
    for the traffic prediction (e.g. 2 for bf16 projections).
    """
    hw_cfg = resolve_hw(hw)
    resolved = mode if force_mode else resolve_layer_mode(
        mode, d_kv=d_kv, num_kv_heads=kv_heads, head_dim=head_dim,
        attn_kind=attn_kind, fuse_kv_generation=fuse_kv_generation)
    lp = LayerPlan(
        op_index=0, layer_index=0, name=name, mode=resolved,
        seq_q=seq_q, seq_kv=seq_kv, d_q=d_q or d_kv, d_kv=d_kv,
        heads=heads, kv_heads=kv_heads, head_dim=head_dim, cross=cross,
        block_q=block_q, block_kv=block_kv,
        fuse_kv=resolved == ExecutionMode.TILE_STREAM,
        keep_tokens=seq_q, hbm_bytes=0, rewrite_cycles=0)
    be = bytes_per_el if bytes_per_el is not None else hw_cfg.act_bytes
    hbm = attn_hbm_bytes(seq_q, seq_kv, d_kv, heads, kv_heads, head_dim,
                         resolved, block_q=block_q, bytes_per_el=be)
    return dataclasses.replace(
        lp, hbm_bytes=hbm,
        rewrite_cycles=_predict_rewrites(lp, resolved, hw_cfg,
                                         act_bytes=be))
