"""``plan_decode_step``: the decode-side half of the compile→plan API
(DESIGN.md §11).

A prefill ``ExecutionPlan`` describes one *shape*; serving traffic is a
timeline of *steps*, each advancing a set of slots whose KV caches have
different lengths.  ``plan_decode_step`` compiles one such step into a
``DecodePlan``: per attention layer, the resolved execution mode (the same
TBR-CIM hybrid/normal reconfiguration decision the prefill planner makes),
the per-slot KV length the layer actually attends over after DTPU pruning
(``PruningConfig.kept_tokens`` — the ``LayerPlan.keep_tokens`` decision,
now *load-bearing*: it shrinks ``seq_kv`` layer by layer), and the
predicted HBM bytes + CIM rewrite cycles for the step.

Like ``ExecutionPlan``, one ``DecodePlan`` object drives all three paths:

* ``repro.kernels.ops.decode_attention_by_plan`` — the jax-numeric decode
  attention (records ``KernelTrace``s under ``repro.sim.replay``),
* ``repro.sim.simulate_serve``                   — the serving-timeline
  simulator (per-step cross-assert: simulated HBM bytes must equal this
  plan's prediction), and
* ``repro.serve.Engine``                         — the live engine, which
  compiles one per decode step from its active slots' cache lengths.

Plans serialize (``to_json``) alongside ``ExecutionPlan`` with the same
versioned-dict discipline, traces included.
"""
from __future__ import annotations

import dataclasses
import json
from typing import (Dict, Iterable, List, Mapping, Optional, Sequence,
                    Tuple, TYPE_CHECKING, Union)

from repro.core.types import (AttnKind, ExecutionMode, Family, ModelConfig,
                              pad_to)
from repro.configs.hardware import HW_PRESETS, HardwareConfig
from repro.plan.heuristics import (DEFAULT_BLOCK, decode_attn_hbm_bytes,
                                   decode_rewrite_cycles, resolve_layer_mode)
from repro.plan.planner import GemmPlan, resolve_hw

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.sim.replay import KernelTrace

DECODE_PLAN_VERSION = 1

#: suffix distinguishing decode-step ops from their prefill counterparts,
#: so a prefill ``KernelTrace`` can never attach to a decode op (and vice
#: versa) by name collision.
DECODE_SUFFIX = ".decode"


@dataclasses.dataclass(frozen=True)
class DecodeLayerPlan:
    """The resolved decision record for one attention layer of one decode
    step, across all active slots."""

    op_index: int          # position in the lowered op stream
    layer_index: int       # model layer this op belongs to
    name: str              # prefill op tag + ``.decode`` (e.g. "l3_self.decode")
    mode: ExecutionMode    # resolved macro mode for this step's layer
    seq_kv: Tuple[int, ...]  # per-slot KV length *attended* (post-pruning,
                             # post window clamp, incl. the new token); the
                             # unpruned lengths live on DecodePlan.context
    d_q: int
    d_kv: int
    heads: int
    kv_heads: int
    head_dim: int
    cross: bool            # static KV (enc-dec cross-attn: no append)
    block_kv: int          # kv-tile edge the rewrite schedule iterates with
    hbm_bytes: int         # predicted streamed HBM bytes, summed over slots
    rewrite_cycles: int    # predicted CIM write-port cycles, summed
    trace: Optional["KernelTrace"] = None   # recorded decode kernel timing

    @property
    def kv_width(self) -> int:
        return 2 * self.kv_heads * self.head_dim

    @property
    def keep_tokens(self) -> Tuple[int, ...]:
        """Per-slot kept KV tokens — ``seq_kv`` IS the DTPU prune decision
        (named to echo ``LayerPlan.keep_tokens``)."""
        return self.seq_kv

    def attach_trace(self, trace: Optional["KernelTrace"]
                     ) -> "DecodeLayerPlan":
        if trace is not None and trace.op != self.name:
            raise ValueError(f"trace for op {trace.op!r} cannot attach to "
                             f"DecodeLayerPlan {self.name!r}")
        return dataclasses.replace(self, trace=trace)


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """The compile→plan artifact for one decode step of a slot batch."""

    model: str
    hw: str
    context: Tuple[int, ...]   # per-slot cache length incl. the new token
    layers: Tuple[DecodeLayerPlan, ...]
    gemms: Tuple[GemmPlan, ...] = ()
    hw_params: Mapping[str, object] = dataclasses.field(default_factory=dict)

    def hw_config(self) -> HardwareConfig:
        if self.hw_params:
            return HardwareConfig(**self.hw_params)
        return HW_PRESETS[self.hw]

    # ---------- inspection ----------

    @property
    def slots(self) -> int:
        return len(self.context)

    @property
    def modes(self) -> Tuple[ExecutionMode, ...]:
        seen: List[ExecutionMode] = []
        for lp in self.layers:
            if lp.mode not in seen:
                seen.append(lp.mode)
        return tuple(seen)

    @property
    def uniform_mode(self) -> Optional[ExecutionMode]:
        ms = self.modes
        return ms[0] if len(ms) == 1 else None

    @property
    def heterogeneous(self) -> bool:
        return len(self.modes) > 1

    @property
    def total_hbm_bytes(self) -> int:
        """Predicted attention HBM traffic for the whole step (the number
        ``sim.simulate_serve`` cross-asserts against)."""
        return sum(lp.hbm_bytes for lp in self.layers)

    @property
    def total_rewrite_cycles(self) -> int:
        return sum(lp.rewrite_cycles for lp in self.layers)

    @property
    def traced_ops(self) -> Tuple[str, ...]:
        return tuple(p.name for p in self.layers + self.gemms
                     if p.trace is not None)

    def layer(self, key: Union[int, str]) -> DecodeLayerPlan:
        """Look up by op name, or by position in ``self.layers``."""
        if isinstance(key, str):
            for lp in self.layers:
                if lp.name == key:
                    return lp
            raise KeyError(key)
        return self.layers[key]

    def summary(self) -> Dict[str, object]:
        counts: Dict[str, int] = {}
        for lp in self.layers:
            counts[lp.mode.value] = counts.get(lp.mode.value, 0) + 1
        return {
            "model": self.model, "hw": self.hw,
            "slots": self.slots, "context": list(self.context),
            "attention_layers": len(self.layers), "modes": counts,
            "heterogeneous": self.heterogeneous,
            "total_hbm_bytes": self.total_hbm_bytes,
            "total_rewrite_cycles": self.total_rewrite_cycles,
            "traced_ops": len(self.traced_ops),
        }

    # ---------- trace attachment (repro.sim.replay) ----------

    def attach_traces(self, traces: Union[Mapping[str, object],
                                          Iterable[object]]) -> "DecodePlan":
        """Attach recorded ``KernelTrace``s to the decode ops they name —
        same contract as ``ExecutionPlan.attach_traces`` (records naming
        no plan op are ignored)."""
        if isinstance(traces, Mapping):
            by_op = dict(traces)
        else:
            by_op = {t.op: t for t in traces}
        layers = tuple(lp.attach_trace(by_op[lp.name])
                       if lp.name in by_op else lp for lp in self.layers)
        gemms = tuple(g.attach_trace(by_op[g.name])
                      if g.name in by_op else g for g in self.gemms)
        return dataclasses.replace(self, layers=layers, gemms=gemms)

    def without_traces(self) -> "DecodePlan":
        return dataclasses.replace(
            self,
            layers=tuple(lp.attach_trace(None) for lp in self.layers),
            gemms=tuple(g.attach_trace(None) for g in self.gemms))

    # ---------- serialization ----------

    def to_dict(self) -> Dict[str, object]:
        def enc(obj):
            d = dataclasses.asdict(obj)
            d["mode"] = obj.mode.value
            d["trace"] = obj.trace.to_dict() if obj.trace else None
            if "seq_kv" in d:
                d["seq_kv"] = list(d["seq_kv"])
            return d
        return {
            "version": DECODE_PLAN_VERSION,
            "model": self.model, "hw": self.hw,
            "hw_params": dict(self.hw_params),
            "context": list(self.context),
            "layers": [enc(lp) for lp in self.layers],
            "gemms": [enc(g) for g in self.gemms],
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "DecodePlan":
        if d.get("version") != DECODE_PLAN_VERSION:
            raise ValueError(
                f"unsupported decode-plan version {d.get('version')!r}")

        def dec(rec):
            rec = dict(rec)
            rec["mode"] = ExecutionMode(rec["mode"])
            tr = rec.get("trace")
            if tr is not None:
                from repro.sim.replay import KernelTrace
                rec["trace"] = KernelTrace.from_dict(tr)
            if "seq_kv" in rec:
                rec["seq_kv"] = tuple(rec["seq_kv"])
            return rec

        layers = tuple(DecodeLayerPlan(**dec(lp)) for lp in d["layers"])
        gemms = tuple(GemmPlan(**dec(g)) for g in d.get("gemms", []))
        return cls(model=d["model"], hw=d["hw"],
                   hw_params=dict(d.get("hw_params", {})),
                   context=tuple(d["context"]), layers=layers, gemms=gemms)

    @classmethod
    def from_json(cls, s: str) -> "DecodePlan":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Decode-op enumeration (mirrors sim.workload's prefill naming)
# ---------------------------------------------------------------------------

def _decode_attn_specs(cfg: ModelConfig) -> List[Dict[str, object]]:
    """The attention ops one decode step runs, in op order, named after
    their ``sim.workload`` prefill counterparts.  Decoder families run one
    self-attention per layer; enc-dec decoders add the static-KV
    cross-attention.  Attention-free and encoder-only families have no
    decode step — same contract as ``registry.cell_supported``."""
    if cfg.num_heads == 0 or cfg.attn_kind == AttnKind.NONE:
        raise ValueError(f"{cfg.name}: attention-free families have no "
                         "decode attention to plan")
    if cfg.family == Family.CROSSMODAL:
        raise ValueError(f"{cfg.name}: encoder-only (crossmodal) families "
                         "have no decode step")
    d = cfg.d_model
    hd = cfg.head_dim or d // cfg.num_heads
    specs: List[Dict[str, object]] = []
    if cfg.family == Family.ENCDEC:
        se = pad_to(cfg.encoder_seq, DEFAULT_BLOCK)
        for i in range(cfg.num_layers):
            specs.append(dict(tag=f"dec{i}_self", layer=i, cross=False,
                              d_q=d, d_kv=d, heads=cfg.num_heads,
                              kv_heads=cfg.num_kv_heads, hd=hd,
                              static_kv=0))
            specs.append(dict(tag=f"dec{i}_cross", layer=i, cross=True,
                              d_q=d, d_kv=d, heads=cfg.num_heads,
                              kv_heads=cfg.num_kv_heads, hd=hd,
                              static_kv=se))
        return specs
    for i in range(cfg.num_layers):
        specs.append(dict(tag=f"l{i}_self", layer=i, cross=False,
                          d_q=d, d_kv=d, heads=cfg.num_heads,
                          kv_heads=cfg.num_kv_heads, hd=hd, static_kv=0))
    return specs


def plan_decode_step(cfg: ModelConfig,
                     context: Union[int, Sequence[int]], *,
                     hw: Union[str, HardwareConfig, None] = None,
                     mode: Optional[ExecutionMode] = None,
                     force_mode: bool = False,
                     block_kv: int = DEFAULT_BLOCK) -> DecodePlan:
    """Compile one decode step into a ``DecodePlan``.

    ``context`` — per-active-slot KV length the step attends over
    *including* the token being decoded (i.e. ``prompt_len +
    tokens_generated_so_far + 1``); a bare int plans a single slot.

    Per layer, the plan records:

    * the resolved execution mode — same per-layer rule as ``plan_model``
      (``force_mode=True`` pins the requested mode verbatim);
    * ``seq_kv`` per slot: the context clamped by the sliding window
      (ring-buffer caches never exceed ``cfg.sliding_window``) and then by
      the DTPU prune decision ``PruningConfig.kept_tokens(layer, ...)`` —
      the ``LayerPlan.keep_tokens`` schedule applied to the KV cache, so
      deeper layers attend over monotonically fewer tokens;
    * predicted HBM bytes (``decode_attn_hbm_bytes``) and CIM rewrite
      cycles (``decode_rewrite_cycles``), summed over slots — the numbers
      ``sim.simulate_serve`` cross-asserts per step.

    The step's weight-stationary GEMMs (output projection + FFN, one token
    per slot) ride along as ``GemmPlan``s so the plan lowers
    self-contained, exactly like ``ExecutionPlan.gemms``.
    """
    hw_cfg = resolve_hw(hw)
    ctxs = (context,) if isinstance(context, int) else tuple(context)
    if not ctxs or any(c < 1 for c in ctxs):
        raise ValueError(f"context lengths must be >= 1, got {ctxs!r}")
    requested = mode or cfg.execution_mode
    specs = _decode_attn_specs(cfg)
    n_layers = max(s["layer"] for s in specs) + 1
    nslots = len(ctxs)

    layers: List[DecodeLayerPlan] = []
    gemms: List[GemmPlan] = []
    op_index = 0
    specs_of: Dict[int, List[Dict[str, object]]] = {}
    for s in specs:
        specs_of.setdefault(s["layer"], []).append(s)
    d, d_ff = cfg.d_model, cfg.d_ff
    for li in sorted(specs_of):
        cur_mode = requested
        for s in specs_of[li]:
            if force_mode:
                resolved = requested
            else:
                resolved = resolve_layer_mode(
                    requested, d_kv=s["d_kv"], num_kv_heads=s["kv_heads"],
                    head_dim=s["hd"], attn_kind=cfg.attn_kind,
                    fuse_kv_generation=cfg.fuse_kv_generation)
            cur_mode = resolved
            per_slot: List[int] = []
            for c in ctxs:
                kv = c if not s["static_kv"] else int(s["static_kv"])
                if not s["static_kv"] and cfg.attn_kind == AttnKind.SLIDING:
                    kv = min(kv, cfg.sliding_window)
                if cfg.pruning.enabled:
                    kv = min(kv, max(1, cfg.pruning.kept_tokens(
                        s["layer"], n_layers, kv)))
                per_slot.append(kv)
            append = not s["cross"]
            hbm = sum(decode_attn_hbm_bytes(
                kv, s["heads"], s["kv_heads"], s["hd"], resolved,
                append=append, bytes_per_el=hw_cfg.act_bytes)
                for kv in per_slot)
            rw = sum(decode_rewrite_cycles(
                kv, s["kv_heads"], s["hd"], resolved, block_kv=block_kv,
                rewrite_bytes_per_cycle=hw_cfg.rewrite_bytes_per_cycle,
                bytes_per_el=hw_cfg.act_bytes) for kv in per_slot)
            layers.append(DecodeLayerPlan(
                op_index=op_index, layer_index=s["layer"],
                name=s["tag"] + DECODE_SUFFIX, mode=resolved,
                seq_kv=tuple(per_slot),
                d_q=s["d_q"], d_kv=s["d_kv"], heads=s["heads"],
                kv_heads=s["kv_heads"], head_dim=s["hd"], cross=s["cross"],
                block_kv=block_kv, hbm_bytes=hbm, rewrite_cycles=rw))
            op_index += 1
            gemms.append(GemmPlan(
                op_index=op_index, layer_index=s["layer"],
                name=f"{s['tag']}_oproj" + DECODE_SUFFIX,
                m=nslots, k=s["heads"] * s["hd"], n=s["d_q"], mode=resolved))
            op_index += 1
        # FFN stack per model layer (gated MLPs carry the extra gate
        # matmul, matching sim.workload._ffn_ops).
        prefix = f"dec{li}" if cfg.family == Family.ENCDEC else f"l{li}"
        ffn = [("ffn_up", d, d_ff)]
        if cfg.act == "silu":
            ffn.append(("ffn_gate", d, d_ff))
        ffn.append(("ffn_down", d_ff, d))
        for t, k, n in ffn:
            gemms.append(GemmPlan(
                op_index=op_index, layer_index=li,
                name=f"{prefix}_{t}" + DECODE_SUFFIX,
                m=nslots, k=k, n=n, mode=cur_mode))
            op_index += 1

    return DecodePlan(model=cfg.name, hw=hw_cfg.name,
                      hw_params=dataclasses.asdict(hw_cfg),
                      context=ctxs, layers=tuple(layers),
                      gemms=tuple(gemms))


def plan_decode_buckets(cfg: ModelConfig,
                        context: Sequence[int], *,
                        hw: Union[str, HardwareConfig, None] = None,
                        mode: Optional[ExecutionMode] = None,
                        force_mode: bool = False,
                        block_kv: int = DEFAULT_BLOCK
                        ) -> List[Tuple[Tuple[int, ...], DecodePlan]]:
    """Plan one decode step as per-shape-bucket ``DecodePlan``s.

    Slots with equal KV length share cache shape and position counter, so
    the batched engine advances each such *bucket* with one
    ``decode_step`` call.  Returns ``[(slot_positions, plan), ...]`` —
    positions index into ``context``, buckets appear in order of their
    first member — where each ``plan`` is ``plan_decode_step`` of that
    bucket's (uniform) context.

    Per-layer attention bytes/cycles and GEMM shapes are per-slot
    additive (the planner never couples slots), so bucket plans are exact
    slices of the whole-step plan: summed over buckets they reproduce
    ``plan_decode_step(cfg, context, ...)``'s ``total_hbm_bytes`` —
    ``sim.simulate_serve`` keeps cross-asserting the whole-step number,
    coarse lowering accounts it bucket-by-bucket.
    """
    ctxs = tuple(context)
    if not ctxs:
        raise ValueError("context must name at least one active slot")
    order: List[int] = []
    members: Dict[int, List[int]] = {}
    for i, c in enumerate(ctxs):
        c = int(c)
        if c not in members:
            members[c] = []
            order.append(c)
        members[c].append(i)
    return [(tuple(members[c]),
             plan_decode_step(cfg, (c,) * len(members[c]), hw=hw, mode=mode,
                              force_mode=force_mode, block_kv=block_kv))
            for c in order]
