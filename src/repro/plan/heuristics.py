"""Planner heuristics: mode resolution + the analytic HBM-traffic model.

These are the decision rules that used to live scattered across the repo
(``core.streaming.choose_mode`` / ``tile_stream_profitable`` /
``streamed_bytes_per_layer``, ``models.layers``' inline fallback, the
``sim.workload`` re-derivation).  They are now *planner internals*
(DESIGN.md §8): ``repro.plan.plan_model`` calls them once per layer and
records the outcome in an ``ExecutionPlan``; the legacy entry points in
``repro.core.streaming`` are deprecation shims over this module.

The core decision (DESIGN.md §2): the TBR-CIM macro's *mode_config* bit
(hybrid vs normal reconfiguration, paper §II-A) maps to an analytic
dataflow choice per attention layer — fusing KV-generation into attention
(TILE_STREAM) reduces HBM traffic iff streaming the raw activations
``x_kv`` (width ``d_kv``) beats streaming materialized K/V
(width ``2·Hkv·hd``):

    per-q-block streamed bytes:   TILE_STREAM  = S·d_kv
                                  LAYER_STREAM = S·2·Hkv·hd   (+ one-time
                                                 2·S·Hkv·hd write for K/V)

For MHA models (the paper's ViLBERT targets: Hkv·hd = d) tile-streaming
strictly wins; for aggressively-GQA LMs (2·Hkv·hd << d) generation-fusion
is traffic-negative and the planner falls back to LAYER_STREAM — the
normal-mode/weight-stationary path.
"""
from __future__ import annotations

from repro.core.types import AttnKind, ExecutionMode

#: q/kv tile edge used by default plans — matches
#: ``kernels/stream_attention.py`` and ``sim.workload.BLOCK``.
DEFAULT_BLOCK = 256


def tile_stream_profitable(d_model: int, num_kv_heads: int,
                           head_dim: int) -> bool:
    """True iff fused KV-generation reduces streamed HBM bytes.

    ``d_model`` is the width of the KV-*source* activations (the other
    modality's width for cross-attention — paper Fig. 4a).
    """
    return 2 * num_kv_heads * head_dim >= d_model


def resolve_layer_mode(requested: ExecutionMode, *, d_kv: int,
                       num_kv_heads: int, head_dim: int,
                       attn_kind: AttnKind = AttnKind.FULL,
                       fuse_kv_generation: bool = True) -> ExecutionMode:
    """Resolve the execution mode for one attention layer.

    Honors an explicit NON_STREAM / LAYER_STREAM request (benchmark
    baselines); for TILE_STREAM, applies the profitability rule unless the
    layer is MLA (latent decompress: always fuse) or ``fuse_kv_generation``
    is off (cross-forwarding disabled).
    """
    if requested != ExecutionMode.TILE_STREAM:
        return requested
    if attn_kind == AttnKind.MLA:
        return ExecutionMode.TILE_STREAM
    if fuse_kv_generation and tile_stream_profitable(d_kv, num_kv_heads,
                                                     head_dim):
        return ExecutionMode.TILE_STREAM
    return ExecutionMode.LAYER_STREAM


def decode_attn_hbm_bytes(seq_kv: int, num_heads: int, num_kv_heads: int,
                          head_dim: int, mode: ExecutionMode, *,
                          append: bool = True,
                          bytes_per_el: int = 2) -> int:
    """Analytic HBM-traffic model for one *decode-step* attention layer,
    one slot (DESIGN.md §11).

    ``seq_kv`` is the KV length the step actually attends over — the
    cache length *including* the token being decoded, after DTPU pruning
    (``PruningConfig.kept_tokens``) shrank it for this layer.  ``append``
    is False for static caches (enc-dec cross-attention: the encoder KV
    never grows).  Mirrored exactly by the simulator's decode lowering
    (``sim.pipeline``):

    * TILE_STREAM  — the new token's K/V are generated on the stationary
      macros and cross-forwarded straight into the attention macros (never
      read back from HBM this step); one cache-append write + a streamed
      read of the ``seq_kv - 1`` previously cached tokens.
    * LAYER_STREAM — layer-granular sync: the append commits to HBM first,
      then attention re-reads the *whole* cache including the new token.
    * NON_STREAM   — unfused: Q and the score/probability rows spill and
      round-trip HBM around every stage, exactly like the prefill model.
    """
    kv_w = 2 * num_kv_heads * head_dim * bytes_per_el
    qo = num_heads * head_dim * bytes_per_el       # one token's Q (== O)
    if mode == ExecutionMode.NON_STREAM:
        a = num_heads * seq_kv * bytes_per_el      # one score row per head
        return ((kv_w if append else 0) + seq_kv * kv_w
                + 2 * qo + 4 * a + 2 * qo)
    if mode == ExecutionMode.LAYER_STREAM:
        return (kv_w if append else 0) + seq_kv * kv_w
    # TILE_STREAM: forwarded new-token KV is not re-read — with append the
    # step moves (seq_kv - 1) cached rows in + 1 appended row out, without
    # it just the seq_kv cached rows; both total seq_kv rows.
    return seq_kv * kv_w


def decode_rewrite_cycles(seq_kv: int, num_kv_heads: int, head_dim: int,
                          mode: ExecutionMode, *,
                          block_kv: int = DEFAULT_BLOCK,
                          rewrite_bytes_per_cycle: int,
                          bytes_per_el: int = 2) -> int:
    """CIM write-port cycles to land one decode step's KV working set in
    the attention macros — the same per-tile arithmetic the simulator's
    decode lowering charges.  Streaming modes rewrite the cached KV tile
    by tile (the last tile may be partial — decode lengths are ragged);
    NON_STREAM rewrites K and V whole.  This is where DTPU pruning pays
    off in decode: fewer kept tokens, fewer tiles rewritten."""
    kv_row = 2 * num_kv_heads * head_dim * bytes_per_el
    if mode == ExecutionMode.NON_STREAM:
        half = seq_kv * num_kv_heads * head_dim * bytes_per_el
        return 2 * -(-half // rewrite_bytes_per_cycle)
    cycles = 0
    done = 0
    while done < seq_kv:
        tile = min(block_kv, seq_kv - done)
        cycles += -(-(tile * kv_row) // rewrite_bytes_per_cycle)
        done += tile
    return cycles


def attn_hbm_bytes(seq_q: int, seq_kv: int, d_kv: int, num_heads: int,
                   num_kv_heads: int, head_dim: int, mode: ExecutionMode, *,
                   block_q: int = DEFAULT_BLOCK,
                   bytes_per_el: int = 2) -> int:
    """Analytic HBM-traffic model for one attention layer (DESIGN.md §6).

    Counts Q/K/V/O/x_kv movement; weight traffic is identical across modes
    and omitted.  ``d_kv`` is the KV-source activation width (== d_model
    for self-attention).
    """
    # ceil, matching the simulator's schedulers (which pad partial tiles).
    nqb = max(-(-seq_q // block_q), 1)
    q_bytes = seq_q * num_heads * head_dim * bytes_per_el
    o_bytes = q_bytes
    kv_width = 2 * num_kv_heads * head_dim
    if mode == ExecutionMode.NON_STREAM:
        # Q,K,V written+read; scores A (H·Sq·Skv) written+read; P written+
        # read; out written.  (The paper's off-chip round-trip baseline.)
        a_bytes = num_heads * seq_q * seq_kv * bytes_per_el
        kv_bytes = seq_kv * kv_width * bytes_per_el
        return (2 * q_bytes + 2 * kv_bytes + 4 * a_bytes + 2 * o_bytes
                + seq_kv * d_kv * bytes_per_el)
    if mode == ExecutionMode.LAYER_STREAM:
        # x_kv read once + K/V written once, then re-read per q block.
        kv_bytes = seq_kv * kv_width * bytes_per_el
        return (q_bytes + o_bytes + seq_kv * d_kv * bytes_per_el
                + kv_bytes + nqb * kv_bytes)
    # TILE_STREAM: x_kv re-read per q block; K/V never touch HBM.
    return (q_bytes + o_bytes + nqb * seq_kv * d_kv * bytes_per_el)
