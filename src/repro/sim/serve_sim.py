"""``simulate_serve``: lower a multi-request serving timeline through the
StreamDCIM schedulers (DESIGN.md §11).

The prefill-only simulator answers "how fast is one shape"; serving
traffic is a *timeline* — arrivals, per-prompt prefills, per-step decodes
over growing KV caches, slot recycling.  ``simulate_serve`` drives the
exact continuous-batching schedule the live engine executes
(``repro.serve.schedule.build_schedule`` — the shared scheduling core)
through the existing discrete-event schedulers:

* each admission lowers that request's prefill ``ExecutionPlan`` (compiled
  per prompt length, heterogeneous per-layer modes included);
* each step's active slots lower one ``DecodePlan``
  (``repro.plan.plan_decode_step``): per-layer modes, per-slot KV lengths
  shrunk by DTPU pruning, tile-granular cache rewrites;
* steps chain sequentially on one engine, so TILE/LAYER/NON comparisons,
  ``SimResult.energy()`` and trace calibration all apply to serving
  traffic, not just one prefill.

Cross-assert (always on): each decode step's simulated HBM bytes must
equal its ``DecodePlan.total_hbm_bytes`` prediction — the planner and the
simulator implement the same traffic model or the run fails loudly.
Decode ops carrying recorded ``KernelTrace``s (via ``decode_plans`` /
``attach_traces``) replay their measured timing instead and are exempt.

``decode_lowering="coarse"`` (DESIGN.md §15) collapses each step's
decode sub-graph to one aggregated event per shape bucket instead of
per-layer tasks, keeping long-context × many-slot sweeps tractable.
This is *exact*, not approximate: every step ends in a barrier covering
all its tasks, so a decode sub-graph always starts with every resource
free — its span is context-independent, and simulating the step's
``DecodePlan`` once on a scratch engine (same calibration) yields the
very span the fine lowering would produce in situ.  Spans are memoized
per KV-length tuple; bytes are re-emitted per bucket (analytic per-slot
split, recorded-trace remainder on the last bucket) so the per-step
cross-assert and every byte total stay bit-identical to fine.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.configs.hardware import HardwareConfig
from repro.core.types import ExecutionMode, ModelConfig
from repro.obs.metrics import (MetricsRegistry, RequestSpan, observe_spans,
                               spans_from_steps, spans_from_timeline,
                               summarize_spans)
from repro.serve.schedule import Schedule, ServeRequest, build_schedule
from repro.sim.dataflow import Engine
from repro.sim.pipeline import (SimResult, _SCHEDULERS, _Scheduler,
                                _build_replay, _CalibratedEngine)
from repro.sim.workload import (AttnOp, DecodeOp, Workload,
                                decode_workload_from_plan,
                                workload_from_plan)

#: tag prefixes keeping each step's events separable in the trace
_PREFILL = "pre.r{rid}."
_DECODE = "dec."


@dataclasses.dataclass(frozen=True)
class ServeStepSim:
    """One simulated engine step."""

    step: int
    admitted: Tuple[int, ...]          # rids prefilled this step
    decoded: Tuple[int, ...]           # rids advanced one token
    kv_lens: Tuple[int, ...]           # per decoded slot: attended KV length
    cycles: int                        # span of this step's task graph
    hbm_bytes: int                     # all HBM bytes the step moved
    prefill_hbm_bytes: int
    decode_hbm_bytes: int
    predicted_decode_hbm_bytes: int    # DecodePlan.total_hbm_bytes
    predicted_rewrite_cycles: int      # DecodePlan.total_rewrite_cycles

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        for k in ("admitted", "decoded", "kv_lens"):
            d[k] = list(d[k])
        return d


@dataclasses.dataclass
class ServeSimResult:
    """The simulated serving timeline plus its derived artifacts."""

    workload: str
    slots: int
    schedule: Schedule
    steps: List[ServeStepSim]
    result: SimResult                  # whole-timeline trace (energy-ready)
    prefill_plans: Dict[int, object]   # prompt_len -> ExecutionPlan
    decode_plans: Dict[Tuple[int, ...], object]  # kv_lens -> DecodePlan
    arrivals: Dict[int, int] = dataclasses.field(default_factory=dict)
    # Cycle-domain lifecycle spans (admission/first-token/finish mapped to
    # simulated cycle bounds) — the *interesting* TTFT/TPOT distributions.
    cycle_spans: List[RequestSpan] = dataclasses.field(default_factory=list)
    registry: Optional[MetricsRegistry] = None

    @property
    def request_spans(self) -> List[RequestSpan]:
        """Step-domain lifecycle spans from the *executed* step records —
        the side compared against ``Engine.stats()`` by
        ``obs.metrics.assert_serve_parity`` (DESIGN.md §12)."""
        return spans_from_steps(self.steps, self.arrivals)

    @property
    def metrics(self) -> Dict[str, object]:
        """Step-domain TTFT/TPOT/queue-delay p50/p95/p99 summary
        (well-defined zeros for a zero-request run)."""
        return summarize_spans(self.request_spans, unit="steps")

    @property
    def cycle_metrics(self) -> Dict[str, object]:
        """Cycle-domain lifecycle summary over ``cycle_spans``."""
        return summarize_spans(self.cycle_spans, unit="cycles")

    @property
    def cycles(self) -> int:
        return self.result.cycles

    @property
    def hbm_bytes(self) -> int:
        return self.result.hbm_bytes

    @property
    def decode_steps(self) -> Dict[int, int]:
        """rid -> decode steps consumed (the engine-agreement number)."""
        return dict(self.schedule.decode_steps)

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    def energy(self, model=None):
        return self.result.energy(model)

    def requests_per_kilocycle(self) -> float:
        n = len(self.schedule.admit_step)
        return 1000.0 * n / max(self.result.cycles, 1)

    def to_dict(self) -> Dict[str, object]:
        return {
            "workload": self.workload, "slots": self.slots,
            "num_steps": self.num_steps, "cycles": self.cycles,
            "hbm_bytes": self.hbm_bytes,
            "decode_steps": {str(k): v
                             for k, v in self.decode_steps.items()},
            "admit_step": {str(k): v
                           for k, v in self.schedule.admit_step.items()},
            "finish_step": {str(k): v
                            for k, v in self.schedule.finish_step.items()},
            "steps": [s.to_dict() for s in self.steps],
            "prefill_plans": {str(k): p.summary()
                              for k, p in self.prefill_plans.items()},
            "decode_plans": {",".join(map(str, k)): p.summary()
                             for k, p in self.decode_plans.items()},
            "metrics": self.metrics,
            "cycle_metrics": self.cycle_metrics,
            "request_spans": [s.to_dict() for s in self.request_spans],
            "cycle_spans": [s.to_dict() for s in self.cycle_spans],
        }


def _lower(eng: Engine, scheds, wl: Workload, mode_of: Mapping[str, object],
           trace_of: Mapping[str, object], prev: int, *,
           decode: bool = False) -> Tuple[int, int]:
    """Chain one workload's ops onto ``eng`` starting at ``prev``; returns
    (last barrier, replayed op count).  ``decode=True`` lowers GEMMs
    through the shared on-chip builder for *every* mode: a decode step's
    activations are single token vectors that stay resident even in the
    unfused baseline, so only attention traffic differs between modes —
    which is what keeps the per-step byte cross-assert mode-exact."""
    replayed = 0
    for layer in wl.layers:
        for op in layer.ops:
            kt = trace_of.get(op.name)
            if kt is not None:
                prev = _build_replay(eng, op, kt, prev)
                replayed += 1
                continue
            sched = scheds[mode_of[op.name]]
            if isinstance(op, AttnOp):
                prev = sched.build_attn(eng, op, prev)
            elif isinstance(op, DecodeOp):
                prev = sched.build_decode(eng, op, prev)
            elif decode:
                prev = _Scheduler.build_gemm(sched, eng, op, prev)
            else:
                prev = sched.build_gemm(eng, op, prev)
    return prev, replayed


def simulate_serve(cfg: ModelConfig,
                   requests: Sequence[ServeRequest], *,
                   slots: int = 4,
                   hw: Optional[HardwareConfig] = None,
                   mode: Optional[ExecutionMode] = None,
                   force_mode: bool = False,
                   plan_fn: Optional[Callable[[int], object]] = None,
                   decode_plan_fn: Optional[
                       Callable[[Tuple[int, ...]], object]] = None,
                   calibration=None,
                   decode_lowering: str = "fine") -> ServeSimResult:
    """Simulate serving ``requests`` on ``slots`` continuous-batching
    slots.

    ``mode``/``force_mode`` pass through to the planners (three-way
    serving comparisons pin a mode with ``force_mode=True``).  ``plan_fn``
    / ``decode_plan_fn`` override plan compilation — inject the *live
    engine's own* plan objects (cross-validation) or plans with recorded
    ``KernelTrace``s attached (decode replay).  ``calibration`` applies
    fitted per-resource cycle scales to the analytic task durations
    (DESIGN.md §10); replayed ops stay verbatim.
    ``decode_lowering``: ``"fine"`` (default) lowers every decode step's
    per-layer task graph; ``"coarse"`` emits one aggregated event per
    shape bucket with a memoized exact span — same cycles, bytes, and
    metrics, far fewer trace events (see module docstring).
    """
    from repro.plan.decode import plan_decode_step
    from repro.plan.heuristics import decode_attn_hbm_bytes
    from repro.plan.planner import plan_model, resolve_hw
    from repro.serve.kv_cache import shape_buckets
    from repro.sim.replay import resolve_calibration

    if decode_lowering not in ("fine", "coarse"):
        raise ValueError(f"decode_lowering must be 'fine' or 'coarse', "
                         f"got {decode_lowering!r}")

    hw = hw if isinstance(hw, HardwareConfig) else resolve_hw(hw)
    schedule = build_schedule(requests, slots)
    by_rid = {r.rid: r for r in requests}
    scale = resolve_calibration(calibration)
    eng = _CalibratedEngine(scale) if scale else Engine()
    scheds = {m: _SCHEDULERS[m](hw) for m in ExecutionMode}

    if plan_fn is None:
        plan_fn = lambda p: plan_model(cfg, seq_len=p, hw=hw, mode=mode,
                                       force_mode=force_mode)
    if decode_plan_fn is None:
        decode_plan_fn = lambda kv: plan_decode_step(
            cfg, kv, hw=hw, mode=mode, force_mode=force_mode)

    prefill_plans: Dict[int, object] = {}
    decode_plans: Dict[Tuple[int, ...], object] = {}
    # kv-length tuple -> (exact decode span, per-slot analytic bytes over
    # untraced layers, recorded-trace byte total, replayed-op count),
    # memoized from one scratch-engine run of the step's DecodePlan.
    # Bytes are *recomputed* from the plan's shapes — never read off its
    # hbm_bytes predictions — so the per-step cross-assert below still
    # catches a plan whose prediction disagrees with the traffic model.
    coarse_memo: Dict[Tuple[int, ...],
                      Tuple[int, List[int], int, int]] = {}

    def coarse_spec(kv: Tuple[int, ...], dp) -> Tuple[int, List[int], int,
                                                      int]:
        spec = coarse_memo.get(kv)
        if spec is not None:
            return spec
        eng2 = _CalibratedEngine(scale) if scale else Engine()
        p0 = eng2.barrier([], tag="start")
        wl2 = decode_workload_from_plan(dp, _DECODE)
        mode2 = {_DECODE + q.name: q.mode
                 for q in tuple(dp.layers) + tuple(dp.gemms)}
        trace2 = {_DECODE + q.name: q.trace
                  for q in tuple(dp.layers) + tuple(dp.gemms)
                  if getattr(q, "trace", None) is not None}
        pend, r2 = _lower(eng2, scheds, wl2, mode2, trace2, p0, decode=True)
        pend = eng2.barrier([pend], tag="end")
        eng2.run()
        span = eng2.finish_times[pend] - eng2.finish_times[p0]
        per_slot = [sum(decode_attn_hbm_bytes(
            lp.seq_kv[s], lp.heads, lp.kv_heads, lp.head_dim, lp.mode,
            append=not lp.cross, bytes_per_el=hw.act_bytes)
            for lp in dp.layers if lp.trace is None)
            for s in range(len(kv))]
        traced = sum(p.trace.hbm_bytes for p in dp.layers
                     if p.trace is not None)
        traced += sum(g.trace.hbm_bytes for g in dp.gemms
                      if g.trace is not None)
        spec = (span, per_slot, traced, r2)
        coarse_memo[kv] = spec
        return spec

    prev = eng.barrier([], tag="start")
    marks: List[Tuple[object, int, object]] = []   # (sched step, mark, dp)
    replayed = 0
    for st in schedule.steps:
        tprefix = f"t{st.step}."
        for _, rid in st.admitted:
            p = by_rid[rid].prompt_len
            if p not in prefill_plans:
                prefill_plans[p] = plan_fn(p)
            plan = prefill_plans[p]
            prefix = tprefix + _PREFILL.format(rid=rid)
            wl = workload_from_plan(plan, prefix)
            mode_of = {prefix + q.name: q.mode
                       for q in tuple(plan.layers) + tuple(plan.gemms)}
            trace_of = {prefix + q.name: q.trace
                        for q in tuple(plan.layers) + tuple(plan.gemms)
                        if getattr(q, "trace", None) is not None}
            prev, r = _lower(eng, scheds, wl, mode_of, trace_of, prev)
            replayed += r
        dp = None
        if st.decoding:
            kv = tuple(k for _, _, k in st.decoding)
            if kv not in decode_plans:
                decode_plans[kv] = decode_plan_fn(kv)
            dp = decode_plans[kv]
            prefix = tprefix + _DECODE
            if decode_lowering == "coarse":
                span, per_slot, traced, r = coarse_spec(kv, dp)
                replayed += r
                buckets = shape_buckets(kv)
                deps: List[int] = []
                for i, (_, positions) in enumerate(buckets):
                    b = sum(per_slot[p] for p in positions)
                    if i == len(buckets) - 1:
                        # Recorded-trace bytes land on the last bucket
                        # (traces are op-level, not per-slot splittable).
                        b += traced
                    deps.append(eng.task(
                        "dma", "HBM", 0, [prev], nbytes=b,
                        tag=f"{prefix}coarse.b{i}:dma"))
                exempt_before = getattr(eng, "exempt", None)
                if exempt_before is not None:
                    # The memoized span came out of an identically
                    # calibrated scratch engine — re-scaling it here
                    # would double-apply the calibration.
                    eng.exempt = True
                try:
                    deps.append(eng.task("compute", "ATTN", span, [prev],
                                         tag=f"{prefix}coarse:span"))
                finally:
                    if exempt_before is not None:
                        eng.exempt = exempt_before
                prev = eng.barrier(deps, tag=f"{prefix}coarse:done")
            else:
                wl = decode_workload_from_plan(dp, prefix)
                mode_of = {prefix + q.name: q.mode
                           for q in tuple(dp.layers) + tuple(dp.gemms)}
                trace_of = {prefix + q.name: q.trace
                            for q in tuple(dp.layers) + tuple(dp.gemms)
                            if getattr(q, "trace", None) is not None}
                prev, r = _lower(eng, scheds, wl, mode_of, trace_of, prev,
                                 decode=True)
                replayed += r
        prev = eng.barrier([prev], tag=f"t{st.step}:end")
        marks.append((st, prev, dp))

    trace = eng.run()
    finish = eng.finish_times
    # One pass over the trace buckets HBM bytes per (step, prefill|decode)
    # — a per-step bytes_moved(pred=...) scan would be O(steps x events).
    pre_by_step: Dict[int, int] = {}
    dec_by_step: Dict[int, int] = {}
    # max event end per (admit step, rid): the cycle the request's prefill
    # — and hence its first token — actually completed (obs lifecycle).
    pre_end: Dict[Tuple[int, int], int] = {}
    for e in trace.events:
        if not e.tag.startswith("t"):
            continue
        head, _, rest = e.tag.partition(".")
        try:
            step_no = int(head[1:])
        except ValueError:
            continue
        if rest.startswith("pre."):
            parts = rest.split(".", 2)
            if len(parts) > 2 and parts[1][:1] == "r":
                try:
                    key = (step_no, int(parts[1][1:]))
                except ValueError:
                    key = None
                if key is not None and e.end > pre_end.get(key, 0):
                    pre_end[key] = e.end
            if e.resource == "HBM" and e.bytes:
                pre_by_step[step_no] = pre_by_step.get(step_no, 0) + e.bytes
        elif rest.startswith(_DECODE):
            if e.resource == "HBM" and e.bytes:
                dec_by_step[step_no] = dec_by_step.get(step_no, 0) + e.bytes
    steps: List[ServeStepSim] = []
    step_bounds: Dict[int, Tuple[int, int]] = {}
    bound = 0
    for st, mark, dp in marks:
        pre_b = pre_by_step.get(st.step, 0)
        dec_b = dec_by_step.get(st.step, 0)
        pred_b = dp.total_hbm_bytes if dp is not None else 0
        pred_rw = dp.total_rewrite_cycles if dp is not None else 0
        if dp is not None:
            # The planner==simulator traffic cross-assert.  Traced ops
            # replay their *recorded* bytes, so the expected total swaps
            # in trace.hbm_bytes for exactly those ops — a partial
            # recording must not silence the assert for the analytic rest.
            expect = sum(p.trace.hbm_bytes if p.trace is not None
                         else p.hbm_bytes for p in dp.layers)
            expect += sum(g.trace.hbm_bytes for g in dp.gemms
                          if g.trace is not None)
            if dec_b != expect:
                raise RuntimeError(
                    f"step {st.step}: simulated decode HBM bytes {dec_b} "
                    f"!= DecodePlan prediction {expect} (kv_lens "
                    f"{[k for _, _, k in st.decoding]}) — the planner and "
                    "the simulator disagree on the decode traffic model")
        steps.append(ServeStepSim(
            step=st.step,
            admitted=tuple(r for _, r in st.admitted),
            decoded=tuple(r for _, r, _ in st.decoding),
            kv_lens=tuple(k for _, _, k in st.decoding),
            cycles=finish[mark] - bound,
            hbm_bytes=pre_b + dec_b,
            prefill_hbm_bytes=pre_b, decode_hbm_bytes=dec_b,
            predicted_decode_hbm_bytes=pred_b,
            predicted_rewrite_cycles=pred_rw))
        step_bounds[st.step] = (bound, finish[mark])
        bound = finish[mark]

    arrivals = {r.rid: r.arrival_step for r in requests}
    # Cycle-domain lifecycle: first token when the request's prefill's
    # last event retired (``pre_end``), fallback to the step's end bound.
    cycle_spans = spans_from_timeline(
        schedule.admit_step, schedule.finish_step, schedule.decode_steps,
        arrivals, step_bounds,
        {rid: float(pre_end[(a, rid)])
         for rid, a in schedule.admit_step.items() if (a, rid) in pre_end},
        unit="cycles")
    sim = SimResult(cfg.name, mode if force_mode else None, hw.name,
                    trace.makespan, trace.bytes_moved("HBM"),
                    tuple(s.cycles for s in steps), trace, hw_cfg=hw,
                    replayed_ops=replayed)
    res = ServeSimResult(workload=cfg.name, slots=slots, schedule=schedule,
                         steps=steps, result=sim,
                         prefill_plans=prefill_plans,
                         decode_plans=decode_plans,
                         arrivals=arrivals, cycle_spans=cycle_spans,
                         registry=MetricsRegistry())
    res.registry.counter("steps").inc(len(steps))
    observe_spans(res.registry, res.request_spans, "steps.")
    observe_spans(res.registry, cycle_spans, "cycles.")
    return res
