"""TBR-CIM macro timing model (paper §II-A).

A macro stores a ``macro_rows x macro_cols`` INT8 stationary tile per
sub-array and evaluates one input vector bit-serially
(``ceil(input_bits / bits_per_cycle) + drain_cycles`` cycles per vector,
all resident tiles in parallel).  Each macro has **two** sub-arrays; the
reconfigurable modes decide what the second one does:

* ``NORMAL``  — both sub-arrays hold stationary operand tiles: double the
  resident capacity, but a rewrite must overwrite a live sub-array, so
  rewriting serializes with compute (the TranCIM §I stall).
* ``HYBRID``  — one sub-array active, one shadow: half the capacity, but
  tile t+1 can rewrite into the shadow while tile t computes — the
  substrate for the ping-pong compute-rewriting pipeline (§II-C).

Rewrite latency comes from the shared CIM write port
(``rewrite_bus_bits``), exactly the §I arithmetic in
``benchmarks/bench_rewrite_overlap.py``: K = 2048x512 INT8 over a 512-bit
bus takes 2048*512/64 = 16384 cycles.
"""
from __future__ import annotations

import dataclasses
import enum
import math

from repro.configs.hardware import HardwareConfig


class MacroMode(str, enum.Enum):
    NORMAL = "normal"      # both sub-arrays stationary (max capacity)
    HYBRID = "hybrid"      # active + shadow sub-array (ping-pong rewrite)


@dataclasses.dataclass(frozen=True)
class MacroArray:
    """A group allocation of TBR-CIM macros in one reconfigurable mode."""

    hw: HardwareConfig
    groups: int
    mode: MacroMode = MacroMode.NORMAL

    @property
    def num_macros(self) -> int:
        return self.groups * self.hw.macros_per_group

    @property
    def capacity_tiles(self) -> int:
        per_macro = 2 if self.mode == MacroMode.NORMAL else 1
        return self.num_macros * per_macro

    @property
    def overlap_rewrite(self) -> bool:
        return self.mode == MacroMode.HYBRID and self.hw.ping_pong

    # ---------- timing ----------

    def tiles(self, k: int, n: int) -> int:
        """Stationary tiles needed for a k x n resident operand."""
        return (math.ceil(k / self.hw.macro_rows)
                * math.ceil(n / self.hw.macro_cols))

    def passes(self, k: int, n: int, count: int = 1) -> int:
        """Input-streaming passes for ``count`` resident k x n operands
        (e.g. per-head K tiles) given the array's tile capacity."""
        return math.ceil(count * self.tiles(k, n) / self.capacity_tiles)

    def gemm_cycles(self, m: int, k: int, n: int, count: int = 1) -> int:
        """(m x k) @ (k x n) with the k x n operand stationary: each pass
        streams all m input vectors through the resident tile set."""
        return self.passes(k, n, count) * m * self.hw.vector_cycles

    def rewrite_cycles(self, nbytes: int) -> int:
        return math.ceil(nbytes / self.hw.rewrite_bytes_per_cycle)


def dma_cycles(hw: HardwareConfig, nbytes: int) -> int:
    return math.ceil(nbytes / hw.hbm_bytes_per_cycle)


def noc_cycles(hw: HardwareConfig, nbytes: int) -> int:
    """Tile-based streaming network (TBSN) transfer between macro groups."""
    return math.ceil(nbytes / hw.noc_bytes_per_cycle)
