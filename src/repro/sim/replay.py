"""``repro.sim.replay`` — record real kernel timings, attach them to
``ExecutionPlan`` layers, replay them through ``simulate_plan``, and fit a
calibration of the analytic timing model (DESIGN.md §10).

The simulator's per-op timing is analytic; the paper's headline claims
rest on *measured* kernel behavior.  Following CIMFlow's
record-then-calibrate loop (arXiv:2505.01107) and NeuroSim's validated
cost tables (arXiv:2505.02314), this module closes the loop in four
steps:

1. **Record** — ``KernelRecorder`` instruments the jnp/Pallas kernel
   paths (``kernels.ops.attention_by_plan``, ``kernels.tile_gemm``,
   ``kernels.stream_attention``): inside a ``recording()`` block each
   executed op emits a ``KernelTrace`` (grid shape, block tiling actually
   used, wall-time- or cost-analysis-derived cycles, bytes moved).
   ``record_plan`` drives a whole plan's op list through the kernels at
   the plan's own geometry.
2. **Attach** — ``ExecutionPlan.attach_traces`` matches records to
   ``LayerPlan``/``GemmPlan`` entries by op name; traces serialize with
   the plan (``to_json``/``from_json`` round-trip them exactly).
3. **Replay** — ``simulate_plan`` lowers a traced op to its *recorded*
   timing (one compute-resource event spanning ``trace.cycles`` plus an
   HBM accounting event carrying ``trace.hbm_bytes``) instead of the
   analytic task graph; untraced ops fall back to analytic lowering, so
   mixed plans simulate end-to-end.
4. **Calibrate** — ``fit_calibration`` quantifies analytic-vs-recorded
   error per op class and fits a per-resource cycle scale factor
   (ridge-regularized least squares over the analytic per-op busy-cycle
   decomposition).  ``simulate_plan(plan, calibration=report)`` and the
   DSE sweep (``run_sweep(calibrations=...)``) apply it to analytic
   lowering.

Wall-clock seconds convert to cycles at ``KernelRecorder.clock_hz``
(default 1 GHz — the napkin CIM clock).  On CPU-hosted runs the recorded
cycles are *host-platform* timings, so absolute calibration factors are
large and only meaningful per platform; the pipeline, not the constants,
is the contract (DESIGN.md §10 discusses when replayed timing diverges).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import time
from typing import (Callable, Dict, Iterable, Iterator, List, Mapping,
                    Optional, Sequence, Tuple, Union)

KERNEL_TRACE_VERSION = 1

#: Napkin CIM clock for wall-seconds -> cycles conversion (unclocked
#: simulator; ratios between records on one platform are what matter).
DEFAULT_CLOCK_HZ = 1e9

#: Op classes a ``KernelTrace`` can describe; the replay lowering charges
#: the recorded cycles to the class's primary macro-array resource.
TRACE_KINDS = ("attention", "gemm", "decode")
_KIND_RESOURCE = {"attention": "ATTN", "gemm": "GEN", "decode": "ATTN"}


# ---------------------------------------------------------------------------
# KernelTrace
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelTrace:
    """One recorded kernel execution (the unit the replay lowering eats).

    ``op`` names the plan op the record belongs to (``LayerPlan.name`` /
    ``GemmPlan.name``); kernel-level sub-records use ``parent/kernel``
    labels and never attach to a plan.  ``cycles`` is the recorded op
    duration in CIM clock cycles (wall seconds x ``clock_hz``, or an XLA
    cost-analysis estimate — see ``source``); ``hbm_bytes`` the bytes the
    executed arrays actually moved.
    """

    op: str
    kind: str                  # "attention" | "gemm"
    mode: str                  # ExecutionMode value ("" for bare kernels)
    grid: Tuple[int, ...]      # kernel grid actually launched
    block_q: int               # q-tile edge actually used (gemm: block_m)
    block_kv: int              # kv-tile edge actually used (gemm: block_n)
    cycles: int                # recorded duration, CIM clock cycles
    hbm_bytes: int             # bytes moved by the executed arrays
    wall_time_s: float = 0.0   # measured wall seconds (0 for cost_analysis)
    flops: int = 0
    clock_hz: float = DEFAULT_CLOCK_HZ
    source: str = "wall_time"  # "wall_time" | "cost_analysis" | "manual"

    def __post_init__(self):
        if self.kind not in TRACE_KINDS:
            raise ValueError(f"{self.op}: kind must be one of "
                             f"{TRACE_KINDS}, got {self.kind!r}")
        if self.cycles <= 0:
            raise ValueError(f"{self.op}: recorded cycles must be > 0, "
                             f"got {self.cycles!r}")
        if self.hbm_bytes < 0:
            raise ValueError(f"{self.op}: hbm_bytes must be >= 0, "
                             f"got {self.hbm_bytes!r}")

    @property
    def resource(self) -> str:
        """The macro-array resource replay charges the cycles to."""
        return _KIND_RESOURCE[self.kind]

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["version"] = KERNEL_TRACE_VERSION
        d["grid"] = list(self.grid)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "KernelTrace":
        d = dict(d)
        version = d.pop("version", KERNEL_TRACE_VERSION)
        if version != KERNEL_TRACE_VERSION:
            raise ValueError(f"unsupported KernelTrace version {version!r}")
        d["grid"] = tuple(int(g) for g in d.get("grid", ()))
        return cls(**d)


# ---------------------------------------------------------------------------
# Recorder + active-recorder registry (the kernel instrumentation hook)
# ---------------------------------------------------------------------------

class KernelRecorder:
    """Collects ``KernelTrace`` records from instrumented kernel paths.

    The instrumented entry points (``ops.attention_by_plan``,
    ``tile_gemm``, ``stream_attention``) consult ``active_recorder()``:
    inside a ``recording(rec)`` block every concrete (non-traced) call
    appends a record.  ``measure`` times a thunk with warmup and median-
    of-iters (mirroring ``benchmarks.common.time_fn``) and suppresses
    nested kernel-level records so one op yields one op-level trace.
    """

    def __init__(self, clock_hz: float = DEFAULT_CLOCK_HZ, *,
                 iters: int = 1, warmup: int = 1) -> None:
        if clock_hz <= 0:
            raise ValueError(f"clock_hz must be > 0, got {clock_hz!r}")
        self.clock_hz = clock_hz
        self.iters = max(1, iters)
        self.warmup = max(0, warmup)
        self.records: List[KernelTrace] = []
        self._labels: List[str] = []
        self._suppressed = 0

    # ---- labels: record_plan names the op before entering a kernel ----

    @contextlib.contextmanager
    def label(self, name: str) -> Iterator[None]:
        self._labels.append(name)
        try:
            yield
        finally:
            self._labels.pop()

    def current_label(self, default: str) -> str:
        return f"{self._labels[-1]}/{default}" if self._labels else default

    # ---- record/measure ----

    @property
    def suppressed(self) -> bool:
        return self._suppressed > 0

    def add(self, trace: KernelTrace) -> None:
        if not self.suppressed:
            self.records.append(trace)

    def seconds_to_cycles(self, seconds: float) -> int:
        return max(1, int(round(seconds * self.clock_hz)))

    def measure(self, fn: Callable[[], object], *, op: str, kind: str,
                mode: str = "", grid: Tuple[int, ...] = (),
                block_q: int = 0, block_kv: int = 0, hbm_bytes: int = 0,
                flops: int = 0) -> object:
        """Run ``fn`` (warmup + iters), record the median wall time as one
        op-level ``KernelTrace``, and return the *last* result.  Nested
        kernel-level instrumentation is suppressed for the duration."""
        import jax
        self._suppressed += 1
        try:
            out = None
            for _ in range(self.warmup):
                out = jax.block_until_ready(fn())
            times = []
            for _ in range(self.iters):
                t0 = time.perf_counter()
                out = jax.block_until_ready(fn())
                times.append(time.perf_counter() - t0)
            times.sort()
            wall = times[len(times) // 2]
        finally:
            self._suppressed -= 1
        self.records.append(KernelTrace(
            op=op, kind=kind, mode=mode, grid=tuple(grid),
            block_q=block_q, block_kv=block_kv,
            cycles=self.seconds_to_cycles(wall), hbm_bytes=hbm_bytes,
            wall_time_s=wall, flops=flops, clock_hz=self.clock_hz,
            source="wall_time"))
        return out

    def by_op(self) -> Dict[str, KernelTrace]:
        """Latest record per op name (kernel-level ``parent/kernel``
        sub-records keep their slash-labels and never shadow op names)."""
        return {t.op: t for t in self.records}


_ACTIVE: List[KernelRecorder] = []


def active_recorder() -> Optional[KernelRecorder]:
    """The innermost active recorder, or None (the common case — the
    instrumented kernels call this on every invocation)."""
    return _ACTIVE[-1] if _ACTIVE else None


def recorder_for(*arrays) -> Optional[KernelRecorder]:
    """Kernel-side hook: the active recorder iff recording applies to
    this call — none active, nested under a ``measure`` (already being
    timed at op level), or abstract/traced operands (nothing to time
    under ``jit``) all return None.  The kernels consult this through
    ``sys.modules`` so an un-imported replay module costs them nothing."""
    rec = active_recorder()
    if rec is None or rec.suppressed:
        return None
    import jax
    if any(isinstance(a, jax.core.Tracer) for a in arrays):
        return None
    return rec


@contextlib.contextmanager
def recording(recorder: Optional[KernelRecorder] = None, *,
              clock_hz: float = DEFAULT_CLOCK_HZ) -> Iterator[KernelRecorder]:
    """Activate a recorder for the dynamic extent of the block."""
    rec = recorder if recorder is not None else KernelRecorder(clock_hz)
    _ACTIVE.append(rec)
    try:
        yield rec
    finally:
        _ACTIVE.pop()


# ---------------------------------------------------------------------------
# record_plan: drive a plan's op list through the real kernels
# ---------------------------------------------------------------------------

def record_plan(plan, *, ops: Optional[Sequence[str]] = None,
                max_ops: Optional[int] = None, use_pallas: bool = False,
                iters: int = 1, warmup: int = 1,
                clock_hz: float = DEFAULT_CLOCK_HZ, seed: int = 0,
                dtype=None):
    """Execute each planned op's kernel at the plan's own geometry
    (batch 1) under a recorder and return ``(traced_plan, recorder)``.

    ``ops`` restricts recording to the named plan ops; ``max_ops`` caps
    the count (plan order, attention before gemms) — untraced ops keep
    analytic lowering at replay time, which is exactly the mixed-plan
    contract the tests pin.  Plan at a small ``seq_len`` first: recording
    runs real kernels, so a paper-sized plan is minutes of CPU time.

    Byte accounting: recorded ``hbm_bytes`` are the executed arrays'
    host I/O (gemms: x + w + out, matching the kernel-level ``tile_gemm``
    records; attention: the mode's analytic traffic at the actual shapes
    and dtype).  For streamed-mode gemms this intentionally differs from
    the analytic simulator, which keeps their activations on-chip (zero
    HBM bytes) — replayed byte counts reflect the measurement, so compare
    traced-vs-analytic *cycles* (what ``fit_calibration`` does), not
    bytes, across that convention boundary.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    dtype = dtype or jnp.float32
    rec = KernelRecorder(clock_hz, iters=iters, warmup=warmup)
    wanted = set(ops) if ops is not None else None

    def selected(name: str, taken: int) -> bool:
        if wanted is not None and name not in wanted:
            return False
        return max_ops is None or taken < max_ops

    key = jax.random.PRNGKey(seed)
    taken = 0
    with recording(rec):
        for lp in plan.layers:
            if not selected(lp.name, taken):
                continue
            taken += 1
            key, kq, kx, kk, kv = jax.random.split(key, 5)
            q = jax.random.normal(kq, (1, lp.heads, lp.seq_q, lp.head_dim),
                                  dtype)
            x_kv = jax.random.normal(kx, (1, lp.seq_kv, lp.d_kv), dtype)
            wk = jax.random.normal(kk, (lp.d_kv, lp.kv_heads, lp.head_dim),
                                   dtype)
            wv = jax.random.normal(kv, (lp.d_kv, lp.kv_heads, lp.head_dim),
                                   dtype)
            kops.attention_by_plan(lp, q, x_kv, wk, wv,
                                   use_pallas=use_pallas)
        for g in plan.gemms:
            if not selected(g.name, taken):
                continue
            taken += 1
            key, kx, kw = jax.random.split(key, 3)
            x = jax.random.normal(kx, (g.m, g.k), dtype)
            w = jax.random.normal(kw, (g.k, g.n), dtype)
            itemsize = jnp.dtype(dtype).itemsize
            # The tile grid the pallas path launches at tile_gemm's
            # default blocks (the jnp path is the same math untiled).
            bm, bn, bk = min(256, g.m), min(256, g.n), min(512, g.k)
            grid = (-(-g.n // bn), -(-g.m // bm), -(-g.k // bk))
            with rec.label(g.name):
                rec.measure(
                    lambda x=x, w=w: kops.projection(
                        x, w, use_pallas=use_pallas),
                    op=g.name, kind="gemm", mode=g.mode.value,
                    grid=grid, block_q=bm, block_kv=bn,
                    hbm_bytes=(g.m * g.k + g.k * g.n
                               + g.m * g.n) * itemsize,
                    flops=2 * g.m * g.k * g.n)
    return plan.attach_traces(rec.records), rec


# ---------------------------------------------------------------------------
# CalibrationReport + fitting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """Analytic-vs-recorded error per op class + fitted per-resource cycle
    scale factors (DESIGN.md §10).

    ``per_class[kind]`` carries ``count`` / ``analytic_cycles`` /
    ``recorded_cycles`` / ``ratio`` (recorded/analytic totals) /
    ``mean_abs_rel_err`` over the traced ops of that class.  ``scale``
    maps simulator resources to multiplicative cycle factors; apply with
    ``simulate_plan(plan, calibration=report)`` or sweep with
    ``repro.dse.run_sweep(calibrations=(None, report))``.
    """

    name: str
    model: str
    hw: str
    clock_hz: float
    per_class: Mapping[str, Mapping[str, float]]
    scale: Mapping[str, float]

    def __post_init__(self):
        for r, s in self.scale.items():
            if s <= 0:
                raise ValueError(f"{self.name}: scale[{r!r}] must be > 0, "
                                 f"got {s!r}")

    @property
    def traced_ops(self) -> int:
        return int(sum(c.get("count", 0) for c in self.per_class.values()))

    def ratio(self, kind: str) -> float:
        """Recorded/analytic cycle ratio for one op class (1.0 = the
        analytic model already matches the recording)."""
        return float(self.per_class[kind]["ratio"])

    def to_dict(self) -> Dict[str, object]:
        return {
            "version": KERNEL_TRACE_VERSION,
            "name": self.name, "model": self.model, "hw": self.hw,
            "clock_hz": self.clock_hz,
            "per_class": {k: dict(v) for k, v in self.per_class.items()},
            "scale": dict(self.scale),
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "CalibrationReport":
        if d.get("version") != KERNEL_TRACE_VERSION:
            raise ValueError(
                f"unsupported CalibrationReport version {d.get('version')!r}")
        return cls(name=d["name"], model=d["model"], hw=d["hw"],
                   clock_hz=float(d["clock_hz"]),
                   per_class={k: dict(v)
                              for k, v in d["per_class"].items()},
                   scale={k: float(v) for k, v in d["scale"].items()})

    @classmethod
    def from_json(cls, s: str) -> "CalibrationReport":
        return cls.from_dict(json.loads(s))


def _traced_ops(plan) -> List[Tuple[str, KernelTrace]]:
    out = []
    for lp in tuple(plan.layers) + tuple(plan.gemms):
        tr = getattr(lp, "trace", None)
        if tr is not None:
            out.append((lp.name, tr))
    return out


def analytic_op_profile(plan, hw=None) -> Dict[str, Dict[str, object]]:
    """Per-op analytic timing decomposition: simulate the plan with replay
    *off* and reduce the event trace to ``{op: {"span": elapsed cycles,
    "busy": {resource: busy cycles}}}`` — the denominator side of every
    calibration fit."""
    from repro.sim.pipeline import simulate_plan
    res = simulate_plan(plan, hw=hw, replay=False)
    prof: Dict[str, Dict[str, object]] = {}
    for e in res.trace.events:
        p = prof.setdefault(e.op, {"start": e.start, "end": e.end,
                                   "busy": {}})
        p["start"] = min(p["start"], e.start)
        p["end"] = max(p["end"], e.end)
        p["busy"][e.resource] = p["busy"].get(e.resource, 0) + e.cycles
    return {op: {"span": p["end"] - p["start"], "busy": p["busy"]}
            for op, p in prof.items()}


def fit_calibration(plan, hw=None, *, name: Optional[str] = None,
                    ridge: float = 1e-3) -> CalibrationReport:
    """Fit a ``CalibrationReport`` from a plan's attached traces.

    Per-class error compares each traced op's recorded cycles with its
    analytic *span* (elapsed cycles under analytic lowering).  The
    per-resource scale solves ``recorded_i ~= sum_r busy[i][r] * s_r``
    by ridge-regularized least squares (prior: the global recorded/
    analytic-span ratio on every resource), so an under-determined
    system — few traced op shapes, many resources — degrades to the
    global ratio instead of oscillating.  Scales are clamped positive.
    """
    import numpy as np

    traced = _traced_ops(plan)
    if not traced:
        raise ValueError(f"{plan.model}: no attached KernelTrace records — "
                         "record_plan / attach_traces first")
    prof = analytic_op_profile(plan, hw=hw)
    hw_name = hw.name if hw is not None else plan.hw

    resources = sorted({r for op, _ in traced
                        for r in prof[op]["busy"]})
    a = np.zeros((len(traced), len(resources)))
    b = np.zeros(len(traced))
    per_class: Dict[str, Dict[str, float]] = {}
    for i, (op, tr) in enumerate(traced):
        span = prof[op]["span"]
        b[i] = tr.cycles
        for j, r in enumerate(resources):
            a[i, j] = prof[op]["busy"].get(r, 0)
        c = per_class.setdefault(tr.kind, {
            "count": 0, "analytic_cycles": 0, "recorded_cycles": 0,
            "abs_rel_err_sum": 0.0})
        c["count"] += 1
        c["analytic_cycles"] += span
        c["recorded_cycles"] += tr.cycles
        c["abs_rel_err_sum"] += abs(tr.cycles - span) / max(span, 1)

    total_ana = sum(c["analytic_cycles"] for c in per_class.values())
    total_rec = sum(c["recorded_cycles"] for c in per_class.values())
    prior = total_rec / max(total_ana, 1)
    for c in per_class.values():
        c["ratio"] = c["recorded_cycles"] / max(c["analytic_cycles"], 1)
        c["mean_abs_rel_err"] = c.pop("abs_rel_err_sum") / c["count"]

    # Ridge-regularized normal equations around the global-ratio prior.
    ata = a.T @ a
    lam = ridge * max(float(np.trace(ata)) / max(len(resources), 1), 1.0)
    sol = np.linalg.solve(ata + lam * np.eye(len(resources)),
                          a.T @ b + lam * prior * np.ones(len(resources)))
    scale = {r: float(max(s, 1e-9)) for r, s in zip(resources, sol)}

    clock = traced[0][1].clock_hz
    return CalibrationReport(
        name=name or f"{plan.model}@{plan.shape}-{hw_name}",
        model=plan.model, hw=hw_name, clock_hz=clock,
        per_class=per_class, scale=scale)


def resolve_calibration(calibration) -> Optional[Mapping[str, float]]:
    """Normalize a ``simulate_plan(calibration=...)`` argument — a
    ``CalibrationReport``, a raw ``{resource: factor}`` mapping, or None —
    into the scale mapping the engine applies."""
    if calibration is None:
        return None
    scale = getattr(calibration, "scale", calibration)
    if not isinstance(scale, Mapping):
        raise TypeError(f"calibration must be a CalibrationReport or a "
                        f"resource->factor mapping, got {calibration!r}")
    return scale


# ---------------------------------------------------------------------------
# Optional cost-analysis timing source (XLA flop estimate -> cycles)
# ---------------------------------------------------------------------------

def cost_analysis_cycles(fn: Callable, *args, hw=None) -> Tuple[int, int]:
    """(cycles, flops) for one kernel call from XLA's compiled
    ``cost_analysis()`` instead of wall time: flops divided by the design
    point's aggregate INT8 MAC throughput (``EnergyModel
    .macro_ops_per_cycle`` x ``num_macros``).  The deterministic timing
    source for CI — no wall-clock noise."""
    import jax

    from repro.configs.hardware import STREAMDCIM_BASE
    from repro.sim.energy import STREAMDCIM_ENERGY_BASE

    hw = hw or STREAMDCIM_BASE
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):            # older jax returns [dict]
        ca = ca[0] if ca else {}
    flops = int(ca.get("flops", 0.0))
    per_cycle = (STREAMDCIM_ENERGY_BASE.macro_ops_per_cycle(hw)
                 * hw.num_macros)
    return max(1, math.ceil(flops / max(per_cycle, 1.0))), flops
