"""The three execution pipelines (paper §III comparison systems).

All three schedulers execute the same per-(q-block, kv-tile) flash-style
attention schedule — identical ATTN-array compute — and differ only in the
paper's three mechanisms:

* ``NON_STREAM``    — unfused: every intermediate (Q, K, V, scores,
  probabilities, attention out) round-trips HBM, softmax runs on the
  vector unit against spilled score tiles, CIM rewriting serializes with
  compute, and nothing overlaps (a fully sequential accelerator).
* ``LAYER_STREAM``  — fused projections + streaming attention, but with
  *layer-granularity* synchronization: attention starts only after the
  whole K/V layer is generated and spilled, K/V round-trip HBM per
  q-block, and rewriting K/V tiles into the attention macros blocks the
  macro array (normal mode — no shadow sub-array), reproducing the §I
  ~57% rewrite stall.
* ``TILE_STREAM``   — StreamDCIM: the mixed-stationary cross-forwarding
  schedule of ``dataflow.cross_forward_attention`` with tile-level
  decoupling and the ping-pong compute-rewriting overlap.

Capacity note: the §I micro-workload (K = 2048x512) fits the macro array,
so layer-based streaming can hold K fully resident
(``simulate_rewrite_stall``); the §III model workloads cannot (ViLBERT
K+V across heads need ~4x the array), so every scheduler re-streams KV
tiles per q-block — which is why all three share the same tile schedule.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.configs.hardware import HardwareConfig, STREAMDCIM_BASE
from repro.core.types import ExecutionMode, ModelConfig
from repro.sim.dataflow import Engine, cross_forward_attention
from repro.sim.macro import MacroArray, MacroMode, dma_cycles
from repro.sim.trace import Trace
from repro.sim.workload import (AttnOp, BLOCK, DecodeOp, GemmOp, Workload,
                                build_workload)


@dataclasses.dataclass(frozen=True)
class SimResult:
    workload: str
    mode: Optional[ExecutionMode]   # None: heterogeneous plan-driven run
    hw: str
    cycles: int
    hbm_bytes: int
    layer_cycles: Tuple[int, ...]
    trace: Trace
    # The actual design point simulated (not just its name), so ad-hoc
    # sweep configs get correct energy scaling without a preset lookup.
    hw_cfg: Optional[HardwareConfig] = None
    # Ops whose timing came from attached KernelTraces (plan/trace replay,
    # DESIGN.md §10) rather than the analytic schedulers.
    replayed_ops: int = 0

    def op_dma_bytes(self, op_name: str) -> int:
        """Simulated HBM bytes attributed to one op (tag prefix match)."""
        return self.trace.bytes_moved(
            "HBM", pred=lambda e: e.tag.startswith(op_name + ":"))

    def energy(self, model=None):
        """Fold an ``repro.sim.energy.EnergyModel`` (default
        ``STREAMDCIM_ENERGY_BASE``) over this run's trace."""
        from repro.sim.energy import energy_of
        return energy_of(self, model=model)

    def edp(self, model=None) -> float:
        """Energy-delay product, pJ * cycles (DESIGN.md §9)."""
        return self.energy(model).edp

    def critical_path(self):
        """Causal critical-path report for this run's trace
        (``repro.obs.critpath``, DESIGN.md §14)."""
        from repro.obs.critpath import critical_path
        return critical_path(self.trace)


class _Scheduler:
    """Shared structure: layers chain sequentially; ops chain within a
    layer (the macro array is a single shared pool)."""

    mode: ExecutionMode

    def __init__(self, hw: HardwareConfig) -> None:
        self.hw = hw
        self.gen = MacroArray(hw, hw.gen_groups, MacroMode.NORMAL)

    def simulate(self, wl: Workload) -> SimResult:
        return _simulate_ops(wl, self.hw, lambda op: self, self.mode)

    # GEMMs (FFN, output projections) are weight-stationary and identical
    # across modes; streaming modes keep their activations on-chip.
    def build_gemm(self, eng: Engine, op: GemmOp, start: int) -> int:
        return eng.task("compute", "GEN",
                        self.gen.gemm_cycles(op.m, op.k, op.n), [start],
                        tag=f"{op.name}:gemm")

    def build_attn(self, eng: Engine, op: AttnOp, start: int) -> int:
        raise NotImplementedError

    # ---- decode-step lowering (DESIGN.md §11) ----------------------------
    # One DecodeOp advances every active slot by one token: the new
    # token's Q (and, for growing caches, K/V) are generated on the
    # stationary macros, the cached K/V stream in tile by tile and are
    # rewritten into the attention macros, and a 1-row attention runs per
    # tile.  Byte/rewrite accounting mirrors
    # ``plan.heuristics.decode_attn_hbm_bytes`` / ``decode_rewrite_cycles``
    # exactly — ``simulate_serve`` cross-asserts it per step.

    def _decode_gen(self, eng: Engine, op: DecodeOp, start: int,
                    tag: str) -> Tuple[int, int, List[int]]:
        """Shared front half: Q generation, new-token KV generation and
        the cache-append write.  Returns (qgen, kv_ready, byte_events)."""
        hw, ab = self.hw, self.hw.act_bytes
        n = op.slots
        qgen = eng.task("compute", "GEN",
                        self.gen.gemm_cycles(n, op.d_q,
                                             op.heads * op.head_dim),
                        [start], tag=f"{tag}:qgen")
        if not op.append:
            return qgen, start, []
        kvgen = eng.task("compute", "GEN",
                         2 * self.gen.gemm_cycles(
                             n, op.d_kv, op.kv_heads * op.head_dim),
                         [start], tag=f"{tag}:kvgen")
        row = op.kv_width * ab
        app = eng.task("dma", "HBM", dma_cycles(hw, n * row), [kvgen],
                       nbytes=n * row, tag=f"{tag}:kvappend")
        return qgen, kvgen, [app]

    def _decode_tiles(self, seq_kv: int, block_kv: int) -> List[int]:
        """Ragged tile split of one slot's attended KV (last tile short)."""
        out, done = [], 0
        while done < seq_kv:
            tile = min(block_kv, seq_kv - done)
            out.append(tile)
            done += tile
        return out

    def _decode_streamed(self, eng: Engine, op: DecodeOp, start: int,
                         rewrite_res: str) -> int:
        """The streaming decode schedule shared by TILE_STREAM (rewrites
        ride the shadow-array bus: ``rewrite_res="BUS"``) and LAYER_STREAM
        (rewrites block the macro array: ``"ATTN"``).  TILE additionally
        forwards the new token's K/V over the NoC instead of re-reading it
        from HBM — one fewer cached row moved per slot."""
        hw, ab = self.hw, self.hw.act_bytes
        tag = op.name
        qgen, kv_ready, byte_evs = self._decode_gen(eng, op, start, tag)
        row = op.kv_width * ab
        tile_overlap = rewrite_res == "BUS"
        ends: List[int] = list(byte_evs)
        for s, kept in enumerate(op.seq_kv):
            # TILE: the forwarded new-token row never re-reads from HBM.
            read_rows = kept - 1 if (op.append and tile_overlap) else kept
            gate = eng.barrier([qgen, kv_ready] + byte_evs[-1:],
                               tag=f"{tag}:s{s}:ready") \
                if not tile_overlap else qgen
            prev_comp: List[int] = []
            read_left = read_rows
            for j, tile in enumerate(self._decode_tiles(kept, op.block_kv)):
                rd_rows = min(tile, read_left)
                read_left -= rd_rows
                deps = [gate]
                if rd_rows > 0:
                    deps = [eng.task("dma", "HBM",
                                     dma_cycles(hw, rd_rows * row), [gate],
                                     nbytes=rd_rows * row,
                                     tag=f"{tag}:s{s}:kvdma:k{j}")]
                elif tile_overlap:
                    deps = [kv_ready]            # forwarded over the NoC
                rw = eng.task("rewrite", rewrite_res,
                              self.attn.rewrite_cycles(tile * row), deps,
                              nbytes=tile * row, tag=f"{tag}:s{s}:rw:k{j}")
                comp = eng.task("compute", "ATTN",
                                2 * self.attn.gemm_cycles(
                                    1, op.head_dim, tile, count=op.heads),
                                [rw] + prev_comp[-1:],
                                tag=f"{tag}:s{s}:qkpv:k{j}")
                prev_comp.append(comp)
            ends.append(prev_comp[-1])
        return eng.barrier(ends, tag=f"{tag}:done")

    def build_decode(self, eng: Engine, op: DecodeOp, start: int) -> int:
        raise NotImplementedError


class _TileStream(_Scheduler):
    mode = ExecutionMode.TILE_STREAM

    def __init__(self, hw: HardwareConfig) -> None:
        super().__init__(hw)
        # Hybrid reconfigurable mode: active + shadow sub-array per macro.
        self.attn = MacroArray(hw, hw.num_groups - hw.gen_groups,
                               MacroMode.HYBRID)

    def build_attn(self, eng: Engine, op: AttnOp, start: int) -> int:
        return cross_forward_attention(eng, self.hw, op, self.gen,
                                       self.attn, start, op.name)

    def build_decode(self, eng: Engine, op: DecodeOp, start: int) -> int:
        # Hybrid mode: rewrites ride the shadow sub-array bus and overlap
        # attention compute; the new token's K/V cross-forward on-chip.
        return self._decode_streamed(eng, op, start, "BUS")


class _LayerStream(_Scheduler):
    mode = ExecutionMode.LAYER_STREAM

    def __init__(self, hw: HardwareConfig) -> None:
        super().__init__(hw)
        # Normal mode: both sub-arrays stationary, rewrites block compute.
        self.attn = MacroArray(hw, hw.num_groups - hw.gen_groups,
                               MacroMode.NORMAL)

    def build_attn(self, eng: Engine, op: AttnOp, start: int) -> int:
        hw, ab = self.hw, self.hw.act_bytes
        bq = getattr(op, "block_q", BLOCK)
        bkv = getattr(op, "block_kv", BLOCK)
        nqb = math.ceil(op.seq_q / bq)
        nkb = math.ceil(op.seq_kv / bkv)
        q_bytes = op.seq_q * op.heads * op.head_dim * ab
        x_bytes = op.seq_kv * op.d_kv * ab
        kv_bytes = op.seq_kv * op.kv_width * ab

        xdma = eng.task("dma", "HBM", dma_cycles(hw, x_bytes), [start],
                        nbytes=x_bytes, tag=f"{op.name}:xdma")
        qgen = eng.task("compute", "GEN",
                        self.gen.gemm_cycles(op.seq_q, op.d_q,
                                             op.heads * op.head_dim),
                        [start], tag=f"{op.name}:qgen")
        qdma = eng.task("dma", "HBM", dma_cycles(hw, q_bytes), [qgen],
                        nbytes=q_bytes, tag=f"{op.name}:qdma")
        kvgen = eng.task("compute", "GEN",
                         2 * self.gen.gemm_cycles(
                             op.seq_kv, op.d_kv, op.kv_heads * op.head_dim),
                         [xdma], tag=f"{op.name}:kvgen")
        kvw = eng.task("dma", "HBM", dma_cycles(hw, kv_bytes), [kvgen],
                       nbytes=kv_bytes, tag=f"{op.name}:kvdma")
        # Layer-granularity sync: attention waits for the full K/V layer.
        barrier = eng.barrier([kvw, qdma], tag=f"{op.name}:layer_sync")

        kv_tile_bytes = 2 * bkv * op.kv_heads * op.head_dim * ab
        ends = []
        for i in range(nqb):
            prev_comp: List[int] = []
            for j in range(nkb):
                rd = eng.task("dma", "HBM", dma_cycles(hw, kv_tile_bytes),
                              [barrier], nbytes=kv_tile_bytes,
                              tag=f"{op.name}:kvdma:q{i}k{j}")
                # No shadow sub-array: the rewrite occupies the macro array.
                rw = eng.task("rewrite", "ATTN",
                              self.attn.rewrite_cycles(kv_tile_bytes), [rd],
                              nbytes=kv_tile_bytes,
                              tag=f"{op.name}:rw:q{i}k{j}")
                comp = eng.task("compute", "ATTN",
                                2 * self.attn.gemm_cycles(
                                    bq, op.head_dim, bkv,
                                    count=op.heads),
                                [rw] + prev_comp[-1:],
                                tag=f"{op.name}:qkpv:q{i}k{j}")
                prev_comp.append(comp)
            ends.append(prev_comp[-1])
        o_bytes = q_bytes
        odma = eng.task("dma", "HBM", dma_cycles(hw, o_bytes), ends,
                        nbytes=o_bytes, tag=f"{op.name}:odma")
        return eng.barrier([odma], tag=f"{op.name}:done")

    def build_decode(self, eng: Engine, op: DecodeOp, start: int) -> int:
        # Normal mode: layer-granular sync (append commits before the
        # cache re-read) and rewrites block the macro array.
        return self._decode_streamed(eng, op, start, "ATTN")


class _NonStream(_Scheduler):
    mode = ExecutionMode.NON_STREAM

    def __init__(self, hw: HardwareConfig) -> None:
        super().__init__(hw)
        self.attn = MacroArray(hw, hw.num_groups - hw.gen_groups,
                               MacroMode.NORMAL)

    def _chain(self, eng: Engine, prev: int, kind: str, resource: str,
               cycles: int, nbytes: int, tag: str) -> int:
        return eng.task(kind, resource, cycles, [prev], nbytes=nbytes,
                        tag=tag)

    def build_gemm(self, eng: Engine, op: GemmOp, start: int) -> int:
        # Unfused: activations round-trip HBM around every GEMM.  The
        # output projection's input read is already charged to the
        # attention op (odma read), matching the analytic model's 2*o.
        ab = self.hw.act_bytes
        t = start
        if not op.name.endswith("_oproj"):
            in_bytes = op.m * op.k * ab
            t = self._chain(eng, t, "dma", "HBM",
                            dma_cycles(self.hw, in_bytes), in_bytes,
                            f"{op.name}:indma")
        t = self._chain(eng, t, "compute", "GEN",
                        self.gen.gemm_cycles(op.m, op.k, op.n), 0,
                        f"{op.name}:gemm")
        out_bytes = op.m * op.n * ab
        return self._chain(eng, t, "dma", "HBM",
                           dma_cycles(self.hw, out_bytes), out_bytes,
                           f"{op.name}:outdma")

    def build_attn(self, eng: Engine, op: AttnOp, start: int) -> int:
        hw, ab = self.hw, self.hw.act_bytes
        q_bytes = op.seq_q * op.heads * op.head_dim * ab
        k_bytes = op.seq_kv * op.kv_heads * op.head_dim * ab
        x_bytes = op.seq_kv * op.d_kv * ab
        a_bytes = op.heads * op.seq_q * op.seq_kv * ab
        softmax_cycles = math.ceil(op.heads * op.seq_q * op.seq_kv
                                   / hw.macro_cols)
        n = op.name
        t = self._chain(eng, start, "dma", "HBM", dma_cycles(hw, x_bytes),
                        x_bytes, f"{n}:xdma")
        t = self._chain(eng, t, "compute", "GEN",
                        self.gen.gemm_cycles(op.seq_q, op.d_q,
                                             op.heads * op.head_dim),
                        0, f"{n}:qgen")
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, q_bytes),
                        q_bytes, f"{n}:qdma")
        t = self._chain(eng, t, "compute", "GEN",
                        2 * self.gen.gemm_cycles(
                            op.seq_kv, op.d_kv, op.kv_heads * op.head_dim),
                        0, f"{n}:kvgen")
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, 2 * k_bytes),
                        2 * k_bytes, f"{n}:kvdma")                 # K,V out
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, q_bytes),
                        q_bytes, f"{n}:qdma:read")
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, k_bytes),
                        k_bytes, f"{n}:kvdma:readk")
        t = self._chain(eng, t, "rewrite", "ATTN",
                        self.attn.rewrite_cycles(k_bytes), k_bytes,
                        f"{n}:rwk")
        t = self._chain(eng, t, "compute", "ATTN",
                        self.attn.gemm_cycles(op.seq_q, op.head_dim,
                                              op.seq_kv, count=op.heads),
                        0, f"{n}:qk")
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, a_bytes),
                        a_bytes, f"{n}:adma:write")
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, a_bytes),
                        a_bytes, f"{n}:adma:read")
        t = self._chain(eng, t, "compute", "VEC", softmax_cycles, 0,
                        f"{n}:softmax")
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, a_bytes),
                        a_bytes, f"{n}:adma:writep")
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, a_bytes),
                        a_bytes, f"{n}:adma:readp")
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, k_bytes),
                        k_bytes, f"{n}:kvdma:readv")
        t = self._chain(eng, t, "rewrite", "ATTN",
                        self.attn.rewrite_cycles(k_bytes), k_bytes,
                        f"{n}:rwv")
        t = self._chain(eng, t, "compute", "ATTN",
                        self.attn.gemm_cycles(op.seq_q, op.seq_kv,
                                              op.head_dim, count=op.heads),
                        0, f"{n}:pv")
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, q_bytes),
                        q_bytes, f"{n}:odma:write")
        t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, q_bytes),
                        q_bytes, f"{n}:odma:read")
        return eng.barrier([t], tag=f"{n}:done")

    def build_decode(self, eng: Engine, op: DecodeOp, start: int) -> int:
        # Unfused: per slot, Q and the score/probability rows round-trip
        # HBM; whole K then whole V rewrite serially into the array.
        hw, ab = self.hw, self.hw.act_bytes
        n = op.name
        qgen, kv_ready, byte_evs = self._decode_gen(eng, op, start, n)
        q_bytes = op.heads * op.head_dim * ab
        ends: List[int] = []
        for s, kept in enumerate(op.seq_kv):
            half = kept * op.kv_heads * op.head_dim * ab
            a_bytes = op.heads * kept * ab
            t = eng.barrier([qgen, kv_ready] + byte_evs, tag=f"{n}:s{s}:in")
            t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, q_bytes),
                            q_bytes, f"{n}:s{s}:qdma:write")
            t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, q_bytes),
                            q_bytes, f"{n}:s{s}:qdma:read")
            t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, half),
                            half, f"{n}:s{s}:kvdma:readk")
            t = self._chain(eng, t, "rewrite", "ATTN",
                            self.attn.rewrite_cycles(half), half,
                            f"{n}:s{s}:rwk")
            t = self._chain(eng, t, "compute", "ATTN",
                            self.attn.gemm_cycles(1, op.head_dim, kept,
                                                  count=op.heads),
                            0, f"{n}:s{s}:qk")
            t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, a_bytes),
                            a_bytes, f"{n}:s{s}:adma:write")
            t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, a_bytes),
                            a_bytes, f"{n}:s{s}:adma:read")
            t = self._chain(eng, t, "compute", "VEC",
                            math.ceil(op.heads * kept / hw.macro_cols), 0,
                            f"{n}:s{s}:softmax")
            t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, a_bytes),
                            a_bytes, f"{n}:s{s}:adma:writep")
            t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, a_bytes),
                            a_bytes, f"{n}:s{s}:adma:readp")
            t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, half),
                            half, f"{n}:s{s}:kvdma:readv")
            t = self._chain(eng, t, "rewrite", "ATTN",
                            self.attn.rewrite_cycles(half), half,
                            f"{n}:s{s}:rwv")
            t = self._chain(eng, t, "compute", "ATTN",
                            self.attn.gemm_cycles(1, kept, op.head_dim,
                                                  count=op.heads),
                            0, f"{n}:s{s}:pv")
            t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, q_bytes),
                            q_bytes, f"{n}:s{s}:odma:write")
            t = self._chain(eng, t, "dma", "HBM", dma_cycles(hw, q_bytes),
                            q_bytes, f"{n}:s{s}:odma:read")
            ends.append(t)
        return eng.barrier(ends, tag=f"{n}:done")


_SCHEDULERS = {
    ExecutionMode.TILE_STREAM: _TileStream,
    ExecutionMode.LAYER_STREAM: _LayerStream,
    ExecutionMode.NON_STREAM: _NonStream,
}


class _CalibratedEngine(Engine):
    """Engine whose task durations scale by a fitted per-resource factor
    (``repro.sim.replay.CalibrationReport.scale``).  Replayed tasks are
    recorded ground truth and bypass scaling (``exempt``)."""

    def __init__(self, scale) -> None:
        super().__init__()
        self.scale = dict(scale)
        self.exempt = False

    def task(self, kind, resource, cycles, deps=(), nbytes=0, tag=""):
        s = self.scale.get(resource, 1.0)
        if cycles and not self.exempt and s != 1.0:
            cycles = max(1, int(math.ceil(cycles * s)))
        return super().task(kind, resource, cycles, deps, nbytes, tag)


def _build_replay(eng: Engine, op, kt, start: int) -> int:
    """Lower one traced op to its *recorded* timing (DESIGN.md §10): a
    single compute event spanning ``kt.cycles`` on the op class's macro
    resource, plus a zero-cycle HBM accounting event carrying the bytes
    the recorded kernel actually moved (the measured wall time already
    includes memory time — the recording platform overlaps DMA with
    compute, so charging the span once is the honest accounting)."""
    exempt_before = getattr(eng, "exempt", None)
    if exempt_before is not None:
        eng.exempt = True
    try:
        dma = eng.task("dma", "HBM", 0, [start], nbytes=kt.hbm_bytes,
                       tag=f"{op.name}:replay:dma")
        comp = eng.task("compute", kt.resource, kt.cycles, [start],
                        tag=f"{op.name}:replay")
        return eng.barrier([dma, comp], tag=f"{op.name}:replay:done")
    finally:
        if exempt_before is not None:
            eng.exempt = exempt_before


def _simulate_ops(wl: Workload, hw: HardwareConfig, sched_for_op,
                  mode: Optional[ExecutionMode],
                  trace_of: Optional[Dict[str, object]] = None,
                  scale: Optional[Dict[str, float]] = None) -> SimResult:
    """The shared per-layer scheduling loop: layers chain sequentially;
    ``sched_for_op(op)`` picks the scheduler that builds each op's task
    graph — a constant for the homogeneous paths, per-op for plan-driven
    simulation (heterogeneous modes in one model).  Ops named in
    ``trace_of`` replay their recorded ``KernelTrace`` timing instead;
    ``scale`` applies a fitted per-resource calibration factor to the
    analytic (non-replayed) task durations."""
    eng = _CalibratedEngine(scale) if scale else Engine()
    prev = eng.barrier([], tag="start")
    layer_marks: List[int] = []
    replayed = 0
    for layer in wl.layers:
        for op in layer.ops:
            kt = trace_of.get(op.name) if trace_of else None
            if kt is not None:
                prev = _build_replay(eng, op, kt, prev)
                replayed += 1
            else:
                sched = sched_for_op(op)
                if isinstance(op, AttnOp):
                    prev = sched.build_attn(eng, op, prev)
                elif isinstance(op, DecodeOp):
                    prev = sched.build_decode(eng, op, prev)
                else:
                    prev = sched.build_gemm(eng, op, prev)
        prev = eng.barrier([prev], tag=f"layer{layer.index}")
        layer_marks.append(prev)
    trace = eng.run()
    finish = eng.finish_times
    bounds = [0] + [finish[m] for m in layer_marks]
    per_layer = tuple(b - a for a, b in zip(bounds, bounds[1:]))
    return SimResult(wl.name, mode, hw.name, trace.makespan,
                     trace.bytes_moved("HBM"), per_layer, trace, hw_cfg=hw,
                     replayed_ops=replayed)


def simulate(wl: Workload, hw: HardwareConfig,
             mode: ExecutionMode) -> SimResult:
    return _SCHEDULERS[mode](hw).simulate(wl)


def simulate_plan(plan, hw: Optional[HardwareConfig] = None, *,
                  replay: bool = True,
                  calibration=None) -> SimResult:
    """Execute an ``repro.plan.ExecutionPlan``: the plan's op list is
    lowered directly (``workload_from_plan``) and each op's task graph is
    built by the scheduler for *that op's* resolved mode — per-layer
    heterogeneous modes run in one simulated model.  ``SimResult.mode``
    is the plan's uniform mode, or None for a heterogeneous plan.

    Plan/trace replay (DESIGN.md §10): ops carrying an attached
    ``KernelTrace`` (``plan.attach_traces`` / ``record_plan``) replay
    their *recorded* timing and bytes verbatim; untraced ops keep the
    analytic lowering — one plan mixes both.  ``replay=False`` forces
    analytic lowering everywhere (the denominator of every calibration
    fit).  ``calibration`` — a ``repro.sim.replay.CalibrationReport`` or
    raw ``{resource: factor}`` mapping — scales the analytic task
    durations by the fitted per-resource factors (replayed ops are
    ground truth and stay untouched)."""
    from repro.sim.replay import resolve_calibration
    from repro.sim.workload import workload_from_plan
    hw = hw or _hw_for_plan(plan)
    scheds = {m: _SCHEDULERS[m](hw) for m in ExecutionMode}
    mode_of: Dict[str, ExecutionMode] = {}
    trace_of: Dict[str, object] = {}
    for p in tuple(plan.layers) + tuple(plan.gemms):
        mode_of[p.name] = p.mode
        kt = getattr(p, "trace", None)
        if replay and kt is not None:
            trace_of[p.name] = kt
    wl = workload_from_plan(plan)
    return _simulate_ops(wl, hw, lambda op: scheds[mode_of[op.name]],
                         plan.uniform_mode, trace_of=trace_of or None,
                         scale=resolve_calibration(calibration))


def _hw_for_plan(plan) -> HardwareConfig:
    if hasattr(plan, "hw_config"):
        return plan.hw_config()      # carries ad-hoc design points verbatim
    from repro.configs.hardware import HW_PRESETS
    return HW_PRESETS[plan.hw]


def simulate_model(cfg, hw: Optional[HardwareConfig] = None,
                   mode: Optional[ExecutionMode] = None,
                   seq_len: int = 0) -> SimResult:
    """Simulate a ``ModelConfig`` (legacy: mode forced or taken from the
    config; default hardware STREAMDCIM_BASE) or an
    ``repro.plan.ExecutionPlan`` (the planned path — per-layer modes come
    from the plan; ``hw`` overrides the plan's recorded preset, ``mode``
    is rejected: re-plan instead)."""
    if hasattr(cfg, "layers") and hasattr(cfg, "gemms"):
        if mode is not None:
            raise ValueError(
                "mode= conflicts with an ExecutionPlan (the plan already "
                "records per-layer modes); build a new plan instead")
        return simulate_plan(cfg, hw=hw)
    return simulate(build_workload(cfg, seq_len), hw or STREAMDCIM_BASE,
                    mode or cfg.execution_mode)


def compare_modes(cfg: ModelConfig, hw: HardwareConfig = STREAMDCIM_BASE,
                  seq_len: int = 0) -> Dict[ExecutionMode, SimResult]:
    """Three forced-mode plans for one model, built once and simulated —
    the §III comparison harness.  Each plan pins every layer to one mode
    (``force_mode=True``), so TILE_STREAM is simulated even where the
    planner would fall back (that inversion is the GQA cross-check).
    ``hw`` is passed through to the simulation verbatim, so ad-hoc
    (unregistered / modified) design points sweep correctly."""
    from repro.plan.planner import plan_model
    return {m: simulate_plan(plan_model(cfg, hw=hw, seq_len=seq_len,
                                        mode=m, force_mode=True), hw=hw)
            for m in ExecutionMode}


def rewrite_stall_trace(hw: HardwareConfig = STREAMDCIM_BASE,
                        n: int = 2048, d: int = 512, *,
                        ping_pong: bool = False,
                        iters: int = 4) -> Trace:
    """The §I micro-workload as a raw ``Trace`` — the input to
    ``simulate_rewrite_stall``'s arithmetic and to ``obs.attribution``'s
    reproduction of the 57% stall number."""
    mode = MacroMode.HYBRID if ping_pong else MacroMode.NORMAL
    arr = MacroArray(hw, hw.num_groups, mode)
    rw_cycles = arr.rewrite_cycles(n * d)            # INT8: n*d bytes
    comp_cycles = arr.gemm_cycles(n, d, n)           # stream n q-vectors
    eng = Engine()
    comps: List[int] = []
    for it in range(iters):
        deps = comps[-1:] if not arr.overlap_rewrite else comps[-2:-1]
        res = "ATTN" if not arr.overlap_rewrite else "BUS"
        rw = eng.task("rewrite", res, rw_cycles, deps, nbytes=n * d,
                      tag=f"it{it}:rw")
        comp = eng.task("compute", "ATTN", comp_cycles,
                        [rw] + comps[-1:], tag=f"it{it}:qk")
        comps.append(comp)
    return eng.run()


def simulate_rewrite_stall(hw: HardwareConfig = STREAMDCIM_BASE,
                           n: int = 2048, d: int = 512, *,
                           ping_pong: bool = False,
                           iters: int = 4) -> Dict[str, float]:
    """Paper §I micro-workload: QK^T phases with K = n x d INT8 resident
    in the macro array (it fits, unlike the §III models).  Serial
    (layer-based streaming) rewriting stalls the array; with the ping-pong
    shadow sub-array the next phase's K rewrites during the current
    phase's compute and only the bus-bound residue is exposed."""
    mode = MacroMode.HYBRID if ping_pong else MacroMode.NORMAL
    arr = MacroArray(hw, hw.num_groups, mode)
    rw_cycles = arr.rewrite_cycles(n * d)            # INT8: n*d bytes
    comp_cycles = arr.gemm_cycles(n, d, n)           # stream n q-vectors
    trace = rewrite_stall_trace(hw, n, d, ping_pong=ping_pong, iters=iters)
    span = trace.makespan
    exposed = span - trace.busy_cycles("ATTN") if arr.overlap_rewrite else 0
    return {
        "rewrite_cycles": float(rw_cycles),
        "compute_cycles": float(comp_cycles),
        "span_cycles": float(span),
        "cycles_per_phase": span / iters,
        "rewrite_frac": trace.rewrite_stall_fraction(),
        "exposed_stall_frac": (exposed / span if arr.overlap_rewrite
                               else trace.rewrite_stall_fraction()),
    }
