"""Discrete-event engine + the mixed-stationary cross-forwarding schedule.

``Engine`` is a small list-scheduling discrete-event simulator: tasks carry
a resource, a cycle cost, and dependencies; each resource issues in-order
and a task starts at max(deps ready, resource free).  Resources model the
StreamDCIM floorplan:

* ``GEN``  — weight-stationary macro groups (Q/K/V generation, FFN GEMMs)
* ``ATTN`` — input-stationary macro groups (QK^T / PV against resident
             K/V tiles)
* ``BUS``  — the shared CIM rewrite port (only used as a separate resource
             when ping-pong shadow sub-arrays let rewrite overlap compute;
             otherwise rewrite tasks occupy ``ATTN`` directly)
* ``NOC``  — the tile-based streaming network that cross-forwards K/V
             tiles between macro groups
* ``HBM``  — the off-chip port; every event on it carries a byte count so
             traces can be cross-checked against the analytic traffic
             model in ``repro.core.streaming``
* ``VEC``  — the SIMD softmax/elementwise unit

``cross_forward_attention`` builds the paper's §II-B schedule for one
attention op: per query block, ``x_kv`` tiles stream from HBM into the
stationary-weight macros, each generated K/V tile cross-forwards over the
NOC into the attention macros' shadow sub-array (ping-pong, §II-C), and
the tile's QK^T/PV fire as soon as *that tile* is resident — tile-level
execution decoupling, no layer barrier, K/V never touching HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.configs.hardware import HardwareConfig
from repro.sim.macro import MacroArray, dma_cycles, noc_cycles
from repro.sim.trace import Event, Trace
from repro.sim.workload import BLOCK, AttnOp

import math


@dataclasses.dataclass(slots=True)
class _Task:
    kind: str
    resource: str
    cycles: int
    deps: Tuple[int, ...]
    nbytes: int
    tag: str


class Engine:
    """In-order-per-resource list scheduler over an explicit task DAG."""

    def __init__(self) -> None:
        self._tasks: List[_Task] = []

    def task(self, kind: str, resource: str, cycles: int,
             deps: Sequence[int] = (), nbytes: int = 0, tag: str = "") -> int:
        for d in deps:
            if not 0 <= d < len(self._tasks):
                raise ValueError(f"dep {d} not yet submitted (task {tag})")
        self._tasks.append(_Task(kind, resource, int(cycles), tuple(deps),
                                 nbytes, tag))
        return len(self._tasks) - 1

    def barrier(self, deps: Sequence[int], tag: str = "sync") -> int:
        """Zero-cost join point (layer boundaries, phase barriers)."""
        return self.task("sync", "SYNC", 0, deps, tag=tag)

    def run(self) -> Trace:
        # The DSE/sweep hot loop: locals for every per-iteration global
        # lookup, events gathered in a plain list and handed to the
        # trace in one assignment (one cache invalidation instead of one
        # per ``add``).  Semantics are unchanged from the reference loop.
        tasks = self._tasks
        n = len(tasks)
        free: Dict[str, int] = {}
        last_on: Dict[str, int] = {}   # last emitted event per resource
        end: List[int] = [0] * n
        # Resolved predecessors per task: data deps with zero-cost SYNC
        # joins flattened to the real events behind them, plus the
        # in-order resource-occupancy predecessor.  Stamped onto every
        # emitted Event so the trace is a self-contained scheduling DAG
        # (repro.obs.critpath / repro.obs.whatif rebuild the schedule
        # from events alone).
        preds: List[Tuple[int, ...]] = [()] * n
        events: List[Event] = []
        emit = events.append
        free_get = free.get
        last_get = last_on.get
        is_sync = [t.resource == "SYNC" for t in tasks]
        for i, t in enumerate(tasks):
            start = 0
            resolved: List[int] = []
            extend = resolved.extend
            append = resolved.append
            for d in t.deps:
                e = end[d]
                if e > start:
                    start = e
                if is_sync[d]:
                    extend(preds[d])
                else:
                    append(d)
            res = t.resource
            if not is_sync[i]:
                f = free_get(res, 0)
                if f > start:
                    start = f
                rp = last_get(res)
                if rp is not None:
                    append(rp)
            if len(resolved) > 1:
                seen: set = set()
                deps = tuple(d for d in resolved
                             if not (d in seen or seen.add(d)))
            else:
                deps = tuple(resolved)
            preds[i] = deps
            fin = start + t.cycles
            end[i] = fin
            if not is_sync[i]:
                free[res] = fin
                last_on[res] = i
                emit(Event(i, t.kind, res, start, fin,
                           t.nbytes, t.tag, deps))
        trace = Trace()
        trace.events = events
        self.finish_times = end
        return trace


def cross_forward_attention(eng: Engine, hw: HardwareConfig, op: AttnOp,
                            gen: MacroArray, attn: MacroArray,
                            start: int, tag: str) -> int:
    """Mixed-stationary cross-forwarding schedule for one attention op
    (TILE_STREAM).  Returns the op's completion barrier task id.

    Streamed HBM bytes mirror ``streamed_bytes_per_layer(TILE_STREAM)``:
    Q written once, output written once, ``x_kv`` re-streamed per q-block;
    K/V only ever cross the NOC.
    """
    ab = hw.act_bytes
    bq = getattr(op, "block_q", BLOCK)
    bkv = getattr(op, "block_kv", BLOCK)
    nqb = math.ceil(op.seq_q / bq)
    nkb = math.ceil(op.seq_kv / bkv)
    q_bytes = op.seq_q * op.heads * op.head_dim * ab

    # Q projection on the stationary-weight macros, written out once.
    qgen = eng.task("compute", "GEN",
                    gen.gemm_cycles(op.seq_q, op.d_q, op.heads * op.head_dim),
                    [start], tag=f"{tag}:qgen")
    qdma = eng.task("dma", "HBM", dma_cycles(hw, q_bytes), [qgen],
                    nbytes=q_bytes, tag=f"{tag}:qdma")

    kv_tile_bytes = 2 * bkv * op.kv_heads * op.head_dim * ab
    x_tile_bytes = bkv * op.d_kv * ab
    ends = []
    for i in range(nqb):
        compute_hist: List[int] = []   # per-tile QK/PV tasks of this q-block
        for j in range(nkb):
            xdma = eng.task("dma", "HBM", dma_cycles(hw, x_tile_bytes),
                            [start], nbytes=x_tile_bytes,
                            tag=f"{tag}:xdma:q{i}k{j}")
            # K_j and V_j generated from the x_kv tile (one read feeds both).
            kvgen = eng.task(
                "compute", "GEN",
                2 * gen.gemm_cycles(bkv, op.d_kv,
                                    op.kv_heads * op.head_dim),
                [xdma], tag=f"{tag}:kvgen:q{i}k{j}")
            fwd = eng.task("forward", "NOC", noc_cycles(hw, kv_tile_bytes),
                           [kvgen], nbytes=kv_tile_bytes,
                           tag=f"{tag}:fwd:q{i}k{j}")
            # Ping-pong: the shadow sub-array takes tile j while tile j-1
            # computes, but tile j must wait for tile j-2's compute to free
            # its buffer.  Without shadow arrays, rewrite holds ATTN itself.
            rw_deps = [fwd]
            if attn.overlap_rewrite and len(compute_hist) >= 2:
                rw_deps.append(compute_hist[-2])
            rw_res = "BUS" if attn.overlap_rewrite else "ATTN"
            rw = eng.task("rewrite", rw_res,
                          attn.rewrite_cycles(kv_tile_bytes), rw_deps,
                          nbytes=kv_tile_bytes, tag=f"{tag}:rw:q{i}k{j}")
            # QK^T + PV for this tile; online softmax keeps tiles in-order.
            c_deps = [rw, qdma] + compute_hist[-1:]
            comp = eng.task(
                "compute", "ATTN",
                2 * attn.gemm_cycles(bq, op.head_dim, bkv,
                                     count=op.heads),
                c_deps, tag=f"{tag}:qkpv:q{i}k{j}")
            compute_hist.append(comp)
        ends.append(compute_hist[-1])

    o_bytes = q_bytes
    odma = eng.task("dma", "HBM", dma_cycles(hw, o_bytes), ends,
                    nbytes=o_bytes, tag=f"{tag}:odma")
    return eng.barrier([odma], tag=f"{tag}:done")
