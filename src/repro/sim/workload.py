"""Lower ``ModelConfig``s into per-layer op graphs the simulator executes.

The simulator sees a model as a sequence of layers, each a tuple of ops:

* ``AttnOp``  — one attention (self- or cross-) including its Q projection
  and KV generation; the scheduler decides how Q/K/V move (HBM round-trip,
  layer-granular streaming, or tile-granular cross-forwarding).
* ``GemmOp``  — a plain weight-stationary GEMM (FFN matmuls, output
  projections); identical compute across schedulers, but the non-streaming
  baseline round-trips its activations through HBM.

* ``DecodeOp`` — one attention layer of one decode *step* across active
  serving slots (per-slot cached-KV lengths); built from
  ``repro.plan.DecodePlan``s via ``decode_workload_from_plan`` and
  consumed by ``sim.simulate_serve`` (DESIGN.md §11).

Supported families (the paper's §III pool): CROSSMODAL (ViLBERT two-stream
co-TRM), ENCDEC (whisper), and dense/VLM decoders (qwen2-vl).  Prefill
sequence lengths are padded to the attention block size; decode KV
lengths are ragged (the last tile may be partial) and shrink per layer
under DTPU pruning.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.core.types import Family, ModelConfig, pad_to

BLOCK = 256           # q/kv tile edge — matches kernels/stream_attention.py


@dataclasses.dataclass(frozen=True)
class AttnOp:
    name: str
    seq_q: int
    seq_kv: int
    d_q: int            # width of the query-side activations
    d_kv: int           # width of the KV-source activations (other modality
                        # for cross-forwarding — paper Fig. 4a)
    heads: int
    kv_heads: int
    head_dim: int
    cross: bool = False  # K/V generated from the *other* stream
    block_q: int = BLOCK   # tile edges the schedulers iterate with —
    block_kv: int = BLOCK  # plan-driven lowering carries the plan's tiling

    @property
    def kv_width(self) -> int:
        return 2 * self.kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class GemmOp:
    name: str
    m: int
    k: int
    n: int


@dataclasses.dataclass(frozen=True)
class DecodeOp:
    """One attention layer of one decode *step* across active slots: each
    slot streams its cached K/V (post-DTPU-pruning length ``seq_kv[s]``)
    through the attention macros for a single query token.  ``append`` is
    False for static caches (enc-dec cross-attention).  Built from a
    ``repro.plan.DecodePlan`` layer (``decode_workload_from_plan``)."""

    name: str
    seq_kv: Tuple[int, ...]   # per-slot attended KV length (incl. new token)
    d_q: int
    d_kv: int
    heads: int
    kv_heads: int
    head_dim: int
    cross: bool = False
    append: bool = True
    block_kv: int = BLOCK

    @property
    def kv_width(self) -> int:
        return 2 * self.kv_heads * self.head_dim

    @property
    def slots(self) -> int:
        return len(self.seq_kv)


@dataclasses.dataclass(frozen=True)
class Layer:
    index: int
    ops: Tuple[object, ...]


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    layers: Tuple[Layer, ...]

    @property
    def attention_ops(self) -> List[Tuple[int, AttnOp]]:
        return [(l.index, op) for l in self.layers for op in l.ops
                if isinstance(op, AttnOp)]


def _ffn_ops(tag: str, seq: int, d: int, d_ff: int, act: str) -> List[GemmOp]:
    ops = [GemmOp(f"{tag}_ffn_up", seq, d, d_ff)]
    if act == "silu":                       # gated MLP: extra gate matmul
        ops.append(GemmOp(f"{tag}_ffn_gate", seq, d, d_ff))
    ops.append(GemmOp(f"{tag}_ffn_down", seq, d_ff, d))
    return ops


def _attn_block(tag: str, seq_q: int, seq_kv: int, d_q: int, d_kv: int,
                heads: int, kv_heads: int, hd: int,
                cross: bool = False) -> List[object]:
    return [AttnOp(tag, seq_q, seq_kv, d_q, d_kv, heads, kv_heads, hd,
                   cross=cross),
            GemmOp(f"{tag}_oproj", seq_q, heads * hd, d_q)]


def workload_from_plan(plan, prefix: str = "") -> Workload:
    """Lower an ``repro.plan.ExecutionPlan`` back into the op graph the
    schedulers execute — no mode re-derivation: the plan *is* the op list
    (attention ``LayerPlan``s + ``GemmPlan``s in recorded op order), and
    per-op modes stay on the plan (``sim.pipeline.simulate_plan`` reads
    them).  ``prefix`` renames every op (serving timelines keep per-step
    tags distinct — ``sim.simulate_serve``).  Duck-typed so this module
    never imports the planner."""
    ops: List[Tuple[int, int, object]] = []          # (op_index, layer, op)
    for lp in plan.layers:
        ops.append((lp.op_index, lp.layer_index,
                    AttnOp(prefix + lp.name, lp.seq_q, lp.seq_kv, lp.d_q,
                           lp.d_kv, lp.heads, lp.kv_heads, lp.head_dim,
                           cross=lp.cross, block_q=lp.block_q,
                           block_kv=lp.block_kv)))
    for g in plan.gemms:
        ops.append((g.op_index, g.layer_index,
                    GemmOp(prefix + g.name, g.m, g.k, g.n)))
    return _group_ops(plan.model, ops)


def _group_ops(model: str,
               ops: List[Tuple[int, int, object]]) -> Workload:
    """Fold (op_index, layer_index, op) records into the per-layer op
    tuples the schedulers walk — shared by the prefill and decode plan
    lowerings."""
    ops = sorted(ops, key=lambda t: t[0])
    layers: List[Layer] = []
    for _, li, op in ops:
        if not layers or layers[-1].index != li:
            layers.append(Layer(li, ()))
        layers[-1] = Layer(li, layers[-1].ops + (op,))
    return Workload(model, tuple(layers))


def decode_workload_from_plan(plan, prefix: str = "") -> Workload:
    """Lower an ``repro.plan.DecodePlan`` into the op graph one decode
    step executes: per model layer, its ``DecodeOp``(s) followed by the
    step's weight-stationary GEMMs (output projection + FFN at one token
    per slot).  ``prefix`` renames every op (``f"{prefix}{name}"``) so a
    multi-step serving timeline keeps per-step tags distinct.  Duck-typed
    like ``workload_from_plan``."""
    ops: List[Tuple[int, int, object]] = []
    for lp in plan.layers:
        ops.append((lp.op_index, lp.layer_index,
                    DecodeOp(prefix + lp.name, tuple(lp.seq_kv), lp.d_q,
                             lp.d_kv, lp.heads, lp.kv_heads, lp.head_dim,
                             cross=lp.cross, append=not lp.cross,
                             block_kv=lp.block_kv)))
    for g in plan.gemms:
        ops.append((g.op_index, g.layer_index,
                    GemmOp(prefix + g.name, g.m, g.k, g.n)))
    return _group_ops(plan.model, ops)


def build_workload(cfg, seq_len: int = 0) -> Workload:
    """seq_len = 0 picks the model's paper-typical sequence (ViLBERT:
    N_X = N_Y = 4096; whisper: 1500-frame encoder / 448-token decoder;
    decoders: 4096), padded to the tile block.

    Also accepts an ``repro.plan.ExecutionPlan`` (PR 2): the plan is
    lowered directly (``workload_from_plan``) instead of re-deriving the
    op graph from the config."""
    if hasattr(cfg, "layers") and hasattr(cfg, "gemms"):
        return workload_from_plan(cfg)
    if cfg.num_heads == 0:
        raise ValueError(
            f"{cfg.name}: attention-free families are out of simulator "
            "scope (no K/V streaming to schedule) — see ROADMAP §Simulator")
    if cfg.family == Family.CROSSMODAL:
        return _build_crossmodal(cfg, seq_len)
    if cfg.family == Family.ENCDEC:
        return _build_encdec(cfg, seq_len)
    return _build_decoder(cfg, seq_len)


def _build_crossmodal(cfg: ModelConfig, seq_len: int) -> Workload:
    sx = pad_to(seq_len or 4096, BLOCK)
    sy = pad_to(seq_len or cfg.seq_y or 4096, BLOCK)
    dx, dy = cfg.d_model, cfg.d_model_y
    hx, hy = cfg.num_heads, cfg.num_heads_y
    hdx, hdy = dx // hx, dy // hy
    layers: List[Layer] = []
    n_pre = cfg.num_layers - cfg.num_coattn_layers
    for i in range(n_pre):
        ops = _attn_block(f"y{i}_self", sy, sy, dy, dy, hy, hy, hdy)
        ops += _ffn_ops(f"y{i}", sy, dy, cfg.d_ff_y, cfg.act)
        layers.append(Layer(len(layers), tuple(ops)))
    for i in range(cfg.num_coattn_layers):
        # Co-TRM block: each stream's K/V are generated from the *other*
        # modality's activations — the cross-forwarding case.
        ops: List[object] = []
        ops += _attn_block(f"cox{i}_co", sx, sy, dx, dy, hx, hx, hdx,
                           cross=True)
        ops += _attn_block(f"cox{i}_self", sx, sx, dx, dx, hx, hx, hdx)
        ops += _ffn_ops(f"cox{i}", sx, dx, cfg.d_ff, cfg.act)
        ops += _attn_block(f"coy{i}_co", sy, sx, dy, dx, hy, hy, hdy,
                           cross=True)
        ops += _attn_block(f"coy{i}_self", sy, sy, dy, dy, hy, hy, hdy)
        ops += _ffn_ops(f"coy{i}", sy, dy, cfg.d_ff_y, cfg.act)
        layers.append(Layer(len(layers), tuple(ops)))
    return Workload(cfg.name, tuple(layers))


def _build_encdec(cfg: ModelConfig, seq_len: int) -> Workload:
    se = pad_to(cfg.encoder_seq, BLOCK)
    sd = pad_to(seq_len or 448, BLOCK)
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim or cfg.d_model // cfg.num_heads
    hkv = cfg.num_kv_heads
    layers: List[Layer] = []
    for i in range(cfg.num_encoder_layers):
        ops = _attn_block(f"enc{i}_self", se, se, d, d, h, hkv, hd)
        ops += _ffn_ops(f"enc{i}", se, d, cfg.d_ff, cfg.act)
        layers.append(Layer(len(layers), tuple(ops)))
    for i in range(cfg.num_layers):
        ops = _attn_block(f"dec{i}_self", sd, sd, d, d, h, hkv, hd)
        ops += _attn_block(f"dec{i}_cross", sd, se, d, d, h, hkv, hd,
                           cross=True)
        ops += _ffn_ops(f"dec{i}", sd, d, cfg.d_ff, cfg.act)
        layers.append(Layer(len(layers), tuple(ops)))
    return Workload(cfg.name, tuple(layers))


def _build_decoder(cfg: ModelConfig, seq_len: int) -> Workload:
    s = pad_to(seq_len or 4096, BLOCK)
    d, h = cfg.d_model, cfg.num_heads
    hd = cfg.head_dim or d // h
    layers: List[Layer] = []
    for i in range(cfg.num_layers):
        ops = _attn_block(f"l{i}_self", s, s, d, d, h, cfg.num_kv_heads, hd)
        ops += _ffn_ops(f"l{i}", s, d, cfg.d_ff, cfg.act)
        layers.append(Layer(len(layers), tuple(ops)))
    return Workload(cfg.name, tuple(layers))
