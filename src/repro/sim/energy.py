"""``repro.sim.energy`` — napkin energy model folded over simulator traces.

The simulator (``repro.sim``) reports *cycles*; the paper's efficiency
claims (§IV: 2.30x/1.13x energy vs non-/layer-based streaming) are about
*energy*.  ``EnergyModel`` closes that gap the way CIMFlow
(arXiv:2505.01107) and NeuroSim (arXiv:2505.02314) do for digital CIM: a
per-event cost table folded over ``Trace.events``, producing a
per-resource / per-op breakdown, total pJ, and EDP for any ``SimResult``.

Cost structure (all picojoules):

* dynamic — ``pj_per_macro_cycle`` per *macro* per busy compute cycle on
  the CIM arrays (GEN scaled by ``hw.gen_macros``, ATTN by
  ``hw.attn_macros``: the whole allocation switches together under
  bit-serial broadcast), ``pj_per_rewrite_byte`` on the CIM write port,
  ``pj_per_noc_byte`` on the tile-based streaming network,
  ``pj_per_hbm_byte`` off-chip, ``pj_per_vec_cycle`` on the SIMD unit;
* static — ``leak_pj_per_cycle[resource]`` per makespan cycle (GEN/ATTN
  again scaled per macro), so a bigger macro array pays idle leakage for
  the whole run: the latency/energy trade-off ``repro.dse`` sweeps.

``STREAMDCIM_ENERGY_BASE`` is calibrated against the same napkin
constants the roofline benchmarks use (``benchmarks/common.py``: HBM
~45 pJ/byte, on-chip ~2 pJ/byte, ~0.8 pJ/bf16-flop — those names are now
thin aliases over this model), with the CIM-side constants chosen so the
three-way comparison's energy ordering reproduces the paper's §IV claim
(TILE < LAYER < NON on the MHA models).  Ratios between design points are
meaningful; absolute joules are not (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, TYPE_CHECKING

from repro.configs.hardware import HW_PRESETS, HardwareConfig

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.sim.pipeline import SimResult
    from repro.sim.trace import Trace

@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """One pJ-cost table (an energy design point, like ``HardwareConfig``
    is a timing design point).  Registered in
    ``repro.configs.registry.ENERGY_CONFIGS``."""

    name: str = "streamdcim-energy-base"
    # --- dynamic costs ---
    pj_per_macro_cycle: float = 30.0   # per TBR-CIM macro per busy cycle
    pj_per_rewrite_byte: float = 4.0   # CIM write port (§I rewrite path)
    pj_per_noc_byte: float = 2.0       # TBSN hop (== on-chip napkin const)
    pj_per_hbm_byte: float = 45.0      # off-chip DRAM (~5.6 pJ/bit)
    pj_per_vec_cycle: float = 50.0     # SIMD softmax/elementwise lane bank
    # --- static leakage, per makespan cycle ---
    #     GEN/ATTN entries are per macro; others per resource instance.
    leak_pj_per_cycle: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: {"GEN": 0.5, "ATTN": 0.5, "BUS": 10.0,
                                 "NOC": 20.0, "HBM": 100.0, "VEC": 10.0})
    # --- napkin bridge: bf16 MXU flop (roofline comparisons only;
    #     the CIM arrays are charged per macro-cycle, not per flop) ---
    pj_per_flop: float = 0.8

    def __post_init__(self):
        for f in dataclasses.fields(self):
            if f.name in ("name", "leak_pj_per_cycle"):
                continue
            v = getattr(self, f.name)
            if v < 0:
                raise ValueError(f"{self.name}: {f.name} must be >= 0, "
                                 f"got {v!r}")
        if any(v < 0 for v in self.leak_pj_per_cycle.values()):
            raise ValueError(f"{self.name}: leakage rates must be >= 0, "
                             f"got {dict(self.leak_pj_per_cycle)!r}")

    def macro_ops_per_cycle(self, hw: HardwareConfig) -> float:
        """INT8 MAC throughput of one macro per cycle (both multiply and
        add counted), for pJ/op cross-checks against ``pj_per_flop``."""
        return 2 * hw.macro_rows * hw.macro_cols / hw.vector_cycles


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """The fold result for one simulated run."""

    model: str                       # EnergyModel name
    hw: str                          # HardwareConfig name
    makespan_cycles: int
    by_resource: Dict[str, float]    # dynamic + that resource's leakage, pJ
    by_op: Dict[str, float]          # dynamic energy keyed by op tag, pJ
    dynamic_pj: float
    leakage_pj: float

    @property
    def total_pj(self) -> float:
        return self.dynamic_pj + self.leakage_pj

    @property
    def edp(self) -> float:
        """Energy-delay product, pJ * cycles (relative units — the
        simulator is cycle-approximate and unclocked)."""
        return self.total_pj * self.makespan_cycles

    def summary(self) -> Dict[str, float]:
        s = {"total_pj": self.total_pj, "dynamic_pj": self.dynamic_pj,
             "leakage_pj": self.leakage_pj, "edp_pj_cycles": self.edp}
        for r, pj in sorted(self.by_resource.items()):
            s[f"pj_{r}"] = pj
        return s


def _event_pj(em: EnergyModel, hw: HardwareConfig, resource: str,
              kind: str, cycles: int, nbytes: int) -> float:
    """Dynamic energy of ``cycles``/``nbytes`` on one (resource, kind)."""
    if kind == "compute":
        if resource == "GEN":
            return cycles * hw.gen_macros * em.pj_per_macro_cycle
        if resource == "ATTN":
            return cycles * hw.attn_macros * em.pj_per_macro_cycle
        if resource == "VEC":
            return cycles * em.pj_per_vec_cycle
        return 0.0
    if kind == "rewrite":
        # Rewrite events carry their byte counts; a byte-less event (old
        # traces) falls back to the write-port width the cycles imply.
        nb = nbytes or cycles * hw.rewrite_bytes_per_cycle
        return nb * em.pj_per_rewrite_byte
    if kind == "forward":
        return nbytes * em.pj_per_noc_byte
    if kind == "dma":
        return nbytes * em.pj_per_hbm_byte
    return 0.0


def _leak_scale(hw: HardwareConfig, resource: str) -> int:
    if resource == "GEN":
        return hw.gen_macros
    if resource == "ATTN":
        return hw.attn_macros
    return 1


def energy_of_trace(trace: "Trace", hw: HardwareConfig,
                    model: Optional[EnergyModel] = None) -> EnergyReport:
    """Fold ``model`` over a trace's events: one per-event pass builds the
    per-resource and per-op dynamic breakdowns together (so the two always
    sum to the same ``dynamic_pj``, including the byte-less rewrite
    fallback); leakage reads the trace's cached makespan."""
    em = model or STREAMDCIM_ENERGY_BASE
    agg = trace.aggregates
    by_resource: Dict[str, float] = {}
    by_op: Dict[str, float] = {}
    dynamic = 0.0
    for e in trace.events:
        pj = _event_pj(em, hw, e.resource, e.kind, e.cycles, e.bytes)
        if pj:
            by_resource[e.resource] = by_resource.get(e.resource, 0.0) + pj
            by_op[e.op] = by_op.get(e.op, 0.0) + pj
            dynamic += pj
    leakage = 0.0
    for resource, rate in em.leak_pj_per_cycle.items():
        pj = agg.makespan * rate * _leak_scale(hw, resource)
        by_resource[resource] = by_resource.get(resource, 0.0) + pj
        leakage += pj
    return EnergyReport(model=em.name, hw=hw.name,
                        makespan_cycles=agg.makespan,
                        by_resource=by_resource, by_op=by_op,
                        dynamic_pj=dynamic, leakage_pj=leakage)


def energy_of(result: "SimResult",
              model: Optional[EnergyModel] = None,
              hw: Optional[HardwareConfig] = None) -> EnergyReport:
    """Energy report for a ``SimResult``.  The design point defaults to
    the one the simulation ran on (``SimResult.hw_cfg``, falling back to
    the preset its name points at)."""
    hw = hw or getattr(result, "hw_cfg", None) or HW_PRESETS[result.hw]
    return energy_of_trace(result.trace, hw, model)


STREAMDCIM_ENERGY_BASE = EnergyModel()

# Low-leakage corner (e.g. aggressive power gating): latency-optimal
# points pay less for their idle area, flattening the Pareto frontier.
STREAMDCIM_ENERGY_LOWLEAK = EnergyModel(
    name="streamdcim-energy-lowleak",
    leak_pj_per_cycle={"GEN": 0.1, "ATTN": 0.1, "BUS": 2.0, "NOC": 4.0,
                       "HBM": 20.0, "VEC": 2.0})

# DRAM-heavy corner (older HBM / LPDDR-class ~2x pJ/byte): traffic
# differences between execution modes dominate even harder.
STREAMDCIM_ENERGY_DRAMHEAVY = EnergyModel(
    name="streamdcim-energy-dramheavy", pj_per_hbm_byte=90.0)

ENERGY_PRESETS: Dict[str, EnergyModel] = {
    m.name: m for m in (STREAMDCIM_ENERGY_BASE, STREAMDCIM_ENERGY_LOWLEAK,
                        STREAMDCIM_ENERGY_DRAMHEAVY)}
