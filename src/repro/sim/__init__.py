"""``repro.sim`` — cycle-approximate StreamDCIM hardware simulator.

A discrete-event model of the accelerator the paper describes, turning the
repo's analytic claims (``repro.core.streaming``) and kernel dataflows
(``repro.kernels.stream_attention``) into checkable simulation results:
the three-way NON_STREAM / LAYER_STREAM / TILE_STREAM comparison, the §I
rewrite-stall arithmetic, and per-mode HBM traffic.

Module map
----------
``macro.py``     TBR-CIM macro timing: normal vs hybrid reconfigurable
                 modes, weight/input sub-array partitioning, bit-serial
                 GEMM cycles, per-tile rewrite latency from the write-bus
                 width (§II-A; calibrated against §I's TranCIM numbers).
``dataflow.py``  The discrete-event engine (resources: GEN / ATTN / BUS /
                 NOC / HBM / VEC) and the mixed-stationary
                 cross-forwarding schedule: stationary-weight macros
                 generate K/V tiles that forward over the tile-based
                 streaming network straight into the attention macros,
                 with tile-level execution decoupling (§II-B).
``pipeline.py``  The ping-pong fine-grained compute-rewriting pipeline
                 (TILE_STREAM) plus the two baseline schedulers
                 (NON_STREAM, LAYER_STREAM) and the §I rewrite-stall
                 micro-simulation (§II-C / §I).
``trace.py``     Per-tile event traces; utilization, latency, DMA-byte
                 and rewrite-stall summaries (cached aggregates — DSE
                 sweeps summarize thousands of traces).
``energy.py``    Napkin energy model: ``EnergyModel`` pJ-cost tables
                 folded over traces into per-resource/per-op breakdowns,
                 total pJ and EDP (``SimResult.energy()``); presets in
                 ``repro.configs.registry.ENERGY_CONFIGS``.
``workload.py``  Lowers ``ModelConfig``s (ViLBERT-base/large co-TRM,
                 whisper enc-dec, qwen2-vl / dense decoders) — or
                 ``repro.plan.ExecutionPlan``s directly
                 (``workload_from_plan``) — into the per-layer op graphs
                 the schedulers execute.
``replay.py``    Plan/trace replay + calibration (DESIGN.md §10):
                 ``KernelRecorder``/``recording`` instrument the real
                 kernel paths into per-op ``KernelTrace`` records,
                 ``record_plan`` drives a whole plan through them,
                 attached traces replay through ``simulate_plan`` in
                 place of the analytic lowering, and ``fit_calibration``
                 yields a ``CalibrationReport`` (per-op-class error +
                 fitted per-resource cycle scales) the DSE sweep can
                 opt into.

Since PR 2 the canonical entry point is plan-driven (DESIGN.md §8):
``simulate_plan(repro.plan.plan_model(cfg, ...))`` executes each op under
*that op's* planner-resolved mode, so heterogeneous per-layer modes run
in one simulated model; ``simulate_model`` / ``compare_modes`` build the
plans internally for the legacy config-first signatures.

Hardware design points live in ``repro.configs.hardware`` and are
registered in ``repro.configs.registry.HW_CONFIGS``.

Design-space exploration over (HardwareConfig x EnergyModel x model)
grids lives in ``repro.dse``, which drives ``plan_model -> simulate_plan``
per point and reads ``SimResult.energy()`` here.

Serving timelines (DESIGN.md §11): ``simulate_serve`` lowers a
multi-request continuous-batching schedule — per-prompt prefill
``ExecutionPlan``s plus per-step ``DecodePlan``s
(``repro.plan.plan_decode_step``, DTPU pruning shrinking seq_kv per
layer) — through the same schedulers, cross-asserting per-step decode
HBM bytes against the planner's prediction.
"""
from repro.configs.hardware import (HW_PRESETS, HardwareConfig,
                                    STREAMDCIM_BASE, STREAMDCIM_SMALL,
                                    STREAMDCIM_WIDEBUS)
from repro.sim.energy import (ENERGY_PRESETS, EnergyModel, EnergyReport,
                              STREAMDCIM_ENERGY_BASE, energy_of,
                              energy_of_trace)
from repro.sim.macro import MacroArray, MacroMode
from repro.sim.pipeline import (SimResult, compare_modes,
                                rewrite_stall_trace, simulate,
                                simulate_model, simulate_plan,
                                simulate_rewrite_stall)
from repro.sim.replay import (CalibrationReport, KernelRecorder,
                              KernelTrace, active_recorder,
                              analytic_op_profile, fit_calibration,
                              record_plan, recording)
from repro.sim.serve_sim import ServeSimResult, ServeStepSim, simulate_serve
from repro.sim.trace import Event, Trace
from repro.sim.workload import (AttnOp, DecodeOp, GemmOp, Layer, Workload,
                                build_workload, decode_workload_from_plan,
                                workload_from_plan)

__all__ = [
    "HW_PRESETS", "HardwareConfig", "STREAMDCIM_BASE", "STREAMDCIM_SMALL",
    "STREAMDCIM_WIDEBUS", "ENERGY_PRESETS", "EnergyModel", "EnergyReport",
    "STREAMDCIM_ENERGY_BASE", "energy_of", "energy_of_trace", "MacroArray",
    "MacroMode", "SimResult", "compare_modes", "simulate", "simulate_model",
    "simulate_plan", "simulate_rewrite_stall", "rewrite_stall_trace",
    "CalibrationReport",
    "KernelRecorder", "KernelTrace", "active_recorder",
    "analytic_op_profile", "fit_calibration", "record_plan", "recording",
    "ServeSimResult", "ServeStepSim", "simulate_serve",
    "Event", "Trace", "AttnOp", "DecodeOp", "GemmOp", "Layer", "Workload",
    "build_workload", "decode_workload_from_plan", "workload_from_plan",
]
