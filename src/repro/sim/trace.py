"""Per-tile event traces + utilization/latency summaries for ``repro.sim``.

Every scheduled task becomes one ``Event`` with its resource, cycle
interval, byte count (for DMA/NoC/rewrite events) and a free-form tag
(``layer:op:tile``).  ``Trace`` aggregates the events into the numbers the
benchmarks and tests consume: makespan, per-resource busy cycles and
utilization, DMA bytes (optionally filtered by op class), and the rewrite
stall fraction that reproduces the paper's §I analysis.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Event:
    task_id: int
    kind: str          # "compute" | "rewrite" | "dma" | "forward"
    resource: str      # "GEN" | "ATTN" | "BUS" | "NOC" | "HBM" | ...
    start: int
    end: int
    bytes: int = 0
    tag: str = ""      # "cox0_co:xdma:q0k1" — op, kind, tile

    @property
    def cycles(self) -> int:
        return self.end - self.start


class Trace:
    """Append-only event log with summary reductions."""

    def __init__(self) -> None:
        self.events: List[Event] = []

    def add(self, ev: Event) -> None:
        self.events.append(ev)

    # ---------- reductions ----------

    @property
    def makespan(self) -> int:
        return max((e.end for e in self.events), default=0)

    def busy_cycles(self, resource: str) -> int:
        return sum(e.cycles for e in self.events if e.resource == resource)

    def utilization(self, resource: str) -> float:
        span = self.makespan
        return self.busy_cycles(resource) / span if span else 0.0

    def bytes_moved(self, resource: str = "HBM",
                    pred: Optional[Callable[[Event], bool]] = None) -> int:
        return sum(e.bytes for e in self.events
                   if e.resource == resource and (pred is None or pred(e)))

    def dma_bytes_by_op(self) -> Dict[str, int]:
        """HBM bytes keyed by the op field (first tag segment)."""
        out: Dict[str, int] = defaultdict(int)
        for e in self.events:
            if e.resource == "HBM":
                out[e.tag.split(":", 1)[0]] += e.bytes
        return dict(out)

    def rewrite_stall_fraction(self, compute_resource: str = "ATTN") -> float:
        """Paper §I metric: rewrite cycles / (rewrite + compute) cycles on
        the attention macro array.  Under serial scheduling this is the
        stall fraction; under ping-pong it is just the overlap ratio."""
        rw = sum(e.cycles for e in self.events if e.kind == "rewrite")
        comp = sum(e.cycles for e in self.events
                   if e.resource == compute_resource and e.kind == "compute")
        return rw / (rw + comp) if rw + comp else 0.0

    def summary(self) -> Dict[str, float]:
        resources = sorted({e.resource for e in self.events})
        s: Dict[str, float] = {"makespan_cycles": float(self.makespan)}
        for r in resources:
            s[f"busy_{r}"] = float(self.busy_cycles(r))
            s[f"util_{r}"] = self.utilization(r)
        s["hbm_bytes"] = float(self.bytes_moved("HBM"))
        s["rewrite_stall_frac"] = self.rewrite_stall_fraction()
        return s

    # ---------- rendering ----------

    def format_events(self, limit: int = 40) -> str:
        lines = [f"{'cycle':>10}  {'res':<5} {'kind':<8} {'bytes':>9}  tag"]
        for e in sorted(self.events, key=lambda e: (e.start, e.resource))[:limit]:
            lines.append(f"{e.start:>10}  {e.resource:<5} {e.kind:<8} "
                         f"{e.bytes:>9}  {e.tag}")
        if len(self.events) > limit:
            lines.append(f"... ({len(self.events) - limit} more events)")
        return "\n".join(lines)
