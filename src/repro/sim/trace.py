"""Per-tile event traces + utilization/latency summaries for ``repro.sim``.

Every scheduled task becomes one ``Event`` with its resource, cycle
interval, byte count (for DMA/NoC/rewrite events) and a free-form tag
(``layer:op:tile``).  ``Trace`` aggregates the events into the numbers the
benchmarks and tests consume: makespan, per-resource busy cycles and
utilization, DMA bytes (optionally filtered by op class), and the rewrite
stall fraction that reproduces the paper's §I analysis.

Reductions are served from a cached single-pass aggregate (rebuilt lazily,
invalidated by ``add``): a DSE sweep (``repro.dse``) summarizes thousands
of simulated traces, so per-call O(events) scans would go quadratic.
The energy fold (``repro.sim.energy``) reads the cached makespan and does
its own single event pass (per-op attribution needs per-event costs).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class Event:
    task_id: int
    kind: str          # "compute" | "rewrite" | "dma" | "forward"
    resource: str      # "GEN" | "ATTN" | "BUS" | "NOC" | "HBM" | ...
    start: int
    end: int
    bytes: int = 0
    tag: str = ""      # "cox0_co:xdma:q0k1" — op, kind, tile

    @property
    def cycles(self) -> int:
        return self.end - self.start

    @property
    def op(self) -> str:
        """First tag segment: the op this event belongs to.  A tag with
        no ``:`` separators (including the empty tag) is returned raw —
        never an exception, never a silent index assumption."""
        return self.tag.split(":", 1)[0]

    @property
    def kind_tag(self) -> str:
        """Second tag segment — the schedule-step name the scheduler
        tagged this event with (``xdma``, ``rw``, ``qkpv``, ...).  Empty
        for tags with fewer than two segments."""
        parts = self.tag.split(":")
        return parts[1] if len(parts) > 1 else ""

    @property
    def tile(self) -> str:
        """Everything after the kind segment — the tile coordinate
        (``q0k1``, ``s2:kvdma:k0``'s trailing ``k0``-style indices stay
        joined verbatim).  Empty for tags with fewer than three
        segments."""
        parts = self.tag.split(":")
        return ":".join(parts[2:]) if len(parts) > 2 else ""


@dataclasses.dataclass
class _Aggregates:
    """One-pass reduction over the event list (see ``Trace._agg``)."""

    makespan: int = 0
    busy: Dict[str, int] = dataclasses.field(default_factory=dict)
    bytes_by_resource: Dict[str, int] = dataclasses.field(default_factory=dict)
    rewrite_cycles: int = 0
    compute_cycles: Dict[str, int] = dataclasses.field(default_factory=dict)
    dma_by_op: Dict[str, int] = dataclasses.field(default_factory=dict)


class Trace:
    """Append-only event log with cached summary reductions."""

    def __init__(self) -> None:
        self.events: list[Event] = []
        self._agg: Optional[_Aggregates] = None
        self._agg_n = -1              # event count the cache was built at

    def add(self, ev: Event) -> None:
        self.events.append(ev)
        self._agg = None              # invalidate cached aggregates

    @property
    def aggregates(self) -> _Aggregates:
        # Rebuilt lazily; the count check also catches direct
        # ``trace.events.append`` (events are frozen, so append is the
        # only way the list changes).
        if self._agg is None or self._agg_n != len(self.events):
            self._agg = self._reduce()
            self._agg_n = len(self.events)
        return self._agg

    def _reduce(self) -> _Aggregates:
        a = _Aggregates()
        busy = defaultdict(int)
        nbytes = defaultdict(int)
        comp = defaultdict(int)
        dma = defaultdict(int)
        for e in self.events:
            if e.end > a.makespan:
                a.makespan = e.end
            cyc = e.end - e.start
            busy[e.resource] += cyc
            nbytes[e.resource] += e.bytes
            if e.kind == "rewrite":
                a.rewrite_cycles += cyc
            elif e.kind == "compute":
                comp[e.resource] += cyc
            if e.resource == "HBM":
                dma[e.op] += e.bytes
        a.busy = dict(busy)
        a.bytes_by_resource = dict(nbytes)
        a.compute_cycles = dict(comp)
        a.dma_by_op = dict(dma)
        return a

    # ---------- reductions (cache-served) ----------

    @property
    def makespan(self) -> int:
        return self.aggregates.makespan

    def busy_cycles(self, resource: str) -> int:
        return self.aggregates.busy.get(resource, 0)

    def utilization(self, resource: str) -> float:
        span = self.makespan
        return self.busy_cycles(resource) / span if span else 0.0

    def bytes_moved(self, resource: str = "HBM",
                    pred: Optional[Callable[[Event], bool]] = None) -> int:
        if pred is None:
            return self.aggregates.bytes_by_resource.get(resource, 0)
        return sum(e.bytes for e in self.events
                   if e.resource == resource and pred(e))

    def dma_bytes_by_op(self) -> Dict[str, int]:
        """HBM bytes keyed by the op field (first tag segment)."""
        return dict(self.aggregates.dma_by_op)

    def rewrite_stall_fraction(self, compute_resource: str = "ATTN") -> float:
        """Paper §I metric: rewrite cycles / (rewrite + compute) cycles on
        the attention macro array.  Under serial scheduling this is the
        stall fraction; under ping-pong it is just the overlap ratio."""
        a = self.aggregates
        rw = a.rewrite_cycles
        comp = a.compute_cycles.get(compute_resource, 0)
        return rw / (rw + comp) if rw + comp else 0.0

    def utilizations(self) -> Dict[str, float]:
        """Per-resource utilization for every resource seen in the trace."""
        span = self.makespan
        return {r: (b / span if span else 0.0)
                for r, b in sorted(self.aggregates.busy.items())}

    def summary(self) -> Dict[str, float]:
        a = self.aggregates
        s: Dict[str, float] = {"makespan_cycles": float(a.makespan)}
        for r in sorted(a.busy):
            s[f"busy_{r}"] = float(a.busy[r])
            s[f"util_{r}"] = self.utilization(r)
        s["hbm_bytes"] = float(self.bytes_moved("HBM"))
        s["rewrite_stall_frac"] = self.rewrite_stall_fraction()
        return s

    # ---------- rendering ----------

    def format_events(self, limit: int = 40) -> str:
        lines = [f"{'cycle':>10}  {'res':<5} {'kind':<8} {'bytes':>9}  tag"]
        for e in sorted(self.events, key=lambda e: (e.start, e.resource))[:limit]:
            lines.append(f"{e.start:>10}  {e.resource:<5} {e.kind:<8} "
                         f"{e.bytes:>9}  {e.tag}")
        if len(self.events) > limit:
            lines.append(f"... ({len(self.events) - limit} more events)")
        return "\n".join(lines)
