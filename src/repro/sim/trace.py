"""Per-tile event traces + utilization/latency summaries for ``repro.sim``.

Every scheduled task becomes one ``Event`` with its resource, cycle
interval, byte count (for DMA/NoC/rewrite events) and a free-form tag
(``layer:op:tile``).  ``Trace`` aggregates the events into the numbers the
benchmarks and tests consume: makespan, per-resource busy cycles and
utilization, DMA bytes (optionally filtered by op class), and the rewrite
stall fraction that reproduces the paper's §I analysis.

Reductions are served from a cached single-pass aggregate (rebuilt lazily,
invalidated by any mutation of the event list — ``add``, direct
``trace.events.append``, slice assignment, ``sort`` — via the
version-counting ``_EventList``): a DSE sweep (``repro.dse``) summarizes
thousands of simulated traces, so per-call O(events) scans would go
quadratic.  The energy fold (``repro.sim.energy``) reads the cached
makespan and does its own single event pass (per-op attribution needs
per-event costs).

Every event also carries ``deps`` — the task ids of the events whose
completion gated its start (data dependencies, with zero-cost SYNC
barriers resolved transitively, plus the in-order resource-occupancy
predecessor).  This makes any ``Trace`` a scheduling DAG: for every
event, ``start == 0`` or ``start == max(end of some dep)``, which is what
``repro.obs.critpath`` and ``repro.obs.whatif`` build on.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Dict, Optional, Tuple


# ``slots=True``: a DSE sweep materializes millions of events; dropping
# the per-instance ``__dict__`` cuts event memory roughly in half and
# speeds attribute access in the scheduler/replay hot loops.
@dataclasses.dataclass(frozen=True, slots=True)
class Event:
    task_id: int
    kind: str          # "compute" | "rewrite" | "dma" | "forward"
    resource: str      # "GEN" | "ATTN" | "BUS" | "NOC" | "HBM" | ...
    start: int
    end: int
    bytes: int = 0
    tag: str = ""      # "cox0_co:xdma:q0k1" — op, kind, tile
    deps: Tuple[int, ...] = ()   # predecessor task ids (data + resource)

    @property
    def cycles(self) -> int:
        return self.end - self.start

    @property
    def op(self) -> str:
        """First tag segment: the op this event belongs to.  A tag with
        no ``:`` separators (including the empty tag) is returned raw —
        never an exception, never a silent index assumption."""
        return self.tag.split(":", 1)[0]

    @property
    def kind_tag(self) -> str:
        """Second tag segment — the schedule-step name the scheduler
        tagged this event with (``xdma``, ``rw``, ``qkpv``, ...).  Empty
        for tags with fewer than two segments."""
        parts = self.tag.split(":")
        return parts[1] if len(parts) > 1 else ""

    @property
    def tile(self) -> str:
        """Everything after the kind segment — the tile coordinate
        (``q0k1``, ``s2:kvdma:k0``'s trailing ``k0``-style indices stay
        joined verbatim).  Empty for tags with fewer than three
        segments."""
        parts = self.tag.split(":")
        return ":".join(parts[2:]) if len(parts) > 2 else ""


class _EventList(list):
    """A ``list`` that counts its mutations.

    ``Trace`` keys its cached aggregates on ``version`` so *any* mutation
    — ``append``/``extend`` (replay paths call ``trace.events.append``
    directly), but also same-length in-place replacement
    (``trace.events[i] = ...``), ``sort``, ``remove`` — invalidates the
    cache.  The previous length-only check missed every mutation that
    kept ``len()`` constant.
    """

    __slots__ = ("version",)

    def __init__(self, iterable=()):
        super().__init__(iterable)
        self.version = 0

    def _bump(self):
        self.version += 1

    def append(self, item):
        super().append(item)
        self._bump()

    def extend(self, iterable):
        super().extend(iterable)
        self._bump()

    def insert(self, index, item):
        super().insert(index, item)
        self._bump()

    def remove(self, item):
        super().remove(item)
        self._bump()

    def pop(self, index=-1):
        item = super().pop(index)
        self._bump()
        return item

    def clear(self):
        super().clear()
        self._bump()

    def sort(self, **kwargs):
        super().sort(**kwargs)
        self._bump()

    def reverse(self):
        super().reverse()
        self._bump()

    def __setitem__(self, index, value):
        super().__setitem__(index, value)
        self._bump()

    def __delitem__(self, index):
        super().__delitem__(index)
        self._bump()

    def __iadd__(self, other):
        result = super().__iadd__(other)
        self._bump()
        return result

    def __imul__(self, other):
        result = super().__imul__(other)
        self._bump()
        return result


@dataclasses.dataclass
class _Aggregates:
    """One-pass reduction over the event list (see ``Trace._agg``)."""

    makespan: int = 0
    busy: Dict[str, int] = dataclasses.field(default_factory=dict)
    bytes_by_resource: Dict[str, int] = dataclasses.field(default_factory=dict)
    rewrite_cycles: int = 0
    compute_cycles: Dict[str, int] = dataclasses.field(default_factory=dict)
    dma_by_op: Dict[str, int] = dataclasses.field(default_factory=dict)


class Trace:
    """Event log with cached summary reductions."""

    def __init__(self) -> None:
        self._events = _EventList()
        self._agg: Optional[_Aggregates] = None
        self._agg_version = -1        # list version the cache was built at

    @property
    def events(self) -> "_EventList":
        return self._events

    @events.setter
    def events(self, value) -> None:
        # Wholesale replacement (tests / ad-hoc trace surgery): rewrap so
        # future in-place mutations keep invalidating the cache.
        self._events = _EventList(value)
        self._agg = None

    def add(self, ev: Event) -> None:
        self._events.append(ev)

    @property
    def aggregates(self) -> _Aggregates:
        # Rebuilt lazily; the version check catches every mutation of the
        # event list, including same-length in-place replacement that the
        # old length-only check missed.
        if self._agg is None or self._agg_version != self._events.version:
            self._agg = self._reduce()
            self._agg_version = self._events.version
        return self._agg

    def _reduce(self) -> _Aggregates:
        a = _Aggregates()
        busy = defaultdict(int)
        nbytes = defaultdict(int)
        comp = defaultdict(int)
        dma = defaultdict(int)
        for e in self.events:
            if e.end > a.makespan:
                a.makespan = e.end
            cyc = e.end - e.start
            busy[e.resource] += cyc
            nbytes[e.resource] += e.bytes
            if e.kind == "rewrite":
                a.rewrite_cycles += cyc
            elif e.kind == "compute":
                comp[e.resource] += cyc
            if e.resource == "HBM":
                dma[e.op] += e.bytes
        a.busy = dict(busy)
        a.bytes_by_resource = dict(nbytes)
        a.compute_cycles = dict(comp)
        a.dma_by_op = dict(dma)
        return a

    # ---------- reductions (cache-served) ----------

    @property
    def makespan(self) -> int:
        return self.aggregates.makespan

    def busy_cycles(self, resource: str) -> int:
        return self.aggregates.busy.get(resource, 0)

    def utilization(self, resource: str) -> float:
        span = self.makespan
        return self.busy_cycles(resource) / span if span else 0.0

    def bytes_moved(self, resource: str = "HBM",
                    pred: Optional[Callable[[Event], bool]] = None) -> int:
        if pred is None:
            return self.aggregates.bytes_by_resource.get(resource, 0)
        return sum(e.bytes for e in self.events
                   if e.resource == resource and pred(e))

    def dma_bytes_by_op(self) -> Dict[str, int]:
        """HBM bytes keyed by the op field (first tag segment)."""
        return dict(self.aggregates.dma_by_op)

    def rewrite_stall_fraction(self, compute_resource: str = "ATTN") -> float:
        """Paper §I metric: rewrite cycles / (rewrite + compute) cycles on
        the attention macro array.  Under serial scheduling this is the
        stall fraction; under ping-pong it is just the overlap ratio."""
        a = self.aggregates
        rw = a.rewrite_cycles
        comp = a.compute_cycles.get(compute_resource, 0)
        return rw / (rw + comp) if rw + comp else 0.0

    def utilizations(self) -> Dict[str, float]:
        """Per-resource utilization for every resource seen in the trace."""
        span = self.makespan
        return {r: (b / span if span else 0.0)
                for r, b in sorted(self.aggregates.busy.items())}

    def summary(self) -> Dict[str, float]:
        a = self.aggregates
        s: Dict[str, float] = {"makespan_cycles": float(a.makespan)}
        for r in sorted(a.busy):
            s[f"busy_{r}"] = float(a.busy[r])
            s[f"util_{r}"] = self.utilization(r)
        s["hbm_bytes"] = float(self.bytes_moved("HBM"))
        s["rewrite_stall_frac"] = self.rewrite_stall_fraction()
        return s

    # ---------- rendering ----------

    def format_events(self, limit: int = 40) -> str:
        lines = [f"{'cycle':>10}  {'res':<5} {'kind':<8} {'bytes':>9}  tag"]
        for e in sorted(self.events, key=lambda e: (e.start, e.resource))[:limit]:
            lines.append(f"{e.start:>10}  {e.resource:<5} {e.kind:<8} "
                         f"{e.bytes:>9}  {e.tag}")
        if len(self.events) > limit:
            lines.append(f"... ({len(self.events) - limit} more events)")
        return "\n".join(lines)
