"""Activation-sharding hints — the §Perf hillclimbing lever.

Model code calls ``constrain(x, key)`` at strategic points; by default this
is a no-op (XLA sharding propagation decides).  The dry-run / launcher
installs a hint table via ``runtime.flags(sharding_hints={key: Named
Sharding | PartitionSpec})`` to pin activation shardings where propagation
goes wrong:

* ``embed_out``  — the token-embedding gather output (B, S, D).  With a
  vocab-sharded table, XLA propagates the table sharding into the gather
  and then 'involuntarily fully rematerializes' (its own warning) — pinning
  batch-sharding here removes an all-gather of the whole activation.
* ``attn_q`` / ``attn_out`` — (B, H, S, hd) attention activations.  For
  archs whose head count does not divide the model axis (starcoder2 36H,
  minitron 24H, qwen2-vl 12H, hymba 25H, whisper 8H) the attention weights
  replicate, and without a hint the whole attention computation replicates
  16x across 'model'.  Pinning the *query sequence* over 'model' makes
  attention context-parallel: each model shard computes Sq/16 query rows
  against the (small, GQA-compressed) full K/V.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.core import runtime


def constrain(x: jax.Array, key: str) -> jax.Array:
    hints = runtime.get("sharding_hints")
    if not hints or key not in hints:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, hints[key])
    except (ValueError, TypeError):
        # shape not divisible by the hinted axis -> leave unconstrained
        return x
