"""Cross-pod gradient compression (int8 + error feedback).

At 2+ pods the data-parallel all-reduce crosses the DCN (an order of
magnitude slower than ICI — launch/dryrun.py models it at ICI/10).  The
standard mitigation: reduce in-pod at full precision, then exchange int8
per-tensor-scaled gradients across pods, with an error-feedback accumulator
so quantization noise is unbiased over steps (1-bit-Adam lineage).

Implemented with ``shard_map`` over the 'pod' axis; lowers to
collective-permute (pairwise exchange for 2 pods) on int8 payloads —
8x less DCN traffic than bf16/f32 all-reduce.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import jax_compat


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def cross_pod_mean_int8(grads: Any, mesh, *, axis: str = "pod") -> Any:
    """Average gradient pytree across the pod axis with int8 payloads.

    Gradients are assumed already reduced within-pod (XLA inserts the in-pod
    all-reduce from sharding propagation); this exchanges pod-halves only.
    """
    if axis not in mesh.shape or mesh.shape[axis] == 1:
        return grads

    npods = mesh.shape[axis]

    def exchange(g):
        def body(local):
            q, scale = _quantize(local.astype(jnp.float32))
            total = _dequantize(q, scale)      # own contribution, dequantized
            # ring exchange: (npods-1) hops of int8 payloads
            perm = [(i, (i + 1) % npods) for i in range(npods)]
            cur_q, cur_s = q, scale
            for _ in range(npods - 1):
                cur_q = jax.lax.ppermute(cur_q, axis, perm)
                cur_s = jax.lax.ppermute(cur_s, axis, perm)
                total = total + _dequantize(cur_q, cur_s)
            return (total / npods).astype(local.dtype)

        spec = P()  # grads replicated w.r.t. pod axis inside the shard_map
        return jax_compat.shard_map(body, mesh=mesh, in_specs=spec,
                                    out_specs=spec)(g)

    return jax.tree.map(exchange, grads)


class ErrorFeedback:
    """Error-feedback state: residual = (true - quantized) accumulates and
    is re-injected next step, making int8 compression unbiased over time."""

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    @staticmethod
    def apply(grads: Any, residual: Any) -> Tuple[Any, Any]:
        """Returns (corrected_grads, quantization_error_to_carry)."""
        corrected = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residual)
        quantized = jax.tree.map(
            lambda c: _dequantize(*_quantize(c)), corrected)
        new_residual = jax.tree.map(lambda c, q: c - q, corrected, quantized)
        return quantized, new_residual
