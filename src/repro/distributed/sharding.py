"""Logical-axis sharding rules (t5x/MaxText style), adapted per architecture.

Production mesh axes: ``("data", "model")`` single-pod, ``("pod", "data",
"model")`` multi-pod (launch/mesh.py).  Batch shards over (pod, data);
parameters shard over 'model' by these rules:

* embedding / unembed       -> vocab over 'model' (all vocabs padded /128)
* MLP w_up/w_gate           -> d_ff over 'model' (col-parallel); w_down
                               row-parallel ('model' on d_ff input dim)
* attention q/k/v/o         -> heads over 'model' IF num_heads % axis == 0
                               (Megatron); otherwise weights stay replicated
                               and attention runs *context-parallel* (query
                               seq over 'model' via activation hints —
                               non-divisible-head archs: starcoder2 36H,
                               minitron 24H, qwen2-vl 12H, hymba 25H,
                               whisper 8H)
* MoE experts               -> expert dim over 'model' if E % axis == 0
                               (EP: deepseek 256e), else per-expert d_ff
                               over 'model' (expert-TP: grok 8e)
* MLA latent projections    -> low-rank dims replicated, per-head dims over
                               'model' (128 heads % 16 == 0)
* FSDP: for models >= fsdp_threshold params, every replicated-weight dim
  of size % |data| == 0 additionally shards its largest dim over 'data'
  (ZeRO-3 semantics; XLA all-gathers at use)

Optimizer state inherits the param sharding (ZeRO-1 comes free: adam m/v
shard exactly like their param).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.types import AttnKind, Family, ModelConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def heads_shardable(cfg: ModelConfig, mesh: Mesh) -> bool:
    m = _axis_size(mesh, "model")
    return cfg.num_heads % m == 0 if cfg.num_heads else False


def kv_heads_shardable(cfg: ModelConfig, mesh: Mesh) -> bool:
    m = _axis_size(mesh, "model")
    return cfg.num_kv_heads % m == 0 if cfg.num_kv_heads else False


def experts_shardable(cfg: ModelConfig, mesh: Mesh) -> bool:
    m = _axis_size(mesh, "model")
    return cfg.num_experts % m == 0 if cfg.num_experts else False


def _fsdp_wrap(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh,
               use_fsdp: bool) -> Tuple:
    """Add 'data' sharding on the largest unsharded, divisible dim."""
    if not use_fsdp:
        return spec
    d = _axis_size(mesh, "data")
    best, best_size = None, 0
    for i, (s, ax) in enumerate(zip(shape, spec)):
        if ax is None and s % d == 0 and s > best_size:
            best, best_size = i, s
    if best is None:
        return spec
    out = list(spec)
    out[best] = "data"
    return tuple(out)


def spec_for_param(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
                   mesh: Mesh, use_fsdp: bool) -> P:
    """Rule table keyed on the param-tree path (slash-joined keys)."""
    m_ok = _axis_size(mesh, "model") > 1
    heads_ok = heads_shardable(cfg, mesh)
    kv_ok = kv_heads_shardable(cfg, mesh)
    ep_ok = experts_shardable(cfg, mesh)
    nd = len(shape)

    def fs(spec):
        spec = tuple(spec) + (None,) * (nd - len(spec))
        return P(*_fsdp_wrap(spec, shape, mesh, use_fsdp))

    leaf = path.split("/")[-1]

    if not m_ok:
        return fs((None,) * nd)

    # --- embeddings ---
    if leaf == "embedding":
        return fs(("model", None))
    if leaf == "unembed":
        return fs((None, "model"))
    if leaf in ("text_pos", "dec_pos"):
        return fs((None, None))

    # --- MoE expert weights (E, D, F) / (E, F, D); router (D, E) ---
    if "moe" in path or (cfg.family == Family.MOE and leaf in
                         ("w_gate", "w_up", "w_down") and nd == 3):
        if nd == 3:
            if ep_ok:
                return fs(("model", None, None))
            # expert-TP (E ∤ |model|, e.g. grok 8e): shard the hidden dim
            # over 'model'; FSDP supplies the 'data' factor.  (A 2-axis
            # hidden sharding was hypothesized to remove the FSDP weight
            # gathers but measured 2.7x MORE collective traffic — XLA
            # reshards the dispatch activations to match; EXPERIMENTS
            # §Perf cell D, refuted.)
            if leaf == "w_down":
                return fs((None, "model", None))
            return fs((None, None, "model"))
        if leaf == "router":
            return fs((None, None))

    # --- MLA ---
    if cfg.attn_kind == AttnKind.MLA and nd >= 2:
        if leaf in ("wq_b", "wk_b", "wv_b") and nd == 3:
            return fs((None, "model", None))       # per-head dim (128 % 16)
        if leaf == "wo" and nd == 3:
            return fs(("model", None, None))
        if leaf in ("wq_a", "wkv_a"):
            return fs((None, None))

    # --- dense attention (D, H, hd) / (H, hd, D) ---
    if leaf == "wq" and nd == 3:
        return fs((None, "model", None)) if heads_ok else fs((None,) * 3)
    if leaf in ("wk", "wv") and nd == 3:
        return fs((None, "model", None)) if kv_ok else fs((None,) * 3)
    if leaf == "wo" and nd == 3:
        return fs(("model", None, None)) if heads_ok else fs((None,) * 3)

    # --- MLP (D, F) col / (F, D) row ---
    if leaf in ("w_gate", "w_up") and nd == 2:
        return fs((None, "model"))
    if leaf == "w_down" and nd == 2:
        return fs(("model", None))

    # --- SSM ---
    if leaf == "in_proj":     # (D, 2*d_inner + 2N + H) — shard fused out dim
        return fs((None, "model")) if shape[1] % _axis_size(mesh, "model") == 0 \
            else fs((None, None))
    if leaf == "out_proj":
        return fs(("model", None)) if shape[0] % _axis_size(mesh, "model") == 0 \
            else fs((None, None))

    # norms / scalars / small tables: replicated
    return fs((None,) * nd)


def _flatten_with_paths(tree) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    def pstr(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return "/".join(parts)
    return [(pstr(kp), leaf) for kp, leaf in flat], treedef


class _SimulatedMesh:
    """Stand-in with production axis sizes for rule evaluation on a small
    (e.g. single-device test) mesh — only ``.shape`` is consulted by the
    rule table."""

    def __init__(self, axis_sizes):
        self.shape = dict(axis_sizes)


def param_shardings(param_tree, cfg: ModelConfig, mesh: Mesh, *,
                    fsdp_threshold: float = 8e9, axis_sizes=None):
    """param_tree: pytree of arrays or ShapeDtypeStructs -> NamedShardings.

    Layer-stacked params (leading L dim from vmap-init) get the rule applied
    to the trailing dims with the stack dim replicated.  ``axis_sizes``
    (name -> size) overrides the axis sizes the *rules* see, so tests can
    check production-size divisibility while building NamedShardings on a
    single-device mesh.
    """
    rule_mesh = mesh if axis_sizes is None else _SimulatedMesh(axis_sizes)
    use_fsdp = (cfg.param_count() >= fsdp_threshold
                and _axis_size(rule_mesh, "data") > 1)
    flat, treedef = _flatten_with_paths(param_tree)
    stacked_prefixes = ("layers", "dense_layers", "enc_layers", "dec_layers",
                        "text_pre", "co_x", "co_y")

    specs = []
    for path, leaf in flat:
        shape = tuple(leaf.shape)
        top = path.split("/")[0]
        if top in stacked_prefixes and len(shape) >= 1:
            inner = spec_for_param(path, shape[1:], cfg, rule_mesh, use_fsdp)
            spec = P(*((None,) + tuple(inner)))
        else:
            spec = spec_for_param(path, shape, cfg, rule_mesh, use_fsdp)
        specs.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_spec(mesh: Mesh) -> P:
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    return P(tuple(axes)) if axes else P()


def batch_shardings(batch_tree, mesh: Mesh, *, seq_sharded: bool = False):
    """Token batches shard dim0 (batch) over (pod, data).  For batch-1
    long-context cells, ``seq_sharded`` shards dim1 (sequence) instead (SP).
    positions (3, B, S) shard dim1."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def spec(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        if leaf.shape[0] == 3 and nd == 3:          # vlm positions
            return NamedSharding(mesh, P(None, baxes, None))
        # NB: ``P(...) + tuple`` degrades to a plain tuple, which
        # NamedSharding rejects — always build the full P in one call.
        if seq_sharded and nd >= 2:
            return NamedSharding(mesh, P(None, baxes, *((None,) * (nd - 2))))
        return NamedSharding(mesh, P(baxes, *((None,) * (nd - 1))))

    return jax.tree.map(spec, batch_tree)


def cache_shardings(cache_tree, cfg: ModelConfig, mesh: Mesh, *,
                    seq_sharded: bool = False):
    """KV caches: batch over (pod,data); heads over 'model' when divisible;
    otherwise cache *sequence* over 'model' (context-parallel decode).
    Layer-stacked: leading L dim replicated.
    SSM states: heads over 'model' when divisible."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in baxes:
        dp *= _axis_size(mesh, a)
    m = _axis_size(mesh, "model")
    kv_ok = kv_heads_shardable(cfg, mesh)
    flat, treedef = _flatten_with_paths(cache_tree)
    out = []
    for path, leaf in flat:
        shape = tuple(leaf.shape)
        nd = len(shape)
        leafname = path.split("/")[-1]
        if leafname == "len" or nd == 0:
            out.append(NamedSharding(mesh, P()))
            continue
        # strip the layer-stack dim
        core = shape[1:]
        batch = () if seq_sharded else baxes   # batch=1 cells replicate B
        if leafname in ("k", "v"):
            # (L, B, Hkv, S, hd) — SP: cache sequence over 'data' when the
            # batch axis is degenerate (long-context decode).
            sq = baxes if (seq_sharded and core[2] % dp == 0) else None
            if kv_ok:
                spec = P(None, batch, "model", sq, None)
            elif core[2] % m == 0 and not seq_sharded:
                spec = P(None, batch, None, "model", None)
            elif seq_sharded and core[2] % (dp * m) == 0:
                spec = P(None, batch, None, baxes + ("model",), None)
            else:
                spec = P(None, batch, None, sq, None)
        elif leafname in ("c", "k_rope"):      # MLA latent (L, B, S, kvr)
            sq = baxes if (seq_sharded and core[1] % dp == 0) else (
                "model" if core[1] % m == 0 and not seq_sharded else None)
            spec = P(None, batch, sq, None)
        elif leafname == "state":     # SSD (L, B, H, P, N)
            spec = P(None, batch, "model" if core[1] % m == 0 else None,
                     None, None)
        elif leafname == "conv":      # (L, B, K-1, C)
            spec = P(None, batch, None,
                     "model" if core[2] % m == 0 else None)
        elif leafname == "enc":       # (B, S_enc, D) — not layer-stacked
            spec = P(batch, None, None)
        else:
            spec = P(*((None,) * nd))
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)
