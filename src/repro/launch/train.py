"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b \
        --shape train_4k --steps 100 [--smoke] [--mode tile_stream] \
        [--checkpoint-dir ckpts/run1] [--microbatches 4]

``--smoke`` uses the arch's reduced config and a single-device mesh — the
same code path that a v5e pod runs, minus the fleet.  On a real cluster
each host runs this entrypoint under its own process index (jax
distributed init is picked up from env vars when present).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.core.types import ExecutionMode, SHAPES, ShapeConfig
from repro.data.pipeline import SyntheticLM, TextCorpus
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train import loop as L
from repro.train import optimizer as OPT


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(registry.ARCHS), required=True)
    ap.add_argument("--shape", choices=list(SHAPES), default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shapes on the host mesh")
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--mode", choices=[m.value for m in ExecutionMode],
                    default=None)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--corpus", default=None,
                    help="path to local text corpus (default: synthetic)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    shape = SHAPES[args.shape]
    if args.smoke:
        shape = ShapeConfig("smoke", args.seq_len or 128,
                            args.global_batch or 8, "train")
    elif args.global_batch or args.seq_len:
        shape = dataclasses.replace(
            shape, global_batch=args.global_batch or shape.global_batch,
            seq_len=args.seq_len or shape.seq_len)

    mesh = make_host_mesh() if args.smoke or jax.device_count() == 1 \
        else make_production_mesh(multi_pod=args.multi_pod)

    source = (TextCorpus(cfg, shape, args.corpus) if args.corpus
              else SyntheticLM(cfg, shape))
    mode = ExecutionMode(args.mode) if args.mode else None
    tcfg = L.TrainConfig(
        steps=args.steps, checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every, mode=mode,
        use_pallas=args.use_pallas, microbatches=args.microbatches,
        opt=OPT.OptimizerConfig(learning_rate=args.lr,
                                decay_steps=args.steps))

    def on_log(m):
        print(f"step {m['step']:6d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.3f}  lr {m['lr']:.2e}  "
              f"{m['steps_per_s']:.2f} it/s", flush=True)

    L.train(cfg, shape, source, mesh, tcfg, hooks={"on_log": on_log})


if __name__ == "__main__":
    main()
