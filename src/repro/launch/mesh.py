"""Production mesh builders.  Functions, not module-level constants — merely
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (16 data, 16 model).  Multi-pod: 2 pods
    (DCN axis) x the same in-pod layout = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh (CPU tests/examples) with the same axis names."""
    return jax.make_mesh((1, 1), ("data", "model"))
