import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture x input-shape x mesh) cell against the
production mesh with 512 placeholder host devices, prints
``memory_analysis()`` / ``cost_analysis()``, parses the post-SPMD HLO for
collective traffic, and writes a JSON artifact per cell that
benchmarks/roofline.py and EXPERIMENTS.md consume.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.core import runtime
from repro.core.types import Family, SHAPES, ShapeConfig
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.train import optimizer as OPT
from repro.train import steps as ST

# --- v5e hardware constants (roofline denominators) ---
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip, 1 axis)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _parse_groups(line: str, total_devices: int, multi_pod: bool
                  ) -> Tuple[int, bool]:
    """Returns (group_size, crosses_pod).  Pods are contiguous device-id
    halves (mesh axis order is (pod, data, model))."""
    if not multi_pod:
        pod_size = total_devices + 1      # nothing can cross
    else:
        pod_size = total_devices // 2
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        src_shape = tuple(int(x) for x in m.group(3).split(","))
        ids = np.arange(int(np.prod(src_shape))).reshape(src_shape)
        if m.group(4):
            perm = tuple(int(x) for x in m.group(4).split(","))
            ids = ids.transpose(perm)
        groups = ids.reshape(ng, gs)
        crosses = bool(((groups < pod_size).any(axis=1)
                        & (groups >= pod_size).any(axis=1)).any())
        return gs, crosses
    m = _LIST_GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].replace("{", "")
        ids = [int(x) for x in first.split(",") if x.strip()]
        crosses = (min(ids) < pod_size <= max(ids)) if ids else False
        return max(len(ids), 1), crosses
    return total_devices, False


def parse_collectives(hlo_text: str, total_devices: int,
                      multi_pod: bool = False) -> Dict[str, Any]:
    """Per-device collective traffic (ring-algorithm byte counts)."""
    ops: List[Dict[str, Any]] = []
    ici_bytes = 0.0
    dcn_bytes = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        size = _shape_bytes(m.group(1))
        kind = m.group(2)
        gs, crosses = _parse_groups(line, total_devices, multi_pod)
        frac = (gs - 1) / gs if gs > 1 else 0.0
        if kind == "all-reduce":
            traffic = 2 * size * frac
        elif kind == "all-gather":
            traffic = size * frac          # size = gathered result
        elif kind == "reduce-scatter":
            traffic = size * (gs - 1)      # size = scattered result
        elif kind == "all-to-all":
            traffic = size * frac
        else:                              # collective-permute
            traffic = size
        ops.append({"kind": kind, "bytes": size, "group": gs,
                    "traffic": traffic, "cross_pod": crosses})
        if crosses:
            dcn_bytes += traffic
        else:
            ici_bytes += traffic
    counts: Dict[str, int] = {}
    for o in ops:
        counts[o["kind"]] = counts.get(o["kind"], 0) + 1
    return {"ops": ops, "counts": counts, "ici_traffic": ici_bytes,
            "dcn_traffic": dcn_bytes}


def model_flops(cfg, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode: D = batch
    tokens (1 new token per sequence)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch    # decode: fwd only, 1 tok/seq


def _stack_depths(cfg) -> Dict[str, int]:
    """Named layer-stack sizes (the linear-extrapolation unknowns)."""
    if cfg.family == Family.ENCDEC:
        return {"enc": cfg.num_encoder_layers or cfg.num_layers,
                "dec": cfg.num_layers}
    if cfg.family == Family.CROSSMODAL:
        return {"pre": cfg.num_layers - cfg.num_coattn_layers,
                "co": cfg.num_coattn_layers}
    if cfg.family == Family.MOE and cfg.first_dense_layers:
        return {"dense": cfg.first_dense_layers,
                "moe": cfg.num_layers - cfg.first_dense_layers}
    return {"layers": cfg.num_layers}


def _with_depths(cfg, d: Dict[str, int]):
    if cfg.family == Family.ENCDEC:
        return dataclasses.replace(cfg, num_encoder_layers=d["enc"],
                                   num_layers=d["dec"])
    if cfg.family == Family.CROSSMODAL:
        return dataclasses.replace(cfg, num_layers=d["pre"] + d["co"],
                                   num_coattn_layers=d["co"])
    if cfg.family == Family.MOE and cfg.first_dense_layers:
        return dataclasses.replace(cfg, first_dense_layers=d["dense"],
                                   num_layers=d["dense"] + d["moe"])
    return dataclasses.replace(cfg, num_layers=d["layers"])


def probe_plan(cfg):
    """Probe depth-vectors: base {1,..}, then +1 on each stack."""
    names = list(_stack_depths(cfg))
    base = {n: 1 for n in names}
    plan = [dict(base)]
    for n in names:
        v = dict(base)
        v[n] = 2
        plan.append(v)
    return names, plan


def extrapolate(names, plan, probe_vals, real_depths) -> float:
    """cost = base + sum slope_i * n_i from probe measurements."""
    slopes = {n: probe_vals[i + 1] - probe_vals[0]
              for i, n in enumerate(names)}
    base = probe_vals[0] - sum(slopes[n] for n in names)
    return base + sum(slopes[n] * real_depths[n] for n in names)


def auto_microbatches(cfg, shape: ShapeConfig, mesh) -> int:
    """Smallest power-of-two microbatch count whose per-layer checkpointed
    activations fit the HBM budget (activation-memory lever, DESIGN.md §5)."""
    if shape.kind != "train":
        return 1
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    per_dev_seqs = max(shape.global_batch // dp, 1)
    d_eff = cfg.d_model + (cfg.d_model_y if cfg.family == Family.CROSSMODAL
                           else 0)
    if cfg.family == Family.CROSSMODAL:
        d_eff *= 4        # two streams x (co+self) attention per block
    if cfg.family == Family.SSM or cfg.family == Family.HYBRID:
        d_eff += cfg.ssm_expand * cfg.d_model
    seq = shape.seq_len if cfg.family != Family.ENCDEC else \
        (shape.seq_len + cfg.encoder_seq)
    layers = sum(_stack_depths(cfg).values())
    act = layers * per_dev_seqs * seq * d_eff * 2 * 1.5
    budget = 6e9
    mb = 1
    while act / mb > budget and mb < per_dev_seqs:
        mb *= 2
    return mb


def build_cell(arch: str, shape_name: str, mesh, *, microbatches: int = 1,
               seq_shard_long: bool = True, cfg=None):
    """Returns (jitted_fn, arg_specs tuple) for one cell."""
    cfg = cfg or registry.get_config(arch)
    shape = SHAPES[shape_name]
    total = int(np.prod(list(mesh.shape.values())))

    pspecs = registry.param_specs(cfg)
    pshard = SH.param_shardings(pspecs, cfg, mesh)

    if shape.kind == "train":
        ospecs = jax.eval_shape(OPT.init, pspecs)
        oshard = OPT.OptState(step=NamedSharding(mesh, P()),
                              mu=pshard, nu=pshard)
        bspecs = registry.input_specs(cfg, shape)
        bshard = SH.batch_shardings(bspecs, mesh)
        fn = ST.make_train_step(cfg, microbatches=microbatches)
        jitted = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                         donate_argnums=(0, 1))
        return jitted, (pspecs, ospecs, bspecs), cfg, shape

    if shape.kind == "prefill":
        bspecs = registry.input_specs(cfg, shape)
        bshard = SH.batch_shardings(bspecs, mesh)
        fn = ST.make_prefill_step(cfg, max_len=shape.seq_len)
        jitted = jax.jit(fn, in_shardings=(pshard, bshard))
        return jitted, (pspecs, bspecs), cfg, shape

    # decode
    seq_sharded = shape.global_batch == 1 and seq_shard_long
    cspecs = registry.cache_specs(cfg, shape)
    cshard = SH.cache_shardings(cspecs, cfg, mesh, seq_sharded=seq_sharded)
    tspecs = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1),
                                             jnp.int32)}
    tshard = SH.batch_shardings(tspecs, mesh) if shape.global_batch > 1 else \
        jax.tree.map(lambda s: NamedSharding(mesh, P()), tspecs)
    fn = ST.make_serve_step(cfg)
    jitted = jax.jit(fn, in_shardings=(pshard, cshard, tshard["tokens"]),
                     donate_argnums=(1,))
    return jitted, (pspecs, cspecs, tspecs["tokens"]), cfg, shape


def _compile_metrics(jitted, specs, total: int, multi_pod: bool):
    """Compile + analyze with the while-trip-aware HLO analyzer
    (launch/hlo_analysis.py) — XLA's own cost_analysis counts loop bodies
    once and is kept only as the uncorrected reference."""
    from repro.launch import hlo_analysis as HA
    lowered = jitted.lower(*specs)
    compiled = lowered.compile()
    r = HA.analyze(compiled.as_text(), total_devices=total,
                   multi_pod=multi_pod)
    return compiled, {
        "flops": r["flops"],
        "bytes": r["bytes"],
        "ici": r["ici"],
        "dcn": r["dcn"],
        "counts": r["counts"],
    }


def probe_corrected_costs(arch: str, shape_name: str, mesh, *,
                          multi_pod: bool) -> Dict[str, Any]:
    """XLA cost analysis counts while-loop bodies once, so scanned layer
    stacks are invisible to it.  We compile shallow *unrolled* probes
    (depth 1, and depth 2 per stack) and extrapolate cost = base +
    sum(slope_i * depth_i).  Probes run at full width/batch — only depth is
    reduced — so per-layer costs are exact."""
    cfg = registry.get_config(arch)
    total = int(np.prod(list(mesh.shape.values())))
    names, plan = probe_plan(cfg)
    vals = []
    with runtime.flags(unroll=True):
        for depths in plan:
            pc = _with_depths(cfg, depths)
            jitted, specs, _, _ = build_cell(arch, shape_name, mesh,
                                             microbatches=1, cfg=pc)
            _, m = _compile_metrics(jitted, specs, total, multi_pod)
            vals.append(m)
    real = _stack_depths(cfg)
    out = {}
    for key in ("flops", "bytes", "ici", "dcn"):
        out[key] = extrapolate(names, plan, [v[key] for v in vals], real)
    out["probe_counts"] = vals[0]["counts"]
    return out


def hint_shardings(names: List[str], mesh) -> Dict[str, Any]:
    """Build the activation-sharding hint table (distributed/hints.py)."""
    from jax.sharding import NamedSharding
    baxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    table = {}
    for n in names:
        if n == "embed_out":
            table[n] = NamedSharding(mesh, P(baxes, None, None))
        elif n in ("attn_q", "attn_out"):
            # context-parallel: query sequence over 'model'
            table[n] = NamedSharding(mesh, P(baxes, None, "model", None))
        elif n == "moe_dispatch":
            # (E, G, C, D): experts over 'model', groups over batch axes
            table[n] = NamedSharding(mesh, P("model", baxes, None, None))
    return table


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Optional[str] = None, microbatches: int = 0,
             verbose: bool = True, probes: bool = False,
             hints: Optional[List[str]] = None,
             tag: str = "", extra_flags: Optional[Dict[str, Any]] = None
             ) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    total = int(np.prod(list(mesh.shape.values())))
    mesh_name = "2x16x16" if multi_pod else "16x16"

    skip = registry.cell_supported(arch, shape_name)
    result: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                              "mesh": mesh_name, "devices": total}
    if tag:
        result["tag"] = tag
    if hints:
        result["hints"] = hints
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        _emit(result, out_dir, verbose, tag)
        return result

    t0 = time.time()
    try:
        cfg0 = registry.get_config(arch)
        shape0 = SHAPES[shape_name]
        mb = microbatches or auto_microbatches(cfg0, shape0, mesh)
        jitted, specs, cfg, shape = build_cell(arch, shape_name, mesh,
                                               microbatches=mb)
        with runtime.flags(sharding_hints=hint_shardings(hints or [], mesh),
                           **(extra_flags or {})):
            lowered = jitted.lower(*specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as e:  # noqa: BLE001 — dry-run failures are findings
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"[:2000]
        _emit(result, out_dir, verbose, tag)
        return result

    from repro.launch import hlo_analysis as HA
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    an = HA.analyze(hlo, total_devices=total, multi_pod=multi_pod)

    raw_flops = float(ca.get("flops", 0.0))
    hlo_flops, hlo_bytes = an["flops"], an["bytes"]
    ici_traffic, dcn_traffic = an["ici"], an["dcn"]
    coll = {"counts": an["counts"], "ops": []}
    corr = None
    if probes:  # optional cross-validation against unrolled shallow probes
        try:
            corr = probe_corrected_costs(arch, shape_name, mesh,
                                         multi_pod=multi_pod)
        except Exception as e:  # noqa: BLE001
            result["probe_error"] = f"{type(e).__name__}: {e}"[:500]
        if corr:
            result["probe_flops"] = corr["flops"]
    mf = model_flops(cfg, shape)

    # Roofline terms (seconds) — per-chip work over per-chip rates.
    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    coll_s = ici_traffic / ICI_BW
    dcn_s = dcn_traffic / (ICI_BW / 10)   # DCN ~ an order slower
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s, "dcn_s": dcn_s}
    bottleneck = max(terms, key=terms.get)

    result.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "microbatches": mb,
        "hlo_flops_per_device": hlo_flops,
        "hlo_bytes_per_device": hlo_bytes,
        "raw_flops_uncorrected": raw_flops,
        "probe_corrected": corr is not None,
        "model_flops_global": mf,
        "model_flops_per_device": mf / total,
        "useful_flop_ratio": (mf / total) / hlo_flops if hlo_flops else None,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "total_bytes": (ma.argument_size_in_bytes
                            + ma.output_size_in_bytes
                            + ma.temp_size_in_bytes
                            - ma.alias_size_in_bytes),
        },
        "collectives": {"counts": coll["counts"],
                        "ici_traffic_bytes": ici_traffic,
                        "dcn_traffic_bytes": dcn_traffic,
                        "num_ops": len(coll["ops"])},
        "roofline": {**terms, "bottleneck": bottleneck,
                     "step_time_est_s": max(terms.values()),
                     "roofline_fraction":
                         compute_s / max(max(terms.values()), 1e-30)},
    })
    _emit(result, out_dir, verbose, tag)
    return result


def _emit(result: Dict[str, Any], out_dir: Optional[str], verbose: bool,
          tag: str = ""):
    if verbose:
        status = result["status"]
        line = f"[{result['mesh']:8s}] {result['arch']:18s} {result['shape']:12s} {status}"
        if status == "ok":
            r = result["roofline"]
            mem = result["memory"]["total_bytes"] / 2**30
            line += (f"  flops/dev={result['hlo_flops_per_device']:.3g}"
                     f" mem/dev={mem:.2f}GiB"
                     f" bottleneck={r['bottleneck']}"
                     f" roofline_frac={r['roofline_fraction']:.3f}"
                     f" (lower {result['lower_s']}s compile"
                     f" {result['compile_s']}s)")
        elif status == "error":
            line += "  " + result["error"].splitlines()[0][:120]
        else:
            line += "  " + result["reason"]
        print(line, flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        slim = dict(result)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(
            out_dir,
            f"{result['arch']}__{result['shape']}__{result['mesh']}{suffix}.json")
        with open(path, "w") as f:
            json.dump(slim, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(registry.ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) on this mesh")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = auto (fit activation memory)")
    ap.add_argument("--probes", action="store_true",
                    help="cross-validate the HLO analyzer against unrolled "
                         "shallow probe compiles (slow)")
    ap.add_argument("--hints", default="",
                    help="comma-separated activation-sharding hints "
                         "(embed_out,attn_q,attn_out)")
    ap.add_argument("--tag", default="",
                    help="artifact filename suffix (perf-iteration runs)")
    ap.add_argument("--remat-policy", default="none",
                    choices=["none", "dots"])
    ap.add_argument("--moe-groups", type=int, default=1)
    ap.add_argument("--block-k", type=int, default=0,
                    help="flash KV block size override")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the hillclimbed beyond-paper preset: "
                         "embed_out hint, context-parallel attention for "
                         "non-divisible-head archs, grouped MoE dispatch, "
                         "block_k=2048")
    args = ap.parse_args()

    cells: List[Tuple[str, str]] = []
    if args.all:
        for arch in registry.ASSIGNED:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        extra = {}
        hints = [h for h in args.hints.split(",") if h]
        tag = args.tag
        if args.remat_policy != "none":
            extra["remat_policy"] = args.remat_policy
        if args.moe_groups > 1:
            extra["moe_groups"] = args.moe_groups
        if args.block_k:
            extra["block_k"] = args.block_k
        if args.optimized:
            cfg_a = registry.get_config(arch)
            mesh_probe = {"data": 16, "model": 16}

            class _M:
                shape = mesh_probe
            hints = list({*hints, "embed_out"})
            from repro.distributed import sharding as _SH
            if cfg_a.num_heads and not _SH.heads_shardable(cfg_a, _M):
                hints += ["attn_q", "attn_out"]
            if cfg_a.num_experts:
                dp = 32 if args.multi_pod else 16
                extra.setdefault("moe_groups", dp)
            extra.setdefault("block_k", 2048)
            tag = tag or "optimized"
        r = run_cell(arch, shape, multi_pod=args.multi_pod, out_dir=args.out,
                     microbatches=args.microbatches, probes=args.probes,
                     hints=hints, tag=tag, extra_flags=extra)
        if r["status"] == "error":
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
