"""Serving launcher: batched generation with the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-7b --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.serve.engine import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(registry.ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    mod = registry.model_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, args.max_len // 4))
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                       size=(plen,)).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {total} new tokens, {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
