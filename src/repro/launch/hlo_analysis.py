"""Static analyzer for post-SPMD optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless
of trip count, making scanned layer stacks invisible.  This analyzer walks
the HLO module with loop-trip multipliers:

* parses every computation and instruction (name -> shape symbol table)
* extracts while-loop trip counts from their condition computations
  (scan-generated conditions compare the induction var against a constant)
* propagates a multiplier down the call graph
  (entry=1; while body/cond x= trip; fusion/call x= 1)
* FLOPs: 2 * prod(result_dims) * contraction_size for every ``dot``
* bytes: operand+result bytes of top-level (non-fused-interior)
  instructions — fusion interiors excluded, matching HBM-traffic semantics
* collectives: ring-algorithm traffic per op kind x multiplier

Validated against unrolled shallow probes (tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "token": 0, "opaque": 0}

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_ATTR_CALLS = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_IOTA_GROUPS = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_LIST_GROUPS = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str          # everything after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    bytes_ = 0
    for dt, dims in _SHAPE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES.get(dt, 4)
    return elems, bytes_


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.startswith(" ") and ("->" in line) and ("{" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
        elif line.strip() == "}":
            cur = None
    return comps


def _entry_name(text: str, comps: Dict[str, Computation]) -> Optional[str]:
    m = re.search(r"^ENTRY %?([\w.\-]+)", text, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    return next(iter(comps)) if comps else None


_KNOWN_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _trip_count(cond: Computation) -> int:
    """Scan-generated loop conditions compare the induction variable to a
    constant trip count; take the max int constant in the condition."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = _CONST_INT.search("constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
        m = _CONST_INT.search(ins.rest)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _multipliers(text: str, comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = _entry_name(text, comps)
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry is None:
        return mult
    mult[entry] = 1.0
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(len(comps)):
        changed = False
        for name, comp in comps.items():
            m0 = mult.get(name, 0.0)
            if m0 == 0.0:
                continue
            for ins in comp.instrs:
                if ins.op == "while":
                    body = cond = None
                    mm = re.search(r"body=%?([\w.\-]+)", ins.rest)
                    mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                    if mm:
                        body = mm.group(1)
                    if mc:
                        cond = mc.group(1)
                    # Prefer XLA's own annotation when present.
                    mt = _KNOWN_TRIP.search(ins.rest)
                    if mt:
                        trips = int(mt.group(1))
                    else:
                        trips = _trip_count(comps[cond]) if cond in comps \
                            else 1
                    if body in comps:
                        new = m0 * trips
                        if mult.get(body, 0.0) < new:
                            mult[body] = new
                            changed = True
                elif ins.op in ("fusion", "call", "conditional", "map",
                                "reduce", "reduce-window", "scatter", "sort",
                                "custom-call", "select-and-scatter"):
                    for mm in _ATTR_CALLS.finditer(ins.rest):
                        callee = mm.group(1)
                        if callee in comps and mult.get(callee, 0.0) < m0:
                            mult[callee] = m0
                            changed = True
        if not changed:
            break
    return mult


def _symbol_table(comps: Dict[str, Computation]) -> Dict[str, str]:
    table = {}
    for comp in comps.values():
        for ins in comp.instrs:
            table[ins.name] = ins.shape
    return table


def _dot_flops(ins: Instr, symbols: Dict[str, str]) -> float:
    res_elems, _ = _shape_elems_bytes(ins.shape)
    ops = _OPERAND.findall(ins.rest.split(")", 1)[0])
    if not ops:
        return 0.0
    lhs_shape = symbols.get(ops[0])
    if lhs_shape is None:
        return 0.0
    dims = []
    for dt, ds in _SHAPE.findall(lhs_shape):
        dims = [int(x) for x in ds.split(",") if x]
        break
    mc = _CONTRACT.search(ins.rest)
    contract = 1
    if mc and mc.group(1):
        for i in (int(x) for x in mc.group(1).split(",")):
            if i < len(dims):
                contract *= dims[i]
    return 2.0 * res_elems * contract


def _group_info(rest: str, total: int, multi_pod: bool) -> Tuple[int, bool]:
    pod = total // 2 if multi_pod else total + 1
    m = _IOTA_GROUPS.search(rest)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        src = tuple(int(x) for x in m.group(3).split(","))
        ids = np.arange(int(np.prod(src))).reshape(src)
        if m.group(4):
            ids = ids.transpose(tuple(int(x) for x in m.group(4).split(",")))
        groups = ids.reshape(ng, gs)
        crosses = bool(((groups < pod).any(1) & (groups >= pod).any(1)).any())
        return gs, crosses
    m = _LIST_GROUPS.search(rest)
    if m:
        first = m.group(1).split("}")[0].replace("{", "")
        ids = [int(x) for x in first.split(",") if x.strip()]
        crosses = (min(ids) < pod <= max(ids)) if ids else False
        return max(len(ids), 1), crosses
    return total, False


# Ops whose operand/result bytes we count toward HBM traffic at the
# non-fused level.  Pure control/aliasing ops are free.
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "while", "conditional", "call", "custom-call", "domain",
             "opt-barrier", "optimization-barrier"}


def analyze(text: str, *, total_devices: int, multi_pod: bool) -> Dict:
    comps = parse_module(text)
    symbols = _symbol_table(comps)
    mult = _multipliers(text, comps)

    flops = 0.0
    bytes_ = 0.0
    ici = 0.0
    dcn = 0.0
    counts: Dict[str, float] = {}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        # fusion-interior computations get bytes-excluded but their dots
        # still count flops: detect interiors by name convention
        interior = name.startswith("fused_") or ".fused" in name
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, symbols)
            elif ins.op in ("convolution",):
                # rare here; approximate as 2 * result * window elems
                res_elems, _ = _shape_elems_bytes(ins.shape)
                flops += m * 2.0 * res_elems
            if interior:
                continue
            if ins.op in _FREE_OPS:
                continue
            _, rb = _shape_elems_bytes(ins.shape)
            ob = 0
            for opn in _OPERAND.findall(ins.rest.split(")", 1)[0]):
                sh = symbols.get(opn)
                if sh is not None:
                    ob += _shape_elems_bytes(sh)[1]
            bytes_ += m * (rb + ob)
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in COLLECTIVE_OPS:
                size = _shape_elems_bytes(ins.shape)[1]
                gs, crosses = _group_info(ins.rest, total_devices, multi_pod)
                frac = (gs - 1) / gs if gs > 1 else 0.0
                if base == "all-reduce":
                    traffic = 2 * size * frac
                elif base == "all-gather":
                    traffic = size * frac
                elif base == "reduce-scatter":
                    traffic = size * (gs - 1)
                elif base == "all-to-all":
                    traffic = size * frac
                else:
                    traffic = size
                counts[base] = counts.get(base, 0) + m
                if crosses:
                    dcn += m * traffic
                else:
                    ici += m * traffic
    return {"flops": flops, "bytes": bytes_, "ici": ici, "dcn": dcn,
            "counts": {k: int(v) for k, v in counts.items()},
            "num_computations": len(comps)}
