"""Design-space exploration section (``run.py dse``) — the ROADMAP's
multi-macro-group sweep, energy-scored.

Sweeps the default ``repro.dse`` grid (registry presets + num/gen-group
splits x rewrite-bus widths x ping-pong) over every simulator-supported
model, then reports per model: the latency/energy Pareto frontier size and
endpoints, the utilization knee, and the ping-pong EDP win at the base
design point.  The full machine-readable sweep (every row carrying its
serialized ``ExecutionPlan``) is registered via ``common.log_dse`` so
``run.py dse --json`` emits a diffable artifact; ``--points N`` caps the
design-point budget for CI smoke.
"""
from __future__ import annotations

import os
import sys
from typing import List, Optional

if __name__ == "__main__":      # allow ``python benchmarks/bench_dse.py``
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

from benchmarks.common import (csv_row, log_bench, log_dse, log_search,
                               log_timeline)


def run(points: Optional[int] = None, workers: Optional[int] = None,
        search: bool = False, cache: Optional[str] = None) -> List[str]:
    import time

    from repro.configs.registry import ENERGY_CONFIGS
    from repro.dse import run_sweep
    ems = list(ENERGY_CONFIGS.values())
    rows: List[str] = []
    t0 = time.perf_counter()
    search_result = None
    if search:
        # Successive-halving frontier search (DESIGN.md §16): cheap
        # low-seq rungs rank the grid, survivors graduate to the same
        # full-fidelity rows the exhaustive sweep would emit.
        from repro.dse import successive_halving
        search_result = successive_halving(
            num_candidates=points, energy_models=ems,
            cache=cache, workers=workers)
        result = search_result.sweep
        log_search(search_result)
    else:
        # The ROADMAP's joint sweep: every energy preset folds over every
        # simulated design point (the simulation runs once per point —
        # the energy axis is a re-fold, so 3x the rows, not 3x the
        # runtime).
        result = run_sweep(points=points, energy_models=ems,
                           workers=workers, cache=cache)
    elapsed = time.perf_counter() - t0
    log_dse(result)

    base_em = result.energy_model
    n_points = len(result.rows) // max(len(result.energy_models()), 1)
    rows.append(csv_row(
        "dse_grid", elapsed * 1e6,
        f"{len(result.rows)} rows ({len(result.models())} models x "
        f"{len(result.energy_models())} energy tables); "
        f"{len(result.skipped)} invalid combos skipped; "
        f"base energy model {base_em}"))
    if search_result is not None:
        rungs = " -> ".join(str(len(r.candidates))
                            for r in search_result.rungs)
        rows.append(csv_row(
            "dse_search", 0.0,
            f"successive halving over {search_result.space_size} "
            f"candidates (eta {search_result.eta}): {rungs}; "
            f"{search_result.proxy_sims} proxy + "
            f"{search_result.full_sims} full sims"))
    if result.cache_stats:
        cs = result.cache_stats
        rows.append(csv_row(
            "dse_cache", 0.0,
            f"{cs.get('hits', 0)} hits / {cs.get('misses', 0)} misses "
            f"({cs.get('disk_hits', 0)} from disk)"))
    # Harness throughput (gated with the wide wall-clock band — see
    # benchmarks.history): full-fidelity points swept per minute.
    log_bench("dse", {
        "dse_points_per_min": (n_points / (elapsed / 60.0)
                               if elapsed else 0.0),
        "num_rows": float(len(result.rows)),
        "frontier_size": float(len(result.pareto(energy_model=base_em))),
    }, info={"points": n_points, "elapsed_s": elapsed,
             "workers": workers or 1, "search": bool(search),
             "cache_stats": dict(result.cache_stats)})
    knees = result.knees()
    for model, seq_len in result.groups():
        label = result.label(model, seq_len, energy_model=base_em)
        mrows = result.rows_for(model, seq_len, energy_model=base_em)
        frontier = result.pareto(model, seq_len, energy_model=base_em)
        fastest = min(mrows, key=lambda r: r.latency_cycles)
        frugal = min(mrows, key=lambda r: r.energy_pj)
        rows.append(csv_row(
            f"dse_{label}_pareto", 0.0,
            f"{len(frontier)}/{len(mrows)} non-dominated; fastest "
            f"{fastest.hw} ({fastest.latency_cycles} cyc); lowest-energy "
            f"{frugal.hw} ({frugal.energy_pj / 1e6:.1f} uJ)"))
        knee = knees.get(label)
        if knee is not None:
            rows.append(csv_row(
                f"dse_{label}_knee", 0.0,
                f"{knee.hw}: {knee.num_macros} macros within "
                f"{result.knee_tolerance:.0%} of best latency "
                f"(utilGEN {knee.utilization.get('GEN', 0.0):.2f} "
                f"utilATTN {knee.utilization.get('ATTN', 0.0):.2f} "
                f"bottleneck {knee.bottleneck or 'n/a'})"))

            def _knee_timeline(pj=knee.plan_json,
                               title=f"dse knee {label} ({knee.hw})"):
                # Replay the knee row from its own plan artifact — the
                # timeline shows exactly what the sweep scored.
                from repro.plan import ExecutionPlan
                from repro.sim import simulate_plan
                from repro.obs.timeline import timeline_from_sim
                return timeline_from_sim(
                    simulate_plan(ExecutionPlan.from_json(pj)), title=title)

            log_timeline(f"dse_{label}_knee", _knee_timeline)
        # Ping-pong EDP at the base geometry, if both variants swept.
        by_hw = {r.hw: r for r in mrows}
        pp = by_hw.get("streamdcim-base")
        nopp = by_hw.get("streamdcim-base/pp0")
        if pp and nopp:
            rows.append(csv_row(
                f"dse_{label}_pingpong_edp", 0.0,
                f"ping-pong EDP {nopp.edp / pp.edp:.2f}x better at "
                f"base geometry"))
    # Frontier sensitivity to the pJ-cost table (ROADMAP item): how much
    # of the Pareto frontier survives swapping the energy model.
    for label, rec in result.frontier_sensitivity().items():
        worst = min((j for em, j in rec["jaccard_vs_base"].items()
                     if em != rec["base"]), default=1.0)
        rows.append(csv_row(
            f"dse_{label}_energy_sensitivity", 0.0,
            f"frontier jaccard >= {worst:.2f} across "
            f"{len(rec['jaccard_vs_base'])} cost tables; "
            f"{len(rec['stable_hw'])} designs stable on every table "
            f"({', '.join(rec['stable_hw'][:3])}"
            f"{'...' if len(rec['stable_hw']) > 3 else ''})"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
