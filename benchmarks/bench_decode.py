"""Decode-regime analysis: where tile-streaming wins on *latency*.

Decode attention reads the whole KV cache per token (arithmetic intensity
~1 query row) — always HBM-bound on v5e.  The paper's 'K/V are runtime
products, don't materialize them' insight becomes: cache the *pre-K/V*
representation when it is smaller and decompress in-stream.  MLA
(deepseek-v3) is the limit case: the latent (kvr+dr = 576 B/token bf16)
replaces materialized K+V (128 heads x (192+128) dims = 81,920 B/token) —
a 71x cache-traffic reduction, which is a direct decode-latency bound
improvement at the HBM roofline.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import HBM_BW, csv_row
from repro.configs import registry


def run() -> List[str]:
    rows = []
    cfg = registry.get_config("deepseek-v3-671b")
    S = 32768                          # decode_32k context
    # materialized multi-head K/V bytes per token (bf16)
    kv_naive = cfg.num_heads * ((cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
                                + cfg.v_head_dim) * 2
    # MLA latent cache bytes per token
    kv_mla = (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * 2
    ratio = kv_naive / kv_mla
    rows.append(csv_row("decode_cache_bytes_per_token_naive", 0.0,
                        f"{kv_naive} B/token (materialized 128-head K+V)"))
    rows.append(csv_row("decode_cache_bytes_per_token_mla", 0.0,
                        f"{kv_mla} B/token (latent; tile-stream decompress)"))
    rows.append(csv_row("decode_cache_reduction", 0.0,
                        f"{ratio:.1f}x less HBM traffic per decode step"))

    # per-token attention-read time at the HBM roofline, one layer
    t_naive = S * kv_naive / HBM_BW
    t_mla = S * kv_mla / HBM_BW
    rows.append(csv_row("decode_attn_read_us_naive", t_naive * 1e6,
                        f"32k-context cache read / layer / token"))
    rows.append(csv_row("decode_attn_read_us_mla", t_mla * 1e6,
                        f"{t_naive / t_mla:.1f}x faster at HBM roofline — "
                        f"the tile-streaming latency win lives in decode"))

    # SWA ring buffers (danube/hymba): long_500k decode in window memory
    dan = registry.get_config("h2o-danube3-4b")
    full = 524288 * 2 * dan.num_kv_heads * dan.head_dim * 2
    ring = dan.sliding_window * 2 * dan.num_kv_heads * dan.head_dim * 2
    rows.append(csv_row("long500k_swa_ring_cache", 0.0,
                        f"{full / 2**30:.1f} GiB -> {ring / 2**20:.0f} MiB "
                        f"per layer ({full / ring:.0f}x)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
