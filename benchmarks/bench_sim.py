"""StreamDCIM simulator benchmark — the paper's §III three-way comparison
(Fig. 6) and the §I rewrite-stall analysis, produced by ``repro.sim``
instead of the closed-form model.

For every supported model the simulator executes the full per-layer op
graph under all three schedulers and reports cycles, HBM traffic and the
speedups of StreamDCIM (TILE_STREAM) over the non-streaming and
layer-based-streaming baselines.  The "adaptive" geomean rows apply the
engine's arch-adaptive mode choice (``repro.core.streaming.choose_mode``):
for aggressively-GQA models tile-streaming is traffic-negative and the
engine falls back to LAYER_STREAM, which the simulation independently
confirms (qwen2-vl: tile-stream simulates *slower* than layer-stream).

Note: speedups over NON_STREAM exceed the paper's 2.63x geomean because
the baseline here (like ``streamed_bytes_per_layer``) charges the full
score-matrix HBM round-trips; the paper's non-streaming baseline keeps
softmax on-chip.
"""
from __future__ import annotations

import math
import os
import sys
from typing import List

if __name__ == "__main__":      # allow ``python benchmarks/bench_sim.py``
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

from benchmarks.common import csv_row
from repro.configs import registry
from repro.core.streaming import choose_mode
from repro.core.types import ExecutionMode
from repro.sim import compare_modes, simulate_rewrite_stall


def run() -> List[str]:
    rows: List[str] = []
    hw = registry.get_hw_config("streamdcim-base")

    # --- §I rewrite-stall arithmetic, simulated ---
    serial = simulate_rewrite_stall(hw)
    pp = simulate_rewrite_stall(hw, ping_pong=True, iters=8)
    rows.append(csv_row(
        "sim_rewrite_stall_serial", 0.0,
        f"rewrite {serial['rewrite_frac']:.1%} of QK^T phase "
        f"(paper SI: 57%); {serial['cycles_per_phase']:.0f} cyc/phase"))
    rows.append(csv_row(
        "sim_rewrite_stall_pingpong", 0.0,
        f"exposed stall {pp['exposed_stall_frac']:.1%}; "
        f"{pp['cycles_per_phase']:.0f} cyc/phase "
        f"({serial['cycles_per_phase'] / pp['cycles_per_phase']:.2f}x)"))
    wide = simulate_rewrite_stall(registry.get_hw_config("streamdcim-widebus"),
                                  ping_pong=True, iters=8)
    rows.append(csv_row(
        "sim_rewrite_stall_widebus", 0.0,
        f"2048-bit bus + ping-pong: exposed stall "
        f"{wide['exposed_stall_frac']:.1%}"))

    # --- §III three-way model comparison ---
    non_speedups, layer_speedups = [], []
    for arch in registry.SIM_ARCHS:
        cfg = registry.get_config(arch)
        res = compare_modes(cfg, hw)
        tile = res[ExecutionMode.TILE_STREAM]
        layer = res[ExecutionMode.LAYER_STREAM]
        non = res[ExecutionMode.NON_STREAM]
        # Arch-adaptive StreamDCIM: the engine's mode choice per model.
        chosen = choose_mode(cfg)
        adaptive = res[chosen]
        non_speedups.append(non.cycles / adaptive.cycles)
        layer_speedups.append(layer.cycles / adaptive.cycles)
        rows.append(csv_row(
            f"sim_{arch}", 0.0,
            f"tile {tile.cycles}cyc (hbm {tile.hbm_bytes >> 20}MiB); "
            f"vs non {non.cycles / tile.cycles:.2f}x; "
            f"vs layer {layer.cycles / tile.cycles:.2f}x; "
            f"mode={chosen.value}"))

    def geomean(xs):
        return math.exp(sum(math.log(x) for x in xs) / len(xs))

    rows.append(csv_row(
        "sim_geomean_vs_non_stream", 0.0,
        f"{geomean(non_speedups):.2f}x (paper: 2.63x; see module note)"))
    rows.append(csv_row(
        "sim_geomean_vs_layer_stream", 0.0,
        f"{geomean(layer_speedups):.2f}x (paper: 1.28x)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
