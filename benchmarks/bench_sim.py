"""StreamDCIM simulator benchmark — the paper's §III three-way comparison
(Fig. 6) and the §I rewrite-stall analysis, produced by ``repro.sim``
instead of the closed-form model.

Plan-driven since PR 2: for every supported model the section builds
``ExecutionPlan``s once — three forced-mode baselines plus the planner's
arch-adaptive plan — and simulates each plan.  The adaptive geomean rows
therefore report exactly what ``repro.plan.plan_model`` decides (for
aggressively-GQA models tile-streaming is traffic-negative and the planner
falls back to LAYER_STREAM, which the simulation independently confirms:
qwen2-vl tile-streams *slower* than layer-stream).  Each model's simulated
per-op DMA bytes are asserted against the same plan object's predicted
``LayerPlan.hbm_bytes`` — the analytic and simulated traffic models cannot
drift apart silently.

Note: speedups over NON_STREAM exceed the paper's 2.63x geomean because
the baseline here (like the planner's traffic model) charges the full
score-matrix HBM round-trips; the paper's non-streaming baseline keeps
softmax on-chip.
"""
from __future__ import annotations

import math
import os
import sys
from typing import List

if __name__ == "__main__":      # allow ``python benchmarks/bench_sim.py``
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

from benchmarks.common import csv_row, log_bench, log_plan, log_timeline
from repro.configs import registry
from repro.core.types import ExecutionMode
from repro.plan import plan_model
from repro.sim import (rewrite_stall_trace, simulate_plan,
                       simulate_rewrite_stall)


def run() -> List[str]:
    rows: List[str] = []
    hw = registry.get_hw_config("streamdcim-base")

    # --- §I rewrite-stall arithmetic, simulated ---
    serial = simulate_rewrite_stall(hw)
    pp = simulate_rewrite_stall(hw, ping_pong=True, iters=8)
    rows.append(csv_row(
        "sim_rewrite_stall_serial", 0.0,
        f"rewrite {serial['rewrite_frac']:.1%} of QK^T phase "
        f"(paper SI: 57%); {serial['cycles_per_phase']:.0f} cyc/phase"))
    rows.append(csv_row(
        "sim_rewrite_stall_pingpong", 0.0,
        f"exposed stall {pp['exposed_stall_frac']:.1%}; "
        f"{pp['cycles_per_phase']:.0f} cyc/phase "
        f"({serial['cycles_per_phase'] / pp['cycles_per_phase']:.2f}x)"))
    wide = simulate_rewrite_stall(registry.get_hw_config("streamdcim-widebus"),
                                  ping_pong=True, iters=8)
    rows.append(csv_row(
        "sim_rewrite_stall_widebus", 0.0,
        f"2048-bit bus + ping-pong: exposed stall "
        f"{wide['exposed_stall_frac']:.1%}"))
    from repro.obs.timeline import timeline_from_trace
    log_timeline("rewrite_stall_serial", lambda: timeline_from_trace(
        rewrite_stall_trace(hw), title="§I rewrite stall (serial)"))
    log_timeline("rewrite_stall_pingpong", lambda: timeline_from_trace(
        rewrite_stall_trace(hw, ping_pong=True, iters=8),
        title="§I rewrite stall (ping-pong)"))

    # --- §III three-way model comparison: one plan per (model, mode) ---
    non_speedups, layer_speedups = [], []
    total_checks = 0
    bench_metrics = {"rewrite_stall_serial_frac": serial["rewrite_frac"]}
    bench_trace = None
    for arch in registry.SIM_ARCHS:
        cfg = registry.get_config(arch)
        plans = {m: plan_model(cfg, hw=hw, mode=m, force_mode=True)
                 for m in ExecutionMode}
        adaptive_plan = plan_model(cfg, hw=hw)         # planner's decision
        log_plan(adaptive_plan)
        res = {m: simulate_plan(p) for m, p in plans.items()}
        # A uniform adaptive plan is one of the forced runs — reuse it.
        adaptive = (res[adaptive_plan.uniform_mode]
                    if adaptive_plan.uniform_mode
                    else simulate_plan(adaptive_plan))
        tile = res[ExecutionMode.TILE_STREAM]
        layer = res[ExecutionMode.LAYER_STREAM]
        non = res[ExecutionMode.NON_STREAM]
        from repro.obs.timeline import timeline_from_sim
        log_timeline(f"sim_{arch}_tile",
                     lambda r=tile, a=arch: timeline_from_sim(
                         r, title=f"{a} TILE_STREAM"))

        # Cross-check: simulated per-op DMA bytes == the plan's prediction
        # for EVERY attention op (same object drives both paths; 10%
        # covers DMA rounding).
        for mode, plan in plans.items():
            for lp in plan.layers:
                sim_bytes = res[mode].op_dma_bytes(lp.name)
                if abs(sim_bytes - lp.hbm_bytes) > 0.10 * lp.hbm_bytes:
                    raise AssertionError(
                        f"{arch}/{mode.value}: simulated {sim_bytes} vs "
                        f"planned {lp.hbm_bytes} bytes for {lp.name}")
        total_checks += sum(len(p.layers) for p in plans.values())

        bench_metrics[f"{arch}_tile_cycles"] = tile.cycles
        bench_metrics[f"{arch}_tile_hbm_bytes"] = tile.hbm_bytes
        bench_metrics[f"{arch}_adaptive_cycles"] = adaptive.cycles
        if bench_trace is None:
            bench_trace = tile.trace
        non_speedups.append(non.cycles / adaptive.cycles)
        layer_speedups.append(layer.cycles / adaptive.cycles)
        mode_str = (adaptive_plan.uniform_mode.value
                    if adaptive_plan.uniform_mode else "heterogeneous")
        rows.append(csv_row(
            f"sim_{arch}", 0.0,
            f"tile {tile.cycles}cyc (hbm {tile.hbm_bytes >> 20}MiB); "
            f"vs non {non.cycles / tile.cycles:.2f}x; "
            f"vs layer {layer.cycles / tile.cycles:.2f}x; "
            f"mode={mode_str}"))

    def geomean(xs):
        return math.exp(sum(math.log(x) for x in xs) / len(xs))

    rows.append(csv_row(
        "sim_geomean_vs_non_stream", 0.0,
        f"{geomean(non_speedups):.2f}x (paper: 2.63x; see module note)"))
    rows.append(csv_row(
        "sim_geomean_vs_layer_stream", 0.0,
        f"{geomean(layer_speedups):.2f}x (paper: 1.28x)"))
    rows.append(csv_row(
        "sim_plan_crosscheck", 0.0,
        f"{total_checks} per-op plan-vs-sim DMA-byte checks passed"))

    # --- DES throughput microbench (DESIGN.md §16) ---
    # Events scheduled per wall-second on a fresh full simulation of the
    # first arch's tile plan: the one gated wall-clock metric (wide
    # tolerance band in benchmarks.history) guarding the Engine.run /
    # Trace hot path against order-of-magnitude collapses.
    import time
    micro_plan = plan_model(registry.get_config(registry.SIM_ARCHS[0]),
                            hw=hw, mode=ExecutionMode.TILE_STREAM,
                            force_mode=True)
    t0 = time.perf_counter()
    micro = simulate_plan(micro_plan)
    des_elapsed = time.perf_counter() - t0
    n_events = len(micro.trace.events)
    events_per_sec = n_events / des_elapsed if des_elapsed else 0.0
    rows.append(csv_row(
        "sim_des_throughput", des_elapsed * 1e6,
        f"{n_events} events in {des_elapsed * 1e3:.0f}ms = "
        f"{events_per_sec:,.0f} events/sec"))

    # Perf-tracking snapshot (DESIGN.md §14): deterministic simulation
    # metrics + the causal critical path of the first arch's tile trace.
    bench_metrics["sim_events_per_sec"] = events_per_sec
    bench_metrics["geomean_vs_non_speedup"] = geomean(non_speedups)
    bench_metrics["geomean_vs_layer_speedup"] = geomean(layer_speedups)
    log_bench("bench_sim", bench_metrics, trace=bench_trace,
              info={"archs": list(registry.SIM_ARCHS), "hw": hw.name})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
