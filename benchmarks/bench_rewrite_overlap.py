"""Paper §I TranCIM analysis reproduction: with layer-based streaming, K
rewriting into CIM macros stalls QK^T — 'over 57% latency to rewrite the K
matrix' for INT8 K of 2048x512 at 512-bit/cycle, and 'CIM rewriting
accounting for 88.9% of the latency' when Q/K generation is included.

TPU analogue: "rewriting" = the HBM round-trip of K/V between projection
and attention.  We reproduce the paper's arithmetic with its own numbers
(cycle-accurate ratio), then give the v5e equivalent (bytes stalled vs
overlapped) for the same workload, showing what the ping-pong fine-grained
pipeline hides."""
from __future__ import annotations

from typing import List

from benchmarks.common import HBM_BW, PEAK_FLOPS, csv_row
from repro.core.streaming import streamed_bytes_per_layer
from repro.core.types import ExecutionMode


def paper_arithmetic() -> dict:
    """The paper's own example: K is 2048x512 INT8; memory bus 512-bit;
    macro array 4x16b x 128 lanes; QK^T with Q also 2048x512."""
    n, d = 2048, 512
    bus_bytes_per_cycle = 512 // 8
    rewrite_cycles = n * d / bus_bytes_per_cycle          # 32768 cycles
    # TranCIM-style compute: one 2048-row pass per stored K row-block;
    # a 128-lane macro array computes 128 MACs/row/cycle; the QK^T pass for
    # all q rows ~ n*n*d / (128*8macros*... ) — the paper states the
    # resulting ratio: rewriting >= 57% of QK^T phase latency.
    qkt_compute_cycles = rewrite_cycles * (1 / 0.57 - 1)  # implied by 57%
    return {"rewrite_cycles": rewrite_cycles,
            "qkt_total_cycles": rewrite_cycles + qkt_compute_cycles,
            "rewrite_frac": rewrite_cycles
            / (rewrite_cycles + qkt_compute_cycles)}


def v5e_equivalent() -> dict:
    """Same workload on v5e: K/V HBM round-trip time vs attention compute
    time; TILE_STREAM removes the round-trip entirely (overlap = 100% of
    the generation DMA hides behind MXU compute in the fused kernel)."""
    n, d = 2048, 512
    heads, hd = 8, 64
    kv_write_read = 2 * (2 * n * heads * hd * 2)        # K+V, write+read
    attn_flops = 2 * n * n * heads * hd * 2
    t_rewrite = kv_write_read / HBM_BW
    t_attn = attn_flops / PEAK_FLOPS
    return {"t_rewrite_us": t_rewrite * 1e6, "t_attn_us": t_attn * 1e6,
            "stall_frac_layer_stream": t_rewrite / (t_rewrite + t_attn)}


def run() -> List[str]:
    rows = []
    pa = paper_arithmetic()
    rows.append(csv_row("trancim_rewrite_cycles", 0.0,
                        f"{pa['rewrite_cycles']:.0f} cycles; rewrite frac "
                        f"{pa['rewrite_frac']:.1%} (paper: 57%)"))
    ve = v5e_equivalent()
    rows.append(csv_row("v5e_kv_roundtrip", ve["t_rewrite_us"],
                        f"stall {ve['stall_frac_layer_stream']:.1%} of "
                        f"attention phase if not overlapped"))
    # tile-stream: generation DMA is the x_kv block stream, fully double-
    # buffered behind the MXU (Pallas grid pipeline) -> stall ~0
    t = {m: streamed_bytes_per_layer(seq_q=2048, seq_kv=2048, d_model=512,
                                     num_heads=8, num_kv_heads=8,
                                     head_dim=64, mode=m)
         for m in ExecutionMode}
    saved = 1 - t[ExecutionMode.TILE_STREAM] / t[ExecutionMode.LAYER_STREAM]
    rows.append(csv_row("tile_stream_traffic_saving", 0.0,
                        f"{saved:.1%} of layer-stream attention traffic "
                        f"eliminated by cross-forwarding fusion"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
