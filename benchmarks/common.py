"""Shared benchmark utilities + v5e napkin constants."""
from __future__ import annotations

import time
from typing import Callable

import jax

# v5e roofline constants (same as launch/dryrun.py)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# Energy napkin model (order-of-magnitude; replaces the paper's PrimeTime
# numbers — DESIGN.md §7): HBM ~5.6 pJ/bit, on-chip ~2 pJ/byte, bf16 MAC.
E_HBM_PER_BYTE = 45e-12
E_VMEM_PER_BYTE = 2e-12
E_PER_FLOP = 0.8e-12


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-seconds per call (CPU measurement)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ---------------------------------------------------------------------------
# ExecutionPlan logging: sections register the plans they ran under so
# ``run.py --json`` can attach plan summaries to the machine-readable
# output (sweep tooling — DESIGN.md §8).
# ---------------------------------------------------------------------------

PLAN_LOG: list = []


def log_plan(plan) -> None:
    """Register an ``repro.plan.ExecutionPlan`` for the --json report."""
    PLAN_LOG.append(plan)


def reset_plan_log() -> None:
    PLAN_LOG.clear()
