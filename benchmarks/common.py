"""Shared benchmark utilities + v5e napkin constants."""
from __future__ import annotations

import os
import time
from typing import Callable

import jax

from repro.sim.energy import STREAMDCIM_ENERGY_BASE

# v5e roofline constants (same as launch/dryrun.py)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# Energy napkin constants (order-of-magnitude; replace the paper's
# PrimeTime numbers — DESIGN.md §7/§9).  Since the `repro.sim.energy`
# model was calibrated against these, the calibrated model is now the
# single source of truth; these joule-per-unit names are thin aliases kept
# so roofline.py / dryrun.py comparisons keep running unchanged.
E_HBM_PER_BYTE = STREAMDCIM_ENERGY_BASE.pj_per_hbm_byte * 1e-12
E_VMEM_PER_BYTE = STREAMDCIM_ENERGY_BASE.pj_per_noc_byte * 1e-12
E_PER_FLOP = STREAMDCIM_ENERGY_BASE.pj_per_flop * 1e-12


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-seconds per call (CPU measurement)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ---------------------------------------------------------------------------
# ExecutionPlan logging: sections register the plans they ran under so
# ``run.py --json`` can attach plan summaries to the machine-readable
# output (sweep tooling — DESIGN.md §8).
# ---------------------------------------------------------------------------

PLAN_LOG: list = []

# The dse section registers its full SweepResult here so ``run.py --json``
# can attach the machine-readable sweep artifact (rows + plans + pareto).
DSE_LOG: list = []

# The replay section registers (traced ExecutionPlan, CalibrationReport)
# pairs so ``run.py --json`` can emit the calibration artifact the CI
# replay-smoke step uploads (DESIGN.md §10).
REPLAY_LOG: list = []

# The serve section registers (Engine, ServeSimResult) pairs so
# ``run.py --json`` can emit the serving artifact (per-step records with
# predicted-vs-simulated decode bytes) the CI serve-smoke step uploads
# (DESIGN.md §11).
SERVE_LOG: list = []

# The shard section registers its ``ShardSweepResult`` here so
# ``run.py --json`` can emit the scale-out artifact (speedup-vs-chips
# curves + serialized ShardedPlans) the CI shard-smoke step uploads
# (DESIGN.md §13).
SHARD_LOG: list = []

# The dse section registers its ``repro.dse.search.SearchResult`` here
# (when run with --search) so ``run.py --json`` can emit the search
# artifact — the survivors' sweep plus the per-rung elimination ledger —
# that the CI search-smoke step uploads (DESIGN.md §16).
SEARCH_LOG: list = []

# Sections register (name, thunk) pairs producing Perfetto timeline
# documents (``repro.obs.timeline``); ``run.py --perfetto DIR`` renders
# them.  Thunks, not documents: sections stay cheap when nobody asked
# for timelines (DESIGN.md §12).
TIMELINE_LOG: list = []

# Sections register BenchSnapshot inputs here: gating metrics (compared
# against committed baselines by ``run.py --check-baseline``) plus an
# optional critical-path summary and non-gating info.  One entry per
# section name (DESIGN.md §14).
BENCH_LOG: dict = {}

#: Version stamp on every ``run.py --json`` artifact; bump on breaking
#: report-shape changes so downstream tooling can reject stale files.
#: v2: reports gained the ``shard`` scale-out block (DESIGN.md §13).
#: v3: reports gained the ``bench`` snapshot block + SweepRow.headroom
#: (DESIGN.md §14).
#: v4: dse rows intern their plan JSON (``plan_ref`` into the sweep's
#: ``plan_table`` side table, rehydrated by
#: ``repro.dse.resolve_plan_json``), sweeps carry ``cache_stats``, and
#: reports may carry a ``search`` block (successive-halving ledger) and
#: a ``dse`` bench section (DESIGN.md §16).
REPORT_SCHEMA_VERSION = 4


def log_plan(plan) -> None:
    """Register an ``repro.plan.ExecutionPlan`` for the --json report."""
    PLAN_LOG.append(plan)


def log_dse(result) -> None:
    """Register a ``repro.dse.SweepResult`` for the --json report."""
    DSE_LOG.append(result)


def log_search(result) -> None:
    """Register a ``repro.dse.search.SearchResult`` for the --json
    report (the dse section under ``--search``)."""
    SEARCH_LOG.append(result)


def log_replay(traced_plan, report) -> None:
    """Register a traced plan + its ``CalibrationReport`` for --json."""
    REPLAY_LOG.append((traced_plan, report))


def log_serve(engine, sim_result) -> None:
    """Register a served ``Engine`` + its ``ServeSimResult`` for --json."""
    SERVE_LOG.append((engine, sim_result))


def log_shard(result) -> None:
    """Register a ``repro.shard.ShardSweepResult`` for the --json report."""
    SHARD_LOG.append(result)


def log_bench(section: str, metrics: dict, *, trace=None,
              info: dict | None = None) -> None:
    """Register a section's perf-tracking metrics for the bench-history
    snapshot path (``run.py --baseline`` / ``--check-baseline``).

    ``metrics`` should be deterministic simulation-domain scalars
    (cycles, bytes, tokens-per-kilocycle, speedups) so baselines compare
    across machines; the one sanctioned wall-clock family is harness
    throughput named ``*_per_sec`` / ``*_per_min``, which
    ``benchmarks.history`` gates with a much wider tolerance band.
    ``trace`` (optional) attaches a causal critical-path summary
    (``repro.obs.critpath``); ``info`` carries non-gating context (never
    compared)."""
    entry = {"metrics": dict(metrics), "info": dict(info or {})}
    if trace is not None:
        from repro.obs.critpath import critical_path
        entry["critical_path"] = critical_path(trace).to_dict()
    BENCH_LOG[section] = entry


def log_timeline(name: str, thunk: Callable[[], dict]) -> None:
    """Register a lazily-built Perfetto timeline for ``--perfetto DIR``.
    ``thunk`` must return a ``trace_event`` document
    (``repro.obs.timeline.timeline_from_*``); ``name`` becomes the file
    stem (``DIR/<name>.perfetto.json``)."""
    TIMELINE_LOG.append((name, thunk))


def reset_plan_log() -> None:
    PLAN_LOG.clear()
    DSE_LOG.clear()
    SEARCH_LOG.clear()
    REPLAY_LOG.clear()
    SERVE_LOG.clear()
    SHARD_LOG.clear()
    TIMELINE_LOG.clear()
    BENCH_LOG.clear()


def run_metadata() -> dict:
    """Provenance stamped into every ``--json`` artifact: schema version,
    git-describable source revision, and toolchain versions — enough for
    downstream tooling to reject stale or mismatched artifacts."""
    import platform
    import subprocess
    meta = {
        "schema_version": REPORT_SCHEMA_VERSION,
        "python": platform.python_version(),
        "jax": jax.__version__,
    }
    try:
        meta["git"] = subprocess.run(
            ["git", "describe", "--always", "--dirty", "--tags"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 — git absent in some containers
        meta["git"] = "unknown"
    return meta
