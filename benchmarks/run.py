"""Benchmark harness main — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (deliverable d); ``--json <path>``
additionally writes a machine-readable report (per-section rows +
``ExecutionPlan`` summaries + the DSE sweep + replay calibration
artifacts registered via ``benchmarks.common``).  The full row/report
schema is documented in README.md §"The --json report schema".

Usage::

    python benchmarks/run.py [section ...] [--json out.json]
    python benchmarks/run.py --list

With no section arguments all sections run; otherwise only the named ones
(e.g. ``run.py bench_sim --json bench_sim.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

# Allow ``python benchmarks/run.py`` (not just ``python -m benchmarks.run``
# with PYTHONPATH=src): both the repo root and src/ must be importable.
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO_ROOT, os.path.join(_REPO_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _sections(points=None, workers=None, search=False, cache=None):
    import functools

    from benchmarks import (bench_decode, bench_dse, bench_kernels,
                            bench_pruning, bench_replay,
                            bench_rewrite_overlap, bench_serve, bench_shard,
                            bench_sim, bench_stream_modes, roofline)
    return [
        ("bench_stream_modes", "Fig6/Fig7 stream-mode comparison",
         bench_stream_modes.run),
        ("bench_pruning", "Token pruning (paper SI claim)",
         bench_pruning.run),
        ("bench_rewrite_overlap", "TranCIM rewrite-latency analysis",
         bench_rewrite_overlap.run),
        ("bench_sim", "StreamDCIM simulator (three-way + SI stall)",
         bench_sim.run),
        ("dse", "Design-space exploration (energy/latency Pareto + knee)",
         functools.partial(bench_dse.run, points=points, workers=workers,
                           search=search, cache=cache)),
        ("replay", "Plan/trace replay + calibration (record real kernels)",
         bench_replay.run),
        ("serve", "Continuous-batching serving (engine vs simulate_serve)",
         bench_serve.run),
        ("shard", "Chiplet-mesh scale-out (speedup-vs-chips, NoC model)",
         bench_shard.run),
        ("bench_decode", "Decode regime (tile-stream latency win)",
         bench_decode.run),
        ("bench_kernels", "Kernel micro-benchmarks", bench_kernels.run),
        ("roofline", "Roofline summary (from dry-run artifacts)",
         roofline.run),
    ]


def _parse_row(row: str) -> dict:
    """Split a ``name,us_per_call,derived`` CSV row (derived may itself
    contain commas) into a JSON-ready record."""
    parts = row.split(",", 2)
    rec = {"name": parts[0]}
    if len(parts) > 1:
        try:
            rec["us_per_call"] = float(parts[1])
        except ValueError:
            rec["us_per_call"] = parts[1]
    if len(parts) > 2:
        rec["derived"] = parts[2]
    return rec


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks/run.py",
        description="StreamDCIM repro benchmark harness")
    ap.add_argument("sections", nargs="*",
                    help="section names to run (default: all)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write a machine-readable JSON report "
                         "(rows + ExecutionPlan summaries + DSE sweep)")
    ap.add_argument("--points", type=int, metavar="N", default=None,
                    help="design-point budget for the dse section "
                         "(presets first; CI smoke)")
    ap.add_argument("--workers", type=int, metavar="N", default=None,
                    help="process-pool width for the dse sweep "
                         "(rows byte-identical to serial; DESIGN.md §16)")
    ap.add_argument("--search", action="store_true",
                    help="run the dse section as a successive-halving "
                         "frontier search instead of the exhaustive "
                         "grid (DESIGN.md §16)")
    ap.add_argument("--cache", metavar="DIR", default=None,
                    help="on-disk simulation cache for the dse section "
                         "— repeat runs warm-start (DESIGN.md §16)")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="cProfile each section into DIR: raw pstats "
                         "(<section>.pstats) + a top-20 cumulative text "
                         "summary (<section>.txt)")
    ap.add_argument("--perfetto", metavar="DIR", default=None,
                    help="dump Perfetto trace_event timelines registered "
                         "by the sections that ran (sim/serve/dse/replay) "
                         "into DIR — open at https://ui.perfetto.dev")
    ap.add_argument("--baseline", metavar="DIR", default=None,
                    help="write schema-versioned BENCH_<section>.json "
                         "snapshots for the sections that ran into DIR "
                         "(commit them to start/refresh the perf "
                         "trajectory)")
    ap.add_argument("--check-baseline", metavar="DIR", default=None,
                    dest="check_baseline",
                    help="compare this run's bench snapshots against the "
                         "committed baselines in DIR (tolerance bands, "
                         "direction-aware); exit 1 on any regression — "
                         "the `make bench-check` CI gate")
    ap.add_argument("--list", action="store_true", dest="list_sections",
                    help="print available sections and exit")
    args = ap.parse_args(argv)

    sections = _sections(points=args.points, workers=args.workers,
                         search=args.search, cache=args.cache)
    if args.list_sections:
        for key, title, _ in sections:
            print(f"{key:24s} {title}")
        return

    if args.sections:
        known = {key for key, _, _ in sections}
        unknown = [w for w in args.sections if w not in known]
        if unknown:
            print(f"unknown section(s) {unknown}; available: {sorted(known)}",
                  file=sys.stderr)
            sys.exit(2)
        sections = [s for s in sections if s[0] in args.sections]

    from benchmarks import common
    common.reset_plan_log()

    report = {"schema_version": common.REPORT_SCHEMA_VERSION,
              "command": "benchmarks/run.py " + " ".join(args.sections),
              "metadata": common.run_metadata(),
              "sections": [], "plans": []}
    if args.profile:
        os.makedirs(args.profile, exist_ok=True)

    print("name,us_per_call,derived")
    failed = 0
    for key, title, fn in sections:
        print(f"# --- {title} ---")
        sec = {"name": key, "title": title, "ok": True, "rows": []}
        try:
            if args.profile:
                # Receipts for hot-path claims: raw pstats for pstats/
                # snakeviz plus a human-readable top-20 cumulative dump.
                import cProfile
                import io
                import pstats
                prof = cProfile.Profile()
                out = prof.runcall(fn)
                pstats_path = os.path.join(args.profile, f"{key}.pstats")
                prof.dump_stats(pstats_path)
                buf = io.StringIO()
                stats = pstats.Stats(prof, stream=buf)
                stats.sort_stats("cumulative").print_stats(20)
                with open(os.path.join(args.profile, f"{key}.txt"),
                          "w") as f:
                    f.write(buf.getvalue())
                print(f"# profile -> {pstats_path}", file=sys.stderr)
            else:
                out = fn()
            for row in out:
                print(row)
                sec["rows"].append(_parse_row(row))
        except Exception:  # noqa: BLE001
            failed += 1
            sec["ok"] = False
            sec["error"] = traceback.format_exc()
            print(f"# SECTION FAILED: {title}")
            traceback.print_exc()
        report["sections"].append(sec)

    if args.json:
        report["plans"] = [p.summary() for p in common.PLAN_LOG]
        if common.SEARCH_LOG:
            # The search artifact (DESIGN.md §16): the survivors' full
            # sweep plus the per-rung elimination ledger — supersedes
            # the plain dse block for a --search run (CI uploads this).
            report["search"] = common.SEARCH_LOG[-1].to_dict()
        elif common.DSE_LOG:
            report["dse"] = common.DSE_LOG[-1].to_dict()
        if common.SERVE_LOG:
            # The serving artifact (DESIGN.md §11): the engine's executed
            # timeline next to the simulator's — per-step records carry
            # predicted vs simulated decode HBM bytes (CI uploads this).
            report["serve"] = [
                {"engine": eng.stats(), "sim": sim.to_dict()}
                for eng, sim in common.SERVE_LOG]
        if common.SHARD_LOG:
            # The scale-out artifact (DESIGN.md §13): speedup-vs-chips
            # curves + per-row serialized ShardedPlans (CI uploads this).
            report["shard"] = common.SHARD_LOG[-1].to_dict()
        if common.REPLAY_LOG:
            # The calibration artifact (DESIGN.md §10): one entry per
            # recorded model — the fitted CalibrationReport plus the
            # traced plan JSON that replays it (CI uploads this).
            report["replay"] = [
                {"calibration": rep.to_dict(),
                 "traced_ops": list(plan.traced_ops),
                 "plan_json": plan.to_json()}
                for plan, rep in common.REPLAY_LOG]
        if common.BENCH_LOG:
            # The perf-tracking block (DESIGN.md §14): per-section
            # gating metrics + critical-path summaries, same shape the
            # BENCH_<section>.json baselines commit.
            from benchmarks import history
            report["bench"] = {
                sec: history.snapshot(sec, entry,
                                      metadata=common.run_metadata()
                                      ).to_dict()
                for sec, entry in sorted(common.BENCH_LOG.items())}
        report["ok"] = failed == 0
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# json report -> {args.json}", file=sys.stderr)

    if args.perfetto:
        from repro.obs.timeline import validate_timeline, write_timeline
        os.makedirs(args.perfetto, exist_ok=True)
        for name, thunk in common.TIMELINE_LOG:
            tl = thunk()
            validate_timeline(tl)
            stem = "".join(c if c.isalnum() or c in "-_." else "_"
                           for c in name)
            path = os.path.join(args.perfetto, f"{stem}.perfetto.json")
            write_timeline(tl, path)
            print(f"# perfetto timeline -> {path}", file=sys.stderr)
        if not common.TIMELINE_LOG:
            print("# --perfetto: no section registered a timeline",
                  file=sys.stderr)

    if args.baseline or args.check_baseline:
        from benchmarks import history
        if not common.BENCH_LOG:
            print("# no section registered bench metrics "
                  "(run bench_sim/serve/shard)", file=sys.stderr)
            sys.exit(2)

    if args.baseline:
        for sec, entry in sorted(common.BENCH_LOG.items()):
            snap = history.snapshot(sec, entry,
                                    metadata=common.run_metadata())
            path = history.write_snapshot(snap, args.baseline)
            print(f"# bench baseline -> {path}", file=sys.stderr)

    regressed = False
    if args.check_baseline:
        for sec, entry in sorted(common.BENCH_LOG.items()):
            snap = history.snapshot(sec, entry)
            path = history.baseline_path(args.check_baseline, sec)
            if not os.path.exists(path):
                print(f"# bench-check: no committed baseline {path} — "
                      f"run with --baseline first", file=sys.stderr)
                regressed = True
                continue
            cmp = history.compare(snap, history.load_snapshot(path))
            print(cmp.format())
            if not cmp.ok:
                regressed = True
        if regressed:
            print("# bench-check FAILED: perf regression against "
                  "committed baselines (re-baseline with --baseline "
                  "if intentional)", file=sys.stderr)

    if failed or regressed:
        sys.exit(1)


if __name__ == '__main__':
    main()
