"""Benchmark harness main — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (deliverable d)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_decode, bench_kernels, bench_pruning,
                            bench_rewrite_overlap, bench_stream_modes,
                            roofline)
    sections = [
        ("Fig6/Fig7 stream-mode comparison", bench_stream_modes.run),
        ("Token pruning (paper SI claim)", bench_pruning.run),
        ("TranCIM rewrite-latency analysis", bench_rewrite_overlap.run),
        ("Decode regime (tile-stream latency win)", bench_decode.run),
        ("Kernel micro-benchmarks", bench_kernels.run),
        ("Roofline summary (from dry-run artifacts)", roofline.run),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for title, fn in sections:
        print(f"# --- {title} ---")
        try:
            for row in fn():
                print(row)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"# SECTION FAILED: {title}")
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
