"""Benchmark harness main — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (deliverable d).

Usage: ``python benchmarks/run.py [section ...]`` — with no arguments all
sections run; otherwise only the named ones (e.g. ``run.py bench_sim``).
"""
from __future__ import annotations

import os
import sys
import traceback

# Allow ``python benchmarks/run.py`` (not just ``python -m benchmarks.run``
# with PYTHONPATH=src): both the repo root and src/ must be importable.
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO_ROOT, os.path.join(_REPO_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main(argv=None) -> None:
    from benchmarks import (bench_decode, bench_kernels, bench_pruning,
                            bench_rewrite_overlap, bench_sim,
                            bench_stream_modes, roofline)
    sections = [
        ("bench_stream_modes", "Fig6/Fig7 stream-mode comparison",
         bench_stream_modes.run),
        ("bench_pruning", "Token pruning (paper SI claim)",
         bench_pruning.run),
        ("bench_rewrite_overlap", "TranCIM rewrite-latency analysis",
         bench_rewrite_overlap.run),
        ("bench_sim", "StreamDCIM simulator (three-way + SI stall)",
         bench_sim.run),
        ("bench_decode", "Decode regime (tile-stream latency win)",
         bench_decode.run),
        ("bench_kernels", "Kernel micro-benchmarks", bench_kernels.run),
        ("roofline", "Roofline summary (from dry-run artifacts)",
         roofline.run),
    ]
    wanted = list(sys.argv[1:] if argv is None else argv)
    if wanted:
        known = {key for key, _, _ in sections}
        unknown = [w for w in wanted if w not in known]
        if unknown:
            print(f"unknown section(s) {unknown}; available: {sorted(known)}",
                  file=sys.stderr)
            sys.exit(2)
        sections = [s for s in sections if s[0] in wanted]
    print("name,us_per_call,derived")
    failed = 0
    for key, title, fn in sections:
        print(f"# --- {title} ---")
        try:
            for row in fn():
                print(row)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"# SECTION FAILED: {title}")
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
