"""Search/cache smoke checks (``make search-smoke`` — DESIGN.md §16).

Takes the ``run.py dse --search --json`` artifact written just before and
asserts it is well-formed (rows, interned plan table, rung ledger, the
full-sim budget actually below the candidate count), then exercises the
two fast-DSE invariants in-process on a tiny grid:

* **cache warm vs cold**: the second sweep over a shared on-disk cache
  must be hits-only, produce byte-identical rows, and run measurably
  faster than the cold sweep that populated the store;
* **search == grid**: successive halving over a small exhaustive space
  recovers exactly the grid's Pareto frontier while fully simulating at
  most half the points.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [_root, os.path.join(_root, "src")]


def check_artifact(path: str) -> None:
    with open(path) as f:
        d = json.load(f)
    assert d["ok"], d
    search = d["search"]
    assert search["rows"], "search emitted no rows"
    for row in search["rows"]:
        for key in ("latency_cycles", "energy_pj", "edp", "plan_ref",
                    "bottleneck"):
            assert key in row, (key, sorted(row.keys()))
        assert row["plan_ref"] in search["plan_table"], row["plan_ref"]
        assert row["bottleneck"], "full-fidelity row missing bottleneck"
    meta = search["search"]
    assert meta["rungs"], "no rung ledger"
    final = meta["rungs"][-1]
    assert not final["proxy"], "last rung must be full fidelity"
    assert len(final["survivors"]) <= meta["space_size"], meta
    if meta["space_size"] > 3:          # enough room for eliminations
        assert len(final["survivors"]) < meta["space_size"], (
            "search eliminated nothing")
    assert all(search["pareto"].values()), "empty Pareto frontier"
    print(f"search artifact ok: {len(search['rows'])} rows, "
          f"{meta['space_size']} candidates -> "
          f"{len(final['survivors'])} survivors, "
          f"{meta['proxy_sims']} proxy + {meta['full_sims']} full sims")


def check_cache_warm_cold() -> None:
    from repro.dse import run_sweep
    from repro.dse.sweep import Axes
    axes = Axes(groups=((2, 1), (4, 2), (8, 4)),
                rewrite_bus_bits=(512,), ping_pong=(True,))
    kw = dict(models=["whisper-base"], axes=axes, seq_lens=(512,),
              include_presets=False)
    with tempfile.TemporaryDirectory() as store:
        t0 = time.perf_counter()
        cold = run_sweep(cache=store, **kw)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = run_sweep(cache=store, **kw)
        t_warm = time.perf_counter() - t0
    assert cold.cache_stats["misses"] == len(cold.rows), cold.cache_stats
    assert warm.cache_stats["hits"] == len(warm.rows), warm.cache_stats
    assert warm.cache_stats["misses"] == 0, warm.cache_stats
    assert ([r.to_dict() for r in warm.rows]
            == [r.to_dict() for r in cold.rows]), (
        "warm rows differ from cold rows")
    assert t_warm < t_cold, (
        f"warm sweep ({t_warm:.2f}s) not faster than cold ({t_cold:.2f}s)")
    print(f"cache ok: cold {t_cold:.2f}s -> warm {t_warm:.2f}s "
          f"({t_cold / t_warm:.1f}x), {warm.cache_stats['hits']} hits")


def check_search_matches_grid() -> None:
    from repro.dse import run_sweep, successive_halving
    from repro.dse.sweep import Axes
    axes = Axes(groups=((2, 1), (4, 2), (8, 4)),
                rewrite_bus_bits=(512, 1024), ping_pong=(True, False))
    kw = dict(models=["whisper-base"], seq_len=512, include_presets=False)
    grid = run_sweep(models=["whisper-base"], axes=axes, seq_lens=(512,),
                     include_presets=False)
    found = successive_halving(axes=axes, **kw)
    want = sorted((r.hw, r.latency_cycles, r.energy_pj)
                  for r in grid.pareto())
    got = sorted((r.hw, r.latency_cycles, r.energy_pj)
                 for r in found.sweep.pareto())
    assert want == got, f"frontier mismatch:\n  grid {want}\n  search {got}"
    n_grid = len(grid.rows)
    assert found.full_sims <= n_grid / 2, (
        f"search fully simulated {found.full_sims} of {n_grid} points")
    print(f"search==grid ok: frontier of {len(want)} recovered with "
          f"{found.full_sims}/{n_grid} full sims")


def main() -> None:
    if len(sys.argv) > 1:
        check_artifact(sys.argv[1])
    check_cache_warm_cold()
    check_search_matches_grid()
    print("search smoke OK")


if __name__ == "__main__":
    main()
