"""Bench-history snapshots + regression comparison (DESIGN.md §14).

Seven PRs of perf-sensitive changes shipped with no regression tracking;
this module is the missing trajectory.  Every bench section that calls
``common.log_bench`` can be snapshotted to a schema-versioned
``BENCH_<section>.json`` (``run.py --baseline DIR``) and later compared
against the committed baseline with direction-aware tolerance bands
(``run.py --check-baseline DIR`` / ``make bench-check`` — the CI gate).

Snapshot metrics are *deterministic simulation-domain scalars* (cycles,
HBM bytes, simulated tokens-per-kilocycle, speedups) so baselines are
machine-independent; most wall-clock numbers belong in the non-gating
``info`` block.  The exception is harness-throughput metrics named
``*_per_sec`` / ``*_per_min`` (DES events simulated per second, DSE
points swept per minute — DESIGN.md §16): those gate with the wide
``WALLCLOCK_REL_TOL`` band, catching hot-path collapses without flaking
on machine variance.  Each snapshot also carries the section's causal
critical-path summary (``repro.obs.critpath``) so a regression comes
with its "what chain grew" context attached.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Mapping, Optional

#: Bump on breaking snapshot-shape changes; ``load_snapshot`` rejects
#: mismatched files instead of mis-comparing them.
BENCH_SCHEMA_VERSION = 1

#: Default relative tolerance band: a gating metric may drift this much
#: in the "worse" direction before the check fails.  Simulation metrics
#: are deterministic, so the band only absorbs intentional small drifts
#: (re-baselining is the escape hatch for larger ones).
DEFAULT_REL_TOL = 0.02

#: Metric-name suffixes where *higher* is better; everything else
#: (cycles, bytes, pj, fractions of stall...) regresses upward.
_HIGHER_IS_BETTER = ("tokens_per_kcycle", "requests_per_kcycle",
                     "speedup", "throughput", "_util",
                     "_per_sec", "_per_min")

#: Wall-clock throughput metrics (``*_per_sec`` / ``*_per_min``, e.g. the
#: DES ``sim_events_per_sec`` microbench) DO vary across machines and
#: load, unlike the simulation-domain scalars; they gate with this much
#: wider default band — an order-of-magnitude hot-path collapse still
#: fails (current < 10% of baseline), but a slower or noisier runner
#: never does.
_WALLCLOCK_SUFFIXES = ("_per_sec", "_per_min")
WALLCLOCK_REL_TOL = 0.90


def metric_direction(name: str) -> str:
    """``"higher"`` if a larger value is an improvement, else
    ``"lower"``."""
    return ("higher" if name.endswith(_HIGHER_IS_BETTER) else "lower")


@dataclasses.dataclass(frozen=True)
class BenchSnapshot:
    """One section's perf record at one revision."""

    section: str
    schema_version: int
    metrics: Dict[str, float]
    critical_path: Dict[str, object]
    info: Dict[str, object]
    metadata: Dict[str, object]

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def snapshot(section: str, entry: Mapping[str, object],
             metadata: Optional[Mapping[str, object]] = None
             ) -> BenchSnapshot:
    """Build a snapshot from a ``common.BENCH_LOG`` entry."""
    return BenchSnapshot(
        section=section,
        schema_version=BENCH_SCHEMA_VERSION,
        metrics={k: float(v) for k, v in entry["metrics"].items()},
        critical_path=dict(entry.get("critical_path", {})),
        info=dict(entry.get("info", {})),
        metadata=dict(metadata or {}))


def snapshot_name(section: str) -> str:
    """``bench_sim`` -> ``BENCH_sim.json`` (the ``bench_`` prefix is
    harness namespacing, not part of the trajectory name)."""
    short = section[len("bench_"):] if section.startswith("bench_") else section
    return f"BENCH_{short}.json"


def baseline_path(directory: str, section: str) -> str:
    return os.path.join(directory, snapshot_name(section))


def write_snapshot(snap: BenchSnapshot, directory: str) -> str:
    os.makedirs(directory, exist_ok=True)
    path = baseline_path(directory, snap.section)
    with open(path, "w") as f:
        json.dump(snap.to_dict(), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_snapshot(path: str) -> BenchSnapshot:
    with open(path) as f:
        d = json.load(f)
    version = d.get("schema_version")
    if version != BENCH_SCHEMA_VERSION:
        raise ValueError(
            f"{path}: bench snapshot schema {version!r} != "
            f"{BENCH_SCHEMA_VERSION} — re-baseline with run.py --baseline")
    return BenchSnapshot(
        section=d["section"], schema_version=version,
        metrics={k: float(v) for k, v in d["metrics"].items()},
        critical_path=dict(d.get("critical_path", {})),
        info=dict(d.get("info", {})),
        metadata=dict(d.get("metadata", {})))


@dataclasses.dataclass(frozen=True)
class MetricDelta:
    """One metric compared against its baseline."""

    name: str
    baseline: float
    current: float
    direction: str            # "lower" | "higher" is better
    rel_change: float         # (current - baseline) / |baseline|
    verdict: str = "ok"       # "ok" | "improvement" | "regression"

    @property
    def regressed(self) -> bool:
        return self.verdict == "regression"


@dataclasses.dataclass(frozen=True)
class BenchComparison:
    """A snapshot vs its baseline: regressions fail the gate, the rest
    is context."""

    section: str
    regressions: List[MetricDelta]
    improvements: List[MetricDelta]
    unchanged: List[MetricDelta]
    missing: List[str]        # in baseline, absent from current run
    new: List[str]            # in current run, absent from baseline

    @property
    def ok(self) -> bool:
        return not self.regressions and not self.missing

    def format(self) -> str:
        lines = [f"[{self.section}] "
                 f"{'OK' if self.ok else 'REGRESSION'}: "
                 f"{len(self.regressions)} regressed, "
                 f"{len(self.improvements)} improved, "
                 f"{len(self.unchanged)} unchanged"]
        for d in self.regressions:
            lines.append(f"  REGRESSED {d.name}: {d.baseline:g} -> "
                         f"{d.current:g} ({d.rel_change:+.2%}, "
                         f"{d.direction} is better)")
        for d in self.improvements:
            lines.append(f"  improved  {d.name}: {d.baseline:g} -> "
                         f"{d.current:g} ({d.rel_change:+.2%})")
        for name in self.missing:
            lines.append(f"  MISSING   {name} (in baseline, not in run)")
        for name in self.new:
            lines.append(f"  new       {name} (not in baseline; "
                         f"re-baseline to start tracking)")
        return "\n".join(lines)


def compare(current: BenchSnapshot, baseline: BenchSnapshot,
            rel_tol: float = DEFAULT_REL_TOL,
            tolerances: Optional[Mapping[str, float]] = None
            ) -> BenchComparison:
    """Direction-aware comparison with relative tolerance bands.

    A lower-is-better metric regresses when it exceeds
    ``baseline * (1 + tol)``; a higher-is-better one when it drops below
    ``baseline * (1 - tol)``.  Zero baselines compare exactly (any
    nonzero move in the worse direction regresses — there is no relative
    band around 0).  Per-metric ``tolerances`` override ``rel_tol``;
    wall-clock throughput metrics (``*_per_sec`` / ``*_per_min``)
    default to the wide ``WALLCLOCK_REL_TOL`` band instead of
    ``rel_tol`` unless explicitly overridden.
    """
    regressions: List[MetricDelta] = []
    improvements: List[MetricDelta] = []
    unchanged: List[MetricDelta] = []
    missing: List[str] = []
    for name in sorted(baseline.metrics):
        if name not in current.metrics:
            missing.append(name)
            continue
        b, c = baseline.metrics[name], current.metrics[name]
        default_tol = (WALLCLOCK_REL_TOL
                       if name.endswith(_WALLCLOCK_SUFFIXES) else rel_tol)
        tol = (tolerances or {}).get(name, default_tol)
        direction = metric_direction(name)
        rel = (c - b) / abs(b) if b else (0.0 if c == b else float("inf"))
        worse = (c - b) if direction == "lower" else (b - c)
        band = abs(b) * tol
        if worse > band:
            regressions.append(MetricDelta(
                name=name, baseline=b, current=c, direction=direction,
                rel_change=rel, verdict="regression"))
        elif worse < 0:
            improvements.append(MetricDelta(
                name=name, baseline=b, current=c, direction=direction,
                rel_change=rel, verdict="improvement"))
        else:
            unchanged.append(MetricDelta(
                name=name, baseline=b, current=c, direction=direction,
                rel_change=rel))
    new = sorted(set(current.metrics) - set(baseline.metrics))
    return BenchComparison(section=current.section,
                           regressions=regressions,
                           improvements=improvements,
                           unchanged=unchanged,
                           missing=missing, new=new)
