"""Chiplet-mesh scale-out section (``run.py shard``) — DESIGN.md §13.

Sweeps ``repro.shard`` over the registry scale-out models x all three
execution modes x chip counts on a ring mesh, each point run through
plan -> shard -> simulate with byte-exactness asserted inside the
simulator.  Reports per (model, mode): the speedup-vs-chips curve, the
scale-out efficiency at the widest mesh, the resolved sharding axis, and
the bottleneck resource (``INTERCONNECT`` when the NoC wire plan
dominates).  The machine-readable sweep registers via
``common.log_shard`` so ``run.py shard --json`` emits the replayable
artifact, and the widest mesh's Perfetto timeline (one track group per
chip + the NoC links) registers via ``common.log_timeline``.
"""
from __future__ import annotations

import os
import sys
from typing import List

if __name__ == "__main__":      # allow ``python benchmarks/bench_shard.py``
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

from benchmarks.common import csv_row, log_bench, log_shard, log_timeline


def run() -> List[str]:
    from repro.shard import run_shard_sweep
    from repro.shard.sweep import DEFAULT_CHIPS, DEFAULT_MODELS

    result = run_shard_sweep(DEFAULT_MODELS, chips=DEFAULT_CHIPS,
                             topologies=("ring",), keep_plans=True)
    log_shard(result)

    rows: List[str] = []
    cells = {}
    for r in result.rows:
        cells.setdefault(result.label(r), []).append(r)
    rows.append(csv_row(
        "shard_grid", 0.0,
        f"{len(result.rows)} points ({len(cells)} cells x "
        f"chips {list(DEFAULT_CHIPS)}); byte-exactness asserted per point"))
    widest_overall = None
    bench_metrics = {
        "total_collective_bytes": float(sum(r.collective_bytes
                                            for r in result.rows))}
    for label, cell in cells.items():
        cell.sort(key=lambda r: r.chips)
        widest = cell[-1]
        key = f"{widest.model}_{widest.mode}_{widest.chips}c"
        bench_metrics[f"{key}_cycles"] = widest.latency_cycles
        bench_metrics[f"{key}_speedup"] = widest.speedup
        curve = " ".join(f"{r.chips}c={r.speedup:.2f}x" for r in cell)
        rows.append(csv_row(
            f"shard_{widest.model}_{widest.mode}_speedup", 0.0,
            f"{curve}; axis {widest.axis}; eff@{widest.chips}c "
            f"{widest.efficiency:.2f}; bottleneck "
            f"{widest.bottleneck or 'n/a'}"))
        if (widest_overall is None
                or widest.chips > widest_overall.chips):
            widest_overall = widest

    if widest_overall is not None:
        def _shard_timeline(pj=widest_overall.plan_json,
                            title=(f"shard {widest_overall.model} "
                                   f"{widest_overall.mode} "
                                   f"{widest_overall.topology}"
                                   f"{widest_overall.chips}")):
            # Replay the row from its own serialized ShardedPlan — the
            # timeline shows exactly what the sweep scored.
            from repro.obs.timeline import timeline_from_sharded
            from repro.shard import ShardedPlan, simulate_sharded_plan
            res = simulate_sharded_plan(ShardedPlan.from_dict(pj))
            return timeline_from_sharded(res, title=title)

        log_timeline(
            f"shard_{widest_overall.model}_{widest_overall.mode}"
            f"_{widest_overall.topology}{widest_overall.chips}",
            _shard_timeline)

        # Perf-tracking snapshot (DESIGN.md §14).  Replay the widest row
        # from its serialized plan for the critical-path summary — the
        # INTERCONNECT on-path share lands in the committed baseline.
        from repro.shard import ShardedPlan, simulate_sharded_plan
        widest_res = simulate_sharded_plan(
            ShardedPlan.from_dict(widest_overall.plan_json))
        log_bench("shard", bench_metrics, trace=widest_res.trace,
                  info={"models": sorted({r.model for r in result.rows}),
                        "chips": list(DEFAULT_CHIPS),
                        "widest": f"{widest_overall.model}/"
                                  f"{widest_overall.mode}/"
                                  f"{widest_overall.chips}c"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
