"""Paper §I token-pruning claim: pruning image-token redundancy gives
>= 1.6x speedup with negligible accuracy loss (Evo-ViT, ref [21]).

We measure (a) the compute retained under the default Evo-ViT-style keep
schedule, (b) CPU wall-time of a pruned vs unpruned reduced ViLBERT forward,
and (c) the DTPU scoring-pass overhead."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.configs import registry
from repro.core import pruning as P
from repro.core.types import PruningConfig


def run() -> List[str]:
    rows = []
    cfg_full = registry.get_config("vilbert-base")
    plan = P.keep_plan(cfg_full.pruning, cfg_full.num_coattn_layers, 4096)
    frac = P.pruning_compute_savings(plan, 4096)
    rows.append(csv_row("pruning_attention_compute_retained", 0.0,
                        f"{frac:.3f} of FLOPs -> {1 / frac:.2f}x attention "
                        f"speedup (paper claims >=1.6x)"))
    rows.append(csv_row("pruning_keep_plan", 0.0,
                        "plan=" + "/".join(str(n) for n in plan)))

    # measured: reduced vilbert forward, pruned vs unpruned
    import dataclasses
    cfg = registry.get_config("vilbert-base", smoke=True)
    cfg_on = dataclasses.replace(cfg, pruning=PruningConfig(
        enabled=True, min_tokens=8))
    cfg_off = dataclasses.replace(cfg, pruning=PruningConfig(enabled=False))
    mod = registry.model_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, cfg.seq_y            # text position table bounds the length
    batch = {"regions": jax.random.normal(jax.random.PRNGKey(1),
                                          (B, S, cfg.d_model)) * 0.1,
             "tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                          cfg.vocab_size)}
    f_on = jax.jit(lambda p, b: mod.forward(p, cfg_on, b))
    f_off = jax.jit(lambda p, b: mod.forward(p, cfg_off, b))
    t_on = time_fn(f_on, params, batch) * 1e6
    t_off = time_fn(f_off, params, batch) * 1e6
    rows.append(csv_row("pruning_vilbert_fwd_pruned", t_on,
                        f"{t_off / t_on:.2f}x vs unpruned (CPU, reduced)"))
    rows.append(csv_row("pruning_vilbert_fwd_unpruned", t_off, "baseline"))

    # scoring-pass overhead (full vs strided)
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 1024, 64))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 1024, 64))
    t_full = time_fn(jax.jit(lambda q, k: P.attention_column_scores(q, k)),
                     q, k) * 1e6
    t_str = time_fn(jax.jit(lambda q, k: P.attention_column_scores(
        q, k, sample_stride=8)), q, k) * 1e6
    rows.append(csv_row("dtpu_score_full", t_full, "full column-mean pass"))
    rows.append(csv_row("dtpu_score_strided8", t_str,
                        f"{t_full / max(t_str, 1e-9):.2f}x cheaper"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
