"""Paper Fig. 6 (performance) + Fig. 7 (energy): Non-stream vs Layer-stream
vs Tile-stream on ViLBERT-base and ViLBERT-large.

Plan-driven since PR 2: each (mode, geometry) cell builds one
``repro.plan.LayerPlan`` and *shares it* between the two measurements —

* measured CPU wall-time of one co-attention layer at reduced dims through
  ``kernels.ops.attention_by_plan`` (numerics proof — all modes compute
  the same function), and
* the plan's predicted HBM traffic (``LayerPlan.hbm_bytes``) at the
  paper's full config (N_X = N_Y = 4096) projected onto v5e bandwidth ->
  latency and energy.  CPU wall-time cannot express DMA/compute overlap;
  the traffic model is the TPU-faithful comparison (DESIGN.md §6).

The plan's bytes are asserted against the legacy analytic entry point
(``core.streaming.streamed_bytes_per_layer``) so the deprecation shim and
the planner cannot drift apart.

Paper reference points: ViLBERT-base speedups 2.86x (vs Non-stream) and
1.25x (vs Layer-stream); ViLBERT-large 2.42x / 1.31x; geomean 2.63x/1.28x.
Energy: 2.64x/1.27x (base), 1.94x/1.19x (large); geomean 2.26x/1.23x.
"""
from __future__ import annotations

import math
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import (E_HBM_PER_BYTE, E_PER_FLOP, HBM_BW,
                               PEAK_FLOPS, csv_row, log_plan, time_fn)
from repro.configs import registry
from repro.core.streaming import streamed_bytes_per_layer
from repro.core.types import ExecutionMode
from repro.kernels import ops
from repro.plan import plan_attention, plan_model

MODES = [ExecutionMode.NON_STREAM, ExecutionMode.LAYER_STREAM,
         ExecutionMode.TILE_STREAM]


def measured_layer_us(d_model: int, heads: int, seq: int) -> Dict[str, float]:
    """CPU wall-µs for one cross-attention layer per mode (reduced dims),
    dispatched through per-mode LayerPlans."""
    hd = d_model // heads
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (1, heads, seq, hd), jnp.float32) * 0.3
    x_kv = jax.random.normal(ks[1], (1, seq, d_model), jnp.float32) * 0.3
    wk = jax.random.normal(ks[2], (d_model, heads, hd)) * (d_model ** -0.5)
    wv = jax.random.normal(ks[3], (d_model, heads, hd)) * (d_model ** -0.5)
    out = {}
    for mode in MODES:
        lp = plan_attention(mode, seq_q=seq, seq_kv=seq, d_kv=d_model,
                            heads=heads, kv_heads=heads, head_dim=hd,
                            cross=True)
        fn = jax.jit(lambda q, x, wk, wv, lp=lp: ops.attention_by_plan(
            lp, q, x, wk, wv, causal=False))
        out[mode.value] = time_fn(fn, q, x_kv, wk, wv) * 1e6
    return out


def projected_v5e(arch: str, *, bytes_per_el: int = 1,
                  peak_flops: float = 2 * PEAK_FLOPS
                  ) -> Dict[str, Dict[str, float]]:
    """Full-config per-co-attention-layer latency/energy per mode, with
    the traffic side read off per-mode ``LayerPlan``s.

    Latency semantics follow real TPU execution: *separate kernels
    serialize* (the attention kernel cannot start until K/V finish writing
    — the TranCIM 'rewrite stall' reborn), while *within* a kernel DMA and
    MXU overlap (roofline max).  Defaults model the paper's quantized
    regime (INT16 attention -> int8 MXU path on v5e: 394 TOPS, 1-byte
    elements); pass bytes_per_el=2, peak_flops=PEAK_FLOPS for bf16.

    * NON_STREAM:  Σ over ops of (compute ⊔ traffic), every intermediate
      round-trips HBM and every op is its own kernel.
    * LAYER_STREAM: proj kernel (KV gen + write) ; attention kernel
      (max(compute, KV re-reads)).
    * TILE_STREAM: one fused kernel: max(total compute, x_kv stream).
    """
    cfg = registry.get_config(arch)
    seq = 4096                                       # paper: N_X = N_Y = 4096
    heads, d = cfg.num_heads, cfg.d_model
    hd = d // heads
    be = bytes_per_el
    kv_w = 2 * heads * hd                            # K+V width (MHA here)
    gen_flops = 2 * seq * d * kv_w                   # K,V generation
    attn_flops = 2 * seq * seq * heads * hd * 2      # QK^T + PV
    flops = gen_flops + attn_flops
    nqb = max(seq // 256, 1)
    out = {}
    for mode in MODES:
        lp = plan_attention(mode, seq_q=seq, seq_kv=seq, d_kv=d,
                            heads=heads, kv_heads=cfg.num_kv_heads,
                            head_dim=hd, bytes_per_el=be, cross=True)
        traffic = lp.hbm_bytes
        # Shim agreement: the plan's prediction IS the legacy model.
        legacy = streamed_bytes_per_layer(
            seq_q=seq, seq_kv=seq, d_model=d, num_heads=heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=hd, mode=mode,
            bytes_per_el=be)
        if traffic != legacy:
            raise AssertionError(
                f"{arch}/{mode.value}: plan {traffic} != legacy {legacy}")
        if mode == ExecutionMode.TILE_STREAM:
            latency = max(flops / peak_flops, traffic / HBM_BW)
        elif mode == ExecutionMode.LAYER_STREAM:
            t_proj = max(gen_flops / peak_flops,
                         (seq * d + seq * kv_w) * be / HBM_BW)
            kv_reread = nqb * seq * kv_w * be
            t_attn = max(attn_flops / peak_flops, kv_reread / HBM_BW)
            latency = t_proj + t_attn
        else:
            # every matmul/softmax its own kernel; intermediates (Q,K,V,
            # A,P) round-trip; serialize compute-or-traffic maxima
            a_bytes = heads * seq * seq * be
            t_gen = max(gen_flops / peak_flops,
                        (seq * d + seq * kv_w) * be / HBM_BW)
            t_qkt = max(attn_flops / 2 / peak_flops,
                        (seq * kv_w / 2 + a_bytes) * be / HBM_BW)
            t_sm = 2 * a_bytes / HBM_BW              # softmax: read A write P
            t_pv = max(attn_flops / 2 / peak_flops,
                       (a_bytes + seq * kv_w / 2) * be / HBM_BW)
            latency = t_gen + t_qkt + t_sm + t_pv
        energy = flops * E_PER_FLOP + traffic * E_HBM_PER_BYTE
        out[mode.value] = {"latency_s": latency, "energy_j": energy,
                           "traffic_bytes": traffic, "flops": flops}
    return out


def run() -> List[str]:
    rows = []
    # measured equivalence + wall time at reduced dims
    meas = measured_layer_us(256, 8, 512)
    for mode, us in meas.items():
        rows.append(csv_row(f"fig6_measured_cpu_{mode}", us,
                            "reduced dims d=256 h=8 seq=512"))

    geo_perf = {"non_stream": 1.0, "layer_stream": 1.0}
    geo_energy = {"non_stream": 1.0, "layer_stream": 1.0}
    for arch in ("vilbert-base", "vilbert-large"):
        # The whole-model plan for the --json report (per-layer modes).
        log_plan(plan_model(registry.get_config(arch)))
        proj = projected_v5e(arch)
        t_tile = proj["tile_stream"]["latency_s"]
        e_tile = proj["tile_stream"]["energy_j"]
        for base in ("non_stream", "layer_stream"):
            sp = proj[base]["latency_s"] / t_tile
            ev = proj[base]["energy_j"] / e_tile
            geo_perf[base] *= sp
            geo_energy[base] *= ev
            rows.append(csv_row(
                f"fig6_{arch}_speedup_vs_{base}",
                proj[base]["latency_s"] * 1e6,
                f"tile-stream speedup {sp:.2f}x (paper: "
                f"{_paper_perf(arch, base):.2f}x)"))
            rows.append(csv_row(
                f"fig7_{arch}_energy_vs_{base}",
                0.0, f"energy saving {ev:.2f}x (paper: "
                     f"{_paper_energy(arch, base):.2f}x)"))
    for base in ("non_stream", "layer_stream"):
        rows.append(csv_row(
            f"fig6_geomean_speedup_vs_{base}", 0.0,
            f"{math.sqrt(geo_perf[base]):.2f}x (paper: "
            f"{2.63 if base == 'non_stream' else 1.28:.2f}x)"))
        rows.append(csv_row(
            f"fig7_geomean_energy_vs_{base}", 0.0,
            f"{math.sqrt(geo_energy[base]):.2f}x (paper: "
            f"{2.26 if base == 'non_stream' else 1.23:.2f}x)"))
    return rows


def _paper_perf(arch, base):
    return {("vilbert-base", "non_stream"): 2.86,
            ("vilbert-base", "layer_stream"): 1.25,
            ("vilbert-large", "non_stream"): 2.42,
            ("vilbert-large", "layer_stream"): 1.31}[(arch, base)]


def _paper_energy(arch, base):
    return {("vilbert-base", "non_stream"): 2.64,
            ("vilbert-base", "layer_stream"): 1.27,
            ("vilbert-large", "non_stream"): 1.94,
            ("vilbert-large", "layer_stream"): 1.19}[(arch, base)]


if __name__ == "__main__":
    for r in run():
        print(r)
