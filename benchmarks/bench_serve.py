"""Serving section (``run.py serve``): continuous-batching engine vs the
serving-timeline simulator (DESIGN.md §11).

Runs a staggered-arrival, mixed-length request trace through the live
``serve.Engine`` (slot-level continuous batching, per-layer plan-dispatched
prefill, per-step ``DecodePlan``s) at smoke scale on CPU, then lowers the
*same* trace through ``sim.simulate_serve`` and checks the two agree on
the step timeline: identical step counts, identical per-request decode
step counts.  Reports requests/s and per-step latency (wall, CPU numerics)
plus the simulator's cycle/HBM view of the same traffic.

``run.py serve --json`` attaches the machine-readable serving artifact
(per-step records with predicted-vs-simulated decode bytes) via
``common.log_serve`` — the CI serve-smoke step uploads it.
"""
from __future__ import annotations

import os
import sys
import time
from typing import List

if __name__ == "__main__":      # allow ``python benchmarks/bench_serve.py``
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

from benchmarks.common import csv_row, log_bench, log_serve, log_timeline

SLOTS = 3


def _trace(cfg, rng):
    import numpy as np
    from repro.serve.engine import Request
    lens = [6, 18, 9, 24, 12, 7]
    news = [8, 5, 12, 6, 9, 4]
    arrs = [0, 0, 1, 3, 3, 6]
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(lens[i],)).astype(np.int32),
                    max_new_tokens=news[i], arrival_step=arrs[i])
            for i in range(len(lens))]


def run() -> List[str]:
    import jax
    import numpy as np
    from repro.configs import registry
    from repro.serve.engine import Engine
    from repro.serve.schedule import ServeRequest
    from repro.sim import simulate_serve

    cfg = registry.get_config("starcoder2-7b", smoke=True)
    mod = registry.model_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=SLOTS, max_len=96)
    reqs = _trace(cfg, np.random.default_rng(0))
    for r in reqs:
        eng.submit(r)

    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    stats = eng.stats()
    total_new = sum(len(r.out_tokens) for r in done)

    sim = simulate_serve(
        cfg, [ServeRequest(r.rid, len(r.prompt), r.max_new_tokens,
                           r.arrival_step) for r in reqs],
        slots=SLOTS)
    log_serve(eng, sim)

    # Serving SLO parity (DESIGN.md §12): the engine's executed
    # step-domain TTFT/TPOT/queue-delay percentiles must match the
    # simulator's on the shared schedule.
    from repro.obs.metrics import assert_serve_parity
    assert_serve_parity(stats, sim.metrics)

    from repro.obs.timeline import timeline_from_serve
    log_timeline("serve", lambda: timeline_from_serve(
        sim, title=f"serve {cfg.name} ({SLOTS} slots)"))

    # stats() derives from the engine's executed step_log; decode_calls
    # counts actual decode_step invocations — so this compares what ran
    # against what the simulator lowered, not the schedule with itself.
    agree = (sim.decode_steps == stats["decode_steps"]
             and sim.num_steps == stats["steps"]
             and stats["decode_calls"] == sum(
                 stats["decode_steps"].values()))
    rows = [
        csv_row("serve_requests_per_s", 1e6 * wall / max(len(done), 1),
                f"{len(done) / wall:.2f} req/s, {total_new / wall:.1f} "
                f"tok/s CPU smoke ({len(done)} reqs, {SLOTS} slots)"),
        csv_row("serve_step_latency", 1e6 * wall / max(stats["steps"], 1),
                f"{stats['steps']} engine steps, "
                f"{stats['decode_calls']} decode calls "
                f"(max concurrency {stats['max_concurrency']})"),
        csv_row("serve_sim_agreement", 0.0,
                f"{'exact' if agree else 'MISMATCH'}: sim {sim.num_steps} "
                f"steps / engine {stats['steps']}; per-request decode "
                f"counts {'equal' if sim.decode_steps == stats['decode_steps'] else 'DIFFER'}"),
        csv_row("serve_sim_cycles", 0.0,
                f"{sim.cycles} simulated cycles, "
                f"{sim.hbm_bytes >> 10} KiB HBM, "
                f"{sim.requests_per_kilocycle():.3f} req/kcycle"),
        csv_row("serve_slo_metrics", 0.0,
                f"engine==sim parity OK; queue p95 "
                f"{sim.metrics['queue_delay']['p95']:.1f} steps, cycle "
                f"ttft p50/p95 {sim.cycle_metrics['ttft']['p50']:.0f}/"
                f"{sim.cycle_metrics['ttft']['p95']:.0f}, tpot p50 "
                f"{sim.cycle_metrics['tpot']['p50']:.0f} cycles"),
    ]
    if not agree:
        raise RuntimeError(
            f"engine/simulator timeline mismatch: engine {stats}, "
            f"sim steps {sim.num_steps} decode {sim.decode_steps}")

    # 64-concurrent-slot batched decode (DESIGN.md §15): equal-shape
    # requests form one shape bucket, so the batched path issues a single
    # decode_step per step where the per-slot baseline issues 64.
    n64, plen64, new64 = 64, 8, 9

    def _trace64():
        rng64 = np.random.default_rng(7)
        from repro.serve.engine import Request
        return [Request(rid=1000 + i,
                        prompt=rng64.integers(
                            0, cfg.vocab_size,
                            size=(plen64,)).astype(np.int32),
                        max_new_tokens=new64, arrival_step=0)
                for i in range(n64)]

    def _timed64(batch_decode, repeats=3):
        # Best-of-N: min wall time per path, so a single scheduler hiccup
        # on a shared host cannot flip the batched-vs-per-slot comparison.
        # The *gated* number is decode-phase throughput (decode_wall_s):
        # batching cuts per-token dispatch, while prefill cost — identical
        # on both paths — dominates this short-generation trace's
        # end-to-end wall and would drown the signal in host noise.
        e = Engine(cfg, params, slots=n64, max_len=32,
                   batch_decode=batch_decode)
        for r in _trace64():
            e.submit(r)
        e.run()                              # warm-up (jit compiles)
        best = best_dec = float("inf")
        for _ in range(repeats):
            for r in _trace64():
                e.submit(r)
            t0 = time.perf_counter()
            d = e.run()
            best = min(best, time.perf_counter() - t0)
            best_dec = min(best_dec, e.decode_wall_s())
        return (e, sum(len(r.out_tokens) for r in d) / best,
                e.decode_calls / best_dec)

    eng64, tok_s_batched, dec_s_batched = _timed64(True)
    _, tok_s_perslot, dec_s_perslot = _timed64(False)
    if dec_s_batched <= dec_s_perslot:
        raise RuntimeError(
            f"batched decode ({dec_s_batched:.1f} decode tok/s) failed "
            f"to beat the per-slot baseline ({dec_s_perslot:.1f} decode "
            f"tok/s) at {n64} slots")
    sim64 = simulate_serve(
        cfg, [ServeRequest(1000 + i, plen64, new64, 0)
              for i in range(n64)],
        slots=n64, decode_lowering="coarse")
    assert_serve_parity(eng64.stats(), sim64.metrics)
    total64 = n64 * new64
    dispatch_speedup = (eng64.decode_calls
                        / max(eng64.decode_batches, 1))
    rows.append(csv_row(
        "serve64_batched_tokens_per_s", 1e6 / max(tok_s_batched, 1e-9),
        f"decode phase {dec_s_batched:.0f} tok/s batched vs "
        f"{dec_s_perslot:.0f} per-slot "
        f"({dec_s_batched / dec_s_perslot:.1f}x); end-to-end "
        f"{tok_s_batched:.1f} vs {tok_s_perslot:.1f} tok/s at {n64} "
        f"slots; {eng64.decode_batches} decode_step calls for "
        f"{eng64.decode_calls} token advances "
        f"({dispatch_speedup:.0f}x dispatch)"))

    # Perf-tracking snapshot (DESIGN.md §14): simulation-domain only —
    # wall-clock req/s stays out of the gating metrics (info block).
    log_bench(
        "serve",
        {"sim_cycles": sim.cycles,
         "sim_hbm_bytes": sim.hbm_bytes,
         "num_steps": sim.num_steps,
         "decode_calls": stats["decode_calls"],
         "tokens_per_kcycle": 1000.0 * total_new / max(sim.cycles, 1),
         "requests_per_kcycle": sim.requests_per_kilocycle(),
         "ttft_p95_cycles": sim.cycle_metrics["ttft"]["p95"],
         "serve64_tokens_per_kcycle":
             1000.0 * total64 / max(sim64.cycles, 1),
         "serve64_dispatch_speedup": dispatch_speedup},
        trace=sim.result.trace,
        info={"model": cfg.name, "slots": SLOTS,
              "wall_tokens_per_s": total_new / wall,
              "serve64_slots": n64,
              "serve64_wall_tokens_per_s_batched": tok_s_batched,
              "serve64_wall_tokens_per_s_perslot": tok_s_perslot,
              "serve64_decode_tokens_per_s_batched": dec_s_batched,
              "serve64_decode_tokens_per_s_perslot": dec_s_perslot})

    dsteps = [s for s in sim.steps if s.decoded]
    if dsteps:
        ok = all(s.decode_hbm_bytes == s.predicted_decode_hbm_bytes
                 for s in dsteps)
        rows.append(csv_row(
            "serve_decode_plan_bytes", 0.0,
            f"{'exact' if ok else 'MISMATCH'} plan==sim decode HBM bytes "
            f"over {len(dsteps)} decode steps (e.g. step "
            f"{dsteps[0].step}: {dsteps[0].predicted_decode_hbm_bytes} B)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
