"""Plan/trace replay section (``run.py replay``) — DESIGN.md §10.

Runs the full record→attach→replay→calibrate pipeline at smoke size for
one MHA model (vilbert-base: the planner tile-streams) and one GQA model
(qwen2-vl-2b: the planner falls back to layer-streaming — the worked
divergence example of DESIGN.md §10):

1. compile a small-seq plan, run its first ops through the *real*
   jnp kernel paths under ``repro.sim.replay.recording`` (wall-time
   ``KernelTrace`` records: grid, tiling, cycles, bytes);
2. attach the records to the plan and replay through ``simulate_plan``
   (recorded timing for traced ops, analytic lowering for the rest —
   the mixed-plan contract the tests pin);
3. fit a ``CalibrationReport`` (per-op-class analytic-vs-recorded error
   + per-resource cycle scale factors) and re-simulate the analytic
   plan with the calibration applied.

Each (traced plan, report) pair is registered via ``common.log_replay``
so ``run.py replay --json`` emits the calibration artifact the CI
replay-smoke step uploads.  Recorded cycles are *host-platform* wall
time (CPU here), so the absolute calibration factors are large and
per-platform; the pipeline is the deliverable, not the constants.
"""
from __future__ import annotations

import os
import sys
from typing import List

if __name__ == "__main__":      # allow ``python benchmarks/bench_replay.py``
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

from benchmarks.common import csv_row, log_replay, log_timeline

SEQ = 256          # one tile block: real kernels at recordable CPU cost
MAX_OPS = 3        # traced ops per model; the rest replay analytically


def run() -> List[str]:
    from repro.configs import registry
    from repro.plan import plan_model
    from repro.sim import fit_calibration, record_plan, simulate_plan

    rows: List[str] = []
    for arch in ("vilbert-base", "qwen2-vl-2b"):
        cfg = registry.get_config(arch)
        plan = plan_model(cfg, seq_len=SEQ)
        traced, rec = record_plan(plan, max_ops=MAX_OPS, iters=1, warmup=1)
        report = fit_calibration(traced)
        log_replay(traced, report)
        from repro.obs.timeline import timeline_from_records
        log_timeline(f"replay_{arch}_kernels",
                     lambda rs=list(rec.records), a=arch:
                     timeline_from_records(
                         rs, title=f"recorded kernels ({a})"))

        analytic = simulate_plan(plan)
        replayed = simulate_plan(traced)
        calibrated = simulate_plan(plan, calibration=report)

        wall_us = sum(t.wall_time_s for t in rec.records
                      if t.op in traced.traced_ops) * 1e6
        mode = ",".join(m.value for m in plan.modes)
        rows.append(csv_row(
            f"replay_{arch}_record", wall_us,
            f"{len(traced.traced_ops)}/{len(plan.layers) + len(plan.gemms)} "
            f"ops recorded (mode {mode}); grids "
            + " ".join(str(tuple(t.grid)) for t in rec.records
                       if t.op in traced.traced_ops)))
        rows.append(csv_row(
            f"replay_{arch}_mixed", 0.0,
            f"replayed {replayed.replayed_ops} ops: {replayed.cycles} cyc "
            f"vs analytic {analytic.cycles} cyc "
            f"({replayed.cycles / analytic.cycles:.2f}x)"))
        for kind, c in sorted(report.per_class.items()):
            rows.append(csv_row(
                f"replay_{arch}_error_{kind}", 0.0,
                f"recorded/analytic ratio {c['ratio']:.1f}x over "
                f"{int(c['count'])} ops; mean |rel err| "
                f"{c['mean_abs_rel_err']:.2f}"))
        rows.append(csv_row(
            f"replay_{arch}_calibrated", 0.0,
            f"calibrated sim {calibrated.cycles} cyc "
            f"({calibrated.cycles / analytic.cycles:.1f}x analytic; "
            f"scales "
            + " ".join(f"{r}={s:.0f}" for r, s in
                       sorted(report.scale.items())) + ")"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
