"""Kernel micro-benchmarks: interpret-mode Pallas vs blocked-jnp vs oracle
at reduced sizes (CPU wall-time is a correctness/overhead check, not a TPU
projection)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.kernels import jnp_blocked as JB
from repro.kernels import ops, ref


def run() -> List[str]:
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    B, Hq, Hkv, S, hd, D = 1, 8, 2, 512, 64, 256
    q = jax.random.normal(ks[0], (B, Hq, S, hd)) * 0.3
    x = jax.random.normal(ks[1], (B, S, D)) * 0.3
    wk = jax.random.normal(ks[2], (D, Hkv, hd)) * (D ** -0.5)
    wv = jax.random.normal(ks[3], (D, Hkv, hd)) * (D ** -0.5)
    k = jnp.einsum("bsd,dhe->bhse", x, wk)
    v = jnp.einsum("bsd,dhe->bhse", x, wv)

    t = time_fn(jax.jit(lambda *a: ref.ref_attention(*a, causal=True)),
                q, k, v) * 1e6
    rows.append(csv_row("kernel_ref_attention", t, "materialized oracle"))
    t = time_fn(jax.jit(lambda *a: JB.flash_attention_jnp(
        *a, causal=True, block_k=128)), q, k, v) * 1e6
    rows.append(csv_row("kernel_flash_jnp", t, "blocked lowerable path"))
    t = time_fn(jax.jit(lambda *a: JB.stream_attention_jnp(
        *a, causal=True, block_k=128)), q, x, wk, wv) * 1e6
    rows.append(csv_row("kernel_stream_jnp", t, "fused KV-gen + attention"))
    t = time_fn(jax.jit(lambda *a: ops.multi_head_attention(
        *a, causal=True, use_pallas=True)), q, k, v) * 1e6
    rows.append(csv_row("kernel_flash_pallas_interpret", t,
                        "Pallas interpret mode (Python-emulated grid)"))

    # SSD
    Bs, Ss, H, P, N = 1, 512, 4, 32, 16
    xs = jax.random.normal(ks[4], (Bs, Ss, H, P)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[5], (Bs, Ss, H)))
    a = -jnp.exp(jax.random.normal(ks[0], (H,)) * 0.5)
    b = jax.random.normal(ks[1], (Bs, Ss, N)) * 0.3
    c = jax.random.normal(ks[2], (Bs, Ss, N)) * 0.3
    t = time_fn(jax.jit(lambda *args: ref.ref_ssd(*args)),
                xs, dt, a, b, c) * 1e6
    rows.append(csv_row("kernel_ssd_sequential_ref", t, "per-step scan"))
    t = time_fn(jax.jit(lambda *args: JB.ssd_chunked_jnp(
        *args, chunk=128)[0]), xs, dt, a, b, c) * 1e6
    rows.append(csv_row("kernel_ssd_chunked", t,
                        "SSD chunked (tile-streaming analogue)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
