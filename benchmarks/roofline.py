"""Roofline table builder (deliverable g): reads launch/dryrun.py artifacts
and emits the per-(arch x shape x mesh) three-term roofline table for
EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

ART_DIR = os.environ.get("DRYRUN_ART", "artifacts/dryrun")

COLUMNS = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
           "dcn_s", "bottleneck", "roofline_fraction", "useful_flop_ratio",
           "mem_gib", "microbatches")


def load_cells(art_dir: str = ART_DIR) -> List[Dict]:
    cells = []
    if not os.path.isdir(art_dir):
        return cells
    for fn in sorted(os.listdir(art_dir)):
        if fn.endswith(".json"):
            with open(os.path.join(art_dir, fn)) as f:
                cells.append(json.load(f))
    return cells


def row_of(c: Dict) -> Optional[Dict]:
    if c.get("status") != "ok":
        return {"arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
                "status": c.get("status"),
                "note": c.get("reason") or c.get("error", "")[:60]}
    r = c["roofline"]
    return {
        "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
        "status": "ok",
        "compute_s": r["compute_s"], "memory_s": r["memory_s"],
        "collective_s": r["collective_s"], "dcn_s": r["dcn_s"],
        "bottleneck": r["bottleneck"].replace("_s", ""),
        "roofline_fraction": r["roofline_fraction"],
        "useful_flop_ratio": c.get("useful_flop_ratio"),
        "mem_gib": c["memory"]["total_bytes"] / 2 ** 30,
        "microbatches": c.get("microbatches", 1),
    }


def table(art_dir: str = ART_DIR, mesh: Optional[str] = None) -> str:
    rows = [row_of(c) for c in load_cells(art_dir)]
    rows = [r for r in rows if r and (mesh is None or r["mesh"] == mesh)]
    lines = [f"{'arch':20s} {'shape':12s} {'mesh':8s} {'comp(s)':>9s} "
             f"{'mem(s)':>9s} {'coll(s)':>9s} {'dcn(s)':>9s} {'bound':>7s} "
             f"{'RLfrac':>7s} {'useful':>7s} {'GiB/dev':>8s} {'mb':>3s}"]
    for r in sorted(rows, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
        if r["status"] != "ok":
            lines.append(f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:8s} "
                         f"-- {r['status']}: {r['note']}")
            continue
        lines.append(
            f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['compute_s']:9.3g} {r['memory_s']:9.3g} "
            f"{r['collective_s']:9.3g} {r['dcn_s']:9.3g} "
            f"{r['bottleneck']:>7s} {r['roofline_fraction']:7.3f} "
            f"{(r['useful_flop_ratio'] or 0):7.2f} {r['mem_gib']:8.2f} "
            f"{r['microbatches']:3d}")
    return "\n".join(lines)


def run() -> List[str]:
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]
    errors = [c for c in cells if c.get("status") == "error"]
    rows = [f"roofline_cells_ok,{len(ok)},baseline: skipped={len(skipped)} "
            f"errors={len(errors)}"]
    if ok:
        worst = min(ok, key=lambda c: c["roofline"]["roofline_fraction"])
        best = max(ok, key=lambda c: c["roofline"]["roofline_fraction"])
        rows.append(f"roofline_worst,0.0,{worst['arch']}/{worst['shape']}"
                    f"@{worst['mesh']} frac="
                    f"{worst['roofline']['roofline_fraction']:.3f}")
        rows.append(f"roofline_best,0.0,{best['arch']}/{best['shape']}"
                    f"@{best['mesh']} frac="
                    f"{best['roofline']['roofline_fraction']:.3f}")
    # optimized (beyond-paper preset) sweep vs baseline
    opt = [c for c in load_cells("artifacts/dryrun_opt")
           if c.get("status") == "ok"]
    if ok and opt:
        base_map = {(c["arch"], c["shape"], c["mesh"]):
                    c["roofline"]["step_time_est_s"] for c in ok}
        geo, n = 1.0, 0
        for c in opt:
            k = (c["arch"], c["shape"], c["mesh"])
            if k in base_map and c["roofline"]["step_time_est_s"] > 0:
                geo *= base_map[k] / c["roofline"]["step_time_est_s"]
                n += 1
        if n:
            best_o = max(opt,
                         key=lambda c: c["roofline"]["roofline_fraction"])
            rows.append(f"roofline_optimized_cells,{n},geomean step-est "
                        f"speedup {geo ** (1 / n):.2f}x vs paper-faithful "
                        f"baseline")
            rows.append(f"roofline_optimized_best,0.0,"
                        f"{best_o['arch']}/{best_o['shape']}@{best_o['mesh']}"
                        f" frac={best_o['roofline']['roofline_fraction']:.3f}")
    return rows


if __name__ == "__main__":
    print(table())
