"""Causal critical-path + what-if projection tests (DESIGN.md §14):
event-DAG dep stamping, path==makespan property across all three modes,
the §I exposed-rewrite result stated causally, sharded/serve coverage,
what-if identity + validation against re-simulation, headroom, Perfetto
flow events, and the Trace cached-aggregate invalidation audit.
"""
from __future__ import annotations

import math
import sys
from fractions import Fraction

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    sys.path.insert(0, "tests")
    from _hypothesis_fallback import given, settings, st

from repro.configs import registry
from repro.core.types import ExecutionMode as EM
from repro.obs.critpath import (compute_slack, critical_path,
                                format_critpath)
from repro.obs.whatif import (headroom, parse_whatif, project, run_whatif,
                              whatif_link_bandwidth, whatif_ping_pong,
                              whatif_resource)
from repro.plan import plan_model
from repro.shard import MeshSpec, shard_plan
from repro.shard.sim import simulate_sharded_plan
from repro.sim import rewrite_stall_trace, simulate_plan
from repro.sim.dataflow import Engine
from repro.sim.trace import Event, Trace

HW = registry.get_hw_config("streamdcim-base")
SMOKE = registry.get_config("vilbert-base", smoke=True)


def _check_dag(trace):
    """The scheduling-DAG invariant: every event starts at 0 (no gating
    deps) or exactly at the max end over its stamped deps."""
    by_id = {e.task_id: e for e in trace.events}
    for e in trace.events:
        assert all(d in by_id for d in e.deps), (e.tag, e.deps)
        if e.start == 0:
            continue
        assert e.deps, (e.tag, "start > 0 with no deps")
        assert max(by_id[d].end for d in e.deps) == e.start, e.tag


# ---------------------------------------------------------------------------
# Dep stamping (Engine.run)
# ---------------------------------------------------------------------------

def test_engine_stamps_data_and_resource_deps():
    eng = Engine()
    a = eng.task("compute", "GEN", 10, tag="a")
    b = eng.task("compute", "GEN", 5, [a], tag="b")       # data + resource
    c = eng.task("dma", "HBM", 7, [a], tag="c")           # data only
    tr = eng.run()
    ev = {e.tag: e for e in tr.events}
    assert ev["a"].deps == ()
    assert set(ev["b"].deps) == {a}       # data dep == resource pred, deduped
    assert ev["c"].deps == (a,)
    _check_dag(tr)


def test_engine_resolves_sync_barriers_to_real_events():
    """SYNC tasks are never emitted; deps routed through a barrier are
    flattened to the real events behind it (transitively)."""
    eng = Engine()
    a = eng.task("compute", "GEN", 10, tag="a")
    b = eng.task("dma", "HBM", 20, tag="b")
    bar = eng.barrier([a, b])
    bar2 = eng.barrier([bar])                              # nested sync
    c = eng.task("compute", "ATTN", 5, [bar2], tag="c")
    tr = eng.run()
    ev = {e.tag: e for e in tr.events}
    assert all(e.resource != "SYNC" for e in tr.events)
    assert set(ev["c"].deps) == {a, b}
    assert ev["c"].start == 20
    _check_dag(tr)


def test_engine_resource_occupancy_dep():
    """Two independent tasks on one resource: the second's only dep is
    the in-order occupancy predecessor."""
    eng = Engine()
    a = eng.task("compute", "ATTN", 10, tag="a")
    eng.task("compute", "ATTN", 10, tag="b")
    tr = eng.run()
    ev = {e.tag: e for e in tr.events}
    assert ev["b"].deps == (a,)
    assert ev["b"].start == 10


# ---------------------------------------------------------------------------
# Critical path == makespan (property, all three modes + serve + shard)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seq=st.integers(min_value=96, max_value=640))
def test_critical_path_tiles_makespan_all_modes(seq):
    for mode in (EM.TILE_STREAM, EM.LAYER_STREAM, EM.NON_STREAM):
        plan = plan_model(SMOKE, hw=HW, seq_len=seq, mode=mode,
                          force_mode=True)
        res = simulate_plan(plan)
        _check_dag(res.trace)
        rep = critical_path(res.trace)
        assert rep.path_cycles == rep.makespan == res.cycles
        # path tiles [0, makespan] with no gaps
        assert rep.path[0].start == 0
        assert rep.path[-1].end == rep.makespan
        for a, b in zip(rep.path, rep.path[1:]):
            assert a.end == b.start
        # on-path cycles account for the whole makespan, by any split
        assert sum(rep.critical_by_resource.values()) == rep.makespan
        assert sum(rep.critical_by_kind.values()) == rep.makespan


def test_critical_path_on_serve_trace():
    from repro.serve.schedule import ServeRequest
    from repro.sim import simulate_serve
    cfg = registry.get_config("starcoder2-7b", smoke=True)
    sim = simulate_serve(
        cfg, [ServeRequest(0, 24, 4, 0), ServeRequest(1, 12, 6, 1)],
        slots=2)
    _check_dag(sim.result.trace)
    rep = critical_path(sim.result.trace)
    assert rep.path_cycles == rep.makespan == sim.cycles


def test_one_chip_sharded_critical_path_identical_to_unsharded():
    plan = plan_model(SMOKE, hw=HW, mode=EM.TILE_STREAM, force_mode=True)
    base = critical_path(simulate_plan(plan).trace)
    shard = critical_path(
        simulate_sharded_plan(shard_plan(plan, MeshSpec(chips=1))).trace)
    assert shard.makespan == base.makespan
    assert [(e.kind, e.start, e.end) for e in shard.path] \
        == [(e.kind, e.start, e.end) for e in base.path]
    assert shard.critical_by_resource == base.critical_by_resource
    assert shard.exposed_rewrite_cycles == base.exposed_rewrite_cycles


def test_interconnect_on_path_detection():
    """A starved NoC puts link events on the critical path; the report
    folds ``NOC_*`` to INTERCONNECT.  A generous NoC stays off-path."""
    plan = plan_model(SMOKE, hw=HW, mode=EM.NON_STREAM, force_mode=True)
    starved = simulate_sharded_plan(shard_plan(
        plan, MeshSpec(chips=4, link_bytes_per_cycle=1)))
    _check_dag(starved.trace)
    rep = critical_path(starved.trace)
    assert rep.path_cycles == rep.makespan
    assert rep.interconnect_share > 0.2
    generous = critical_path(simulate_sharded_plan(shard_plan(
        plan, MeshSpec(chips=4, link_bytes_per_cycle=65536))).trace)
    assert generous.interconnect_share < rep.interconnect_share


# ---------------------------------------------------------------------------
# §I exposed-rewrite result, stated causally
# ---------------------------------------------------------------------------

def test_critpath_reproduces_si_exposed_rewrite_causally():
    """Serial: rewrites occupy the attention array and sit ON the path
    for exactly 4/7 of the makespan (the paper's 57%).  Ping-pong: zero
    exposed rewrite cycles on the path (shadow-bus rewrites may still be
    on-path — that is the bandwidth-bound residue, reported separately
    as overlapped)."""
    serial = critical_path(rewrite_stall_trace(HW, ping_pong=False))
    assert Fraction(serial.exposed_rewrite_cycles, serial.makespan) \
        == Fraction(4, 7)
    assert serial.overlapped_rewrite_cycles == 0

    pp = critical_path(rewrite_stall_trace(HW, ping_pong=True))
    assert pp.exposed_rewrite_cycles == 0
    assert pp.makespan < serial.makespan


def test_critpath_modes_ordering_on_model():
    """LAYER_STREAM exposes rewrites on the path; TILE_STREAM's ride the
    shadow bus (zero exposed on-path)."""
    layer = critical_path(simulate_plan(plan_model(
        SMOKE, hw=HW, mode=EM.LAYER_STREAM, force_mode=True)).trace)
    tile = critical_path(simulate_plan(plan_model(
        SMOKE, hw=HW, mode=EM.TILE_STREAM, force_mode=True)).trace)
    assert layer.exposed_rewrite_cycles > 0
    assert tile.exposed_rewrite_cycles == 0


def test_slack_zero_on_path_and_histogram():
    tr = rewrite_stall_trace(HW, ping_pong=True)
    rep = critical_path(tr)
    on_path = {e.task_id for e in rep.path}
    for tid in on_path:
        assert rep.slack[tid] == 0
    assert all(s >= 0 for s in rep.slack.values())
    assert sum(c for _, c in rep.slack_histogram) == len(tr.events)
    # format smoke
    text = format_critpath(rep, title="pp")
    assert "critical path" in text and "slack histogram" in text


def test_compute_slack_simple_chain():
    eng = Engine()
    a = eng.task("compute", "GEN", 10, tag="a")
    eng.task("compute", "ATTN", 100, [a], tag="long")
    eng.task("dma", "HBM", 5, [a], tag="short")
    tr = eng.run()
    slack = compute_slack(list(tr.events), tr.makespan)
    ev = {e.tag: e for e in tr.events}
    assert slack[ev["a"].task_id] == 0
    assert slack[ev["long"].task_id] == 0
    assert slack[ev["short"].task_id] == 110 - 15


# ---------------------------------------------------------------------------
# What-if projection
# ---------------------------------------------------------------------------

def test_whatif_k1_is_exact_identity():
    for mode in (EM.TILE_STREAM, EM.LAYER_STREAM, EM.NON_STREAM):
        res = simulate_plan(plan_model(SMOKE, hw=HW, mode=mode,
                                       force_mode=True))
        assert project(res.trace, {}).projected_makespan == res.cycles
        p = project(res.trace, {"ATTN": 1.0, "HBM": 1.0, "GEN": 1.0})
        assert p.projected_makespan == res.cycles
        assert p.speedup == 1.0


@pytest.mark.parametrize("model", ["vilbert-base", "qwen2-vl-2b"])
@pytest.mark.parametrize("resource,k", [("ATTN", 2.0), ("HBM", 4.0),
                                        ("GEN", 2.0)])
def test_whatif_matches_resimulation(model, resource, k):
    """Projection over the fixed DAG vs full re-simulation with the
    matching calibration scale: pinned tolerance 1% (the residual is
    per-task integer rounding only — issue order is identical by
    construction)."""
    cfg = registry.get_config(model, smoke=True)
    for mode in (EM.TILE_STREAM, EM.LAYER_STREAM):
        plan = plan_model(cfg, hw=HW, mode=mode, force_mode=True)
        base = simulate_plan(plan)
        proj = whatif_resource(base.trace, resource, k)
        resim = simulate_plan(plan, calibration={resource: 1.0 / k})
        assert proj.projected_makespan == pytest.approx(resim.cycles,
                                                        rel=0.01)
        assert proj.baseline_makespan == base.cycles


def test_whatif_ping_pong_off_reconstructs_serial():
    """Folding the shadow-bus rewrites back onto the attention array
    projects the ping-pong §I trace onto the serial makespan exactly."""
    serial = rewrite_stall_trace(HW, ping_pong=False)
    pp = rewrite_stall_trace(HW, ping_pong=True)
    off = whatif_ping_pong(pp)
    assert off.projected_makespan == serial.makespan
    assert "off" in off.label


def test_whatif_ping_pong_on_is_perfect_overlap_bound():
    serial = rewrite_stall_trace(HW, ping_pong=False)
    pp = rewrite_stall_trace(HW, ping_pong=True)
    on = whatif_ping_pong(serial)
    assert "on" in on.label
    # the bound: pure compute chain; no worse than the real ping-pong
    assert on.projected_makespan <= pp.makespan
    assert on.projected_makespan == serial.makespan \
        - critical_path(serial).exposed_rewrite_cycles


def test_whatif_link_bandwidth_vs_resim():
    """INTERCONNECT k× projection vs re-simulating with every NoC link's
    cycles scaled (per-link calibration keys reach _ShardEngine raw)."""
    plan = plan_model(SMOKE, hw=HW, mode=EM.NON_STREAM, force_mode=True)
    sp = shard_plan(plan, MeshSpec(chips=4, link_bytes_per_cycle=4))
    base = simulate_sharded_plan(sp)
    proj = whatif_link_bandwidth(base.trace, 2.0)
    links = {e.resource for e in base.trace.events
             if e.resource.startswith("NOC_")}
    assert links, "expected NoC link events"
    resim = simulate_sharded_plan(
        sp, calibration={ln: 0.5 for ln in links})
    assert proj.projected_makespan == pytest.approx(resim.cycles, rel=0.01)


def test_headroom_ranks_causal_bottleneck():
    res = simulate_plan(plan_model(SMOKE, hw=HW, mode=EM.NON_STREAM,
                                   force_mode=True))
    hr = headroom(res.trace)
    assert set(hr) == {base for base in
                       {e.resource for e in res.trace.events}}
    assert all(0.0 <= v < 1.0 for v in hr.values())
    # NON_STREAM is HBM-bound: freeing HBM buys the most
    assert max(hr, key=hr.get) == "HBM"
    assert hr["HBM"] > 0.5


def test_whatif_cli_spec_parsing_and_dispatch():
    assert parse_whatif("ATTN:2") == ("ATTN", 2.0)
    assert parse_whatif("ping_pong") == ("ping_pong", 1.0)
    with pytest.raises(ValueError):
        parse_whatif(":3")
    with pytest.raises(ValueError):
        parse_whatif("ATTN:fast")
    tr = rewrite_stall_trace(HW, ping_pong=False)
    assert run_whatif(tr, "ATTN:2").speedup > 1.0
    assert run_whatif(tr, "ping_pong").speedup > 1.0
    with pytest.raises(ValueError):
        project(tr, {"ATTN": 0.0})


def test_sweeprow_carries_headroom():
    from repro.dse.sweep import simulate_point
    row = simulate_point(SMOKE, HW)
    assert row.headroom
    assert all(0.0 <= v < 1.0 for v in row.headroom.values())
    assert "headroom" in row.to_dict()


# ---------------------------------------------------------------------------
# Perfetto flow events
# ---------------------------------------------------------------------------

def test_timeline_critical_path_flow_events_validate():
    from repro.obs.timeline import timeline_from_trace, validate_timeline
    tr = rewrite_stall_trace(HW, ping_pong=True)
    tl = timeline_from_trace(tr, title="pp", critical_path=True)
    validate_timeline(tl)
    flows = [e for e in tl["traceEvents"] if e.get("ph") in ("s", "f")]
    n_path = len(critical_path(tr).path)
    assert len(flows) == 2 * (n_path - 1)
    starts = [e for e in flows if e["ph"] == "s"]
    finishes = [e for e in flows if e["ph"] == "f"]
    assert {e["id"] for e in starts} == {e["id"] for e in finishes}
    assert all(e.get("bp") == "e" for e in finishes)
    # plain timelines carry no flow events (goldens unchanged)
    plain = timeline_from_trace(tr, title="pp")
    assert not [e for e in plain["traceEvents"]
                if e.get("ph") in ("s", "f")]


# ---------------------------------------------------------------------------
# Trace cached-aggregate invalidation (the stale-cache audit)
# ---------------------------------------------------------------------------

def _ev(task_id, start, end, resource="ATTN", kind="compute"):
    return Event(task_id, kind, resource, start, end)


def test_trace_cache_invalidated_by_same_length_replacement():
    """The audited hole: replacing an event in place keeps len() equal,
    which the old length-only check missed — aggregates went stale."""
    tr = Trace()
    tr.add(_ev(0, 0, 100))
    assert tr.makespan == 100
    tr.events[0] = _ev(0, 0, 250)
    assert tr.makespan == 250


def test_trace_cache_invalidated_by_all_mutations():
    tr = Trace()
    tr.add(_ev(0, 0, 10))
    tr.add(_ev(1, 10, 30))
    assert tr.makespan == 30
    tr.events.append(_ev(2, 30, 45))          # direct append (replay path)
    assert tr.makespan == 45
    tr.events.pop()
    assert tr.makespan == 30
    tr.events.extend([_ev(2, 30, 60)])
    assert tr.makespan == 60
    del tr.events[-1]
    assert tr.makespan == 30
    tr.events.sort(key=lambda e: -e.start)    # reorder: same aggregate
    assert tr.makespan == 30
    tr.events.clear()
    assert tr.makespan == 0


def test_trace_events_setter_rewraps():
    tr = Trace()
    tr.add(_ev(0, 0, 10))
    tr.events = [_ev(0, 0, 99)]
    assert tr.makespan == 99
    tr.events[0] = _ev(0, 0, 7)               # still version-tracked
    assert tr.makespan == 7
