"""Per-assigned-architecture smoke tests: reduced config of the same family,
one forward + one train step on CPU, asserting output shapes + no NaNs
(deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import runtime
from repro.core.types import Family, ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.train import optimizer as OPT
from repro.train import steps as ST

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", list(registry.ARCHS))
def test_arch_smoke_forward_and_train_step(arch):
    cfg = registry.get_config(arch, smoke=True)
    mod = registry.model_module(cfg)
    src = SyntheticLM(cfg, SMOKE_SHAPE, seed=1)
    batch = jax.tree.map(jnp.asarray, src.batch(0))

    params = mod.init(jax.random.PRNGKey(0), cfg)
    out = mod.forward(params, cfg, batch)
    if cfg.family == Family.CROSSMODAL:
        assert out.shape == (2, 3129)
    else:
        assert out.shape[:2] == (2, 32)
        assert out.shape[2] >= cfg.vocab_size
    assert bool(jnp.isfinite(out).all()), f"{arch}: non-finite forward"

    step = ST.make_train_step(
        cfg, OPT.OptimizerConfig(learning_rate=1e-3, warmup_steps=1))
    opt_state = OPT.init(params)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: NaN loss"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0, f"{arch}: optimizer step was a no-op"


@pytest.mark.parametrize("arch", [a for a in registry.ASSIGNED
                                  if registry.cell_supported(a, "decode_32k")
                                  is None])
def test_arch_smoke_prefill_decode(arch):
    cfg = registry.get_config(arch, smoke=True)
    mod = registry.model_module(cfg)
    B, S = 2, 24
    src = SyntheticLM(cfg, ShapeConfig("s", S, B, "prefill"), seed=2)
    batch = jax.tree.map(jnp.asarray, src.batch(0))
    with runtime.flags(moe_capacity=100.0):
        params = mod.init(jax.random.PRNGKey(0), cfg)
        logits_fwd = mod.forward(params, cfg, batch)
        pf_batch = {k: (v[:, :S - 1] if k in ("tokens",) else v)
                    for k, v in batch.items() if k != "labels"}
        if "positions" in pf_batch:
            pf_batch["positions"] = batch["positions"][:, :, :S - 1]
        _, cache = mod.prefill(params, cfg, pf_batch, max_len=S + 8)
        logits_dec, cache = mod.decode_step(params, cfg, cache,
                                            batch["tokens"][:, S - 1:S])
    assert bool(jnp.isfinite(logits_dec).all()), f"{arch}: NaN decode"
    if cfg.family not in (Family.VLM,):     # vlm fwd uses mrope; decode 1-D
        np.testing.assert_allclose(
            np.asarray(logits_dec[:, 0, :64]),
            np.asarray(logits_fwd[:, -1, :64]), atol=5e-2, rtol=5e-2)


def test_full_configs_param_counts():
    """Exact configs match published parameter counts (±10%)."""
    expected = {
        "starcoder2-7b": 7.4e9, "qwen3-32b": 32.8e9, "minitron-4b": 4.2e9,
        "h2o-danube3-4b": 4.0e9, "qwen2-vl-2b": 1.5e9,
        "grok-1-314b": 314e9, "deepseek-v3-671b": 671e9,
        "hymba-1.5b": 1.5e9, "mamba2-780m": 0.78e9, "whisper-base": 0.06e9,
    }
    for arch, n_exp in expected.items():
        n = registry.get_config(arch).param_count()
        assert abs(n - n_exp) / n_exp < 0.15, (arch, n, n_exp)


def test_moe_active_params():
    ds = registry.get_config("deepseek-v3-671b")
    assert abs(ds.active_param_count() - 37e9) / 37e9 < 0.1
    gk = registry.get_config("grok-1-314b")
    assert abs(gk.active_param_count() - 86e9) / 86e9 < 0.1


def test_cell_skip_reasons():
    assert registry.cell_supported("qwen3-32b", "long_500k") is not None
    assert registry.cell_supported("mamba2-780m", "long_500k") is None
    assert registry.cell_supported("hymba-1.5b", "long_500k") is None
    assert registry.cell_supported("h2o-danube3-4b", "long_500k") is None
    assert registry.cell_supported("starcoder2-7b", "train_4k") is None
