"""ExecutionPlan API (PR 2, DESIGN.md §8): plan consistency across the
three consumers, deprecation-shim agreement, serialization round-trip,
heterogeneous plans, and planner-resolved serving.

The load-bearing invariant: ONE plan object, built once per (model,
shape, hw) triple, is what the kernel path, the simulator, and the
serving engine all consume — and its predicted per-layer HBM bytes equal
the legacy analytic model AND the simulator's DMA accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import streaming
from repro.core.types import (ExecutionMode, Family, ModelConfig, SHAPES)
from repro.kernels import ops
from repro.plan import (ExecutionPlan, plan_attention, plan_model,
                        resolve_layer_mode, tile_stream_profitable)
from repro.serve.engine import Engine
from repro.sim import (STREAMDCIM_BASE, build_workload, compare_modes,
                       simulate_model, simulate_plan)
from repro.sim.workload import AttnOp

EM = ExecutionMode

PLANNABLE = [a for a in registry.ARCHS
             if registry.get_config(a).num_heads > 0]


# ------------------------------------------------------- plan consistency

@pytest.mark.parametrize("arch", PLANNABLE)
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_plan_bytes_match_analytic_model_everywhere(arch, shape):
    """For every registry model x shape cell, every LayerPlan's predicted
    bytes equal the legacy analytic entry point called with the plan's own
    recorded geometry and resolved mode — the planner and the deprecation
    shim cannot drift apart."""
    cfg = registry.get_config(arch)
    plan = plan_model(cfg, shape)
    assert plan.layers, arch
    assert plan.shape == shape
    for lp in plan.layers:
        ana = streaming.streamed_bytes_per_layer(
            lp.seq_q, lp.seq_kv, lp.d_kv, lp.heads, lp.kv_heads,
            lp.head_dim, lp.mode, block_q=lp.block_q,
            bytes_per_el=STREAMDCIM_BASE.act_bytes)
        assert lp.hbm_bytes == ana, lp.name


@pytest.mark.parametrize("arch", registry.SIM_ARCHS)
def test_plan_bytes_match_simulated_dma_bytes(arch):
    """Three-way equality, third leg: the simulator's per-op HBM DMA
    accounting agrees with the same plan's prediction (10% covers DMA
    burst rounding) — extends the PR-1 cross-validation to the plan API."""
    cfg = registry.get_config(arch)
    for mode in ExecutionMode:
        plan = plan_model(cfg, mode=mode, force_mode=True)
        res = simulate_plan(plan)
        for lp in plan.layers[:2] + plan.layers[-1:]:
            sim_bytes = res.op_dma_bytes(lp.name)
            assert sim_bytes == pytest.approx(lp.hbm_bytes, rel=0.10), \
                (arch, mode, lp.name)


def test_attention_free_archs_rejected_clearly():
    cfg = registry.get_config("mamba2-780m")
    with pytest.raises(ValueError, match="attention-free"):
        plan_model(cfg)


# -------------------------------------------------- deprecation shims

@pytest.mark.parametrize("arch", PLANNABLE)
def test_choose_mode_shim_agrees_with_planner(arch):
    """The legacy per-config entry point must resolve exactly what the
    planner records for the model's self-attention layers (cross-attention
    layers may legitimately differ: the planner sees the true KV-source
    width)."""
    cfg = registry.get_config(arch)
    plan = plan_model(cfg)
    legacy = streaming.choose_mode(cfg)
    for lp in plan.layers:
        if lp.cross or lp.d_kv != cfg.d_model:
            continue
        assert lp.mode == legacy, lp.name


def test_streaming_shims_emit_deprecation_warnings():
    """ISSUE-4 satellite: the ``core.streaming`` shims must announce
    their replacement — silence kept PR-0/1 call sites on the legacy
    path indefinitely."""
    cfg = registry.get_config("vilbert-base")
    with pytest.warns(DeprecationWarning, match="plan_model"):
        streaming.choose_mode(cfg)
    with pytest.warns(DeprecationWarning, match="attn_hbm_bytes"):
        streaming.streamed_bytes_per_layer(
            seq_q=256, seq_kv=256, d_model=512, num_heads=4,
            num_kv_heads=4, head_dim=128, mode=EM.TILE_STREAM)


def test_choose_mode_shim_still_honors_explicit_baselines():
    base = dict(name="t", family=Family.DENSE, num_layers=1, d_model=1024,
                num_heads=8, num_kv_heads=8, d_ff=1, vocab_size=8,
                head_dim=128)
    for forced in (EM.NON_STREAM, EM.LAYER_STREAM):
        cfg = ModelConfig(**{**base, "execution_mode": forced})
        assert streaming.choose_mode(cfg) == forced
        assert plan_model(cfg).uniform_mode == forced


def test_attention_by_mode_shim_matches_attention_by_plan():
    """The legacy dispatch and the plan dispatch are the same computation
    (shim == planner force_mode semantics)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    B, H, Sq, Sk, hd, D = 1, 4, 96, 128, 32, 128
    q = jax.random.normal(ks[0], (B, H, Sq, hd)) * 0.4
    x_kv = jax.random.normal(ks[1], (B, Sk, D)) * 0.4
    wk = jax.random.normal(ks[2], (D, H, hd)) * (D ** -0.5)
    wv = jax.random.normal(ks[3], (D, H, hd)) * (D ** -0.5)
    for mode in ExecutionMode:
        lp = plan_attention(mode, seq_q=Sq, seq_kv=Sk, d_kv=D, heads=H,
                            kv_heads=H, head_dim=hd)
        assert lp.mode == mode                     # force_mode pins verbatim
        by_plan = ops.attention_by_plan(lp, q, x_kv, wk, wv, causal=True)
        by_mode = ops.attention_by_mode(mode, q, x_kv, wk, wv, causal=True)
        np.testing.assert_allclose(np.asarray(by_plan), np.asarray(by_mode),
                                   atol=1e-6, rtol=1e-6)


def test_plan_attention_resolution_matches_rules():
    gqa = dict(seq_q=256, seq_kv=256, d_kv=5120, heads=40, kv_heads=8,
               head_dim=128)
    lp = plan_attention(EM.TILE_STREAM, force_mode=False, **gqa)
    assert lp.mode == EM.LAYER_STREAM              # GQA fallback
    assert not lp.fuse_kv
    assert not tile_stream_profitable(5120, 8, 128)
    assert resolve_layer_mode(EM.TILE_STREAM, d_kv=5120, num_kv_heads=8,
                              head_dim=128) == EM.LAYER_STREAM


# ------------------------------------------------- serialization round-trip

def test_json_round_trip_reproduces_three_way_ordering():
    """plan_model(...).to_json() -> load -> simulate_model(plan) reproduces
    PR-1's three-way geomean ordering (the acceptance criterion)."""
    cfg = registry.get_config("vilbert-base")
    cycles = {}
    for mode in ExecutionMode:
        plan = plan_model(cfg, mode=mode, force_mode=True)
        restored = ExecutionPlan.from_json(plan.to_json())
        assert restored == plan                    # exact dataclass equality
        cycles[mode] = simulate_model(restored).cycles
    assert cycles[EM.TILE_STREAM] < cycles[EM.LAYER_STREAM] \
        < cycles[EM.NON_STREAM]
    # PR-1 acceptance floors (paper: 2.63x / 1.28x geomean).
    assert cycles[EM.NON_STREAM] / cycles[EM.TILE_STREAM] >= 2.0
    assert cycles[EM.LAYER_STREAM] / cycles[EM.TILE_STREAM] >= 1.1


def test_json_rejects_unknown_version():
    plan = plan_model(registry.get_config("whisper-base"))
    d = plan.to_dict()
    d["version"] = 999
    with pytest.raises(ValueError, match="version"):
        ExecutionPlan.from_dict(d)


# ------------------------------------------------------ heterogeneous plans

def test_heterogeneous_plan_simulates_end_to_end():
    """Different modes on different layers of one model simulate in one
    run, landing strictly between the homogeneous extremes."""
    cfg = registry.get_config("vilbert-base")
    tile = simulate_plan(plan_model(cfg, mode=EM.TILE_STREAM,
                                    force_mode=True))
    layer = simulate_plan(plan_model(cfg, mode=EM.LAYER_STREAM,
                                     force_mode=True))
    het_plan = plan_model(cfg, layer_modes={
        i: (EM.LAYER_STREAM if i % 2 else EM.TILE_STREAM)
        for i in range(cfg.num_layers)})
    assert het_plan.heterogeneous
    assert set(het_plan.modes) == {EM.TILE_STREAM, EM.LAYER_STREAM}
    het = simulate_plan(het_plan)
    assert het.mode is None                        # no single mode
    assert tile.cycles < het.cycles < layer.cycles
    assert tile.hbm_bytes < het.hbm_bytes < layer.hbm_bytes
    # Simulated totals still track the heterogeneous plan's prediction.
    predicted = het_plan.total_hbm_bytes
    attn_sim = sum(het.op_dma_bytes(lp.name) for lp in het_plan.layers)
    assert attn_sim == pytest.approx(predicted, rel=0.10)


def test_with_layer_modes_recomputes_predictions():
    cfg = registry.get_config("vilbert-base")
    plan = plan_model(cfg)                         # all TILE_STREAM (MHA)
    name = plan.layers[0].name
    changed = plan.with_layer_modes({name: EM.NON_STREAM})
    lp0, lp1 = plan.layer(name), changed.layer(name)
    assert lp1.mode == EM.NON_STREAM and not lp1.fuse_kv
    assert lp1.hbm_bytes > lp0.hbm_bytes           # NON_STREAM round-trips
    # Untouched layers are identical; gemms of the layer follow its mode.
    assert changed.layers[1:] == plan.layers[1:]
    li = lp1.layer_index
    assert all(g.mode == EM.NON_STREAM for g in changed.gemms
               if g.layer_index == li)


def test_layer_override_moves_the_ops_own_projection():
    """An op-name override must also move that op's output projection to
    the new mode (gemms follow the nearest *preceding* attention op, not
    the layer's first attention op)."""
    cfg = registry.get_config("whisper-base")
    plan = plan_model(cfg, layer_modes={"dec0_cross": EM.NON_STREAM})
    assert plan.layer("dec0_cross").mode == EM.NON_STREAM
    assert plan.layer("dec0_self").mode == EM.TILE_STREAM
    gemm_modes = {g.name: g.mode for g in plan.gemms}
    assert gemm_modes["dec0_cross_oproj"] == EM.NON_STREAM
    assert gemm_modes["dec0_self_oproj"] == EM.TILE_STREAM
    # FFN gemms trail the cross op — they follow the override too.
    assert gemm_modes["dec0_ffn_up"] == EM.NON_STREAM


def test_compare_modes_honors_ad_hoc_hardware():
    """A modified (even unregistered) HardwareConfig must actually reach
    the simulation — not be silently swapped for the registry preset."""
    import dataclasses as dc
    cfg = registry.get_config("whisper-base")
    slow = dc.replace(STREAMDCIM_BASE, name="sweep-x",
                      hbm_bytes_per_cycle=STREAMDCIM_BASE.hbm_bytes_per_cycle
                      // 4)
    base = compare_modes(cfg, STREAMDCIM_BASE)
    swept = compare_modes(cfg, slow)
    for m in ExecutionMode:
        assert swept[m].hw == "sweep-x"
        assert swept[m].cycles > base[m].cycles     # quartered HBM hurts


def test_plan_block_tiling_reaches_the_simulator():
    """Non-default block_q/block_kv must flow through workload lowering
    into the schedulers, keeping predicted == simulated bytes (the 'same
    object drives both paths' guarantee at any tiling)."""
    cfg = registry.get_config("vilbert-base")
    for mode in (EM.TILE_STREAM, EM.LAYER_STREAM):
        plan = plan_model(cfg, mode=mode, force_mode=True,
                          block_q=1024, block_kv=1024)
        res = simulate_plan(plan)
        for lp in plan.layers[:3]:
            assert lp.block_q == 1024
            sim_bytes = res.op_dma_bytes(lp.name)
            assert sim_bytes == pytest.approx(lp.hbm_bytes, rel=0.10), \
                (mode, lp.name)
    # Coarser q-tiling means fewer x_kv re-reads: strictly less traffic.
    fine = plan_model(cfg, mode=EM.TILE_STREAM, force_mode=True)
    coarse = plan_model(cfg, mode=EM.TILE_STREAM, force_mode=True,
                        block_q=1024, block_kv=1024)
    assert coarse.total_hbm_bytes < fine.total_hbm_bytes


def test_ad_hoc_hardware_survives_plan_round_trip():
    """Plans built from an unregistered HardwareConfig must simulate,
    re-plan, and serialize — the sweep use case — not KeyError on a
    preset lookup."""
    import dataclasses as dc
    cfg = registry.get_config("whisper-base")
    custom = dc.replace(STREAMDCIM_BASE, name="custom-x",
                        rewrite_bus_bits=2048, hbm_bytes_per_cycle=32)
    plan = plan_model(cfg, hw=custom)
    assert plan.hw == "custom-x" and plan.hw_config() == custom
    res = simulate_plan(plan)                      # no KeyError
    assert res.hw == "custom-x"
    het = plan.with_layer_modes({0: EM.NON_STREAM})   # re-predicts on custom
    assert het.layer(0).mode == EM.NON_STREAM
    restored = ExecutionPlan.from_json(plan.to_json())
    assert restored.hw_config() == custom
    assert simulate_plan(restored).cycles == res.cycles


def test_traffic_and_rewrite_predictions_tile_consistently():
    """hbm_bytes and rewrite_cycles must assume the same (ceil) q-block
    count for non-block-multiple sequences."""
    from repro.plan import attn_hbm_bytes
    kw = dict(seq_kv=300, d_kv=512, heads=8, kv_heads=8, head_dim=64)
    lp = plan_attention(EM.TILE_STREAM, seq_q=300, block_q=256,
                        block_kv=256, bytes_per_el=1, **kw)
    # 300/256 -> 2 q-blocks on both sides of the prediction.
    q_bytes = 300 * 8 * 64
    assert lp.hbm_bytes == 2 * q_bytes + 2 * 300 * 512
    assert attn_hbm_bytes(300, 300, 512, 8, 8, 64, EM.TILE_STREAM,
                          block_q=256, bytes_per_el=1) == lp.hbm_bytes
    assert lp.rewrite_cycles == 2 * 2 * -(-2 * 256 * 8 * 64 // 64)
    # A bytes_per_el override must scale bytes AND rewrite cycles together.
    lp2 = plan_attention(EM.TILE_STREAM, seq_q=300, block_q=256,
                         block_kv=256, bytes_per_el=2, **kw)
    assert lp2.hbm_bytes == 2 * lp.hbm_bytes
    assert lp2.rewrite_cycles == 2 * lp.rewrite_cycles


def test_simulate_model_plan_rejects_conflicting_mode():
    plan = plan_model(registry.get_config("whisper-base"))
    with pytest.raises(ValueError, match="conflicts"):
        simulate_model(plan, mode=EM.NON_STREAM)


def test_workload_from_plan_matches_config_lowering():
    """build_workload(plan) reproduces build_workload(cfg) exactly — the
    plan is a faithful lowering, not a re-derivation."""
    cfg = registry.get_config("whisper-base")
    wl_cfg = build_workload(cfg)
    wl_plan = build_workload(plan_model(cfg))      # plan-aware overload
    assert wl_plan.name == wl_cfg.name
    assert len(wl_plan.layers) == len(wl_cfg.layers)
    for a, b in zip(wl_cfg.layers, wl_plan.layers):
        assert a == b


# ------------------------------------------------- planner-resolved serving

def _dense_cfg(**kw):
    base = dict(name="t", family=Family.DENSE, num_layers=2, d_model=5120,
                num_heads=40, num_kv_heads=8, d_ff=64, vocab_size=128,
                head_dim=128)
    base.update(kw)
    return ModelConfig(**base)


def test_engine_resolves_mode_through_planner_per_shape():
    """The PR-2 serving fix: the engine no longer freezes a construction-
    time mode — each admitted wave's shape goes through the planner."""
    gqa = _dense_cfg()                             # TILE requested, GQA geom
    eng = Engine(gqa, params=None, slots=2, max_len=64)
    assert gqa.execution_mode == EM.TILE_STREAM
    assert eng.mode_for(48) == EM.LAYER_STREAM     # profitability fallback
    plan = eng.plan_for(48)
    assert plan.uniform_mode == EM.LAYER_STREAM
    assert eng.plan_for(48) is plan                # cached per length

    mha = _dense_cfg(d_model=1024, num_heads=8, num_kv_heads=8)
    assert Engine(mha, params=None).mode_for(48) == EM.TILE_STREAM


def test_engine_accepts_pinned_plan_and_legacy_mode():
    cfg = _dense_cfg(d_model=1024, num_heads=8, num_kv_heads=8)
    pinned = plan_model(cfg, seq_len=64, mode=EM.NON_STREAM,
                        force_mode=True)
    eng = Engine(cfg, params=None, plan=pinned)
    assert eng.mode_for(48) == EM.NON_STREAM       # plan wins at any shape
    legacy = Engine(cfg, params=None, mode=EM.LAYER_STREAM)
    assert legacy.mode_for(48) == EM.LAYER_STREAM  # deprecated override


def test_engine_attention_free_family_has_no_plan():
    cfg = registry.get_config("mamba2-780m", smoke=True)
    eng = Engine(cfg, params=None)
    assert eng.plan_for(32) is None
    assert eng.mode_for(32) == cfg.execution_mode


# ----------------------------------------------------------- plan anatomy

def test_plan_records_cross_forwarding_geometry():
    """The co-TRM cross-attention layers carry the *other* modality's
    width as d_kv — the planner decides profitability on the true
    KV-source width (paper Fig. 4a)."""
    cfg = registry.get_config("vilbert-base")
    plan = plan_model(cfg)
    co = plan.layer("cox0_co")
    assert co.cross and co.d_q == cfg.d_model and co.d_kv == cfg.d_model_y
    assert co.mode == EM.TILE_STREAM               # MHA: fusion wins
    # layers_of addresses a model layer (with_layer_modes' int-key unit):
    # each co-TRM layer holds 4 attention ops (co + self, both streams).
    assert co in plan.layers_of(co.layer_index)
    assert len(plan.layers_of(co.layer_index)) == 4
    # DTPU prune decision recorded (vilbert ships pruning enabled).
    assert cfg.pruning.enabled
    deep = plan.layers[-1]
    assert deep.keep_tokens < deep.seq_q


def test_plan_matches_workload_op_stream():
    cfg = registry.get_config("qwen2-vl-2b")
    plan = plan_model(cfg)
    wl = build_workload(cfg)
    attn_names = [op.name for _, op in wl.attention_ops]
    assert [lp.name for lp in plan.layers] == attn_names
    n_ops = sum(len(l.ops) for l in wl.layers)
    assert len(plan.layers) + len(plan.gemms) == n_ops
    for lp in plan.layers:
        src = next(op for _, op in wl.attention_ops if op.name == lp.name)
        assert isinstance(src, AttnOp)
        assert (lp.seq_q, lp.seq_kv, lp.d_q, lp.d_kv) == \
            (src.seq_q, src.seq_kv, src.d_q, src.d_kv)
