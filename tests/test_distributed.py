"""Sharding rules, grouped-MoE dispatch, and compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import runtime
from repro.core.types import Family, ModelConfig
from repro.distributed import sharding as SH
from repro.models import layers as L


# Production axis sizes, simulated for rule evaluation (the test mesh is
# single-device; jax.sharding.AxisType does not exist on jax 0.4.x).
PROD_SIZES = {"data": 16, "model": 16, "pod": 2}


@pytest.mark.parametrize("arch", list(registry.ARCHS))
def test_param_shardings_cover_every_leaf(arch):
    """Every param leaf gets a sharding whose partitioned dims divide."""
    cfg = registry.get_config(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pspecs = registry.param_specs(cfg)
    shardings = SH.param_shardings(pspecs, cfg, mesh,
                                   axis_sizes=PROD_SIZES)
    flat_p = jax.tree.leaves(pspecs)
    flat_s = jax.tree.leaves(shardings, is_leaf=lambda x: isinstance(
        x, jax.sharding.NamedSharding))
    assert len(flat_p) == len(flat_s)
    sizes = PROD_SIZES
    for p, s in zip(flat_p, flat_s):
        spec = s.spec
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            factor = 1
            for a in axs:
                factor *= sizes[a]
            assert p.shape[dim] % factor == 0, (arch, p.shape, spec, dim)


def test_head_sharding_rules():
    # qwen3: 64 heads % 16 ok -> head-sharded; starcoder2: 36 heads -> not
    q3 = registry.get_config("qwen3-32b")
    sc = registry.get_config("starcoder2-7b")

    class M:  # mesh stub with production sizes
        shape = PROD_SIZES
    assert SH.heads_shardable(q3, M)
    assert not SH.heads_shardable(sc, M)
    assert SH.experts_shardable(registry.get_config("deepseek-v3-671b"), M)
    assert not SH.experts_shardable(registry.get_config("grok-1-314b"), M)


def _specs_by_path(arch, **kwargs):
    """path -> PartitionSpec for every param leaf, rules evaluated at
    production axis sizes on the single-device test mesh."""
    cfg = registry.get_config(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = SH.param_shardings(registry.param_specs(cfg), cfg, mesh,
                                   axis_sizes=PROD_SIZES, **kwargs)
    flat, _ = SH._flatten_with_paths(shardings)
    return dict(flat)


def test_megatron_head_split_when_divisible():
    """64 heads % 16 == 0: attention projections shard their head dim
    over 'model' (col-parallel qkv, row-parallel o).  FSDP is pushed out
    of the way (qwen3-32b is over the default threshold) to see the pure
    Megatron rule."""
    specs = {p: s.spec
             for p, s in _specs_by_path("qwen3-32b",
                                        fsdp_threshold=1e15).items()}
    wq = [s for p, s in specs.items() if p.endswith("/wq")]
    wo = [s for p, s in specs.items() if p.endswith("/wo")]
    # Stacked layer dim replicated; head dim (middle of D,H,hd) sharded.
    assert wq and all(tuple(s) == (None, None, "model", None) for s in wq)
    assert wo and all(tuple(s) == (None, "model", None, None) for s in wo)


@pytest.mark.parametrize("arch", ["starcoder2-7b", "qwen2-vl-2b"])
def test_context_parallel_fallback_replicates_attention(arch):
    """Non-divisible heads (36H, 12H/2KV vs |model|=16): qkv/o weights
    stay replicated (attention runs context-parallel instead) while the
    MLP keeps its tensor split."""
    specs = {p: s.spec for p, s in _specs_by_path(arch).items()}
    attn = {p: s for p, s in specs.items()
            if p.split("/")[-1] in ("wq", "wk", "wv", "wo")}
    assert attn
    assert all(all(ax is None for ax in tuple(s)) for s in attn.values()), \
        {p: tuple(s) for p, s in attn.items()}
    ups = [s for p, s in specs.items() if p.endswith("/w_up")]
    assert ups and all("model" in tuple(s) for s in ups)


def test_fsdp_threshold_gates_data_axis():
    """starcoder2 (~7e9 params) sits under the default 8e9 threshold —
    no 'data' factor anywhere; forcing the threshold to 0 turns ZeRO-3
    sharding on for its replicated attention weights."""
    def data_sharded(specs):
        return [p for p, s in specs.items()
                if any(ax == "data" for ax in tuple(s.spec))]
    off = _specs_by_path("starcoder2-7b")
    assert not data_sharded(off)
    on = _specs_by_path("starcoder2-7b", fsdp_threshold=0)
    hit = data_sharded(on)
    assert any(p.split("/")[-1] in ("wq", "wk", "wv", "wo") for p in hit), hit


def test_grouped_moe_matches_plain():
    cfg = ModelConfig(name="t", family=Family.MOE, num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      num_experts=4, experts_per_token=2, moe_d_ff=96,
                      dtype="float32", param_dtype="float32")
    p = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64)) * 0.5
    with runtime.flags(moe_capacity=100.0):
        y1 = L.moe_forward(p, cfg, x)
        with runtime.flags(moe_groups=4):
            y4 = L.moe_forward(p, cfg, x)
    np.testing.assert_allclose(y1, y4, atol=2e-5, rtol=2e-5)


def test_hints_noop_without_table():
    from repro.distributed.hints import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "attn_q") is x


def test_quantize_roundtrip_error_bounded():
    from repro.distributed.compression import _dequantize, _quantize
    g = jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 0.02
    q, s = _quantize(g)
    err = jnp.abs(_dequantize(q, s) - g).max()
    assert float(err) <= float(s) / 2 + 1e-9   # half-ulp of the int8 grid
