"""Property-based invariants of ``serve.schedule.build_schedule``.

The schedule is the single shared object the live engine executes and
the simulator lowers (DESIGN.md §11), so its invariants are
load-bearing for every cross-path agreement test: FIFO admission order,
immediate slot recycling, no idle-step emission, and the per-request
decode-step accounting ``decode_steps[rid] == max_new_tokens - 1``
(hence ``Engine.decode_calls == Σ(max_new − 1)``).

Hypothesis-generated traffic when available; the deterministic grid
shim (``tests/_hypothesis_fallback``) otherwise.  Requests derive from
a seeded RNG so both backends explore varied arrival patterns, ragged
lengths, and oversubscribed slot counts.
"""
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    sys.path.insert(0, "tests")
    from _hypothesis_fallback import given, settings, st

from repro.serve.schedule import ServeRequest, build_schedule


def _traffic(seed: int, n: int, arrival_spread: int):
    rng = np.random.default_rng(seed)
    return [ServeRequest(rid=i,
                         prompt_len=int(rng.integers(1, 24)),
                         max_new_tokens=int(rng.integers(1, 12)),
                         arrival_step=int(rng.integers(0, arrival_spread)))
            for i in range(n)]


def _check_invariants(reqs, slots):
    sched = build_schedule(reqs, slots)
    by_rid = {r.rid: r for r in reqs}

    # Every request is admitted exactly once, decoded to completion, and
    # finished; nothing is invented.
    assert set(sched.admit_step) == {r.rid for r in reqs}
    assert set(sched.finish_step) == {r.rid for r in reqs}

    # decode_calls accounting: each request consumes exactly
    # max_new_tokens - 1 decode steps (token #1 comes from prefill).
    for r in reqs:
        assert sched.decode_steps[r.rid] == r.max_new_tokens - 1
    total_decoding = sum(len(s.decoding) for s in sched.steps)
    assert total_decoding == sum(r.max_new_tokens - 1 for r in reqs)

    # FIFO admission: admission order follows (arrival_step, submit
    # order) — a later-arriving request never overtakes an earlier one.
    admit_order = []
    for s in sched.steps:
        for _, rid in s.admitted:
            admit_order.append(rid)
    keys = [(by_rid[rid].arrival_step, admit_order.index(rid))
            for rid in admit_order]
    fifo = sorted(admit_order,
                  key=lambda rid: (by_rid[rid].arrival_step,
                                   [r.rid for r in reqs].index(rid)))
    assert admit_order == fifo

    # No idle steps: every emitted step does work.
    for s in sched.steps:
        assert s.admitted or s.decoding or s.finished

    # Slot discipline: at most ``slots`` concurrently occupied, each
    # slot holds one request at a time, and a freed slot is reusable on
    # the very next admission opportunity (immediate recycling).
    occupant = {}
    for s in sched.steps:
        for slot, rid in s.admitted:
            assert slot not in occupant, (
                f"step {s.step}: slot {slot} admitted {rid} while "
                f"occupied by {occupant[slot]}")
            occupant[slot] = rid
        assert len(occupant) <= slots
        for slot, rid, kv in s.decoding:
            assert occupant[slot] == rid
            # kv grows by one per decode step from prompt_len + 1.
            assert kv >= by_rid[rid].prompt_len + 1
        for rid in s.finished:
            freed = [sl for sl, r in occupant.items() if r == rid]
            assert len(freed) == 1
            del occupant[freed[0]]
    assert not occupant                     # everything drained

    # Immediate recycling, globally: with queued work remaining, no step
    # leaves a free slot unused while an already-arrived request waits.
    admit_step = sched.admit_step
    for s in sched.steps:
        active = sum(1 for r in reqs
                     if admit_step[r.rid] <= s.step
                     and sched.finish_step[r.rid] >= s.step)
        waiting = [r for r in reqs if r.arrival_step <= s.step
                   and admit_step[r.rid] > s.step]
        if waiting:
            assert active >= slots, (
                f"step {s.step}: {len(waiting)} arrived requests wait "
                f"while only {active}/{slots} slots are busy")


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=24),
       slots=st.integers(min_value=1, max_value=8),
       arrival_spread=st.integers(min_value=1, max_value=20))
def test_schedule_invariants(seed, n, slots, arrival_spread):
    _check_invariants(_traffic(seed, n, arrival_spread), slots)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=500),
       slots=st.integers(min_value=1, max_value=4))
@pytest.mark.slow
def test_schedule_invariants_oversubscribed(seed, slots):
    """Heavy oversubscription (many more requests than slots) keeps the
    invariants — the regime the batched engine cares about."""
    _check_invariants(_traffic(seed, 64, 6), slots)


def test_schedule_single_request_min():
    """max_new_tokens == 1 requests take zero decode steps and recycle
    their slot in the admission step."""
    sched = build_schedule([ServeRequest(0, 3, 1, 0)], 2)
    assert sched.decode_steps[0] == 0
    assert sched.admit_step[0] == sched.finish_step[0] == 0
    assert len(sched.steps) == 1
