"""Decode-aware planning + continuous-batching serving (DESIGN.md §11):
``plan_decode_step`` / ``DecodePlan``, the shared slot schedule, the
rewritten ``serve.Engine``, ``sim.simulate_serve``, and the cross-path
agreement guarantees (engine == simulator timeline; planner == simulator
decode HBM bytes)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.types import (AttnKind, ExecutionMode as EM, PruningConfig)
from repro.plan import DecodePlan, plan_decode_step, plan_model
from repro.serve.engine import Engine, Request
from repro.serve.schedule import ServeRequest, build_schedule
from repro.sim import simulate_serve

SMOKE = registry.get_config("starcoder2-7b", smoke=True)


def _params(cfg=SMOKE):
    mod = registry.model_module(cfg)
    return mod.init(jax.random.PRNGKey(0), cfg)


def _req(rid, plen, new, arr=0):
    return Request(rid=rid,
                   prompt=np.arange(1, plen + 1, dtype=np.int32),
                   max_new_tokens=new, arrival_step=arr)


# ---------------------------------------------------------------------------
# The shared schedule
# ---------------------------------------------------------------------------

def test_schedule_immediate_recycle_and_fifo():
    reqs = [ServeRequest(0, 8, 2), ServeRequest(1, 8, 6),
            ServeRequest(2, 4, 3)]
    s = build_schedule(reqs, slots=2)
    # rid 0 burns 1 decode step (2 tokens), frees its slot, rid 2 takes it
    # while rid 1 is still mid-decode.
    assert s.decode_steps == {0: 1, 1: 5, 2: 2}
    assert s.admit_step[2] > s.finish_step[0]
    admit2 = next(st for st in s.steps if (0, 2) in st.admitted
                  or (1, 2) in st.admitted)
    assert admit2.decoding, "admission must overlap a neighbour's decode"


def test_schedule_single_token_and_idle_gap():
    reqs = [ServeRequest(0, 4, 1), ServeRequest(1, 4, 2, arrival_step=7)]
    s = build_schedule(reqs, slots=1)
    assert s.decode_steps[0] == 0          # prefill-only request
    assert s.finish_step[0] == s.admit_step[0]
    assert s.admit_step[1] == 7            # idle gap jumped, not padded
    assert all(st.admitted or st.decoding for st in s.steps)


def test_schedule_kv_lens_grow_by_one():
    s = build_schedule([ServeRequest(0, 10, 4)], slots=1)
    kvs = [kv for st in s.steps for _, rid, kv in st.decoding if rid == 0]
    assert kvs == [11, 12, 13]             # prompt + generated, incl. new


# ---------------------------------------------------------------------------
# DecodePlan
# ---------------------------------------------------------------------------

def test_decode_plan_json_round_trip():
    cfg = registry.get_config("qwen2-vl-2b")
    dp = plan_decode_step(cfg, (300, 17, 513))
    rt = DecodePlan.from_json(dp.to_json())
    assert rt == dp
    assert rt.total_hbm_bytes == dp.total_hbm_bytes
    assert rt.context == (300, 17, 513)
    assert rt.layer(dp.layers[0].name).seq_kv == dp.layers[0].seq_kv


def test_decode_plan_trace_round_trip():
    from repro.sim.replay import KernelTrace
    dp = plan_decode_step(SMOKE, (40,))
    kt = KernelTrace(op=dp.layers[0].name, kind="decode",
                     mode=dp.layers[0].mode.value, grid=(1, 1, 1),
                     block_q=1, block_kv=256, cycles=123, hbm_bytes=456)
    traced = dp.attach_traces([kt])
    assert traced.traced_ops == (dp.layers[0].name,)
    rt = DecodePlan.from_json(traced.to_json())
    assert rt.layers[0].trace == kt
    # a prefill-named trace must not attach to a decode op
    with pytest.raises(ValueError):
        dp.layers[0].attach_trace(dataclasses.replace(kt, op="l0_self"))


def test_decode_plan_rejects_nonsense():
    with pytest.raises(ValueError):
        plan_decode_step(SMOKE, ())
    with pytest.raises(ValueError):
        plan_decode_step(SMOKE, (0,))
    with pytest.raises(ValueError):
        plan_decode_step(registry.get_config("vilbert-base"), (32,))
    with pytest.raises(ValueError):
        plan_decode_step(registry.get_config("mamba2-780m"), (32,))


def test_decode_plan_sliding_window_clamp():
    cfg = dataclasses.replace(SMOKE, attn_kind=AttnKind.SLIDING,
                              sliding_window=64)
    dp = plan_decode_step(cfg, (100, 30))
    for lp in dp.layers:
        assert lp.seq_kv == (64, 30)


def test_decode_plan_keep_tokens_shrinks_seq_kv():
    cfg = dataclasses.replace(
        registry.get_config("qwen2-vl-2b"),
        pruning=PruningConfig(enabled=True))
    ctx = 2048
    dp = plan_decode_step(cfg, (ctx,))
    base = plan_decode_step(registry.get_config("qwen2-vl-2b"), (ctx,))
    seqs = [lp.seq_kv[0] for lp in sorted(dp.layers,
                                          key=lambda l: l.layer_index)]
    assert all(a >= b for a, b in zip(seqs, seqs[1:])), \
        "DTPU pruning must shrink seq_kv monotonically with depth"
    assert seqs[0] == ctx and seqs[-1] < ctx
    assert dp.total_hbm_bytes < base.total_hbm_bytes
    assert dp.total_rewrite_cycles < base.total_rewrite_cycles
    assert dp.layers[0].keep_tokens == dp.layers[0].seq_kv


def test_decode_plan_encdec_cross_is_static():
    cfg = registry.get_config("whisper-base")
    dp = plan_decode_step(cfg, (70,))
    cross = [lp for lp in dp.layers if lp.cross]
    selfa = [lp for lp in dp.layers if not lp.cross]
    assert cross and selfa
    se = cross[0].seq_kv[0]
    assert all(lp.seq_kv == (se,) for lp in cross)   # encoder KV: fixed
    assert all(lp.seq_kv == (70,) for lp in selfa)


# ---------------------------------------------------------------------------
# Planner == simulator decode traffic (the tentpole cross-assert)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-vl-2b", "whisper-base"])
@pytest.mark.parametrize("mode", list(EM))
def test_sim_decode_bytes_match_plan_per_registry_model(arch, mode):
    cfg = registry.get_config(arch)
    reqs = [ServeRequest(0, 24, 4), ServeRequest(1, 300, 3, 1)]
    res = simulate_serve(cfg, reqs, slots=2, mode=mode, force_mode=True)
    decode_steps = [s for s in res.steps if s.decoded]
    assert decode_steps
    for s in decode_steps:
        assert s.decode_hbm_bytes == s.predicted_decode_hbm_bytes > 0
    # and the prediction is the DecodePlan the step ran under
    kv = decode_steps[-1].kv_lens
    assert (res.decode_plans[kv].total_hbm_bytes
            == decode_steps[-1].predicted_decode_hbm_bytes)


def test_sim_decode_rewrite_cycles_match_plan():
    from repro.sim.trace import Trace
    cfg = registry.get_config("qwen2-vl-2b")
    res = simulate_serve(cfg, [ServeRequest(0, 513, 2)], slots=1)
    st = next(s for s in res.steps if s.decoded)
    tprefix = f"t{st.step}.dec."
    rw = sum(e.cycles for e in res.result.trace.events
             if e.kind == "rewrite" and e.tag.startswith(tprefix))
    assert rw == st.predicted_rewrite_cycles


def test_sim_serve_mode_ordering_and_energy():
    """TILE <= LAYER <= NON on serving traffic too (MHA model), and the
    timeline trace folds through the energy model."""
    cfg = registry.get_config("vilbert-base")   # crossmodal: no decode
    with pytest.raises(ValueError):
        simulate_serve(cfg, [ServeRequest(0, 8, 2)], slots=1)
    cfg = registry.get_config("whisper-base")   # MHA: fusion profitable
    reqs = [ServeRequest(0, 24, 3), ServeRequest(1, 40, 4, 1)]
    res = {m: simulate_serve(cfg, reqs, slots=2, mode=m, force_mode=True)
           for m in EM}
    assert (res[EM.TILE_STREAM].cycles < res[EM.LAYER_STREAM].cycles
            < res[EM.NON_STREAM].cycles)
    assert (res[EM.TILE_STREAM].hbm_bytes < res[EM.LAYER_STREAM].hbm_bytes
            < res[EM.NON_STREAM].hbm_bytes)
    e = res[EM.TILE_STREAM].energy()
    assert e.total_pj > 0


def test_decode_trace_replays_through_simulate_serve():
    """A KernelTrace recorded at the decode kernel entry point attaches to
    the DecodePlan and replays verbatim through the serving simulator."""
    from repro.kernels import ops
    from repro.sim.replay import KernelRecorder, recording

    dp0 = plan_decode_step(SMOKE, (11,))
    lp = dp0.layers[0]
    rng = jax.random.PRNGKey(1)
    q = jax.random.normal(rng, (1, SMOKE.num_heads, 1, SMOKE.head_dim),
                          np.float32)
    k = jax.random.normal(rng, (1, SMOKE.num_kv_heads, lp.seq_kv[0],
                                SMOKE.head_dim), np.float32)
    v = jax.random.normal(rng, (1, SMOKE.num_kv_heads, lp.seq_kv[0],
                                SMOKE.head_dim), np.float32)
    rec = KernelRecorder(iters=1, warmup=0)
    with recording(rec):
        out = ops.decode_attention_by_plan(lp, q, k, v)
    assert out.shape == (1, SMOKE.num_heads, 1, SMOKE.head_dim)
    assert len(rec.records) == 1
    kt = rec.records[0]
    assert kt.op == lp.name and kt.kind == "decode"
    assert kt.resource == "ATTN"
    assert dp0.attach_traces(rec.records).traced_ops == (lp.name,)

    def decode_plan_fn(kv):
        # attaches to the steps whose first layer matches the recording
        return plan_decode_step(SMOKE, kv).attach_traces(rec.records)

    res = simulate_serve(SMOKE, [ServeRequest(0, 10, 3)], slots=1,
                         decode_plan_fn=decode_plan_fn)
    assert res.result.replayed_ops >= 1


# ---------------------------------------------------------------------------
# The engine: continuous batching, not waves
# ---------------------------------------------------------------------------

def test_engine_admits_while_others_decode():
    params = _params()
    eng = Engine(SMOKE, params, slots=2, max_len=64)
    for r in [_req(0, 8, 2), _req(1, 12, 8), _req(2, 6, 4, arr=1)]:
        eng.submit(r)
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    # rid 2 was admitted at a step where another slot decoded: no waves.
    mixed = [s for s in eng.step_log if s.admitted and s.decoded]
    assert mixed, "no admission overlapped a decode — still wave batching?"
    assert any(2 in s.admitted for s in mixed)
    for r in done:
        assert len(r.out_tokens) == r.max_new_tokens
        assert all(0 <= t < SMOKE.vocab_size for t in r.out_tokens)


def test_engine_short_request_recycles_immediately():
    """Regression (ISSUE satellite): a finished slot must stop decoding —
    total decode_step calls == sum(max_new_tokens - 1), never the wave
    max times the batch."""
    params = _params()
    eng = Engine(SMOKE, params, slots=2, max_len=64)
    eng.submit(_req(0, 8, 2))
    eng.submit(_req(1, 8, 10))
    done = eng.run()
    assert eng.decode_calls == (2 - 1) + (10 - 1)
    short = next(r for r in done if r.rid == 0)
    assert len(short.out_tokens) == 2
    # the freed slot is re-usable: a third request would have fit there
    assert eng.stats()["max_concurrency"] == 2
    # stats() describe the LAST run: decode_calls reset per run
    eng.submit(_req(2, 8, 3))
    eng.run()
    assert eng.decode_calls == 3 - 1


def test_engine_matches_simulate_serve_timeline():
    params = _params()
    eng = Engine(SMOKE, params, slots=2, max_len=64)
    trace = [(0, 5, 6, 0), (1, 12, 3, 0), (2, 7, 4, 1), (3, 9, 2, 4)]
    for rid, plen, new, arr in trace:
        eng.submit(_req(rid, plen, new, arr))
    eng.run()
    st = eng.stats()
    sim = simulate_serve(
        SMOKE, [ServeRequest(r, p, n, a) for r, p, n, a in trace], slots=2)
    assert sim.decode_steps == st["decode_steps"]
    assert sim.num_steps == st["steps"]
    assert dict(sim.schedule.admit_step) == st["admit_step"]
    assert dict(sim.schedule.finish_step) == st["finish_step"]
    for erec, srec in zip(eng.step_log, sim.steps):
        assert erec.step == srec.step
        assert erec.admitted == srec.admitted
        assert erec.decoded == srec.decoded
        assert erec.kv_lens == srec.kv_lens
        if erec.decode_plan is not None:
            assert (erec.decode_plan.total_hbm_bytes
                    == srec.predicted_decode_hbm_bytes)


def test_engine_decode_plans_drive_steps():
    params = _params()
    eng = Engine(SMOKE, params, slots=2, max_len=64)
    eng.submit(_req(0, 6, 3))
    eng.submit(_req(1, 10, 3))
    eng.run()
    dps = [s.decode_plan for s in eng.step_log if s.decoded]
    assert dps and all(dp is not None for dp in dps)
    for s in eng.step_log:
        if s.decode_plan is not None:
            assert s.decode_plan.context == s.kv_lens
    off = Engine(SMOKE, params, slots=2, max_len=64, plan_decode=False)
    off.submit(_req(0, 6, 3))
    off.run()
    assert all(s.decode_plan is None for s in off.step_log)
    # the deprecated mode= override carries through to decode plans too
    forced = Engine(SMOKE, params, slots=1, max_len=64,
                    mode=EM.NON_STREAM)
    forced.submit(_req(0, 6, 3))
    forced.run()
    fdp = next(s.decode_plan for s in forced.step_log if s.decode_plan)
    assert fdp.uniform_mode == EM.NON_STREAM


def test_engine_queue_is_deque_and_plan_cache_bounded():
    from collections import deque
    eng = Engine(SMOKE, params=None, slots=2, max_len=512,
                 plan_cache_size=4)
    assert isinstance(eng._queue, deque)
    plans = [eng.plan_for(8 * (i + 1)) for i in range(10)]
    assert all(p is not None for p in plans)
    assert eng.plan_cache_len <= 4
    # LRU: the most recent length is still cached (same object back)
    assert eng.plan_for(80) is plans[-1]
    # decode plans live in their OWN bounded cache: the per-step kv-tuple
    # churn must not evict the reusable per-prompt-length prefill plans
    keep = eng.plan_for(80)
    for i in range(10):
        eng.decode_plan_for((81 + i,))
    assert len(eng._decode_plan_cache) <= 4
    assert eng.plan_for(80) is keep


def test_engine_rejects_overflowing_request():
    eng = Engine(SMOKE, params=None, slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(_req(0, 10, 10))


# ---------------------------------------------------------------------------
# Heterogeneous per-layer prefill dispatch (the mode_for fix)
# ---------------------------------------------------------------------------

def test_heterogeneous_prefill_dispatches_per_layer(monkeypatch):
    """A heterogeneous plan must reach attention_by_plan once per
    same-mode segment with *that* segment's mode — not collapse to
    layers[0].mode — and stay numerically equivalent to the default
    path."""
    from repro.kernels import ops
    from repro.models import transformer as T

    cfg = SMOKE                       # 2 layers
    params = _params(cfg)
    plan = plan_model(cfg, seq_len=16).with_layer_modes({0: EM.NON_STREAM})
    assert plan.heterogeneous
    assert [lp.mode for lp in plan.layers] == [EM.NON_STREAM,
                                               plan.layers[1].mode]
    assert plan.layers[1].mode != EM.NON_STREAM

    seen = []
    real = ops.attention_by_plan

    def spy(lp, *a, **kw):
        seen.append(lp.mode)
        return real(lp, *a, **kw)

    monkeypatch.setattr(ops, "attention_by_plan", spy)
    toks = {"tokens": np.arange(1, 17, dtype=np.int32)[None, :]}
    logits, cache = T.prefill(params, cfg, toks, max_len=32, plan=plan)
    # one trace per same-mode scan segment, in layer order
    assert seen == [EM.NON_STREAM, plan.layers[1].mode]
    monkeypatch.setattr(ops, "attention_by_plan", real)
    base_logits, base_cache = T.prefill(params, cfg, toks, max_len=32)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(base_logits),
                               atol=2e-3, rtol=2e-3)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(base_cache)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-3, rtol=2e-3)


def test_uniform_plan_prefill_matches_default():
    cfg = SMOKE
    params = _params(cfg)
    plan = plan_model(cfg, seq_len=16)
    toks = {"tokens": np.arange(3, 19, dtype=np.int32)[None, :]}
    from repro.models import transformer as T
    l1, _ = T.prefill(params, cfg, toks, max_len=32, plan=plan)
    l0, _ = T.prefill(params, cfg, toks, max_len=32)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                               atol=2e-3, rtol=2e-3)


def test_engine_serves_heterogeneous_plan():
    """End to end: an engine pinned to a heterogeneous plan admits and
    completes requests (per-layer dispatch in the live prefill path)."""
    cfg = SMOKE
    params = _params(cfg)
    plan = plan_model(cfg, seq_len=16).with_layer_modes({1: EM.NON_STREAM})
    eng = Engine(cfg, params, slots=2, max_len=64, plan=plan)
    eng.submit(_req(0, 9, 3))
    eng.submit(_req(1, 14, 2))
    done = eng.run()
    assert sorted(len(r.out_tokens) for r in done) == [2, 3]


def test_prefill_recording_traces_each_layer():
    """Under an active kernel recording (+ unrolled scan), the plan
    dispatch splits per layer so every layer's KernelTrace carries its
    own op name — a multi-layer segment must not collapse all records
    onto its representative's name."""
    from repro.core import runtime
    from repro.models import transformer as T
    from repro.sim.replay import KernelRecorder, recording

    cfg = SMOKE
    params = _params(cfg)
    plan = plan_model(cfg, seq_len=16)
    assert plan.uniform_mode is not None and len(plan.layers) > 1
    toks = {"tokens": np.arange(1, 17, dtype=np.int32)[None, :]}
    rec = KernelRecorder(iters=1, warmup=0)
    with runtime.flags(unroll=True), recording(rec):
        T.prefill(params, cfg, toks, max_len=32, plan=plan)
    ops_seen = [t.op for t in rec.records if t.kind == "attention"]
    assert ops_seen == [lp.name for lp in plan.layers]
    traced = plan.attach_traces(rec.records)
    assert traced.traced_ops == tuple(lp.name for lp in plan.layers)


def test_dispatch_segments_merge_planless_layers():
    """Layers with no attention op (SSM/hybrid mixers) carry no dispatch
    decision and merge into the surrounding segment instead of
    shattering the scan."""
    from repro.models.transformer import _dispatch_segments

    plan = plan_model(SMOKE, seq_len=16)
    gap = dataclasses.replace(
        plan, layers=tuple(lp for lp in plan.layers
                           if lp.layer_index != 0))
    segs = _dispatch_segments(SMOKE, gap, 0, SMOKE.num_layers)
    assert len(segs) == 1 and segs[0][:2] == (0, SMOKE.num_layers)
    per = _dispatch_segments(SMOKE, plan, 0, SMOKE.num_layers,
                             per_layer=True)
    assert [s[:2] for s in per] == [(i, i + 1)
                                    for i in range(SMOKE.num_layers)]
