"""Batched decode-attention kernel parity (DESIGN.md §15).

The batched path's correctness claim has two halves:

* *bitwise* batched-vs-B=1 within each implementation — a bucket row's
  online softmax never sees its neighbours, so slicing a row out of the
  batched call must reproduce the B=1 call exactly (fp32), ragged
  lengths and sliding-window edges included;
* *tolerance* across implementations — the batched kernels
  (``kernels.decode_attention`` Pallas, ``jnp_blocked`` reference)
  against the oracle ``ref_decode_attention`` and the per-slot
  ``decode_attention_by_plan`` path (different reduction blocking ⇒
  last-ulp differences), across all three execution modes.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.types import ExecutionMode as EM
from repro.kernels.decode_attention import decode_attention
from repro.kernels.jnp_blocked import decode_attention_jnp
from repro.kernels.ops import (batched_decode_attention_by_plan,
                               decode_attention_by_plan,
                               multi_head_attention)
from repro.kernels.ref import ref_decode_attention
from repro.plan import plan_decode_step

SMOKE = registry.get_config("starcoder2-7b", smoke=True)
MODES = [EM.NON_STREAM, EM.LAYER_STREAM, EM.TILE_STREAM]


def _inputs(B=3, Hq=4, Hkv=2, W=48, hd=16, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Hq, 1, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, W, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, W, hd)), dtype)
    return q, k, v


RAGGED = jnp.asarray([17, 48, 5], jnp.int32)      # mid / full / tiny


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_batched_equals_per_slot_bitwise_fp32(impl):
    """fp32 bucket rows are bit-identical to B=1 calls of the same
    implementation, per ragged row length."""
    q, k, v = _inputs()
    fn = (decode_attention_jnp if impl == "jnp"
          else lambda *a, **kw: decode_attention(*a, interpret=True, **kw))
    batched = fn(q, k, v, RAGGED)
    for i in range(q.shape[0]):
        solo = fn(q[i:i + 1], k[i:i + 1], v[i:i + 1], RAGGED[i])
        assert jnp.array_equal(batched[i:i + 1], solo), (
            f"{impl}: row {i} (len {int(RAGGED[i])}) differs from B=1")


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_batched_matches_oracle(impl):
    q, k, v = _inputs()
    fn = (decode_attention_jnp if impl == "jnp"
          else lambda *a, **kw: decode_attention(*a, interpret=True, **kw))
    out = fn(q, k, v, RAGGED)
    ref = ref_decode_attention(q, k, v, RAGGED)
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("window", [1, 4, 5, 17, 48, 64])
def test_sliding_window_edges(impl, window):
    """Window edges (1, == tiny row's len, around each len, > W) match
    the oracle and stay batched-vs-B=1 bitwise."""
    q, k, v = _inputs()
    fn = (decode_attention_jnp if impl == "jnp"
          else lambda *a, **kw: decode_attention(*a, interpret=True, **kw))
    out = fn(q, k, v, RAGGED, window=window)
    ref = ref_decode_attention(q, k, v, RAGGED, window=window)
    assert jnp.max(jnp.abs(out - ref)) < 1e-5
    for i in range(q.shape[0]):
        solo = fn(q[i:i + 1], k[i:i + 1], v[i:i + 1], RAGGED[i],
                  window=window)
        assert jnp.array_equal(out[i:i + 1], solo)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_bf16_batched_within_tolerance(impl):
    """bf16 buckets match B=1 bitwise (same-impl) and the fp32 oracle
    within bf16 resolution."""
    q, k, v = _inputs(dtype=jnp.bfloat16)
    fn = (decode_attention_jnp if impl == "jnp"
          else lambda *a, **kw: decode_attention(*a, interpret=True, **kw))
    out = fn(q, k, v, RAGGED)
    for i in range(q.shape[0]):
        solo = fn(q[i:i + 1], k[i:i + 1], v[i:i + 1], RAGGED[i])
        assert jnp.array_equal(out[i:i + 1], solo)
    ref = ref_decode_attention(q.astype(jnp.float32),
                               k.astype(jnp.float32),
                               v.astype(jnp.float32), RAGGED)
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - ref)) < 3e-2


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("use_pallas", [False, True])
def test_by_plan_batched_vs_per_slot_path(mode, use_pallas):
    """The plan-dispatched batched entry agrees with the existing
    per-slot ``decode_attention_by_plan`` row-for-row across all three
    modes and a ragged shape bucket (fp32; different reduction blocking
    bounds the comparison at ~1 ulp of the softmax sum)."""
    lens = tuple(int(c) for c in RAGGED)
    dp = plan_decode_step(SMOKE, lens, mode=mode, force_mode=True)
    lp = dp.layers[0]
    hd = lp.head_dim
    q, k, v = _inputs(B=len(lens), Hq=lp.heads, Hkv=lp.kv_heads,
                      W=max(lens), hd=hd)
    batched = batched_decode_attention_by_plan(
        lp, q, k, v, jnp.asarray(lens, jnp.int32), use_pallas=use_pallas)
    for i, c in enumerate(lens):
        solo = decode_attention_by_plan(
            lp, q[i:i + 1], k[i:i + 1, :, :c], v[i:i + 1, :, :c])
        assert jnp.max(jnp.abs(batched[i:i + 1] - solo)) < 1e-6, (
            f"mode {mode}: row {i} diverges from decode_attention_by_plan")


def test_by_plan_rejects_mismatched_bucket():
    dp = plan_decode_step(SMOKE, (9, 9), force_mode=False)
    lp = dp.layers[0]
    q, k, v = _inputs(B=3, Hq=lp.heads, Hkv=lp.kv_heads, W=16,
                      hd=lp.head_dim)
    from repro.sim.replay import KernelRecorder, recording
    with recording(KernelRecorder()):
        with pytest.raises(ValueError, match="bucket batch"):
            batched_decode_attention_by_plan(
                lp, q, k, v, jnp.asarray([9, 9, 9], jnp.int32))


def test_by_plan_recorder_sums_per_slot_bytes():
    """A recorded bucket op charges the sum of the plan's per-slot
    attended bytes — the same total B x B=1 recordings would charge — so
    replayed batched traces keep the sim cross-assert exact."""
    from repro.plan.heuristics import decode_attn_hbm_bytes
    from repro.sim.replay import KernelRecorder, recording
    lens = (17, 48, 5)
    dp = plan_decode_step(SMOKE, lens)
    lp = dp.layers[0]
    q, k, v = _inputs(B=3, Hq=lp.heads, Hkv=lp.kv_heads, W=48,
                      hd=lp.head_dim)
    rec = KernelRecorder()
    with recording(rec):
        batched_decode_attention_by_plan(
            lp, q, k, v, jnp.asarray(lens, jnp.int32))
    (kt,) = rec.records
    expect = sum(decode_attn_hbm_bytes(
        kv, lp.heads, lp.kv_heads, lp.head_dim, lp.mode,
        append=not lp.cross, bytes_per_el=4) for kv in lp.seq_kv)
    assert kt.kind == "decode"
    assert kt.hbm_bytes == expect
    assert kt.op == lp.name


def test_full_width_matches_multi_head_attention():
    """A full bucket (every row attends the whole buffer) reduces to
    plain single-query MHA."""
    q, k, v = _inputs()
    W = k.shape[2]
    out = decode_attention_jnp(q, k, v, W)
    mh = multi_head_attention(q, k, v, causal=False, block_q=8,
                              block_k=256)
    assert jnp.max(jnp.abs(out - mh)) < 1e-6
