"""Fast-DSE tests (DESIGN.md §16): simulation cache, parallel sweep
executor, plan interning, and the successive-halving search.

The three ISSUE-10 acceptance pins live here: a cache hit reproduces the
cold simulation's metrics *exactly* (not approximately — the cache
stores the cold run's serialized numbers and JSON round-trips floats
bit-exactly); ``run_sweep(workers=N)`` emits rows byte-identical to a
serial sweep; and the search recovers the exhaustive grid's Pareto
frontier on a small space while fully simulating at most half the
points.
"""
import dataclasses
import json

import pytest

from repro.configs import registry
from repro.configs.hardware import HardwareConfig, STREAMDCIM_BASE
from repro.dse import (Axes, SimCache, energy_fingerprint, hw_fingerprint,
                       resolve_plan_json, run_sweep, sample_space,
                       sim_cache_key, successive_halving)

SEQ = 512           # short sequences keep the swept points fast

SMALL_AXES = Axes(groups=((2, 1), (4, 2), (8, 4)),
                  rewrite_bus_bits=(512,), ping_pong=(True,))

GRID_AXES = Axes(groups=((2, 1), (4, 2), (8, 4)),
                 rewrite_bus_bits=(512, 1024), ping_pong=(True, False))

KW = dict(models=["whisper-base"], axes=SMALL_AXES, seq_lens=(SEQ,),
          include_presets=False)


def _row_dicts(result):
    return [r.to_dict() for r in result.rows]


# ------------------------------------------------------------ cache keying

def test_hw_fingerprint_ignores_name_only():
    renamed = dataclasses.replace(STREAMDCIM_BASE, name="other-name")
    assert hw_fingerprint(renamed) == hw_fingerprint(STREAMDCIM_BASE)
    slower = dataclasses.replace(STREAMDCIM_BASE, rewrite_bus_bits=1024)
    assert hw_fingerprint(slower) != hw_fingerprint(STREAMDCIM_BASE)


def test_energy_fingerprint_includes_name():
    # Same costs under a different name label a different frontier cell:
    # the folds must cache separately.
    em = registry.ENERGY_CONFIGS[next(iter(registry.ENERGY_CONFIGS))]
    renamed = dataclasses.replace(em, name="same-costs-other-name")
    assert energy_fingerprint(renamed) != energy_fingerprint(em)


def test_cache_key_namespaces_proxy_from_point():
    key_pt = sim_cache_key('{"plan": 1}', STREAMDCIM_BASE,
                           evaluator="point")
    key_px = sim_cache_key('{"plan": 1}', STREAMDCIM_BASE,
                           evaluator="proxy")
    assert key_pt != key_px
    # calibration scale is part of the key (scaling changes the schedule)
    assert sim_cache_key('{"plan": 1}', STREAMDCIM_BASE,
                         scale={"ATTN": 2.0}) != key_pt


# ------------------------------------------------- cache hit == cold run

def test_cache_hit_exactly_reproduces_cold_rows():
    cache = SimCache()
    cold = run_sweep(cache=cache, **KW)
    assert cold.cache_stats["misses"] == len(cold.rows)
    assert cold.cache_stats["hits"] == 0
    warm = run_sweep(cache=cache, **KW)
    assert warm.cache_stats["hits"] == len(warm.rows)
    assert warm.cache_stats["misses"] == 0
    # exact equality, field by field — latency, energy floats, headroom,
    # bottleneck stamps, everything
    assert _row_dicts(warm) == _row_dicts(cold)


def test_disk_cache_warm_starts_fresh_process_state(tmp_path):
    store = str(tmp_path / "simcache")
    cold = run_sweep(cache=store, **KW)
    # A brand-new SimCache over the same directory — models a second
    # ``run.py dse`` invocation — must serve everything from disk.
    warm = run_sweep(cache=SimCache(store), **KW)
    assert warm.cache_stats["hits"] == len(warm.rows)
    assert warm.cache_stats["disk_hits"] > 0
    assert _row_dicts(warm) == _row_dicts(cold)


def test_cache_stats_are_per_sweep_deltas():
    cache = SimCache()
    run_sweep(cache=cache, **KW)
    warm = run_sweep(cache=cache, **KW)
    # the second SweepResult reports ONLY its own hits, not cumulative
    assert warm.cache_stats["misses"] == 0
    assert warm.cache_stats["stores"] == 0
    assert warm.cache_stats["hits"] == len(warm.rows)


def test_partial_energy_folds_resimulate_and_union():
    ems = list(registry.ENERGY_CONFIGS.values())
    cache = SimCache()
    run_sweep(cache=cache, energy_models=ems[:1], **KW)
    # asking for MORE folds than cached must re-simulate (the trace is
    # not stored), then the union serves both subsets
    both = run_sweep(cache=cache, energy_models=ems[:2], **KW)
    assert both.cache_stats["hits"] == 0
    again = run_sweep(cache=cache, energy_models=ems[:2], **KW)
    assert again.cache_stats["hits"] * 2 == len(again.rows)
    first = run_sweep(cache=cache, energy_models=ems[:1], **KW)
    assert first.cache_stats["hits"] == len(first.rows)


# ------------------------------------------------------- parallel executor

def test_workers_rows_byte_identical_to_serial():
    serial = run_sweep(**KW)
    parallel = run_sweep(workers=2, **KW)
    assert (json.dumps(_row_dicts(parallel), sort_keys=True)
            == json.dumps(_row_dicts(serial), sort_keys=True))
    assert parallel.skipped == serial.skipped


def test_workers_with_disk_cache_merge_stats(tmp_path):
    store = str(tmp_path / "simcache")
    cold = run_sweep(workers=2, cache=store, **KW)
    assert cold.cache_stats["misses"] == len(cold.rows)
    assert cold.cache_stats["stores"] == len(cold.rows)
    # serial warm run over the workers' store: everything from disk
    warm = run_sweep(cache=SimCache(store), **KW)
    assert warm.cache_stats["hits"] == len(warm.rows)
    assert _row_dicts(warm) == _row_dicts(cold)


def test_workers_progress_called_in_serial_order():
    seen_serial, seen_parallel = [], []
    run_sweep(progress=lambda r: seen_serial.append(r.hw), **KW)
    run_sweep(workers=2, progress=lambda r: seen_parallel.append(r.hw),
              **KW)
    assert seen_parallel == seen_serial


# ---------------------------------------------------------- plan interning

def test_to_dict_interns_duplicate_plans():
    ems = list(registry.ENERGY_CONFIGS.values())
    res = run_sweep(energy_models=ems, **KW)
    art = res.to_dict()
    assert all("plan_json" not in rd for rd in art["rows"])
    # one plan per simulated point, not per (point x energy table) row
    assert len(art["plan_table"]) * len(ems) == len(art["rows"])
    for rd, row in zip(art["rows"], res.rows):
        assert resolve_plan_json(art, rd) == row.plan_json
    json.dumps(art)                     # artifact stays serializable


def test_to_dict_can_skip_interning():
    res = run_sweep(**KW)
    art = res.to_dict(intern_plans=False)
    assert "plan_table" not in art
    for rd, row in zip(art["rows"], res.rows):
        assert rd["plan_json"] == row.plan_json
        assert resolve_plan_json(art, rd) == row.plan_json


# ------------------------------------------------- successive-halving search

def test_sample_space_is_deterministic_and_keeps_presets():
    a, _ = sample_space(5, seed=7)
    b, _ = sample_space(5, seed=7)
    assert [p.name for p in a] == [p.name for p in b]
    assert len(a) == 5
    # presets lead the draw regardless of seed
    assert [p.name for p in a[:3]] == list(registry.HW_CONFIGS)
    c, _ = sample_space(5, seed=8)
    assert {p.name for p in c} != {p.name for p in a} or c == a


def test_search_recovers_grid_frontier_with_half_the_sims():
    grid = run_sweep(models=["whisper-base"], axes=GRID_AXES,
                     seq_lens=(SEQ,), include_presets=False)
    found = successive_halving(models=["whisper-base"], axes=GRID_AXES,
                               seq_len=SEQ, include_presets=False)
    want = sorted((r.hw, r.latency_cycles, r.energy_pj)
                  for r in grid.pareto())
    got = sorted((r.hw, r.latency_cycles, r.energy_pj)
                 for r in found.sweep.pareto())
    assert want == got
    assert found.full_sims <= len(grid.rows) / 2
    assert found.space_size == len(grid.rows)
    # the ledger is replayable bookkeeping: rungs narrow monotonically
    # and the final rung is full fidelity over the emitted survivors
    sizes = [len(r.candidates) for r in found.rungs]
    assert sizes == sorted(sizes, reverse=True)
    assert not found.rungs[-1].proxy
    assert sorted(found.rungs[-1].survivors) == sorted(
        {r.hw for r in found.sweep.rows})


def test_search_rows_match_grid_rows_exactly():
    # a surviving point's full-fidelity row == the grid's row for that
    # point, stamps and plan JSON included
    grid = run_sweep(models=["whisper-base"], axes=GRID_AXES,
                     seq_lens=(SEQ,), include_presets=False)
    found = successive_halving(models=["whisper-base"], axes=GRID_AXES,
                               seq_len=SEQ, include_presets=False)
    by_hw = {r.hw: r.to_dict() for r in grid.rows}
    for row in found.sweep.rows:
        assert row.to_dict() == by_hw[row.hw]
        assert row.bottleneck
        assert row.headroom


def test_search_artifact_carries_rung_ledger():
    found = successive_halving(models=["whisper-base"], axes=GRID_AXES,
                               seq_len=SEQ, include_presets=False,
                               cache=SimCache())
    art = found.to_dict()
    meta = art["search"]
    assert meta["space_size"] == 12
    assert meta["num_rungs"] == len(meta["rungs"]) == len(found.rungs)
    assert meta["full_sims"] == found.full_sims
    for rec in meta["rungs"]:
        assert set(rec["survivors"]) <= set(rec["candidates"])
    json.dumps(art)

    # proxy rung records must never satisfy a full-fidelity lookup: a
    # fresh search over a cache warmed ONLY with proxies still
    # simulates the final rung (hits there would mean namespace bleed)
    cache = SimCache()
    successive_halving(models=["whisper-base"], axes=GRID_AXES,
                       seq_len=SEQ, include_presets=False, cache=cache)
    again = successive_halving(models=["whisper-base"], axes=GRID_AXES,
                               seq_len=SEQ, include_presets=False,
                               cache=cache)
    # the repeat search is all hits (both namespaces warmed)
    assert again.sweep.cache_stats["hits"] == len(again.sweep.rows)


def test_search_rejects_bad_eta():
    with pytest.raises(ValueError, match="eta"):
        successive_halving(models=["whisper-base"], eta=1)
