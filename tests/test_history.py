"""Perf-regression tracking tests (DESIGN.md §14, benchmarks/history.py):
snapshot round-trips, schema gating, direction-aware tolerance-band
comparison, and the injected-regression drill against the committed
``benchmarks/baselines/BENCH_*.json`` files — proving the CI gate trips.
"""
from __future__ import annotations

import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import history
from benchmarks.history import (BENCH_SCHEMA_VERSION, BenchSnapshot,
                                baseline_path, compare, load_snapshot,
                                metric_direction, snapshot, snapshot_name,
                                write_snapshot)

BASELINES = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "baselines")


def _snap(metrics, section="unit"):
    return snapshot(section, {"metrics": dict(metrics)})


# ---------------------------------------------------------------------------
# Snapshot plumbing
# ---------------------------------------------------------------------------

def test_snapshot_name_strips_bench_prefix():
    assert snapshot_name("bench_sim") == "BENCH_sim.json"
    assert snapshot_name("serve") == "BENCH_serve.json"
    assert baseline_path("d", "shard").endswith(os.path.join(
        "d", "BENCH_shard.json"))


def test_snapshot_write_load_roundtrip(tmp_path):
    entry = {"metrics": {"cycles": 123.0, "foo_speedup": 2.5},
             "info": {"hw": "streamdcim-base"},
             "critical_path": {"makespan": 123, "path_events": 4}}
    snap = snapshot("bench_sim", entry, metadata={"git": "abc"})
    path = write_snapshot(snap, str(tmp_path))
    assert os.path.basename(path) == "BENCH_sim.json"
    loaded = load_snapshot(path)
    assert loaded.section == "bench_sim"
    assert loaded.metrics == snap.metrics
    assert loaded.critical_path == snap.critical_path
    assert loaded.schema_version == BENCH_SCHEMA_VERSION
    # stable on-disk form: sorted keys, trailing newline
    raw = open(path).read()
    assert raw.endswith("\n")
    assert json.loads(raw)["schema_version"] == BENCH_SCHEMA_VERSION


def test_load_snapshot_rejects_schema_mismatch(tmp_path):
    snap = snapshot("serve", {"metrics": {"x": 1.0}})
    path = write_snapshot(snap, str(tmp_path))
    d = json.load(open(path))
    d["schema_version"] = BENCH_SCHEMA_VERSION + 1
    json.dump(d, open(path, "w"))
    with pytest.raises(ValueError, match="schema"):
        load_snapshot(path)


# ---------------------------------------------------------------------------
# Direction-aware comparison
# ---------------------------------------------------------------------------

def test_metric_direction_suffix_convention():
    assert metric_direction("total_cycles") == "lower"
    assert metric_direction("vilbert_tile_hbm_bytes") == "lower"
    assert metric_direction("tokens_per_kcycle") == "higher"
    assert metric_direction("requests_per_kcycle") == "higher"
    assert metric_direction("vilbert_tile_8c_speedup") == "higher"
    assert metric_direction("mesh_link_util") == "higher"


def test_compare_lower_better_band():
    base = _snap({"cycles": 1000.0})
    assert compare(_snap({"cycles": 1000.0}), base).ok
    assert compare(_snap({"cycles": 1019.0}), base).ok        # inside 2%
    bad = compare(_snap({"cycles": 1021.0}), base)
    assert not bad.ok
    assert [d.name for d in bad.regressions] == ["cycles"]
    good = compare(_snap({"cycles": 900.0}), base)
    assert good.ok and [d.name for d in good.improvements] == ["cycles"]


def test_compare_higher_better_band():
    base = _snap({"tokens_per_kcycle": 10.0})
    assert compare(_snap({"tokens_per_kcycle": 9.81}), base).ok
    assert not compare(_snap({"tokens_per_kcycle": 9.79}), base).ok
    assert compare(_snap({"tokens_per_kcycle": 12.0}), base).ok


def test_compare_zero_baseline_exact():
    base = _snap({"dropped": 0.0})
    assert compare(_snap({"dropped": 0.0}), base).ok
    assert not compare(_snap({"dropped": 1.0}), base).ok


def test_compare_missing_metric_fails_new_metric_passes():
    base = _snap({"a": 1.0, "b": 2.0})
    cur = _snap({"a": 1.0, "c": 3.0})
    cmp = compare(cur, base)
    assert not cmp.ok                      # 'b' silently vanished -> fail
    assert list(cmp.missing) == ["b"]
    assert list(cmp.new) == ["c"]
    assert "b" in cmp.format()


def test_compare_per_metric_tolerance_override():
    base = _snap({"cycles": 1000.0})
    cur = _snap({"cycles": 1100.0})
    assert not compare(cur, base).ok
    assert compare(cur, base, tolerances={"cycles": 0.15}).ok


# ---------------------------------------------------------------------------
# The injected-regression drill against the committed baselines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("section", ["bench_sim", "serve", "shard"])
def test_committed_baseline_loads_and_selfcompares(section):
    path = baseline_path(BASELINES, section)
    assert os.path.exists(path), f"missing committed baseline {path}"
    base = load_snapshot(path)
    assert base.metrics, section
    assert base.critical_path["makespan"] > 0
    assert base.critical_path["path_events"] > 0
    # identity compare: a run identical to the baseline passes the gate
    assert compare(base, base).ok


def test_injected_regression_trips_gate_against_committed_baseline():
    """Perturb one committed metric by 10% in the losing direction and
    assert compare() fails — the exact code path ``make bench-check``
    exercises in CI."""
    base = load_snapshot(baseline_path(BASELINES, "bench_sim"))
    cur = copy.deepcopy(base)
    name, val = next((k, v) for k, v in sorted(cur.metrics.items())
                     if metric_direction(k) == "lower" and v > 0)
    cur.metrics[name] = val * 1.10
    cmp = compare(cur, base)
    assert not cmp.ok
    assert any(d.name == name for d in cmp.regressions)
    assert name in cmp.format()


def test_injected_throughput_regression_trips_gate():
    base = load_snapshot(baseline_path(BASELINES, "serve"))
    cur = copy.deepcopy(base)
    assert metric_direction("tokens_per_kcycle") == "higher"
    cur.metrics["tokens_per_kcycle"] *= 0.90
    assert not compare(cur, base).ok
