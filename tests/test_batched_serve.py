"""Batched paged-KV serving (DESIGN.md §15): the paged pool, shape
buckets, bucketed decode planning, the engine's batched decode path, and
the coarse==fine serve-sim equivalence.

Invariant map:

* ``PagedKVCache`` gather→scatter round-trips are value-exact and pages
  allocate/free with slot lifecycle (a leak would exhaust the pool);
* ``plan_decode_buckets`` partitions the whole-step plan exactly —
  per-bucket HBM predictions sum to ``plan_decode_step``'s;
* the batched engine emits token-for-token what the per-slot engine
  emits (row independence end-to-end), while issuing
  ``decode_batches < decode_calls`` dispatches; non-pageable cache trees
  (SSM/hybrid) fall back transparently;
* ``simulate_serve(decode_lowering="coarse")`` reproduces fine's
  cycles, bytes, and metrics *exactly* with strictly fewer trace events.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.types import ExecutionMode as EM
from repro.plan import plan_decode_buckets, plan_decode_step
from repro.serve.engine import Engine, Request
from repro.serve.kv_cache import PagedKVCache, shape_buckets
from repro.serve.schedule import ServeRequest
from repro.sim import simulate_serve

SMOKE = registry.get_config("starcoder2-7b", smoke=True)
SLIDING = registry.get_config("h2o-danube3-4b", smoke=True)


def _params(cfg=SMOKE):
    return registry.model_module(cfg).init(jax.random.PRNGKey(0), cfg)


def _requests(cfg, *, n=6, seed=3, arrival_spread=3, max_new=(2, 6),
              plen=(3, 10)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(int(rng.integers(*plen)),)
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(*max_new)),
                    arrival_step=int(rng.integers(0, arrival_spread)))
            for i in range(n)]


# ---------------------------------------------------------------------------
# shape_buckets / PagedKVCache
# ---------------------------------------------------------------------------

def test_shape_buckets_order_preserving():
    assert shape_buckets([5, 3, 5, 3, 7]) == [
        (5, (0, 2)), (3, (1, 3)), (7, (4,))]
    assert shape_buckets([4]) == [(4, (0,))]
    with pytest.raises(ValueError):
        shape_buckets([3, 0])


def _cache(L=2, Hkv=2, W=24, hd=8, length=9, seed=0):
    rng = np.random.default_rng(seed)
    return {"layers": {
                "k": jnp.asarray(rng.normal(size=(L, 1, Hkv, W, hd)),
                                 jnp.float32),
                "v": jnp.asarray(rng.normal(size=(L, 1, Hkv, W, hd)),
                                 jnp.float32)},
            "len": jnp.asarray(length, jnp.int32)}


def test_paged_pool_roundtrip_and_growth():
    pool = PagedKVCache(slots=3, num_layers=2, kv_heads=2, width=24,
                        head_dim=8, dtype=jnp.float32, page_size=8)
    c0, c1 = _cache(length=9, seed=0), _cache(length=9, seed=1)
    pool.admit(0, c0)
    pool.admit(1, c1)
    assert pool.pages_in_use == 4                 # ceil(9/8) = 2 each
    g = pool.gather([0, 1])
    assert g["layers"]["k"].shape == (2, 2, 2, 24, 8)
    assert int(g["len"]) == 9
    # valid prefix round-trips exactly, per slot
    assert jnp.array_equal(g["layers"]["k"][:, 0, :, :9],
                           c0["layers"]["k"][:, 0, :, :9])
    assert jnp.array_equal(g["layers"]["v"][:, 1, :, :9],
                           c1["layers"]["v"][:, 0, :, :9])
    # grow across a page boundary: 9 -> 17 needs a third page per slot
    cur = g
    for new_len in range(10, 18):
        cur = {"layers": {
                   "k": cur["layers"]["k"].at[:, :, :, new_len - 1].set(1.0),
                   "v": cur["layers"]["v"].at[:, :, :, new_len - 1].set(2.0)},
               "len": jnp.asarray(new_len, jnp.int32)}
        pool.scatter([0, 1], cur)
        cur = pool.gather([0, 1])
    assert pool.pages_in_use == 6
    assert float(cur["layers"]["k"][0, 0, 0, 16, 0]) == 1.0
    pool.free(0)
    assert pool.pages_in_use == 3                 # slot 1 keeps its pages
    assert pool.len_of(1) == 17


def test_paged_pool_guards():
    pool = PagedKVCache(slots=2, num_layers=2, kv_heads=2, width=24,
                        head_dim=8, dtype=jnp.float32, page_size=8)
    pool.admit(0, _cache(length=5))
    with pytest.raises(ValueError, match="already admitted"):
        pool.admit(0, _cache(length=5))
    pool.admit(1, _cache(length=9))
    with pytest.raises(ValueError, match="unequal"):
        pool.gather([0, 1])
    assert not PagedKVCache.supports({"layers": {"attn": 1, "ssm": 2},
                                      "len": 0})
    assert not PagedKVCache.supports(jnp.zeros(3))
    assert PagedKVCache.supports(_cache())


def test_paged_pool_exhaustion_is_loud():
    # slots=1 pool holds exactly ceil(16/8)=2 pages: a second full-width
    # admission (a slot leak) must fail loudly, not corrupt pages.
    pool = PagedKVCache(slots=1, num_layers=1, kv_heads=1, width=16,
                        head_dim=4, dtype=jnp.float32, page_size=8)
    pool.admit(0, _cache(L=1, Hkv=1, W=16, hd=4, length=16))
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.admit(1, _cache(L=1, Hkv=1, W=16, hd=4, length=16))


# ---------------------------------------------------------------------------
# plan_decode_buckets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ctx", [(9, 5, 9, 7, 5), (4,), (6, 6, 6)])
def test_plan_decode_buckets_partition_exact(ctx):
    buckets = plan_decode_buckets(SMOKE, ctx)
    whole = plan_decode_step(SMOKE, ctx)
    covered = sorted(p for slots, _ in buckets for p in slots)
    assert covered == list(range(len(ctx)))
    assert sum(p.total_hbm_bytes for _, p in buckets) \
        == whole.total_hbm_bytes
    assert sum(p.total_rewrite_cycles for _, p in buckets) \
        == whole.total_rewrite_cycles
    for slots, p in buckets:
        assert p.context == tuple(ctx[s] for s in slots)
        assert len(set(p.context)) == 1           # uniform bucket


def test_plan_decode_buckets_respects_mode_override():
    buckets = plan_decode_buckets(SMOKE, (5, 8, 5), mode=EM.NON_STREAM,
                                  force_mode=True)
    for _, p in buckets:
        assert all(lp.mode == EM.NON_STREAM for lp in p.layers)


# ---------------------------------------------------------------------------
# Engine: batched == per-slot, dispatch accounting, fallback
# ---------------------------------------------------------------------------

def _run_both(cfg, *, req_kw=None, eng_kw=None):
    req_kw = req_kw or {}
    eng_kw = eng_kw or {}
    outs = []
    for batch in (True, False):
        eng = Engine(cfg, _params(cfg), slots=3, max_len=32,
                     batch_decode=batch, **eng_kw)
        for r in _requests(cfg, **req_kw):
            eng.submit(r)
        done = eng.run()
        outs.append((eng, {r.rid: list(r.out_tokens) for r in done}))
    return outs


def test_batched_engine_matches_per_slot_tokens():
    (engb, toksb), (engs, tokss) = _run_both(SMOKE)
    assert toksb == tokss
    assert engb.decode_calls == engs.decode_calls
    assert engb.decode_batches < engb.decode_calls
    assert engs.decode_batches == engs.decode_calls
    # the pool drained with the traffic: every page recycled
    assert engb._pool is not None and engb._pool.pages_in_use == 0
    assert engb.stats()["decode_batches"] == engb.decode_batches


@pytest.mark.parametrize("mode", [EM.NON_STREAM, EM.LAYER_STREAM,
                                  EM.TILE_STREAM])
@pytest.mark.slow
def test_batched_engine_matches_per_slot_all_modes(mode):
    (_, toksb), (_, tokss) = _run_both(SMOKE, eng_kw={"mode": mode})
    assert toksb == tokss


@pytest.mark.slow
def test_batched_engine_sliding_window_ring_wrap():
    """Requests long enough to wrap the sliding-window ring buffer
    (kv > window=16) keep batched == per-slot."""
    (engb, toksb), (_, tokss) = _run_both(
        SLIDING, req_kw={"n": 4, "plen": (10, 14), "max_new": (10, 14),
                         "arrival_spread": 2})
    assert toksb == tokss
    assert engb.decode_batches < engb.decode_calls


def test_step_record_buckets_partition_decoded():
    eng = Engine(SMOKE, _params(), slots=3, max_len=32)
    for r in _requests(SMOKE):
        eng.submit(r)
    eng.run()
    assert eng.decode_calls == sum(
        eng.last_schedule.decode_steps.values())
    for rec in eng.step_log:
        if not rec.decoded:
            continue
        assert rec.buckets is not None
        rids = [rid for _, rs in rec.buckets for rid in rs]
        assert sorted(rids) == sorted(rec.decoded)
        for kv, rs in rec.buckets:
            for rid in rs:
                i = rec.decoded.index(rid)
                assert rec.kv_lens[i] == kv


def test_batched_disabled_and_fallback_paths():
    # explicit opt-out records no buckets
    eng = Engine(SMOKE, _params(), slots=2, max_len=32,
                 batch_decode=False)
    for r in _requests(SMOKE, n=3):
        eng.submit(r)
    eng.run()
    assert all(rec.buckets is None for rec in eng.step_log)
    assert eng._pool is None
    # SSM cache trees can't page: auto-fallback, identical behaviour
    ssm = registry.get_config("mamba2-780m", smoke=True)
    (engb, toksb), (_, tokss) = _run_both(
        ssm, req_kw={"n": 3, "max_new": (2, 4)})
    assert toksb == tokss
    assert engb._pool is None
    assert all(rec.buckets is None for rec in engb.step_log)


# ---------------------------------------------------------------------------
# Coarse decode lowering == fine (satellite: sim equivalence)
# ---------------------------------------------------------------------------

TRAFFIC = [ServeRequest(0, 6, 5, 0), ServeRequest(1, 4, 3, 0),
           ServeRequest(2, 9, 4, 1), ServeRequest(3, 6, 6, 2),
           ServeRequest(4, 5, 2, 5)]


def _sim_pair(cfg=SMOKE, **kw):
    fine = simulate_serve(cfg, TRAFFIC, slots=3, **kw)
    coarse = simulate_serve(cfg, TRAFFIC, slots=3,
                            decode_lowering="coarse", **kw)
    return fine, coarse


def _assert_equivalent(fine, coarse):
    assert coarse.cycles == fine.cycles
    assert coarse.hbm_bytes == fine.hbm_bytes
    assert coarse.metrics == fine.metrics
    assert coarse.cycle_metrics == fine.cycle_metrics
    for a, b in zip(fine.steps, coarse.steps):
        assert a.to_dict() == b.to_dict()
    assert len(coarse.result.trace.events) < len(fine.result.trace.events)


def test_coarse_equals_fine_default_mode():
    _assert_equivalent(*_sim_pair())


@pytest.mark.parametrize("mode", [EM.NON_STREAM, EM.LAYER_STREAM,
                                  EM.TILE_STREAM])
@pytest.mark.slow
def test_coarse_equals_fine_forced_modes(mode):
    _assert_equivalent(*_sim_pair(mode=mode, force_mode=True))


def test_coarse_equals_fine_calibrated():
    """Per-resource cycle scaling applies once (in the memoized scratch
    run), never twice."""
    cal = {"ATTN": 1.7, "HBM": 1.3, "CIM": 2.0}
    _assert_equivalent(*_sim_pair(calibration=cal))


def test_coarse_cross_assert_still_fires():
    """The planner==simulator byte cross-assert survives coarsening: a
    decode plan predicting the wrong bytes still fails the run."""
    def bad_decode_plan(kv):
        dp = plan_decode_step(SMOKE, kv)
        lp = dp.layers[0]
        layers = (dataclasses.replace(lp, hbm_bytes=lp.hbm_bytes + 64),) \
            + dp.layers[1:]
        return dataclasses.replace(dp, layers=layers)
    with pytest.raises(RuntimeError, match="disagree on the decode"):
        simulate_serve(SMOKE, TRAFFIC, slots=3,
                       decode_plan_fn=bad_decode_plan,
                       decode_lowering="coarse")


def test_invalid_decode_lowering_rejected():
    with pytest.raises(ValueError, match="decode_lowering"):
        simulate_serve(SMOKE, TRAFFIC, slots=3, decode_lowering="medium")


@pytest.mark.slow
def test_coarse_event_reduction_long_context():
    """The point of coarsening: on long-context many-slot traffic the
    event count collapses (>= 2x here, growing with context x slots)
    while every reported number stays identical."""
    reqs = [ServeRequest(i, 48, 24, i % 4) for i in range(12)]
    fine = simulate_serve(SMOKE, reqs, slots=8)
    coarse = simulate_serve(SMOKE, reqs, slots=8,
                            decode_lowering="coarse")
    assert coarse.cycles == fine.cycles
    assert coarse.hbm_bytes == fine.hbm_bytes
    assert coarse.metrics == fine.metrics
    nf = len(fine.result.trace.events)
    nc = len(coarse.result.trace.events)
    assert nc * 2 <= nf, f"expected >=2x event reduction, got {nf}/{nc}"
