"""Chiplet-mesh scale-out (``repro.shard``, DESIGN.md §13): sharded-plan
byte exactness across modes/chips, the 1-chip identity, weak scaling,
interconnect-bound attribution, the pipelined-multicast overlap calculus,
plan serialization/tampering, mesh serving numerics, and the CLI."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.hardware import STREAMDCIM_BASE
from repro.core.types import ExecutionMode as EM
from repro.plan import plan_model
from repro.shard import (MeshSpec, ShardedPlan, multicast_span,
                         pipelined_multicast_wins, resolve_axis,
                         shard_plan, simulate_sharded_plan)
from repro.shard import noc
from repro.sim import simulate_plan

SCALE_MODELS = ("vilbert-base", "qwen2-vl-2b")

#: Link parameters under which compute, not the wire, is the critical
#: resource — the regime the ISSUE's weak-scaling clause targets.
GENEROUS_NOC = dict(link_bytes_per_cycle=4096, hop_cycles=1)

_PLANS = {}


def _plan(model, mode, seq=512):
    key = (model, mode, seq)
    if key not in _PLANS:
        cfg = registry.get_config(model)
        _PLANS[key] = plan_model(cfg, hw=STREAMDCIM_BASE, seq_len=seq,
                                 mode=mode, force_mode=True)
    return _PLANS[key]


# ---------------------------------------------------------------------------
# Byte exactness + the 1-chip identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", SCALE_MODELS)
@pytest.mark.parametrize("mode", list(EM))
def test_byte_exactness_all_modes_and_chip_counts(model, mode):
    """The acceptance grid: for every mode x model, simulation at
    1/2/4/8 chips must agree with the sharded plan's HBM + collective
    byte predictions (simulate_sharded_plan raises otherwise; the
    totals are re-checked here from the packed result)."""
    plan = _plan(model, mode)
    for chips in (1, 2, 4, 8):
        splan = shard_plan(plan, MeshSpec(chips=chips))
        res = simulate_sharded_plan(splan)
        assert res.collective_bytes == splan.total_collective_link_bytes
        # Attention-stream bytes are predicted op-exactly (the simulator
        # raises otherwise); gemm DMA rides on top of that floor.
        want_attn = sum(lp.hbm_bytes for cp in splan.chip_plans
                        for lp in cp.layers)
        assert res.hbm_bytes >= want_attn > 0
        # Trailing collectives (output gather) can outlive the last
        # chip-local event, never the reverse.
        assert res.cycles >= max(res.per_chip_cycles)
        if chips == 1:
            assert splan.collectives == ()
            assert res.collective_bytes == 0


@pytest.mark.parametrize("mode", list(EM))
def test_one_chip_is_identity(mode):
    """A 1-chip ShardedPlan is byte- AND cycle-identical to the
    unsharded plan through the unsharded simulator."""
    plan = _plan("vilbert-base", mode)
    base = simulate_plan(plan)
    res = simulate_sharded_plan(shard_plan(plan, MeshSpec(chips=1)))
    assert res.cycles == base.cycles
    assert res.hbm_bytes == base.hbm_bytes
    assert res.per_chip_hbm_bytes == (base.hbm_bytes,)


def test_line_topology_byte_exact_and_wrap_penalty():
    plan = _plan("vilbert-base", EM.TILE_STREAM)
    ring = simulate_sharded_plan(shard_plan(plan, MeshSpec(chips=4)))
    line = simulate_sharded_plan(
        shard_plan(plan, MeshSpec(chips=4, topology="line")))
    assert MeshSpec(chips=4, topology="line").num_links == 6
    # The ring schedule's wrap step walks back across the whole line, so
    # the line moves at least as many bytes for the same collectives.
    assert line.collective_bytes >= ring.collective_bytes


# ---------------------------------------------------------------------------
# Weak scaling + attribution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model,mode", [("vilbert-base", EM.TILE_STREAM),
                                        ("qwen2-vl-2b", EM.NON_STREAM)])
def test_weak_scaling_monotone_until_noc_critical(model, mode):
    """With a generous NoC (compute-critical regime) simulated latency
    is monotone non-increasing in chip count."""
    plan = _plan(model, mode)
    cycles = []
    for chips in (1, 2, 4, 8):
        mesh = MeshSpec(chips=chips, **GENEROUS_NOC)
        cycles.append(simulate_sharded_plan(shard_plan(plan, mesh)).cycles)
    assert all(a >= b for a, b in zip(cycles, cycles[1:])), cycles


def test_interconnect_bound_mesh_reports_interconnect():
    from repro.obs import INTERCONNECT, bottleneck_of
    plan = _plan("vilbert-base", EM.TILE_STREAM)
    starved = MeshSpec(chips=4, link_bytes_per_cycle=1)
    res = simulate_sharded_plan(shard_plan(plan, starved))
    assert bottleneck_of(res.trace) == INTERCONNECT
    # ...and a generous mesh does not.
    roomy = simulate_sharded_plan(
        shard_plan(plan, MeshSpec(chips=4, **GENEROUS_NOC)))
    assert bottleneck_of(roomy.trace) != INTERCONNECT
    assert roomy.cycles < res.cycles


def test_attribution_folds_chip_prefixes():
    """bottleneck_of / attribute are identity on unprefixed single-chip
    traces and fold ``c{i}.`` prefixes on sharded ones."""
    from repro.obs import attribute, base_resource, bottleneck_of, op_class
    assert base_resource("c3.ATTN") == "ATTN"
    assert base_resource("ATTN") == "ATTN"
    assert base_resource("NOC_L2") == "INTERCONNECT"
    from repro.obs.attribution import NOC_LINK_PREFIX
    assert noc.LINK_PREFIX == NOC_LINK_PREFIX   # layering-pinned copy
    assert op_class("c2.l0_ffn_up") == "ffn"
    plan = _plan("vilbert-base", EM.TILE_STREAM)
    base = simulate_plan(plan)
    res = simulate_sharded_plan(shard_plan(plan, MeshSpec(chips=1)))
    assert bottleneck_of(res.trace) == bottleneck_of(base.trace)
    rep, srep = attribute(base.trace), attribute(res.trace)
    assert srep.busy == rep.busy
    assert srep.rewrite_exposed == rep.rewrite_exposed


def test_timeline_per_chip_and_noc_tracks():
    from repro.obs import timeline_from_sharded, validate_timeline
    plan = _plan("vilbert-base", EM.TILE_STREAM)
    res = simulate_sharded_plan(shard_plan(plan, MeshSpec(chips=4)))
    tl = timeline_from_sharded(res)
    validate_timeline(tl)
    procs = {e["args"]["name"] for e in tl["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert {"chip0", "chip1", "chip2", "chip3", "noc"} <= procs
    link_tracks = {e["args"]["name"] for e in tl["traceEvents"]
                   if e.get("ph") == "M" and e["name"] == "thread_name"
                   and e["args"]["name"].startswith(noc.LINK_PREFIX)}
    assert len(link_tracks) == 4                 # ring4: one per link


# ---------------------------------------------------------------------------
# The overlap calculus
# ---------------------------------------------------------------------------

def test_pipelined_multicast_wins_when_payload_dominates():
    big = MeshSpec(chips=8, link_bytes_per_cycle=128, hop_cycles=32)
    assert pipelined_multicast_wins(big, 1 << 20)
    assert (multicast_span(big, 1 << 20, pipelined=True)
            < multicast_span(big, 1 << 20, pipelined=False))
    # Tiny payloads: the extra per-chunk hop latency outweighs the saved
    # serialization, so store-and-forward is the right wire plan.
    assert not pipelined_multicast_wins(big, 64)


def test_pipelined_multicast_speeds_up_simulation():
    plan = _plan("vilbert-base", EM.NON_STREAM)
    pipe = simulate_sharded_plan(
        shard_plan(plan, MeshSpec(chips=4, pipelined_multicast=True)))
    saf = simulate_sharded_plan(
        shard_plan(plan, MeshSpec(chips=4, pipelined_multicast=False)))
    assert pipe.collective_bytes == saf.collective_bytes  # same bytes...
    assert pipe.cycles <= saf.cycles                      # ...less exposure


# ---------------------------------------------------------------------------
# Serialization + tamper detection
# ---------------------------------------------------------------------------

def test_sharded_plan_json_round_trip_replays():
    plan = _plan("qwen2-vl-2b", EM.TILE_STREAM)
    splan = shard_plan(plan, MeshSpec(chips=4))
    back = ShardedPlan.from_json(splan.to_json())
    assert back.to_dict() == splan.to_dict()
    a, b = simulate_sharded_plan(splan), simulate_sharded_plan(back)
    assert (a.cycles, a.hbm_bytes, a.collective_bytes) == \
           (b.cycles, b.hbm_bytes, b.collective_bytes)


def test_sharded_plan_version_check():
    d = shard_plan(_plan("vilbert-base", EM.TILE_STREAM),
                   MeshSpec(chips=2)).to_dict()
    d["version"] = 99
    with pytest.raises(ValueError, match="version"):
        ShardedPlan.from_dict(d)


def test_tampered_collective_bytes_raise():
    """Corrupting a collective's predicted link bytes must trip the
    byte-exactness check — the simulator lowers the honest wire plan."""
    plan = _plan("vilbert-base", EM.TILE_STREAM)
    splan = shard_plan(plan, MeshSpec(chips=4))
    assert splan.collectives
    colls = list(splan.collectives)
    colls[0] = dataclasses.replace(colls[0],
                                   link_bytes=colls[0].link_bytes + 1)
    bad = dataclasses.replace(splan, collectives=tuple(colls))
    with pytest.raises(RuntimeError, match="NoC link bytes"):
        simulate_sharded_plan(bad)


# ---------------------------------------------------------------------------
# Axis resolution
# ---------------------------------------------------------------------------

def test_axis_resolution_and_validation():
    vb = _plan("vilbert-base", EM.TILE_STREAM)
    # 8 vision + 12 language heads divide 2 and 4 but not 8: auto falls
    # from tensor parallelism to context parallelism at 8 chips.
    assert resolve_axis(vb, MeshSpec(chips=4)) == "tensor"
    assert resolve_axis(vb, MeshSpec(chips=8)) == "sequence"
    with pytest.raises(ValueError, match="tensor parallelism"):
        shard_plan(vb, MeshSpec(chips=8), axis="tensor")
    # Explicit group parallelism shards layers and stays byte-exact.
    g = shard_plan(vb, MeshSpec(chips=4), axis="group")
    assert g.axis == "group"
    assert {c.kind for c in g.collectives} <= {"multicast", "p2p"}
    simulate_sharded_plan(g)
    with pytest.raises(ValueError, match="group parallelism"):
        shard_plan(vb, MeshSpec(chips=1000), axis="group")


def test_mesh_spec_validation_and_round_trip():
    with pytest.raises(ValueError, match="chips"):
        MeshSpec(chips=0)
    with pytest.raises(ValueError, match="topology"):
        MeshSpec(chips=2, topology="torus")
    with pytest.raises(ValueError, match="axis"):
        MeshSpec(chips=2, axis="expert")
    m = MeshSpec(chips=4, topology="line", hop_cycles=7)
    assert MeshSpec.from_dict(m.to_dict()) == m


# ---------------------------------------------------------------------------
# Mesh serving: host-mesh numerics == single-chip numerics
# ---------------------------------------------------------------------------

def test_mesh_prefill_matches_single_chip():
    from repro.launch.mesh import make_host_mesh
    from repro.shard.serve import mesh_prefill
    cfg = registry.get_config("qwen2-vl-2b", smoke=True)
    mod = registry.model_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    toks = np.arange(1, 17, dtype=np.int32)[None, :]
    ref, _ = mod.prefill(params, cfg, {"tokens": toks}, max_len=32)
    got, _ = mesh_prefill(mod, params, cfg, {"tokens": toks},
                          mesh=make_host_mesh(), max_len=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("arch", ["starcoder2-7b", "qwen2-vl-2b"])
def test_engine_on_host_mesh_matches_single_chip(arch):
    from repro.launch.mesh import make_host_mesh
    from repro.serve.engine import Engine, Request

    def _run(mesh):
        cfg = registry.get_config(arch, smoke=True)
        params = registry.model_module(cfg).init(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, slots=2, max_len=48, mesh=mesh)
        for rid, (plen, new, arr) in enumerate([(8, 4, 0), (12, 3, 1)]):
            eng.submit(Request(rid=rid,
                               prompt=np.arange(1, plen + 1, dtype=np.int32),
                               max_new_tokens=new, arrival_step=arr))
        done = eng.run()
        return {r.rid: list(r.out_tokens) for r in done}

    assert _run(make_host_mesh()) == _run(None)


# ---------------------------------------------------------------------------
# Sweep + CLI
# ---------------------------------------------------------------------------

def test_shard_sweep_rows_and_curves():
    from repro.dse import run_shard_sweep   # re-exported (DESIGN.md §13)
    res = run_shard_sweep(["vilbert-base"], chips=(1, 2), smoke=True,
                          modes=[EM.TILE_STREAM], keep_plans=True)
    assert {r.chips for r in res.rows} == {1, 2}
    one = next(r for r in res.rows if r.chips == 1)
    assert one.speedup == 1.0 and one.efficiency == 1.0
    assert all(r.bottleneck for r in res.rows)
    d = res.to_dict()
    assert d["rows"] and d["speedup_vs_chips"]
    # Rows replay from their embedded plan_json.
    row = next(r for r in res.rows if r.chips == 2)
    replay = simulate_sharded_plan(ShardedPlan.from_dict(row.plan_json))
    assert replay.cycles == row.latency_cycles


def test_cli_smoke(tmp_path, capsys):
    from repro.shard.__main__ import main
    out = tmp_path / "shard.json"
    assert main(["--models", "vilbert-base", "--chips", "1,2",
                 "--modes", "tile_stream", "--smoke",
                 "--json", str(out)]) == 0
    text = capsys.readouterr().out
    assert "speedup" in text and "bottleneck" in text
    d = json.loads(out.read_text())
    assert d["rows"] and all("axis" in r for r in d["rows"])
