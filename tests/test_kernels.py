"""Per-kernel validation: Pallas (interpret=True) and blocked-jnp paths vs
the pure-jnp oracles in kernels/ref.py, swept over shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import jnp_blocked as JB
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.stream_attention import stream_attention
from repro.kernels.tile_gemm import tile_gemm

KEYS = jax.random.split(jax.random.PRNGKey(7), 12)


def _mk_attn(B, Hq, Hkv, Sq, Sk, hd, dtype=jnp.float32, D=None):
    q = jax.random.normal(KEYS[0], (B, Hq, Sq, hd), dtype) * 0.5
    k = jax.random.normal(KEYS[1], (B, Hkv, Sk, hd), dtype) * 0.5
    v = jax.random.normal(KEYS[2], (B, Hkv, Sk, hd), dtype) * 0.5
    out = [q, k, v]
    if D is not None:
        out.append(jax.random.normal(KEYS[3], (B, Sk, D), dtype) * 0.5)
        out.append(jax.random.normal(KEYS[4], (D, Hkv, hd), dtype)
                   * (D ** -0.5))
        out.append(jax.random.normal(KEYS[5], (D, Hkv, hd), dtype)
                   * (D ** -0.5))
    return out


FLASH_CASES = [
    # B, Hq, Hkv, Sq, Sk, hd, causal, window
    (1, 4, 4, 128, 128, 128, False, 0),          # MHA square
    (2, 8, 2, 256, 256, 128, True, 0),           # GQA causal
    (1, 4, 2, 128, 384, 128, True, 0),           # causal w/ offset KV
    (2, 4, 4, 128, 256, 128, True, 100),         # sliding window
    (1, 2, 1, 256, 256, 128, False, 0),          # MQA
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_kernel_interpret(case):
    B, Hq, Hkv, Sq, Sk, hd, causal, window = case
    q, k, v = _mk_attn(B, Hq, Hkv, Sq, Sk, hd)
    off = Sk - Sq if causal else 0
    o = flash_attention(q, k, v, causal=causal, window=window, q_offset=off,
                        block_q=128, block_k=128, interpret=True)
    o_ref = ref.ref_attention(q, k, v, causal=causal, window=window,
                              q_offset=off)
    np.testing.assert_allclose(o, o_ref, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_dtypes(dtype):
    q, k, v = _mk_attn(1, 4, 2, 128, 128, 128, dtype)
    o = flash_attention(q, k, v, causal=True, interpret=True,
                        block_q=128, block_k=128)
    o_ref = ref.ref_attention(q, k, v, causal=True)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(o.astype(jnp.float32),
                               o_ref.astype(jnp.float32), atol=tol, rtol=tol)


STREAM_CASES = [
    # B, Hq, Hkv, Sq, Sk, hd, D, causal, window, rope, knorm
    (1, 4, 4, 128, 128, 128, 256, False, 0, False, False),   # cross-attn MHA
    (2, 8, 2, 128, 256, 128, 256, True, 0, True, False),     # GQA LM
    (1, 4, 2, 128, 128, 128, 384, True, 0, True, True),      # qwen3-style
    (1, 4, 2, 128, 256, 128, 256, True, 96, True, False),    # SWA
]


@pytest.mark.parametrize("case", STREAM_CASES)
def test_stream_kernel_interpret(case):
    B, Hq, Hkv, Sq, Sk, hd, D, causal, window, rope, knorm = case
    q, k, v, x_kv, wk, wv = _mk_attn(B, Hq, Hkv, Sq, Sk, hd, D=D)
    sin = cos = kg = None
    if rope:
        sin, cos = ref.rope_tables(Sk, hd)
    if knorm:
        kg = jax.random.normal(KEYS[6], (hd,)) * 0.1 + 1.0
    off = Sk - Sq if causal else 0
    o = stream_attention(q, x_kv, wk, wv, sin=sin, cos=cos, k_gamma=kg,
                         causal=causal, window=window, q_offset=off,
                         block_q=128, block_k=128, interpret=True)
    o_ref = ref.ref_stream_attention(q, x_kv, wk, wv, sin=sin, cos=cos,
                                     k_gamma=kg, causal=causal,
                                     window=window, q_offset=off)
    np.testing.assert_allclose(o, o_ref, atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stream_kernel_dtypes(dtype):
    q, k, v, x_kv, wk, wv = _mk_attn(1, 4, 2, 128, 128, 128, dtype, D=256)
    sin, cos = ref.rope_tables(128, 128)
    o = stream_attention(q, x_kv, wk, wv, sin=sin, cos=cos, causal=True,
                         block_q=128, block_k=128, interpret=True)
    o_ref = ref.ref_stream_attention(q, x_kv, wk, wv, sin=sin, cos=cos,
                                     causal=True)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(o.astype(jnp.float32),
                               o_ref.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", [(256, 128, 192), (512, 384, 256),
                                   (128, 256, 128)])
def test_tile_gemm_interpret(shape):
    M, K, N = shape
    x = jax.random.normal(KEYS[0], (M, K))
    w = jax.random.normal(KEYS[1], (K, N))
    o = tile_gemm(x, w, block_m=128, block_n=128, block_k=128,
                  interpret=True)
    np.testing.assert_allclose(o, ref.ref_tile_gemm(x, w), atol=1e-3,
                               rtol=1e-3)


@pytest.mark.parametrize("case", [(1, 128, 2, 32, 16, 64),
                                  (2, 256, 4, 64, 32, 64),
                                  (1, 200, 3, 16, 8, 64)])
def test_ssd_kernel_interpret(case):
    B, S, H, P, N, chunk = case
    x = jax.random.normal(KEYS[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(KEYS[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(KEYS[2], (H,)) * 0.5)
    b = jax.random.normal(KEYS[3], (B, S, N)) * 0.3
    c = jax.random.normal(KEYS[4], (B, S, N)) * 0.3
    Sp = -(-S // chunk) * chunk
    xp = jnp.pad(x, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, Sp - S), (0, 0)))
    bp = jnp.pad(b, ((0, 0), (0, Sp - S), (0, 0)))
    cp = jnp.pad(c, ((0, 0), (0, Sp - S), (0, 0)))
    y, st = ssd_scan(xp, dtp, a, bp, cp, chunk=chunk, seq_len=S,
                     interpret=True)
    y_ref, st_ref = ref.ref_ssd(x, dt, a, b, c, return_final_state=True)
    np.testing.assert_allclose(y[:, :S], y_ref, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(st, st_ref, atol=2e-3, rtol=2e-3)


# ---------------- blocked-jnp (lowerable) paths vs oracle ----------------

@pytest.mark.parametrize("unroll", [False, True])
def test_blocked_flash_matches_ref(unroll):
    q, k, v = _mk_attn(2, 4, 2, 100, 200, 32)
    o = JB.flash_attention_jnp(q, k, v, causal=True, window=50,
                               q_offset=100, block_k=64, unroll=unroll)
    o_ref = ref.ref_attention(q, k, v, causal=True, window=50, q_offset=100)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("unroll", [False, True])
def test_blocked_stream_matches_ref(unroll):
    q, k, v, x_kv, wk, wv = _mk_attn(2, 4, 2, 100, 200, 32, D=96)
    sin, cos = ref.rope_tables(200, 32)
    kg = jax.random.normal(KEYS[6], (32,)) * 0.1 + 1.0
    o = JB.stream_attention_jnp(q, x_kv, wk, wv, sin=sin, cos=cos,
                                k_gamma=kg, causal=True, q_offset=100,
                                block_k=64, unroll=unroll)
    o_ref = ref.ref_stream_attention(q, x_kv, wk, wv, sin=sin, cos=cos,
                                     k_gamma=kg, causal=True, q_offset=100)
    np.testing.assert_allclose(o, o_ref, atol=3e-5, rtol=3e-5)


def test_blocked_ssd_matches_ref():
    B, S, H, P, N = 2, 130, 3, 16, 8
    x = jax.random.normal(KEYS[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(KEYS[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(KEYS[2], (H,)) * 0.5)
    b = jax.random.normal(KEYS[3], (B, S, N)) * 0.3
    c = jax.random.normal(KEYS[4], (B, S, N)) * 0.3
    y, st = JB.ssd_chunked_jnp(x, dt, a, b, c, chunk=32)
    y_ref, st_ref = ref.ref_ssd(x, dt, a, b, c, return_final_state=True)
    np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(st, st_ref, atol=1e-3, rtol=1e-3)


# -------------- memory-efficient VJP gradients vs oracle grads -----------

def test_flash_vjp_grads_match_ref():
    q, k, v = _mk_attn(2, 4, 2, 64, 128, 32)

    def f_me(q, k, v):
        return jnp.sum(JB.flash_attention_jnp(
            q, k, v, causal=True, q_offset=64, block_k=64) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.ref_attention(
            q, k, v, causal=True, q_offset=64) ** 2)

    g1 = jax.grad(f_me, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_stream_vjp_grads_match_ref():
    q, k, v, x_kv, wk, wv = _mk_attn(2, 4, 2, 64, 128, 32, D=96)
    sin, cos = ref.rope_tables(128, 32)
    kg = jax.random.normal(KEYS[6], (32,)) * 0.1 + 1.0

    def f_me(q, x, wk_, wv_, g):
        return jnp.sum(JB.stream_attention_jnp(
            q, x, wk_, wv_, sin=sin, cos=cos, k_gamma=g, causal=True,
            q_offset=64, block_k=64) ** 2)

    def f_ref(q, x, wk_, wv_, g):
        return jnp.sum(ref.ref_stream_attention(
            q, x, wk_, wv_, sin=sin, cos=cos, k_gamma=g, causal=True,
            q_offset=64) ** 2)

    g1 = jax.grad(f_me, argnums=(0, 1, 2, 3, 4))(q, x_kv, wk, wv, kg)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2, 3, 4))(q, x_kv, wk, wv, kg)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4)


def test_vjp_survives_checkpoint_scan():
    """Regression: per-call custom_vjp closures leaked tracers under
    checkpoint+scan (module-level nondiff_argnums form required)."""
    def layer(x, w):
        q = jnp.einsum("bsd,dhe->bhse", x, w)
        o = JB.flash_attention_jnp(q, q, q, causal=True, block_k=32)
        return x + jnp.einsum("bhse,dhe->bsd", o, w)

    def f(x, ws):
        def step(c, w):
            return jax.checkpoint(layer)(c, w), None
        y, _ = jax.lax.scan(step, x, ws)
        return jnp.sum(y ** 2)

    x = jax.random.normal(KEYS[0], (1, 64, 16))
    ws = jax.random.normal(KEYS[1], (2, 16, 2, 8)) * 0.1
    g = jax.jit(jax.grad(f))(x, ws)
    assert g.shape == x.shape
    assert bool(jnp.isfinite(g).all())
