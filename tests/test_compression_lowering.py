"""Cross-pod int8 gradient compression — lowering-level proof.

Compiles the compressed exchange on a (pod, data, model) host-device mesh
and asserts, from the optimized HLO, that (a) the cross-pod payloads are
int8 collective-permutes and (b) the modeled DCN traffic is ~8x below an
f32 all-reduce of the same gradients."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, re
    from repro.distributed.compression import cross_pod_mean_int8
    from repro.launch import hlo_analysis as HA

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    grads = {"w": jnp.zeros((256, 256), jnp.float32),
             "b": jnp.zeros((1024,), jnp.float32)}

    def sync(g):
        return cross_pod_mean_int8(g, mesh, axis="pod")

    comp = jax.jit(sync).lower(grads).compile()
    text = comp.as_text()
    # int8 collective-permute payloads present
    n_s8 = len(re.findall(r"s8\\[[0-9,]*\\][^=]*collective-permute", text))
    assert n_s8 >= 2, f"expected int8 collective-permutes, got {n_s8}"
    r = HA.analyze(text, total_devices=8, multi_pod=True)
    # compare against f32 all-reduce traffic over pods of the same tree
    full_bytes = (256 * 256 + 1024) * 4
    f32_ring = 2 * full_bytes * (2 - 1) / 2      # ring all-reduce, group 2
    compressed = r["ici"] + r["dcn"]
    print("RESULT", compressed, f32_ring)
    assert compressed < 0.5 * f32_ring, (compressed, f32_ring)
""")


def test_int8_cross_pod_lowering():
    """Runs in a subprocess: needs 8 host devices without polluting the
    single-device test session."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    compressed, f32 = map(float, line.split()[1:])
    # int8 payloads ≈ 1/4 the bytes of f32 (+ scales); ring permutes vs
    # all-reduce cut another factor
    assert compressed < 0.5 * f32
