"""The paper's core claim carrier: the three execution systems (NON_STREAM /
LAYER_STREAM / TILE_STREAM) are numerically equivalent — they differ only in
dataflow/fusion.  Plus the mode-selection (TBR reconfiguration analogue) and
HBM-traffic model sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import streaming
from repro.core.types import ExecutionMode, ModelConfig, Family
from repro.kernels import ops, ref

KEYS = jax.random.split(jax.random.PRNGKey(3), 8)


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("mode", list(ExecutionMode))
def test_modes_equivalent(mode, use_pallas):
    if mode == ExecutionMode.NON_STREAM and use_pallas:
        pytest.skip("NON_STREAM is the unfused jnp baseline by definition")
    B, Hq, Hkv, Sq, Sk, hd, D = 2, 4, 2, 200, 300, 64, 192
    q = jax.random.normal(KEYS[0], (B, Hq, Sq, hd)) * 0.5
    x_kv = jax.random.normal(KEYS[1], (B, Sk, D)) * 0.5
    wk = jax.random.normal(KEYS[2], (D, Hkv, hd)) * (D ** -0.5)
    wv = jax.random.normal(KEYS[3], (D, Hkv, hd)) * (D ** -0.5)
    sin, cos = ref.rope_tables(Sk, hd)
    base = ops.attention_by_mode(ExecutionMode.NON_STREAM, q, x_kv, wk, wv,
                                 sin=sin, cos=cos, causal=True,
                                 q_offset=Sk - Sq)
    out = ops.attention_by_mode(mode, q, x_kv, wk, wv, sin=sin, cos=cos,
                                causal=True, q_offset=Sk - Sq,
                                use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               atol=3e-4, rtol=3e-4)


def test_mode_selection_mha_fuses():
    """MHA (paper's ViLBERT case): 2·Hkv·hd = 2D >= D -> TILE_STREAM."""
    assert streaming.tile_stream_profitable(1024, 8, 128)


def test_mode_selection_gqa_falls_back():
    """Aggressive GQA (qwen3: 2*8*128=2048 < 5120) -> LAYER_STREAM."""
    assert not streaming.tile_stream_profitable(5120, 8, 128)
    cfg = ModelConfig(name="t", family=Family.DENSE, num_layers=1,
                      d_model=5120, num_heads=64, num_kv_heads=8,
                      d_ff=1, vocab_size=8, head_dim=128)
    assert streaming.choose_mode(cfg) == ExecutionMode.LAYER_STREAM


def _cfg(**kw):
    base = dict(name="t", family=Family.DENSE, num_layers=1, d_model=1024,
                num_heads=8, num_kv_heads=8, d_ff=1, vocab_size=8,
                head_dim=128)
    base.update(kw)
    return ModelConfig(**base)


def test_mode_selection_explicit_overrides_win():
    """Benchmark baselines: an explicit NON_STREAM / LAYER_STREAM config is
    honored even where tile-streaming would be profitable (MHA)."""
    from repro.core.types import AttnKind
    for forced in (ExecutionMode.NON_STREAM, ExecutionMode.LAYER_STREAM):
        assert streaming.choose_mode(_cfg(execution_mode=forced)) == forced
        # ... even for MLA, whose TILE_STREAM path otherwise always fuses.
        assert streaming.choose_mode(
            _cfg(execution_mode=forced, attn_kind=AttnKind.MLA)) == forced


def test_mode_selection_mla_always_fuses():
    """MLA latent decompression always tile-streams, regardless of the
    GQA-style profitability arithmetic (kv_lora << d_model)."""
    from repro.core.types import AttnKind
    cfg = _cfg(d_model=7168, num_heads=128, num_kv_heads=128,
               attn_kind=AttnKind.MLA, kv_lora_rank=512)
    assert streaming.choose_mode(cfg) == ExecutionMode.TILE_STREAM


def test_mode_selection_fusion_knob_off_falls_back():
    """fuse_kv_generation=False disables cross-forwarding even for MHA."""
    cfg = _cfg(fuse_kv_generation=False)
    assert streaming.tile_stream_profitable(cfg.d_model, cfg.num_kv_heads,
                                            cfg.head_dim)
    assert streaming.choose_mode(cfg) == ExecutionMode.LAYER_STREAM


def test_mode_selection_boundary_and_overrides():
    """2*Hkv*hd == d_model is the break-even point — it still fuses (ties
    go to tile-streaming: it additionally removes the K/V round-trip), and
    per-layer kwargs override the config's dims (mixed-width co-attention)."""
    assert streaming.tile_stream_profitable(1024, 4, 128)       # == break-even
    assert not streaming.tile_stream_profitable(1025, 4, 128)   # just under
    cfg = _cfg()                                                # MHA config
    assert streaming.choose_mode(
        cfg, d_model=5120, num_kv_heads=8, head_dim=128) \
        == ExecutionMode.LAYER_STREAM
    gqa = _cfg(d_model=5120, num_heads=64)
    assert streaming.choose_mode(
        gqa, d_model=1024, num_kv_heads=8, head_dim=128) \
        == ExecutionMode.TILE_STREAM


def test_traffic_model_ordering():
    """For the paper's MHA workload the analytic HBM traffic must order
    TILE_STREAM < LAYER_STREAM < NON_STREAM (this is Fig. 6's mechanism)."""
    kw = dict(seq_q=4096, seq_kv=4096, d_model=1024, num_heads=8,
              num_kv_heads=8, head_dim=128)
    t = {m: streaming.streamed_bytes_per_layer(mode=m, **kw)
         for m in ExecutionMode}
    assert t[ExecutionMode.TILE_STREAM] < t[ExecutionMode.LAYER_STREAM] \
        < t[ExecutionMode.NON_STREAM]


def test_traffic_model_gqa_inversion():
    """For aggressive GQA the generation-fusion is traffic-negative — the
    honest finding that drives the adaptive mode selector (DESIGN.md §2)."""
    kw = dict(seq_q=4096, seq_kv=4096, d_model=5120, num_heads=64,
              num_kv_heads=8, head_dim=128)
    t = {m: streaming.streamed_bytes_per_layer(mode=m, **kw)
         for m in ExecutionMode}
    assert t[ExecutionMode.LAYER_STREAM] < t[ExecutionMode.TILE_STREAM]
